"""Shared helpers for the benchmark harness: artifact loading + CSV output."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ROOT = Path(__file__).resolve().parent.parent
COLLOCATION_DIR = ROOT / "artifacts" / "collocation"
DRYRUN_DIR = ROOT / "artifacts" / "dryrun"
CLUSTER_DIR = ROOT / "artifacts" / "cluster"

# paper reference numbers (Section 4.1, resnet_small/medium/large)
PAPER = {
    # (workload, group) -> epoch time the paper measured, seconds
    ("resnet_small", "1g.5gb one"): 39.8,
    ("resnet_small", "7g.40gb one"): 16.1,
    ("resnet_small", "2g.10gb one"): 25.7,
    ("resnet_medium", "7g.40gb one"): 35.4 * 60,
    ("resnet_medium", "2g.10gb one"): 106.8 * 60 / 3,  # not directly reported; parallel/3
}
PAPER_F1_RATIO = 39.8 / 16.1  # 2.47x: 1g vs 7g epoch time, small
PAPER_F2_SPEEDUP = (7 * 16.1) / 39.8  # 2.83x collocation win, small


def load_collocation() -> List[Dict]:
    cells = []
    if COLLOCATION_DIR.exists():
        for f in sorted(COLLOCATION_DIR.glob("*.json")):
            if f.name.startswith("_"):
                continue
            cells.append(json.loads(f.read_text()))
    return cells


def load_cluster() -> List[Dict]:
    """Cluster-simulation cells written by launch/simulate.py."""
    cells = []
    if CLUSTER_DIR.exists():
        for f in sorted(CLUSTER_DIR.glob("*.json")):
            if f.name.startswith("_"):
                continue
            cells.append(json.loads(f.read_text()))
    return cells


def load_dryrun() -> List[Dict]:
    cells = []
    if DRYRUN_DIR.exists():
        for f in sorted(DRYRUN_DIR.glob("*.json")):
            cells.append(json.loads(f.read_text()))
    return cells


def by_group(cells: List[Dict]) -> Dict[tuple, Dict]:
    return {(c["workload"], c["group"]): c for c in cells if c.get("status") == "OK"}


def csv_line(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
