"""Shared helpers for the benchmark harness: artifact loading + the one
table renderer every benchmark prints through (fixed-width, markdown, or
CSV — see :func:`format_table`)."""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence

ROOT = Path(__file__).resolve().parent.parent
COLLOCATION_DIR = ROOT / "artifacts" / "collocation"
DRYRUN_DIR = ROOT / "artifacts" / "dryrun"
CLUSTER_DIR = ROOT / "artifacts" / "cluster"

# paper reference numbers (Section 4.1, resnet_small/medium/large)
PAPER = {
    # (workload, group) -> epoch time the paper measured, seconds
    ("resnet_small", "1g.5gb one"): 39.8,
    ("resnet_small", "7g.40gb one"): 16.1,
    ("resnet_small", "2g.10gb one"): 25.7,
    ("resnet_medium", "7g.40gb one"): 35.4 * 60,
    ("resnet_medium", "2g.10gb one"): 106.8 * 60 / 3,  # not directly reported; parallel/3
}
PAPER_F1_RATIO = 39.8 / 16.1  # 2.47x: 1g vs 7g epoch time, small
PAPER_F2_SPEEDUP = (7 * 16.1) / 39.8  # 2.83x collocation win, small


def load_collocation() -> List[Dict]:
    cells = []
    if COLLOCATION_DIR.exists():
        for f in sorted(COLLOCATION_DIR.glob("*.json")):
            if f.name.startswith("_"):
                continue
            cells.append(json.loads(f.read_text()))
    return cells


def load_cluster() -> List[Dict]:
    """Cluster-simulation cells written by launch/simulate.py."""
    cells = []
    if CLUSTER_DIR.exists():
        for f in sorted(CLUSTER_DIR.glob("*.json")):
            if f.name.startswith("_"):
                continue
            cells.append(json.loads(f.read_text()))
    return cells


def load_dryrun() -> List[Dict]:
    cells = []
    if DRYRUN_DIR.exists():
        for f in sorted(DRYRUN_DIR.glob("*.json")):
            cells.append(json.loads(f.read_text()))
    return cells


def by_group(cells: List[Dict]) -> Dict[tuple, Dict]:
    return {(c["workload"], c["group"]): c for c in cells if c.get("status") == "OK"}


# -- the shared table renderer -------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Column:
    """One table column: which row key to read, how to title and format it.

    ``fmt`` is a str.format template applied to the value (``"{:.2f}"``);
    ``width`` pads fixed-width output (floored at the title width);
    ``align`` is the format alignment char (``">"`` right, ``"<"`` left)."""

    key: str
    title: str = ""
    fmt: str = "{}"
    width: int = 0
    align: str = ">"

    @property
    def header(self) -> str:
        return self.title or self.key

    def cell(self, row: Mapping) -> str:
        return self.fmt.format(row[self.key])


def format_table(
    columns: Sequence[Column], rows: Sequence[Mapping], style: str = "fixed"
) -> str:
    """Render ``rows`` (mappings) under ``columns`` in one of three styles:

      fixed     aligned fixed-width columns with a dashed header rule —
                the terminal tables (benchmarks/cluster_sim.py);
      markdown  GitHub pipe tables — the EXPERIMENTS.md sections
                (benchmarks/report.py);
      csv       headerless comma-joined rows — the ``name,value,derived``
                currency of the CSV benchmarks (:func:`csv_line`).
    """
    if style == "csv":
        return "\n".join(",".join(c.cell(r) for c in columns) for r in rows)
    if style == "markdown":
        lines = [
            "| " + " | ".join(c.header for c in columns) + " |",
            "|" + "|".join("---" for _ in columns) + "|",
        ]
        lines += [
            "| " + " | ".join(c.cell(r) for c in columns) + " |" for r in rows
        ]
        return "\n".join(lines)
    if style == "fixed":
        widths = [max(c.width, len(c.header)) for c in columns]
        hdr = "".join(
            f"{c.header:{c.align}{w}}" for c, w in zip(columns, widths)
        )
        lines = [hdr, "-" * len(hdr)]
        lines += [
            "".join(
                f"{c.cell(r):{c.align}{w}}" for c, w in zip(columns, widths)
            )
            for r in rows
        ]
        return "\n".join(lines)
    raise ValueError(f"unknown table style {style!r}")


CSV_COLUMNS = (Column("name"), Column("value"), Column("derived"))


def csv_line(name: str, value, derived: str = "") -> str:
    return format_table(
        CSV_COLUMNS,
        [{"name": name, "value": value, "derived": derived}],
        style="csv",
    )
