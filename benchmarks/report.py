"""Markdown report generator for EXPERIMENTS.md sections.

    PYTHONPATH=src python -m benchmarks.report dryrun     # §Dry-run/§Roofline
    PYTHONPATH=src python -m benchmarks.report perf       # §Perf tagged cells
    PYTHONPATH=src python -m benchmarks.report collocate  # §Paper-claims
    PYTHONPATH=src python -m benchmarks.report modes      # naive vs MPS vs MIG
    PYTHONPATH=src python -m benchmarks.report placement  # planner vs greedy
    PYTHONPATH=src python -m benchmarks.report devices    # cross-SKU verdicts
    PYTHONPATH=src python -m benchmarks.report gang       # gang placement goodput
    PYTHONPATH=src python -m benchmarks.report autoscale  # forecast vs reactive
    PYTHONPATH=src python -m benchmarks.report trace      # scheduler trace health
    PYTHONPATH=src python -m benchmarks.report trace --format json
                                                          # step-error doc (calib feed)
    PYTHONPATH=src python -m benchmarks.report calibrate  # seed vs calibrated error

All sections render through the shared table renderer
(benchmarks/common.py:format_table, markdown style).
"""
from __future__ import annotations

import sys

from benchmarks.common import Column, format_table, load_collocation, load_dryrun

_DRYRUN_COLUMNS = tuple(
    Column(k)
    for k in ("arch", "shape", "mesh", "compute_s", "memory_s",
              "collective_s", "bound", "MFU", "useful", "GiB/dev")
)


def fmt_dryrun() -> str:
    cells = load_dryrun()

    def is_tagged(c):
        return len(c["cell"].split("__")) > 3

    rows = []
    n_ok = n_skip = 0
    for c in sorted(cells, key=lambda c: c["cell"]):
        if is_tagged(c):
            continue
        parts = c["cell"].split("__")
        row = dict.fromkeys((col.key for col in _DRYRUN_COLUMNS), "")
        row.update(arch=parts[0], shape=parts[1], mesh=parts[2])
        if c["status"] == "SKIP":
            n_skip += 1
            row.update(compute_s="SKIP", memory_s="—", collective_s="—",
                       bound="—", MFU="—", useful="—",
                       **{"GiB/dev": c["reason"][:40]})
        elif c["status"] != "OK":
            row.update(compute_s="FAIL")
        else:
            n_ok += 1
            r = c["roofline"]
            row.update(
                compute_s=f"{r['compute_s']:.4f}",
                memory_s=f"{r['memory_s']:.4f}",
                collective_s=f"{r['collective_s']:.4f}",
                bound=r["bound"],
                MFU=f"{r['mfu']:.3f}",
                useful=f"{r['useful_flops_ratio']:.2f}",
                **{"GiB/dev": f"{r['peak_mem_bytes_per_device']/2**30:.2f}"},
            )
        rows.append(row)
    table = format_table(_DRYRUN_COLUMNS, rows, style="markdown")
    return f"{n_ok} compiled cells + {n_skip} documented skips:\n\n{table}"


_PERF_COLUMNS = (
    Column("cell"),
    Column("tag", "variant/tag"),
    Column("compute_s", fmt="{:.4f}"),
    Column("memory_s", fmt="{:.4f}"),
    Column("collective_s", fmt="{:.4f}"),
    Column("step_s", fmt="{:.4f}"),
    Column("frac", fmt="{:.4f}"),
    Column("gib", "GiB/dev", fmt="{:.2f}"),
)


def fmt_perf() -> str:
    cells = load_dryrun()
    rows = []
    for c in sorted(cells, key=lambda c: c["cell"]):
        parts = c["cell"].split("__")
        if len(parts) <= 3 or c["status"] != "OK":
            continue
        r = c["roofline"]
        rows.append(
            {
                "cell": "__".join(parts[:3]),
                "tag": parts[3],
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "step_s": r["step_s"],
                "frac": r["frac_of_roofline"],
                "gib": r["peak_mem_bytes_per_device"] / 2**30,
            }
        )
    return format_table(_PERF_COLUMNS, rows, style="markdown")


_COLLOCATE_COLUMNS = (
    Column("workload"),
    Column("group"),
    Column("mode"),
    Column("instances"),
    Column("step_s", fmt="{:.5f}"),
    Column("epoch_s", fmt="{:.2f}"),
    Column("fits"),
    Column("interference"),
)


def fmt_collocate() -> str:
    cells = load_collocation()
    rows = []
    for c in sorted(cells, key=lambda c: (c["workload"], c["group"])):
        if c.get("status") != "OK":
            continue
        recs = c["records"]
        if "isolation" in c:
            iso = c["isolation"]
            proved = iso["disjoint"] and iso["programs_identical"]
            interf = "none (proved)" if proved else "ISOLATION FAILED"
        else:
            q = c.get("interference_quant", {})
            interf = f"{q.get('max_slowdown', 0):.2f}x predicted"
        rows.append(
            {
                "workload": c["workload"],
                "group": c["group"],
                "mode": c.get("mode", "mig"),
                "instances": len(recs),
                "step_s": recs[0]["step_s"],
                "epoch_s": c["epoch_time_s"][0],
                "fits": all(r["fits"] for r in recs),
                "interference": interf,
            }
        )
    return format_table(_COLLOCATE_COLUMNS, rows, style="markdown")


_MODES_COLUMNS = (
    Column("workload"),
    Column("mode"),
    Column("k_jobs", "k jobs"),
    Column("solo_step_s", "solo step_s", fmt="{:.5f}"),
    Column("effective_step_s", "collocated step_s", fmt="{:.5f}"),
    Column("speedup", "speedup vs sequential"),
    Column("interference"),
    Column("fits"),
)


def fmt_modes() -> str:
    """The paper's naive-vs-MPS-vs-MIG comparison for the workload grid.

    Speedup = time of k sequential solo runs / collocated completion time;
    interference = neighbour-induced slowdown (effective/solo for the shared
    modes, 1.0 for MIG by construction — F3). Reproduces the recommendation
    (MPS best single-user mode), MIG's interference-free column, and
    naive's sequential-or-worse behaviour.
    """
    from benchmarks.collocation_throughput import mode_rows
    from benchmarks.common import by_group

    cells = by_group(load_collocation())
    if not cells:
        return "no collocation artifacts — run repro.launch.collocate first"
    rows = [
        {
            "workload": r.workload,
            "mode": r.mode,
            "k_jobs": r.k_jobs,
            "solo_step_s": r.solo_step_s,
            "effective_step_s": r.effective_step_s,
            "speedup": f"{r.speedup_vs_sequential:.2f}x",
            "interference": f"{r.max_interference:.2f}x",
            "fits": r.fits,
        }
        for r in mode_rows(cells)
    ]
    return format_table(_MODES_COLUMNS, rows, style="markdown")


_PLACEMENT_COLUMNS = (
    Column("scenario"),
    Column("greedy_goodput", "greedy goodput", fmt="{:.0f}"),
    Column("planner_goodput", "planner goodput", fmt="{:.0f}"),
    Column("delta", "Δ%"),
    Column("greedy_qdelay", "greedy qdelay_s", fmt="{:.3f}"),
    Column("planner_qdelay", "planner qdelay_s", fmt="{:.3f}"),
    Column("replans"),
    Column("optimality"),
)


def fmt_placement() -> str:
    """Planner-vs-greedy placement table: same all-MIG hardware, same
    trace; the deltas are pure placement-decision effects. ``replans``
    counts the planner's committed re-partitions (each charged checkpoint
    rollback + downtime); ``optimality`` summarizes the committed plans'
    search tier (exact partition-tree search vs beam fallback).
    """
    from benchmarks.common import load_cluster
    from repro.core.planner import enumerate_configs, maximal_configs
    from repro.launch.simulate import summarize_cell

    cells = load_cluster()
    by = {}
    for c in cells:
        if c.get("status") != "OK":
            continue
        s = summarize_cell(c)
        by[(s["scenario"], s["policy"])] = (s, c)
    rows = []
    for sc in sorted({k[0] for k in by}):
        g = by.get((sc, "all-mig"))
        p = by.get((sc, "planner"))
        if not (g and p):
            continue
        gs, ps = g[0], p[0]
        events = p[1]["report"]["migration_events"]
        tiers = sorted(
            {e["optimality"] for e in events if e.get("kind") == "replan"}
        )
        gg, pg = gs["goodput_steps_per_s"], ps["goodput_steps_per_s"]
        rows.append(
            {
                "scenario": sc,
                "greedy_goodput": gg,
                "planner_goodput": pg,
                "delta": f"{100.0 * (pg - gg) / gg:+.1f}" if gg else "—",
                "greedy_qdelay": gs["mean_queueing_delay_s"],
                "planner_qdelay": ps["mean_queueing_delay_s"],
                "replans": ps["migrations"],
                "optimality": "/".join(tiers) if tiers else "—",
            }
        )
    if not rows:
        return ("no greedy+planner cluster cells — run "
                "repro.launch.simulate with the planner fleet first")
    head = (
        f"partition tree: {len(enumerate_configs())} valid layouts, "
        f"{len(maximal_configs())} maximal configs (A100 canonical "
        f"analogue); planner objective: jobs placed > kept in place > "
        f"flexibility > compute thrift > goodput (docs/placement.md)"
    )
    return f"{head}\n\n{format_table(_PLACEMENT_COLUMNS, rows, style='markdown')}"


_DEVICES_COLUMNS = (
    Column("sku"),
    Column("tree", "units x GiB/slice"),
    Column("layouts"),
    Column("maximal"),
    Column("mig", "mig placed"),
    Column("mps", "mps placed"),
    Column("naive", "naive placed"),
    Column("best", "best mode"),
    Column("best_tput", "best steps/s", fmt="{:.0f}"),
    Column("provenance", "char provenance"),
)


def fmt_devices() -> str:
    """Cross-SKU verdict table: one canonical job mix, every registered
    device generation — the ROADMAP's "how do the collocation verdicts
    shift across GPU generations" question as a table.

    The mix is slice-aligned 1g jobs + 2g-class jobs + one medium trainer
    + one big-memory serve session (the hetero_sku pivot class). Per SKU
    it reports the partition-tree size (valid layouts / maximal configs —
    the canonical-config analogue) and ``best_mode``'s scorecard: jobs
    placed under each mode and the winning mode's aggregate throughput.
    Everything is computed in-process from the analytic characterization
    (milliseconds, deterministic — no artifacts needed).
    """
    from repro.core.calib import seed_provenance
    from repro.core.collocation import CollocationScheduler
    from repro.core.device import SKUS, format_gib
    from repro.core.instance import JobSpec
    from repro.core.planner import enumerate_configs, maximal_configs
    from repro.core.sharing import CollocationMode
    from repro.core.workload import serve_workload
    from repro.launch.simulate import (
        SERVE_SLO_S,
        SERVE_SUITE,
        SIM_SUITE,
        synthetic_char_db,
    )

    def mix():
        jobs = [JobSpec(f"al{i}", "granite-3-2b", SIM_SUITE) for i in range(4)]
        jobs += [JobSpec(f"tg{i}", "stablelm-12b", SIM_SUITE) for i in range(2)]
        jobs.append(JobSpec("md0", "llama3-8b", SIM_SUITE))
        jobs.append(
            serve_workload(
                "xl0", "qwen2-72b", SERVE_SUITE,
                slo_step_s=SERVE_SLO_S["qwen2-72b"], prefill_steps=4,
            )
        )
        return jobs

    rows = []
    for name, dev in SKUS.items():
        sched = CollocationScheduler(synthetic_char_db(sku=dev), sku=dev)
        decision = sched.best_mode(mix())
        scores = decision.scores()
        winner = decision.mode
        rows.append(
            {
                "sku": name + (" (default)" if name == "a100-40gb" else ""),
                "tree": f"{dev.n_units} x {format_gib(dev.slice_bytes)}"
                        f" ({dev.n_compute_slices}c)",
                "layouts": len(enumerate_configs(sku=dev)),
                "maximal": len(maximal_configs(sku=dev)),
                "mig": scores[CollocationMode.MIG][0],
                "mps": scores[CollocationMode.MPS][0],
                "naive": scores[CollocationMode.NAIVE][0],
                "best": winner.value,
                "best_tput": scores[winner][1],
                "provenance": seed_provenance(name),
            }
        )
    head = (
        "same job mix (4x slice-aligned, 2x 2g-class, 1x medium train, "
        "1x big-memory serve) scored on every registered SKU "
        "(core/device.py); 'placed' counts jobs each mode admits — the "
        "hardware generation, not just the mode, decides the verdict. "
        "'char provenance' is where each SKU's characterization numbers "
        "come from (core/calib/): only the paper's device is measured — "
        "every other row's verdict rests on extrapolated constants until "
        "launch/calibrate.py is run against it"
    )
    return f"{head}\n\n{format_table(_DEVICES_COLUMNS, rows, style='markdown')}"


_GANG_COLUMNS = (
    Column("variant"),
    Column("completed"),
    Column("rejected"),
    Column("gangs", "gangs run"),
    Column("spread", "mean spread", fmt="{:.2f}"),
    Column("goodput", "goodput steps/s", fmt="{:.1f}"),
    Column("jct", "mean jct_s", fmt="{:.3f}"),
    Column("qdelay", "mean qdelay_s", fmt="{:.3f}"),
)


def fmt_gang() -> str:
    """Gang-placement verdict table: the same seed-0 gang_pipeline trace on
    the same all-MIG gang fleet under three placement regimes —

      co-located       gang members packed onto as few devices as possible
                       (the cluster default; tensor neighbours share a
                       device, so collectives stay on the fast local link);
      scattered        members spread one per device, paying the
                       cross-device bandwidth/latency penalty of the comms
                       model (core/gang/comms.py) on every collective;
      full-slice-only  no gang scheduling at all — every gang collapsed to
                       a world_size-1 singleton, so the qwen2-72b class
                       (which fits no single slice in the fleet) is
                       rejected instead of sharded.

    Computed in-process from the analytic characterization (deterministic,
    no artifacts needed). The co-located row strictly beats the scattered
    row on goodput — the inequality tests/test_gang.py and CI pin.
    """
    from repro.launch.simulate import run_cell, summarize_cell

    variants = (
        ("co-located", {"gang_placement": "colocate"}),
        ("scattered", {"gang_placement": "scatter"}),
        ("full-slice-only", {"gang_degrade": True}),
    )
    rows = []
    for label, kwargs in variants:
        cell = run_cell("gang_pipeline", "all-mig", seed=0, **kwargs)
        s = summarize_cell(cell)
        gangs = [j for j in cell["report"]["jobs"] if j.get("world_size", 1) > 1]
        rows.append(
            {
                "variant": label,
                "completed": s["completed"],
                "rejected": s["rejected"],
                "gangs": len(gangs),
                "spread": (sum(j["gang_spread"] for j in gangs) / len(gangs))
                if gangs else 0.0,
                "goodput": s["goodput_steps_per_s"],
                "jct": s["mean_jct_s"],
                "qdelay": s["mean_queueing_delay_s"],
            }
        )
    head = (
        "seed-0 gang_pipeline trace, all-MIG 80GB/40GB gang fleet; only the "
        "placement regime differs per row (docs/gang_scheduling.md). "
        "full-slice-only rejects every only-fits-as-a-gang job — the work "
        "gang scheduling unlocks."
    )
    return f"{head}\n\n{format_table(_GANG_COLUMNS, rows, style='markdown')}"


_AUTOSCALE_COLUMNS = (
    Column("fleet"),
    Column("slo", "slo attain", fmt="{:.4f}"),
    Column("goodput", "goodput steps/s", fmt="{:.1f}"),
    Column("qdelay", "mean qdelay_s", fmt="{:.3f}"),
    Column("reconfigs"),
    Column("proactive", "proactive flips"),
    Column("reactive", "reactive flips"),
    Column("completed"),
)


def fmt_autoscale() -> str:
    """Autoscaling verdict table: the same seed-0 diurnal_serve trace
    (diurnal serve sessions at 10x the train_serve_mix rate over batch
    training, three synthetic days) on the same hardware under three
    control regimes —

      reactive-adaptive  the best-mode-per-device policy: flips a device
                         only after queue pressure from realized SLO
                         misses builds up (always a step behind the ramp);
      planner            the partition-tree optimizer's placements with
                         plan-driven re-partitions — better packing, still
                         purely reactive;
      forecast           the adaptive machinery plus forecast-driven
                         autoscaling (core/forecast/): a seasonal
                         estimator learns the daily profile from completed
                         periods and pre-warms decode slices ahead of the
                         predicted ramp, gated by wave amortization.

    Computed in-process (deterministic, no artifacts needed). The headline
    inequality — forecast strictly beats reactive-adaptive on SLO
    attainment with fewer SLO-miss-triggered (reactive) flips — is the
    tentpole's acceptance bar, pinned by tests/test_forecast.py and CI.
    Day one of the trace is for learning: the cold-start estimator reports
    a zero lower band, so the amortization gate blocks every pre-warm
    until a full period completes (docs/autoscaling.md)."""
    from repro.launch.simulate import run_cell, summarize_cell

    rows = []
    for label, policy in (
        ("reactive-adaptive", "best"),
        ("planner", "planner"),
        ("forecast", "forecast"),
    ):
        cell = run_cell("diurnal_serve", policy, seed=0)
        s = summarize_cell(cell)
        fc = cell["report"].get("forecast") or {}
        proactive = fc.get("prewarm_flips", 0) + fc.get("prewarm_preempts", 0)
        reactive = fc.get("reactive_migrations", s["migrations"])
        rows.append(
            {
                "fleet": label,
                "slo": s["slo_attainment"],
                "goodput": s["goodput_steps_per_s"],
                "qdelay": s["mean_queueing_delay_s"],
                "reconfigs": s["migrations"],
                "proactive": proactive,
                "reactive": reactive,
                "completed": s["completed"],
            }
        )
    head = (
        "seed-0 diurnal_serve trace (three synthetic days of diurnal serve "
        "sessions over batch training); only the control regime differs "
        "per row (docs/autoscaling.md). 'proactive' flips were paid ahead "
        "of the predicted ramp, 'reactive' ones after realized queue "
        "pressure — the forecast row trades training goodput (demoted "
        "into the trough and the tail) for serve SLO."
    )
    return f"{head}\n\n{format_table(_AUTOSCALE_COLUMNS, rows, style='markdown')}"


def _percentile(values, q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not values:
        return 0.0
    xs = sorted(values)
    k = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
    return float(xs[k])


_TRACE_QUEUE_COLUMNS = (
    Column("metric"),
    Column("n"),
    Column("p50", fmt="{:.4f}"),
    Column("p99", fmt="{:.4f}"),
    Column("mean", fmt="{:.4f}"),
)

_TRACE_BUSY_COLUMNS = (
    Column("device"),
    Column("busy_frac", "busy fraction", fmt="{:.4f}"),
    Column("occ_spans", "occupancy spans"),
    Column("decisions", "decision instants"),
)

_TRACE_STEP_COLUMNS = (
    Column("arch"),
    Column("profile", "slice/profile"),
    Column("n", "samples"),
    Column("measured_s", "mean measured_s", fmt="{:.5f}"),
    Column("predicted_s", "mean predicted_s", fmt="{:.5f}"),
    Column("rel_err", "mean |rel err|", fmt="{:.4f}"),
)

_TRACE_FC_COLUMNS = (
    Column("day"),
    Column("ticks"),
    Column("abs_err", "mean |err|/s", fmt="{:.3f}"),
    Column("p99_err", "p99 |err|/s", fmt="{:.3f}"),
    Column("in_band", "in-band frac", fmt="{:.3f}"),
)


def fmt_trace(fmt: str = "markdown") -> str:
    """Trace-derived scheduler health report (docs/observability.md).

    Runs two traced seed-0 cells in-process and summarizes the recorded
    stream — the same numbers a Perfetto load of the exported
    ``_trace__*.json`` shows visually:

    - train_serve_mix x all-mig: queue-depth percentiles from the
      ``queue_depth`` counter series, time-to-first-dispatch from the
      ``dispatch`` decision instants (``first`` pairs only), per-device
      busy fraction as the time-weighted mean of each ``util:<dev>``
      counter, and the measured-vs-predicted step-time table aggregated
      from ``Cluster.observe_step`` + completion samples per
      (arch, slice) — the char-DB calibration data source.
    - diurnal_serve x forecast: per-tick forecast absolute error and
      in-band fraction from the ``forecast_tick`` instants, binned by
      synthetic day (period_s = 1.0).

    With ``--format json`` the step-error table alone is emitted as a
    ``calib_step_error/v1`` document (core/calib/fit.py) — the
    machine-readable feed ``launch/calibrate.py --from-trace`` fits
    residuals from instead of re-deriving the aggregation.
    """
    from repro.core.obs import TraceRecorder
    from repro.launch.simulate import run_cell

    rec = TraceRecorder()
    cell = run_cell("train_serve_mix", "all-mig", seed=0, trace=rec)
    makespan = cell["report"]["makespan_s"]

    depth = [v for _, v in rec.counters.get("queue_depth", [])]
    waits = [
        i[4]["wait_s"]
        for i in rec.instants_named("dispatch")
        if i[4].get("first")
    ]
    qrows = [
        {"metric": "queue_depth", "n": len(depth),
         "p50": _percentile(depth, 0.50), "p99": _percentile(depth, 0.99),
         "mean": sum(depth) / len(depth) if depth else 0.0},
        {"metric": "first_dispatch_wait_s", "n": len(waits),
         "p50": _percentile(waits, 0.50), "p99": _percentile(waits, 0.99),
         "mean": sum(waits) / len(waits) if waits else 0.0},
    ]

    def time_weighted_mean(series, horizon):
        # counters are piecewise-constant between event-boundary samples;
        # the series starts at 0 utilization and the last value holds to
        # the end of the run
        if not series or horizon <= 0.0:
            return 0.0
        area, prev_t, prev_v = 0.0, 0.0, 0.0
        for t, v in series:
            area += prev_v * (min(t, horizon) - prev_t)
            prev_t, prev_v = min(t, horizon), v
        area += prev_v * (horizon - prev_t)
        return area / horizon

    brows = []
    for track in rec.tracks:
        if not track.startswith("dev:"):
            continue
        name = track[len("dev:"):]
        brows.append(
            {
                "device": name,
                "busy_frac": time_weighted_mean(
                    rec.counters.get(f"util:{name}", []), makespan),
                "occ_spans": sum(
                    1 for s in rec.spans
                    if s[0] == track and s[2] == "occupancy"),
                "decisions": sum(
                    1 for i in rec.instants
                    if (i[4] or {}).get("device") == name),
            }
        )

    # the one copy of the error aggregation (core/calib/fit.py): the same
    # rows the calibration harness fits residuals from, so the report and
    # the calibrator can never disagree about what the step error is
    from repro.core.calib import step_error_doc, step_error_rows

    srows = step_error_rows(rec.samples)
    if fmt == "json":
        import json as _json

        return _json.dumps(
            step_error_doc(
                rec.samples,
                meta={
                    "scenario": "train_serve_mix",
                    "policy": "all-mig",
                    "seed": 0,
                    "sku": "a100-40gb",
                },
            ),
            indent=2,
            sort_keys=True,
        )

    fc_rec = TraceRecorder()
    run_cell("diurnal_serve", "forecast", seed=0, trace=fc_rec)
    ticks = fc_rec.instants_named("forecast_tick")
    by_day = {}
    for i in ticks:
        by_day.setdefault(int(i[3] // 1.0), []).append(i[4])
    frows = []
    for day, group in sorted(by_day.items()):
        errs = [a["abs_err_per_s"] for a in group]
        frows.append(
            {
                "day": str(day),
                "ticks": len(group),
                "abs_err": sum(errs) / len(errs),
                "p99_err": _percentile(errs, 0.99),
                "in_band": sum(1 for a in group if a["in_band"]) / len(group),
            }
        )
    if ticks:
        all_args = [i[4] for i in ticks]
        errs = [a["abs_err_per_s"] for a in all_args]
        frows.append(
            {
                "day": "all",
                "ticks": len(all_args),
                "abs_err": sum(errs) / len(errs),
                "p99_err": _percentile(errs, 0.99),
                "in_band": sum(1 for a in all_args if a["in_band"])
                / len(all_args),
            }
        )

    sections = [
        "trace summary: seed-0 train_serve_mix x all-mig "
        f"({len(rec.spans)} spans, {len(rec.instants)} decision instants, "
        f"{len(rec.samples)} step samples; docs/observability.md)",
        "queue health (queue_depth counter / first-dispatch instants):",
        format_table(_TRACE_QUEUE_COLUMNS, qrows, style="markdown"),
        "per-device busy fraction (time-weighted util:<dev> counter):",
        format_table(_TRACE_BUSY_COLUMNS, brows, style="markdown"),
        "measured vs predicted step time per (arch, slice) — the char-DB "
        "calibration table (observe_step + completion samples):",
        format_table(_TRACE_STEP_COLUMNS, srows, style="markdown"),
        "forecast accuracy: seed-0 diurnal_serve x forecast, per synthetic "
        "day (forecast_tick instants, predicted band vs realized rate):",
        format_table(_TRACE_FC_COLUMNS, frows, style="markdown"),
    ]
    return "\n\n".join(sections)


_CALIBRATE_COLUMNS = (
    Column("sku"),
    Column("keys", "(arch,slice) keys"),
    Column("measured"),
    Column("seed_err", "seed mean|err|", fmt="{:.4f}"),
    Column("calib_err", "calibrated mean|err|", fmt="{:.4f}"),
    Column("delta", "Δ"),
    Column("provenance", "calibrated provenance"),
)


def fmt_calibrate(seed: int = 0) -> str:
    """Per-SKU seed-vs-calibrated step-error table (docs/calibration.md).

    For every registered SKU: load the hand-seeded analytic catalog, run
    one full calibration pass against the deterministic stub backend
    (ground truth = seed catalog x systematic per-arch bias x smooth
    per-slice skew x noise), and score both DBs against that ground
    truth. The 'Δ' column is the headline inequality — the calibrated
    DB's mean |relative step error| must be strictly below the seed's on
    every row (the ISSUE's acceptance bar; tests/test_calib.py and the CI
    ``calibrate`` job gate it). Deterministic per seed, runs in-process
    in milliseconds, no artifacts or accelerator needed."""
    from repro.core.calib import StubBackend, calibration_report, run_calibration
    from repro.core.device import SKUS
    from repro.launch.simulate import synthetic_char_db

    rows = []
    for name, dev in SKUS.items():
        db = synthetic_char_db(sku=dev)
        backend = StubBackend(db, sku=dev, seed=seed)
        result = run_calibration(db, backend, sku=dev, seed=seed)
        rep = calibration_report(result, backend.true_step_s)
        prov = rep["provenance"]
        rows.append(
            {
                "sku": name,
                "keys": rep["n_keys"],
                "measured": rep["n_measured"],
                "seed_err": rep["seed_mean_abs_rel_err"],
                "calib_err": rep["calibrated_mean_abs_rel_err"],
                "delta": f"-{100.0 * rep['error_reduction']:.1f}%",
                "provenance": " ".join(
                    f"{k}:{v}" for k, v in sorted(prov.items())
                ),
            }
        )
    head = (
        f"stub-backend calibration loop per SKU (seed={seed}): measure the "
        "MISO probe set (full device + smallest slice per arch), fit "
        "per-arch x per-slice residuals, refine every unmeasured entry "
        "(core/calib/); errors are mean |rel step err| vs the backend's "
        "ground truth over all (arch, slice) keys — calibrated must beat "
        "seed on every row"
    )
    return f"{head}\n\n{format_table(_CALIBRATE_COLUMNS, rows, style='markdown')}"


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    fmt = "json" if "--format" in sys.argv and "json" in sys.argv else "markdown"
    if which == "trace":
        print(fmt_trace(fmt))
    else:
        print({"dryrun": fmt_dryrun, "perf": fmt_perf,
               "collocate": fmt_collocate, "modes": fmt_modes,
               "placement": fmt_placement, "devices": fmt_devices,
               "gang": fmt_gang, "autoscale": fmt_autoscale,
               "calibrate": fmt_calibrate}[which]())
