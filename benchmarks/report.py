"""Markdown report generator for EXPERIMENTS.md sections.

    PYTHONPATH=src python -m benchmarks.report dryrun     # §Dry-run/§Roofline
    PYTHONPATH=src python -m benchmarks.report perf       # §Perf tagged cells
    PYTHONPATH=src python -m benchmarks.report collocate  # §Paper-claims
    PYTHONPATH=src python -m benchmarks.report modes      # naive vs MPS vs MIG

All four sections render through the shared table renderer
(benchmarks/common.py:format_table, markdown style).
"""
from __future__ import annotations

import sys

from benchmarks.common import Column, format_table, load_collocation, load_dryrun

_DRYRUN_COLUMNS = tuple(
    Column(k)
    for k in ("arch", "shape", "mesh", "compute_s", "memory_s",
              "collective_s", "bound", "MFU", "useful", "GiB/dev")
)


def fmt_dryrun() -> str:
    cells = load_dryrun()

    def is_tagged(c):
        return len(c["cell"].split("__")) > 3

    rows = []
    n_ok = n_skip = 0
    for c in sorted(cells, key=lambda c: c["cell"]):
        if is_tagged(c):
            continue
        parts = c["cell"].split("__")
        row = dict.fromkeys((col.key for col in _DRYRUN_COLUMNS), "")
        row.update(arch=parts[0], shape=parts[1], mesh=parts[2])
        if c["status"] == "SKIP":
            n_skip += 1
            row.update(compute_s="SKIP", memory_s="—", collective_s="—",
                       bound="—", MFU="—", useful="—",
                       **{"GiB/dev": c["reason"][:40]})
        elif c["status"] != "OK":
            row.update(compute_s="FAIL")
        else:
            n_ok += 1
            r = c["roofline"]
            row.update(
                compute_s=f"{r['compute_s']:.4f}",
                memory_s=f"{r['memory_s']:.4f}",
                collective_s=f"{r['collective_s']:.4f}",
                bound=r["bound"],
                MFU=f"{r['mfu']:.3f}",
                useful=f"{r['useful_flops_ratio']:.2f}",
                **{"GiB/dev": f"{r['peak_mem_bytes_per_device']/2**30:.2f}"},
            )
        rows.append(row)
    table = format_table(_DRYRUN_COLUMNS, rows, style="markdown")
    return f"{n_ok} compiled cells + {n_skip} documented skips:\n\n{table}"


_PERF_COLUMNS = (
    Column("cell"),
    Column("tag", "variant/tag"),
    Column("compute_s", fmt="{:.4f}"),
    Column("memory_s", fmt="{:.4f}"),
    Column("collective_s", fmt="{:.4f}"),
    Column("step_s", fmt="{:.4f}"),
    Column("frac", fmt="{:.4f}"),
    Column("gib", "GiB/dev", fmt="{:.2f}"),
)


def fmt_perf() -> str:
    cells = load_dryrun()
    rows = []
    for c in sorted(cells, key=lambda c: c["cell"]):
        parts = c["cell"].split("__")
        if len(parts) <= 3 or c["status"] != "OK":
            continue
        r = c["roofline"]
        rows.append(
            {
                "cell": "__".join(parts[:3]),
                "tag": parts[3],
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "step_s": r["step_s"],
                "frac": r["frac_of_roofline"],
                "gib": r["peak_mem_bytes_per_device"] / 2**30,
            }
        )
    return format_table(_PERF_COLUMNS, rows, style="markdown")


_COLLOCATE_COLUMNS = (
    Column("workload"),
    Column("group"),
    Column("mode"),
    Column("instances"),
    Column("step_s", fmt="{:.5f}"),
    Column("epoch_s", fmt="{:.2f}"),
    Column("fits"),
    Column("interference"),
)


def fmt_collocate() -> str:
    cells = load_collocation()
    rows = []
    for c in sorted(cells, key=lambda c: (c["workload"], c["group"])):
        if c.get("status") != "OK":
            continue
        recs = c["records"]
        if "isolation" in c:
            iso = c["isolation"]
            proved = iso["disjoint"] and iso["programs_identical"]
            interf = "none (proved)" if proved else "ISOLATION FAILED"
        else:
            q = c.get("interference_quant", {})
            interf = f"{q.get('max_slowdown', 0):.2f}x predicted"
        rows.append(
            {
                "workload": c["workload"],
                "group": c["group"],
                "mode": c.get("mode", "mig"),
                "instances": len(recs),
                "step_s": recs[0]["step_s"],
                "epoch_s": c["epoch_time_s"][0],
                "fits": all(r["fits"] for r in recs),
                "interference": interf,
            }
        )
    return format_table(_COLLOCATE_COLUMNS, rows, style="markdown")


_MODES_COLUMNS = (
    Column("workload"),
    Column("mode"),
    Column("k_jobs", "k jobs"),
    Column("solo_step_s", "solo step_s", fmt="{:.5f}"),
    Column("effective_step_s", "collocated step_s", fmt="{:.5f}"),
    Column("speedup", "speedup vs sequential"),
    Column("interference"),
    Column("fits"),
)


def fmt_modes() -> str:
    """The paper's naive-vs-MPS-vs-MIG comparison for the workload grid.

    Speedup = time of k sequential solo runs / collocated completion time;
    interference = neighbour-induced slowdown (effective/solo for the shared
    modes, 1.0 for MIG by construction — F3). Reproduces the recommendation
    (MPS best single-user mode), MIG's interference-free column, and
    naive's sequential-or-worse behaviour.
    """
    from benchmarks.collocation_throughput import mode_rows
    from benchmarks.common import by_group

    cells = by_group(load_collocation())
    if not cells:
        return "no collocation artifacts — run repro.launch.collocate first"
    rows = [
        {
            "workload": r.workload,
            "mode": r.mode,
            "k_jobs": r.k_jobs,
            "solo_step_s": r.solo_step_s,
            "effective_step_s": r.effective_step_s,
            "speedup": f"{r.speedup_vs_sequential:.2f}x",
            "interference": f"{r.max_interference:.2f}x",
            "fits": r.fits,
        }
        for r in mode_rows(cells)
    ]
    return format_table(_MODES_COLUMNS, rows, style="markdown")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    print({"dryrun": fmt_dryrun, "perf": fmt_perf, "collocate": fmt_collocate,
           "modes": fmt_modes}[which]())
