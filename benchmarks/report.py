"""Markdown report generator for EXPERIMENTS.md sections.

    PYTHONPATH=src python -m benchmarks.report dryrun     # §Dry-run/§Roofline
    PYTHONPATH=src python -m benchmarks.report perf       # §Perf tagged cells
    PYTHONPATH=src python -m benchmarks.report collocate  # §Paper-claims
    PYTHONPATH=src python -m benchmarks.report modes      # naive vs MPS vs MIG
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import DRYRUN_DIR, load_collocation, load_dryrun


def fmt_dryrun() -> str:
    cells = load_dryrun()
    base = [c for c in cells if c["status"] != "FAIL" and "__" not in c["cell"].replace(
        c["cell"].rsplit("__", 1)[0], "", 1)]
    # separate untagged (baseline) from tagged (perf variants)
    def is_tagged(c):
        return len(c["cell"].split("__")) > 3
    rows = []
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | bound | MFU | useful | GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for c in sorted(cells, key=lambda c: c["cell"]):
        if is_tagged(c):
            continue
        parts = c["cell"].split("__")
        if c["status"] == "SKIP":
            n_skip += 1
            out.append(f"| {parts[0]} | {parts[1]} | {parts[2]} | SKIP | — | — | — | — | — | {c['reason'][:40]} |")
            continue
        if c["status"] != "OK":
            out.append(f"| {parts[0]} | {parts[1]} | {parts[2]} | FAIL | | | | | | |")
            continue
        n_ok += 1
        r = c["roofline"]
        out.append(
            f"| {parts[0]} | {parts[1]} | {parts[2]} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bound']} | "
            f"{r['mfu']:.3f} | {r['useful_flops_ratio']:.2f} | "
            f"{r['peak_mem_bytes_per_device']/2**30:.2f} |"
        )
    out.insert(0, f"{n_ok} compiled cells + {n_skip} documented skips:\n")
    return "\n".join(out)


def fmt_perf() -> str:
    cells = load_dryrun()
    out = ["| cell | variant/tag | compute_s | memory_s | collective_s | step_s | frac | GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: c["cell"]):
        parts = c["cell"].split("__")
        if len(parts) <= 3 or c["status"] != "OK":
            continue
        r = c["roofline"]
        out.append(
            f"| {'__'.join(parts[:3])} | {parts[3]} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['step_s']:.4f} | "
            f"{r['frac_of_roofline']:.4f} | {r['peak_mem_bytes_per_device']/2**30:.2f} |"
        )
    return "\n".join(out)


def fmt_collocate() -> str:
    cells = load_collocation()
    out = ["| workload | group | mode | instances | step_s | epoch_s | fits | interference |",
           "|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["workload"], c["group"])):
        if c.get("status") != "OK":
            continue
        recs = c["records"]
        mode = c.get("mode", "mig")
        if "isolation" in c:
            iso = c["isolation"]
            proved = iso["disjoint"] and iso["programs_identical"]
            interf = "none (proved)" if proved else "ISOLATION FAILED"
        else:
            q = c.get("interference_quant", {})
            interf = f"{q.get('max_slowdown', 0):.2f}x predicted"
        out.append(
            f"| {c['workload']} | {c['group']} | {mode} | {len(recs)} | "
            f"{recs[0]['step_s']:.5f} | {c['epoch_time_s'][0]:.2f} | "
            f"{all(r['fits'] for r in recs)} | {interf} |"
        )
    return "\n".join(out)


def fmt_modes() -> str:
    """The paper's naive-vs-MPS-vs-MIG comparison for the workload grid.

    Speedup = time of k sequential solo runs / collocated completion time;
    interference = neighbour-induced slowdown (effective/solo for the shared
    modes, 1.0 for MIG by construction — F3). Reproduces the recommendation
    (MPS best single-user mode), MIG's interference-free column, and
    naive's sequential-or-worse behaviour.
    """
    from benchmarks.collocation_throughput import mode_rows
    from benchmarks.common import by_group

    cells = by_group(load_collocation())
    if not cells:
        return "no collocation artifacts — run repro.launch.collocate first"
    out = ["| workload | mode | k jobs | solo step_s | collocated step_s | speedup vs sequential | interference | fits |",
           "|---|---|---|---|---|---|---|---|"]
    for r in mode_rows(cells):
        out.append(
            f"| {r.workload} | {r.mode} | {r.k_jobs} | {r.solo_step_s:.5f} | "
            f"{r.effective_step_s:.5f} | {r.speedup_vs_sequential:.2f}x | "
            f"{r.max_interference:.2f}x | {r.fits} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    print({"dryrun": fmt_dryrun, "perf": fmt_perf, "collocate": fmt_collocate,
           "modes": fmt_modes}[which]())
