"""Paper Fig 8 + F5: device memory per experiment, host-RAM scaling model.

Device side: compiled peak bytes/device vs the instance HBM budget — the
paper's GPU-memory chart and the OOM admission rows. Host side: the paper's
"n parallel jobs -> n x RAM" from the pipeline queue accounting."""
from __future__ import annotations

from benchmarks.common import by_group, csv_line, load_collocation
from repro.data import synthetic
from repro.data.pipeline import HostPipeline
from repro.telemetry.constants import HBM_PER_CHIP


def run() -> list[str]:
    cells = by_group(load_collocation())
    out = []
    for (workload, group), cell in sorted(cells.items()):
        recs = cell["records"]
        total = sum(r["peak_bytes_per_device"] * r["chips"] for r in recs)
        out.append(
            csv_line(
                f"gpu_mem/{workload}/{group.replace(' ', '_')}",
                f"{total/2**30:.2f}",
                f"GiB aggregate; per_device={recs[0]['peak_bytes_per_device']/2**30:.3f}GiB "
                f"budget={HBM_PER_CHIP/2**30:.0f}GiB fits={all(r['fits'] for r in recs)}",
            )
        )
    # n-parallel => n x memory (exact in our accounting, paper Fig 8a)
    for w in ("resnet_small", "resnet_medium"):
        one = cells.get((w, "2g.10gb one"))
        par = cells.get((w, "2g.10gb parallel"))
        if one and par:
            m1 = sum(r["peak_bytes_per_device"] * r["chips"] for r in one["records"])
            mk = sum(r["peak_bytes_per_device"] * r["chips"] for r in par["records"])
            k = len(par["records"])
            out.append(
                csv_line(
                    f"gpu_mem_scaling/{w}/2g_parallel_over_one",
                    f"{mk/m1:.2f}",
                    f"expected={k} (n jobs -> n x memory)",
                )
            )
    # host RAM model: prefetch queue bytes x n jobs (paper Fig 8b / F7)
    for w, spec in (("resnet_small", synthetic.CIFAR10),
                    ("resnet_medium", synthetic.IMAGENET64),
                    ("resnet_large", synthetic.IMAGENET224)):
        b = synthetic.image_batch(spec, 32, seed=0)
        q = HostPipeline.queue_bytes(b, 10)
        out.append(
            csv_line(
                f"host_queue_mem/{w}/one",
                f"{q/2**20:.1f}",
                "MiB (queue=10 batches); x7 jobs = "
                f"{7*q/2**20:.1f} MiB (F7: n jobs -> n x host RAM)",
            )
        )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
