"""Simulator perf scoreboard: events/sec, peak queue depth, and wall-clock
per city-scale scenario cell, written to ``BENCH_simperf.json`` at the repo
root so PRs have a trajectory to move (ROADMAP: "Simulator raw speed +
million-event traces").

Each cell drives the discrete-event cluster (core/cluster.py) over one of
the ``city_scale`` trace families (launch/simulate.py: ``city_diurnal``,
``city_burst``) at a scale the default artifact grid never reaches —
10^4-10^6 arrivals over tens to hundreds of devices. The emitted document
separates what must reproduce from what may not:

  ``determinism``  per-cell event/queue/re-timing counters, completion
                   totals, the makespan, and a sha256 fingerprint of the
                   rounded cluster report — byte-identical across runs on
                   any machine (the CI gate strips the volatile keys with
                   :func:`strip_volatile` and asserts exactly this);
  ``perf``         wall-clock seconds and events/sec — the scoreboard
                   numbers, machine-dependent by nature.

``--quick`` (the CI mode) runs the three smallest cells — still including
a 10^5-arrival trace — in about a minute; the full run adds the 10^6-event
cells. ``tests/test_sim_perf_smoke.py`` guards the trajectory with a
relative, per-machine-normalized check against the committed
``benchmarks/sim_perf_baseline.json`` (see :func:`machine_calibration`).

Usage:
    PYTHONPATH=src python -m benchmarks.sim_perf [--quick] [--seed 0]
        [--retime incremental|full] [--out BENCH_simperf.json]
        [--cells name[,name...]] [--write-baseline]
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import heapq
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from benchmarks.common import Column, format_table
from repro.core.cluster import Cluster
from repro.launch.simulate import (
    SIM_SAMPLES_PER_EPOCH,
    _rounded,
    make_fleet,
    make_trace,
    synthetic_sku_dbs,
)

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = ROOT / "BENCH_simperf.json"
BASELINE_PATH = ROOT / "benchmarks" / "sim_perf_baseline.json"
SCHEMA = "sim_perf/v1"


@dataclasses.dataclass(frozen=True)
class SimPerfCell:
    """One scoreboard cell: a (scenario, policy) pair at a fixed scale."""

    name: str
    scenario: str
    policy: str
    n_jobs: int
    n_devices: int


#: CI's quick set: the steady diurnal stream on a 200-device fleet, the
#: burst stressor on a deliberately under-provisioned MIG fleet (that is
#: what drives ``peak_queue_depth``), and the 10^5-arrival acceptance cell.
QUICK_CELLS = (
    SimPerfCell("city_diurnal_25k", "city_diurnal", "all-mps", 25_000, 200),
    SimPerfCell("city_burst_25k", "city_burst", "all-mig", 25_000, 8),
    SimPerfCell("city_diurnal_100k", "city_diurnal", "all-mps", 100_000, 240),
)
#: The full scoreboard adds the million-event tier.
FULL_CELLS = QUICK_CELLS + (
    SimPerfCell("city_burst_200k", "city_burst", "all-mps", 200_000, 96),
    SimPerfCell("city_diurnal_300k", "city_diurnal", "all-mig", 300_000, 320),
)

#: The downsized cell the perf smoke test (and ``--write-baseline``) runs —
#: small enough for the test suite, same code paths as the big cells.
SMOKE_CELL = SimPerfCell("smoke_city_diurnal_2k", "city_diurnal", "all-mps", 2_000, 16)


def run_perf_cell(
    cell: SimPerfCell, *, seed: int = 0, retime: str = "incremental"
) -> Dict:
    """Run one cell and return its scoreboard row (see module docstring
    for the determinism/perf split). The timed region is submit + run —
    the event loop end to end — excluding trace generation."""
    db = synthetic_sku_dbs(("a100-40gb",))
    devices, cluster_policy = make_fleet(cell.policy, cell.n_devices)
    trace = make_trace(cell.scenario, seed, cell.n_jobs, cell.n_devices)
    cluster = Cluster(
        db,
        devices,
        policy=cluster_policy,
        reconfig_cost_s=0.5,
        migration_cooldown_s=1.0,
        retime=retime,
    )
    t0 = time.perf_counter()
    for arrival_s, spec, epochs in trace:
        cluster.submit(
            spec, arrival_s, epochs=epochs, samples_per_epoch=SIM_SAMPLES_PER_EPOCH
        )
    report = cluster.run()
    wall = time.perf_counter() - t0
    events = cluster.perf["events_processed"]
    fingerprint = hashlib.sha256(
        json.dumps(_rounded(report.to_dict()), sort_keys=True).encode()
    ).hexdigest()
    return {
        "name": cell.name,
        "scenario": cell.scenario,
        "policy": cell.policy,
        "n_jobs": cell.n_jobs,
        "n_devices": cell.n_devices,
        "retime": retime,
        "determinism": {
            "events_processed": events,
            "arrivals": len(trace),
            "completed": report.completed,
            "rejected": report.rejected,
            "phase_transitions": report.phase_transitions,
            "peak_queue_depth": cluster.queue.peak_depth,
            "hol_blocked_events": cluster.queue.hol_blocked_events,
            "retime_requests": cluster.perf["retime_requests"],
            "retime_flushes": cluster.perf["retime_flushes"],
            "retime_batched": cluster.perf["retime_batched"],
            "retime_jobs_repriced": cluster.perf["retime_jobs_repriced"],
            "shared_steps_hits": cluster.perf["shared_steps_hits"],
            "shared_steps_misses": cluster.perf["shared_steps_misses"],
            "dispatch_full_scans": cluster.perf["dispatch_full_scans"],
            "dispatch_fast_scans": cluster.perf["dispatch_fast_scans"],
            "heap_compactions": cluster.events.compactions,
            "event_tombstones": cluster.events.tombstones,
            "peak_heap_len": cluster.events.peak_heap_len,
            "makespan_s": round(report.makespan_s, 9),
            "report_sha256": fingerprint,
        },
        "perf": {
            "wall_s": round(wall, 3),
            "events_per_s": round(events / wall, 1) if wall > 0 else 0.0,
        },
    }


def machine_calibration(n: int = 200_000) -> float:
    """Operations/sec of a fixed synthetic heap+dict workload — the
    per-machine speed unit the smoke test normalizes events/sec by, so the
    committed baseline carries no absolute wall-clock assumption."""
    t0 = time.perf_counter()
    h: List = []
    d: Dict[int, int] = {}
    for i in range(n):
        k = (i * 2654435761) % 1000003
        heapq.heappush(h, (k, i))
        d[k] = i
    while h:
        d.pop(heapq.heappop(h)[0], None)
    return n / (time.perf_counter() - t0)


def strip_volatile(doc: Dict) -> Dict:
    """The byte-reproducible projection of a scoreboard document: drop the
    machine-dependent keys (per-cell ``perf``, top-level ``machine``) —
    what CI compares across two runs."""
    return {
        **{k: v for k, v in doc.items() if k != "machine"},
        "cells": [
            {k: v for k, v in c.items() if k != "perf"} for c in doc["cells"]
        ],
    }


_COLUMNS = (
    Column("name", width=22, align="<"),
    Column("n_jobs", "arrivals", "{:d}", 9),
    Column("n_devices", "devices", "{:d}", 9),
    Column("events", width=9, fmt="{:d}"),
    Column("peak_queue_depth", "peakq", "{:d}", 7),
    Column("wall_s", "wall_s", "{:.2f}", 9),
    Column("events_per_s", "events/s", "{:.0f}", 10),
)


def _table_row(row: Dict) -> Dict:
    return {
        "name": row["name"],
        "n_jobs": row["n_jobs"],
        "n_devices": row["n_devices"],
        "events": row["determinism"]["events_processed"],
        "peak_queue_depth": row["determinism"]["peak_queue_depth"],
        "wall_s": row["perf"]["wall_s"],
        "events_per_s": row["perf"]["events_per_s"],
    }


def write_baseline(path: Path = BASELINE_PATH, *, seed: int = 0) -> Dict:
    """(Re)generate the committed smoke-test baseline: the smoke cell's
    events/sec divided by :func:`machine_calibration` ops/sec — a pure
    ratio, portable across machines."""
    calib = machine_calibration()
    row = run_perf_cell(SMOKE_CELL, seed=seed)
    doc = {
        "schema": SCHEMA,
        "cell": SMOKE_CELL.name,
        "seed": seed,
        "events_per_s_normalized": round(row["perf"]["events_per_s"] / calib, 6),
        "note": "events/sec of the smoke cell divided by the synthetic "
                "heap-workload calibration ops/sec on the machine that "
                "wrote this file (benchmarks/sim_perf.py --write-baseline)",
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__ and __doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: the three smallest cells (still includes "
                         "a 10^5-arrival trace)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retime", default="incremental",
                    choices=("incremental", "full"),
                    help="which re-pricing engine to score (full is the "
                         "reference path — useful for before/after columns)")
    ap.add_argument("--out", default=str(DEFAULT_OUT),
                    help="scoreboard path (default: BENCH_simperf.json at "
                         "the repo root)")
    ap.add_argument("--cells", default=None,
                    help="comma-separated cell names to run (default: the "
                         "selected mode's full set)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="also refresh benchmarks/sim_perf_baseline.json "
                         "(the perf smoke test's committed reference)")
    args = ap.parse_args(argv)

    cells = QUICK_CELLS if args.quick else FULL_CELLS
    if args.cells:
        wanted = [c.strip() for c in args.cells.split(",") if c.strip()]
        by_name = {c.name: c for c in FULL_CELLS}
        unknown = [w for w in wanted if w not in by_name]
        if unknown:
            ap.error(
                f"unknown cell(s): {', '.join(unknown)} "
                f"(choose from: {', '.join(by_name)})"
            )
        cells = tuple(by_name[w] for w in wanted)

    rows = []
    for cell in cells:
        row = run_perf_cell(cell, seed=args.seed, retime=args.retime)
        rows.append(row)
        r = _table_row(row)
        print(
            f"[OK] {r['name']:<22} arrivals={r['n_jobs']:>7} "
            f"devices={r['n_devices']:>3} events={r['events']:>8} "
            f"peakq={r['peak_queue_depth']:>5} wall={r['wall_s']:>8.2f}s "
            f"events/s={r['events_per_s']:>9.0f}",
            flush=True,
        )

    doc = {
        "schema": SCHEMA,
        "mode": "quick" if args.quick else "full",
        "seed": args.seed,
        "retime": args.retime,
        "cells": rows,
    }
    Path(args.out).write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print()
    print(format_table(_COLUMNS, [_table_row(r) for r in rows], style="fixed"))
    print(f"\nwrote {args.out}")

    if args.write_baseline:
        base = write_baseline(seed=args.seed)
        print(
            f"wrote {BASELINE_PATH} "
            f"(normalized={base['events_per_s_normalized']:.6f})"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
