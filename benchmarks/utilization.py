"""Paper Fig 4-7: GRACT / SMACT / SMOCC / DRAMA analogues per device group,
at instance level and device (pod) level — reproduces the paper's ordering:
small workloads utilize small instances best; the full-device profile is the
least-utilized choice for them; differences shrink as workloads grow."""
from __future__ import annotations

from benchmarks.common import by_group, csv_line, load_collocation

METRICS = ("gract", "smact", "smocc_proxy", "drama")


def run() -> list[str]:
    cells = by_group(load_collocation())
    out = []
    if not cells:
        return ["utilization,SKIP,run repro.launch.collocate first"]
    for (workload, group), cell in sorted(cells.items()):
        dg = cell.get("device_group")
        if dg is None:  # analytic shared-mode cells carry no DCGM telemetry
            continue
        inst0 = dg["instance_metrics"][0] if dg["instance_metrics"] else {}
        for m in METRICS:
            out.append(
                csv_line(
                    f"util/{workload}/{group.replace(' ', '_')}/{m}",
                    f"{dg['device_metrics'][m]:.4f}",
                    f"instance_level={inst0.get(m, 0):.4f}",
                )
            )
    # paper ordering checks (small workload): device-level activity of the
    # parallel small-instance group exceeds the single full-device profile
    try:
        small_1g_par = cells[("resnet_small", "1g.5gb parallel")]["device_group"]["device_metrics"]
        small_7g = cells[("resnet_small", "7g.40gb one")]["device_group"]["device_metrics"]
        for m in ("gract", "smact"):
            ok = small_1g_par[m] >= small_7g[m]
            out.append(
                csv_line(
                    f"paper_ordering/small_1g_parallel_vs_7g/{m}",
                    "reproduced" if ok else "NOT_REPRODUCED",
                    f"1g_par={small_1g_par[m]:.3f} 7g={small_7g[m]:.3f}",
                )
            )
    except KeyError:
        pass
    return out


if __name__ == "__main__":
    print("\n".join(run()))
