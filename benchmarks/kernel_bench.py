"""Kernel microbenchmarks: per-kernel analytic roofline + CPU wall time of
the XLA reference path (the Pallas kernels target TPU; interpret mode is a
correctness harness, so CPU timings of it are not meaningful — what we
report instead is each kernel's FLOPs, HBM bytes, arithmetic intensity
against the 240.5 FLOP/byte v5e ridge, and its VMEM working set per tile)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.telemetry import constants as C


def _time(f, *args, n=3):
    f(*args).block_until_ready() if hasattr(f(*args), "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, r
        )
    return (time.perf_counter() - t0) / n


def run() -> list[str]:
    out = []
    ridge = C.PEAK_FLOPS_BF16 / C.HBM_BW

    # flash attention: B=1, S, H=8, KVH=2, D=128 (llama3-8b-like tile)
    for S in (4096, 32_768):
        B, H, KVH, D = 1, 8, 2, 128
        flops = 4.0 * B * H * S * S * D * 0.5  # causal halves the work
        bytes_ = 2.0 * (B * S * H * D + 2 * B * S * KVH * D + B * S * H * D)
        ai = flops / bytes_
        bq = bk = 512
        vmem = (bq * (H // KVH) * D * 4 + 2 * bk * D * 2 + bq * (H // KVH) * D * 4)
        out.append(
            csv_line(
                f"kernel/flash_attention/S{S}",
                f"{ai:.0f}",
                f"FLOP/byte (ridge={ridge:.0f}; {'compute' if ai > ridge else 'memory'}-bound) "
                f"flops={flops/1e9:.1f}G vmem_tile={vmem/2**10:.0f}KiB",
            )
        )

    # decode attention: B=128, Smax=32k — pure KV streaming
    B, Smax, KVH, D, H = 128, 32_768, 8, 128, 32
    flops = 4.0 * B * H * Smax * D
    bytes_ = 2.0 * 2 * B * Smax * KVH * D  # read K+V once
    out.append(
        csv_line(
            "kernel/decode_attention/S32k",
            f"{flops/bytes_:.1f}",
            f"FLOP/byte (memory-bound by design) kv_stream={bytes_/2**30:.1f}GiB "
            f"min_time={bytes_/C.HBM_BW*1e3:.1f}ms@819GB/s",
        )
    )

    # wkv6: B=1, T=4096, H=32, K=64
    B, T, H, K = 1, 4096, 32, 64
    Cn = 64
    flops = 2.0 * B * T * H * K * (Cn + 2 * K)  # pairwise + state terms
    bytes_ = 4.0 * 4 * B * T * H * K
    out.append(
        csv_line(
            "kernel/rwkv6_scan/T4096",
            f"{flops/bytes_:.1f}",
            f"FLOP/byte; state stays in VMEM ({K*K*4//1024}KiB/head) — "
            "0 HBM state traffic vs 2x(K*V) per token for naive scan",
        )
    )

    # correctness summary (interpret vs oracle) — cheap shapes
    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 2, 32)[:1] + (64, 4, 32))
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    err = float(
        jnp.max(
            jnp.abs(
                ops.flash_attention(q, k, v, block_q=32, block_k=32, mode="interpret")
                - ref.mha_reference(q, k, v)
            )
        )
    )
    out.append(csv_line("kernel/flash_attention/interpret_max_err", f"{err:.2e}",
                        "vs pure-jnp oracle"))

    # XLA fallback path wall time on CPU (what the dry-run lowers)
    from repro.models.attention import xla_flash_attention

    t = _time(jax.jit(lambda q, k, v: xla_flash_attention(q, k, v)), q, k, v)
    out.append(csv_line("kernel/xla_flash_cpu_us", f"{t*1e6:.0f}",
                        "S=64 H=4 D=32 (CPU wall, reference path)"))
    return out


# -- calibration shapes (core/calib KernelBackend) ---------------------------
#
# One cheap, CPU-interpretable shape per kernel family: large enough that the
# Pallas grid has multiple tiles (so the measurement exercises the real block
# structure), small enough that interpret mode finishes in seconds. The
# calibration harness maps each workload arch to its dominant kernel family
# and times that kernel as the arch's measured compute proxy; families the
# kernel suite does not cover (resnets — no conv kernel in-tree) proxy via
# flash attention, which is documented in docs/calibration.md.

#: kernel family -> the (arch-agnostic) measurement shape.
CALIBRATION_SHAPES = {
    "flash_attention": {"B": 1, "S": 128, "H": 4, "KVH": 2, "D": 32,
                        "block_q": 64, "block_k": 64},
    "decode_attention": {"B": 4, "Smax": 256, "H": 4, "KVH": 2, "D": 32,
                         "kv_len": 192, "block_k": 128},
    "wkv6": {"B": 1, "T": 128, "H": 4, "K": 32, "chunk": 32},
}

#: registry family (configs/registry.py ModelConfig.family) -> kernel family.
CALIBRATION_KERNELS = {
    "dense": "flash_attention",
    "vlm": "flash_attention",
    "moe": "flash_attention",
    "encdec": "flash_attention",
    "hybrid": "flash_attention",
    "resnet": "flash_attention",  # proxy: no conv kernel in-tree
    "rwkv": "wkv6",
}


def calibration_kernel_for(arch: str) -> str:
    """The kernel family the calibration harness times for ``arch``."""
    from repro.configs.registry import CONFIGS

    family = getattr(CONFIGS[arch], "family", "dense")
    return CALIBRATION_KERNELS.get(family, "flash_attention")


def measure_calibration_kernel(
    arch: str, *, mode: str = "interpret", n: int = 2, kernel: str = None
):
    """Wall-time + numerics of ``arch``'s calibration kernel.

    Returns ``{"kernel", "wall_s", "max_err_vs_ref"}``: mean wall seconds
    over ``n`` timed runs (after one warm-up) and the max abs error against
    the pure-jnp oracle (ref.py) at the same shape. ``mode="interpret"``
    runs the Pallas kernel on CPU — the no-GPU CI path; on TPU pass
    ``mode=None`` to let the kernel auto-select the compiled path.
    ``kernel`` overrides the arch->family mapping (e.g. the serve phase's
    ``decode_attention``, which no training arch maps to)."""
    from repro.kernels import ops, ref

    kernel = kernel if kernel is not None else calibration_kernel_for(arch)
    shp = CALIBRATION_SHAPES[kernel]
    ks = jax.random.split(jax.random.key(0), 6)

    if kernel == "flash_attention":
        q = jax.random.normal(ks[0], (shp["B"], shp["S"], shp["H"], shp["D"]))
        k = jax.random.normal(ks[1], (shp["B"], shp["S"], shp["KVH"], shp["D"]))
        v = jax.random.normal(ks[2], (shp["B"], shp["S"], shp["KVH"], shp["D"]))
        run_it = lambda: ops.flash_attention(
            q, k, v, block_q=shp["block_q"], block_k=shp["block_k"], mode=mode
        )
        oracle = lambda: ref.mha_reference(q, k, v)
    elif kernel == "decode_attention":
        q = jax.random.normal(ks[0], (shp["B"], shp["H"], shp["D"]))
        kc = jax.random.normal(ks[1], (shp["B"], shp["Smax"], shp["KVH"], shp["D"]))
        vc = jax.random.normal(ks[2], (shp["B"], shp["Smax"], shp["KVH"], shp["D"]))
        run_it = lambda: ops.decode_attention(
            q, kc, vc, kv_len=shp["kv_len"], block_k=shp["block_k"], mode=mode
        )
        oracle = lambda: ref.decode_attention_reference(q, kc, vc, kv_len=shp["kv_len"])
    elif kernel == "wkv6":
        B, T, H, K = shp["B"], shp["T"], shp["H"], shp["K"]
        r = jax.random.normal(ks[0], (B, T, H, K))
        k = jax.random.normal(ks[1], (B, T, H, K))
        v = jax.random.normal(ks[2], (B, T, H, K))
        logw = -jnp.abs(jax.random.normal(ks[3], (B, T, H, K))) - 0.05
        u = jax.random.normal(ks[4], (H, K))
        s0 = jax.random.normal(ks[5], (B, H, K, K))
        run_it = lambda: ops.wkv6(r, k, v, logw, u, s0, chunk=shp["chunk"], mode=mode)
        oracle = lambda: ref.wkv6_reference(r, k, v, logw, u, s0)
    else:
        raise KeyError(f"no calibration shape for kernel {kernel!r}")

    def _flat(x):
        return jnp.concatenate(
            [jnp.ravel(t).astype(jnp.float32) for t in jax.tree_util.tree_leaves(x)]
        )

    err = float(jnp.max(jnp.abs(_flat(run_it()) - _flat(oracle()))))
    wall = _time(lambda: run_it(), n=n)
    return {"kernel": kernel, "wall_s": wall, "max_err_vs_ref": err}


if __name__ == "__main__":
    print("\n".join(run()))
