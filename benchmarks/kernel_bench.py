"""Kernel microbenchmarks: per-kernel analytic roofline + CPU wall time of
the XLA reference path (the Pallas kernels target TPU; interpret mode is a
correctness harness, so CPU timings of it are not meaningful — what we
report instead is each kernel's FLOPs, HBM bytes, arithmetic intensity
against the 240.5 FLOP/byte v5e ridge, and its VMEM working set per tile)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.telemetry import constants as C


def _time(f, *args, n=3):
    f(*args).block_until_ready() if hasattr(f(*args), "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, r
        )
    return (time.perf_counter() - t0) / n


def run() -> list[str]:
    out = []
    ridge = C.PEAK_FLOPS_BF16 / C.HBM_BW

    # flash attention: B=1, S, H=8, KVH=2, D=128 (llama3-8b-like tile)
    for S in (4096, 32_768):
        B, H, KVH, D = 1, 8, 2, 128
        flops = 4.0 * B * H * S * S * D * 0.5  # causal halves the work
        bytes_ = 2.0 * (B * S * H * D + 2 * B * S * KVH * D + B * S * H * D)
        ai = flops / bytes_
        bq = bk = 512
        vmem = (bq * (H // KVH) * D * 4 + 2 * bk * D * 2 + bq * (H // KVH) * D * 4)
        out.append(
            csv_line(
                f"kernel/flash_attention/S{S}",
                f"{ai:.0f}",
                f"FLOP/byte (ridge={ridge:.0f}; {'compute' if ai > ridge else 'memory'}-bound) "
                f"flops={flops/1e9:.1f}G vmem_tile={vmem/2**10:.0f}KiB",
            )
        )

    # decode attention: B=128, Smax=32k — pure KV streaming
    B, Smax, KVH, D, H = 128, 32_768, 8, 128, 32
    flops = 4.0 * B * H * Smax * D
    bytes_ = 2.0 * 2 * B * Smax * KVH * D  # read K+V once
    out.append(
        csv_line(
            "kernel/decode_attention/S32k",
            f"{flops/bytes_:.1f}",
            f"FLOP/byte (memory-bound by design) kv_stream={bytes_/2**30:.1f}GiB "
            f"min_time={bytes_/C.HBM_BW*1e3:.1f}ms@819GB/s",
        )
    )

    # wkv6: B=1, T=4096, H=32, K=64
    B, T, H, K = 1, 4096, 32, 64
    Cn = 64
    flops = 2.0 * B * T * H * K * (Cn + 2 * K)  # pairwise + state terms
    bytes_ = 4.0 * 4 * B * T * H * K
    out.append(
        csv_line(
            "kernel/rwkv6_scan/T4096",
            f"{flops/bytes_:.1f}",
            f"FLOP/byte; state stays in VMEM ({K*K*4//1024}KiB/head) — "
            "0 HBM state traffic vs 2x(K*V) per token for naive scan",
        )
    )

    # correctness summary (interpret vs oracle) — cheap shapes
    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 2, 32)[:1] + (64, 4, 32))
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    err = float(
        jnp.max(
            jnp.abs(
                ops.flash_attention(q, k, v, block_q=32, block_k=32, mode="interpret")
                - ref.mha_reference(q, k, v)
            )
        )
    )
    out.append(csv_line("kernel/flash_attention/interpret_max_err", f"{err:.2e}",
                        "vs pure-jnp oracle"))

    # XLA fallback path wall time on CPU (what the dry-run lowers)
    from repro.models.attention import xla_flash_attention

    t = _time(jax.jit(lambda q, k, v: xla_flash_attention(q, k, v)), q, k, v)
    out.append(csv_line("kernel/xla_flash_cpu_us", f"{t*1e6:.0f}",
                        "S=64 H=4 D=32 (CPU wall, reference path)"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
