"""Paper Fig 2/3: time-per-epoch per (workload x device group).

Reads the collocation characterization artifacts (roofline-derived step
times on each carved instance x the paper's dataset cardinalities) and
reproduces the two structural findings:

  F1 sub-linear scaling — 1g is far less than 8x slower than 7g;
  isolated == parallel — per-instance epoch time is independent of
  co-located neighbours (exact, by program equivalence).
"""
from __future__ import annotations

from benchmarks.common import PAPER_F1_RATIO, by_group, csv_line, load_collocation


def run() -> list[str]:
    cells = by_group(load_collocation())
    out = []
    if not cells:
        return ["time_per_epoch,SKIP,run `python -m repro.launch.collocate` first"]
    for (workload, group), cell in sorted(cells.items()):
        for i, t in enumerate(cell["epoch_time_s"]):
            out.append(
                csv_line(
                    f"epoch_time_s/{workload}/{group.replace(' ', '_')}/inst{i}",
                    f"{t:.2f}",
                    f"step_s={cell['records'][i]['step_s']:.5f}",
                )
            )
    # F1: sub-linear latency scaling (small workload)
    try:
        t1 = cells[("resnet_small", "1g.5gb one")]["epoch_time_s"][0]
        t7 = cells[("resnet_small", "7g.40gb one")]["epoch_time_s"][0]
        ratio = t1 / t7
        out.append(
            csv_line(
                "F1_small_1g_vs_7g_slowdown",
                f"{ratio:.2f}",
                f"paper=2.47x sublinear(<8x)={'yes' if ratio < 8 else 'NO'}",
            )
        )
    except KeyError:
        pass
    # isolated == parallel (per instance)
    for w in ("resnet_small", "resnet_medium", "resnet_large"):
        for prof in ("1g.5gb", "2g.10gb", "3g.20gb"):
            one = cells.get((w, f"{prof} one"))
            par = cells.get((w, f"{prof} parallel"))
            if not (one and par):
                continue
            t_one = one["epoch_time_s"][0]
            t_pars = par["epoch_time_s"]
            same = all(abs(t - t_one) < 1e-9 for t in t_pars)
            out.append(
                csv_line(
                    f"isolation_epoch_equal/{w}/{prof}",
                    "exact" if same else "DIFFERS",
                    f"one={t_one:.2f}s parallel={t_pars[0]:.2f}s x{len(t_pars)}",
                )
            )
    return out


def calibration_epoch_time_s(
    step_s: float, *, samples_per_epoch: int = 3200, batch: int = 32
) -> float:
    """Epoch time of a measured step — the paper's metric #1 applied to a
    calibration observation (core/calib/harness). Same steps-per-epoch
    algebra as ``core.metrics.epoch_time_s`` (ceil division), with the
    simulation trace defaults (``launch/traces.SIM_SAMPLES_PER_EPOCH``)
    so harness epoch numbers line up with the simulator's clocks."""
    return float(step_s) * (-(-int(samples_per_epoch) // int(batch)))


if __name__ == "__main__":
    print("\n".join(run()))
