"""Paper F2/F4: collocation throughput vs sequential full-device execution.

  small:  k jobs in parallel on k instances vs k sequential runs on 7g
          — the paper's 2.83x headline;
  medium/large: the same ratio collapses to ~1x (saturation, F4).
"""
from __future__ import annotations

from benchmarks.common import PAPER_F2_SPEEDUP, by_group, csv_line, load_collocation
from repro.core.instance import InstanceRecord


def run() -> list[str]:
    cells = by_group(load_collocation())
    out = []
    if not cells:
        return ["collocation_throughput,SKIP,run repro.launch.collocate first"]
    workloads = sorted({w for (w, _g) in cells})
    for w in workloads:
        full = cells.get((w, "7g.40gb one"))
        if full is None:
            continue
        t_full = full["records"][0]["step_s"]
        for prof in ("1g.5gb", "2g.10gb", "3g.20gb"):
            par = cells.get((w, f"{prof} parallel"))
            if par is None:
                continue
            k = len(par["records"])
            t_par = max(r["step_s"] for r in par["records"])
            speedup = (k * t_full) / t_par
            ref = f",paper={PAPER_F2_SPEEDUP:.2f}x" if (w, prof) == ("resnet_small", "1g.5gb") else ""
            out.append(
                csv_line(
                    f"F2_collocation_speedup/{w}/{k}x_{prof}",
                    f"{speedup:.2f}",
                    f"seq_on_7g={k}x{t_full:.5f}s par={t_par:.5f}s{ref}",
                )
            )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
