"""Paper F2/F4 + the headline mode comparison.

  F2 section — k jobs in parallel on k MIG instances vs k sequential runs
  on 7g: the paper's 2.83x headline for small; medium/large collapse to ~1x
  (saturation, F4).

  Mode section — the paper's central table: the same k collocated jobs under
  naive process sharing, MPS, and MIG, each scored as speedup over running
  them sequentially on the full device. Reproduces the recommendation: MPS
  best for a single user's homogeneous jobs, MIG interference-free, naive
  never better than sequential.
"""
from __future__ import annotations

from benchmarks.common import (
    PAPER_F2_SPEEDUP,
    CSV_COLUMNS,
    by_group,
    format_table,
    load_collocation,
)
from repro.core.instance import InstanceRecord
from repro.core.metrics import ModeComparison, mode_comparison
from repro.core.sharing import STEP_LATENCY_S

# MIG parallel groups that correspond to k collocated jobs
_MIG_PARALLEL = (("1g.5gb", 7), ("2g.10gb", 3), ("3g.20gb", 2))


def mode_rows(cells) -> list[ModeComparison]:
    """Assemble naive/mps/mig comparison rows in one currency: per-step
    time including the per-step dispatch-latency floor (the shared-mode
    records already include it; MIG roofline records get it added here)."""
    rows: list[ModeComparison] = []
    workloads = sorted({w for (w, _g) in cells})
    for w in workloads:
        solo_cell = next(
            (c for (w2, _g), c in sorted(cells.items())
             if w2 == w and c.get("solo_step_s")),
            None,
        )
        non_mig = cells.get((w, "non-MIG"))
        if solo_cell is not None:
            solo_step = float(solo_cell["solo_step_s"])
        elif non_mig is not None:
            solo_step = non_mig["records"][0]["step_s"] + STEP_LATENCY_S
        else:
            continue
        for mode in ("naive", "mps"):
            for k in (2, 4, 7):
                c = cells.get((w, f"{mode} x{k}"))
                if c is None:
                    continue
                recs = [InstanceRecord(**r) for r in c["records"]]
                rows.append(mode_comparison(w, mode, recs, solo_step))
        for prof, k in _MIG_PARALLEL:
            c = cells.get((w, f"{prof} parallel"))
            if c is None:
                continue
            recs = [
                InstanceRecord(**{**r, "step_s": r["step_s"] + STEP_LATENCY_S})
                for r in c["records"]
            ]
            # MIG is interference-free by construction (F3): the slice step
            # is slice-sized with or without neighbours
            rows.append(
                mode_comparison(w, f"mig/{prof}", recs, solo_step,
                                interference=1.0)
            )
    return rows


def run() -> list[str]:
    cells = by_group(load_collocation())
    rows = []
    if not cells:
        return ["collocation_throughput,SKIP,run repro.launch.collocate first"]
    workloads = sorted({w for (w, _g) in cells})
    for w in workloads:
        full = cells.get((w, "7g.40gb one"))
        if full is None:
            continue
        t_full = full["records"][0]["step_s"]
        for prof in ("1g.5gb", "2g.10gb", "3g.20gb"):
            par = cells.get((w, f"{prof} parallel"))
            if par is None:
                continue
            k = len(par["records"])
            t_par = max(r["step_s"] for r in par["records"])
            speedup = (k * t_full) / t_par
            ref = f",paper={PAPER_F2_SPEEDUP:.2f}x" if (w, prof) == ("resnet_small", "1g.5gb") else ""
            rows.append(
                {
                    "name": f"F2_collocation_speedup/{w}/{k}x_{prof}",
                    "value": f"{speedup:.2f}",
                    "derived": f"seq_on_7g={k}x{t_full:.5f}s par={t_par:.5f}s{ref}",
                }
            )
    # the naive-vs-MPS-vs-MIG mode comparison (paper recommendation table)
    for r in mode_rows(cells):
        rows.append(
            {
                "name": f"mode_speedup/{r.workload}/{r.mode}/{r.k_jobs}x",
                "value": f"{r.speedup_vs_sequential:.2f}",
                "derived": f"coll={r.effective_step_s:.5f}s solo={r.solo_step_s:.5f}s "
                           f"interference={r.max_interference:.2f}x fits={r.fits}",
            }
        )
    return format_table(CSV_COLUMNS, rows, style="csv").splitlines()


if __name__ == "__main__":
    print("\n".join(run()))
