"""§Roofline: the full (arch x shape x mesh) table from dry-run artifacts.

Prints all three roofline terms in seconds, the dominant bound, MFU,
MODEL_FLOPS/HLO_FLOPs, and peak memory per device for every compiled cell,
plus the explicit SKIP rows — EXPERIMENTS.md §Roofline is generated from
this output.
"""
from __future__ import annotations

from benchmarks.common import csv_line, load_dryrun


def run() -> list[str]:
    cells = load_dryrun()
    out = []
    if not cells:
        return ["roofline,SKIP,run `python -m repro.launch.dryrun --all --mesh both`"]
    for c in cells:
        name = c["cell"]
        if c["status"] == "SKIP":
            out.append(csv_line(f"roofline/{name}", "SKIP", c["reason"]))
            continue
        if c["status"] != "OK":
            out.append(csv_line(f"roofline/{name}", "FAIL", c.get("error", "")[:80]))
            continue
        r = c["roofline"]
        out.append(
            csv_line(
                f"roofline/{name}",
                f"{r['step_s']:.4f}",
                f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                f"collective={r['collective_s']:.4f}s bound={r['bound']} "
                f"mfu={r['mfu']:.3f} useful={r['useful_flops_ratio']:.2f} "
                f"mem/dev={r['peak_mem_bytes_per_device']/2**30:.2f}GiB",
            )
        )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
