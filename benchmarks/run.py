"""Benchmark harness entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only SECTION]

Prints ``name,value,derived`` CSV lines. Sections read the characterization
artifacts under artifacts/ (produced by repro.launch.collocate and
repro.launch.dryrun); sections whose artifacts are missing print SKIP rows
with the command to generate them.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="run a single section")
    args = ap.parse_args()

    from benchmarks import (
        collocation_throughput,
        kernel_bench,
        memory_footprint,
        roofline_table,
        time_per_epoch,
        utilization,
    )

    sections = [
        ("time_per_epoch (paper fig 2/3, F1)", time_per_epoch.run),
        ("collocation_throughput (F2/F4)", collocation_throughput.run),
        ("utilization (paper fig 4-7)", utilization.run),
        ("memory_footprint (paper fig 8, F5/F7)", memory_footprint.run),
        ("roofline_table (section Roofline)", roofline_table.run),
        ("kernel_bench", kernel_bench.run),
    ]

    failures = 0
    print("name,value,derived")
    for title, fn in sections:
        if args.only and args.only not in title:
            continue
        print(f"# --- {title} ---")
        t0 = time.time()
        try:
            for line in fn():
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{title},ERROR,{e}")
            traceback.print_exc(limit=3)
        print(f"# ({title}: {time.time() - t0:.1f}s)")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
