"""Cluster-simulation tables: makespan / JCT / queueing delay / utilization
per fleet-mode policy — the paper's dynamic-workload findings as metrics.

Reads the (scenario x policy) cells written by ``launch/simulate.py`` from
``artifacts/cluster/``; if none exist, runs the simulation in-process
(seed 0 — it is pure Python and takes milliseconds). After the tables it
prints verdict lines tying the numbers back to the paper:

  * MIG rigidity: on the mixed dynamic trace the all-MIG fleet accrues
    more queueing delay than all-MPS ("MIG's rigid partitioning may create
    sub-optimal GPU utilization for more dynamic mixed workloads");
  * MIG alignment: on the partition-aligned static trace the all-MIG
    fleet wins makespan ("MIG can be beneficial ... when the sizes of the
    models align with the MIG partitioning options");
  * live reconfiguration: the best-mode-per-device policy performed mode
    migrations and was charged their reconfiguration cost (queueing-time
    analogue of MISO-style repartitioning).

Usage:
    PYTHONPATH=src python -m benchmarks.cluster_sim
"""
from __future__ import annotations

import sys
from typing import Dict, List, Optional

from benchmarks.common import load_cluster

_COLS = (  # (metric key, column title, width, value format)
    ("makespan_s", "makespan", 10, "{:.2f}"),
    ("mean_jct_s", "mean_jct", 10, "{:.2f}"),
    ("mean_queueing_delay_s", "mean_qdly", 11, "{:.3f}"),
    ("max_queueing_delay_s", "max_qdly", 10, "{:.3f}"),
    ("utilization_mean", "util", 7, "{:.2f}"),
    ("migrations", "migr", 6, "{:d}"),
    ("reconfig_cost_s", "reconf_s", 10, "{:.1f}"),
    ("completed", "done", 6, "{:d}"),
    ("still_queued", "queued", 8, "{:d}"),
)


def cell_metrics(cell: Dict) -> Dict:
    from repro.launch.simulate import summarize_cell

    # the summary metrics plus what the verdict lines need
    return {
        **summarize_cell(cell),
        "migration_events": cell["report"]["migration_events"],
    }


def format_scenario_table(scenario: str, rows: List[Dict]) -> str:
    hdr = f"{'policy':<11}" + "".join(
        f"{title:>{width}}" for _, title, width, _ in _COLS
    )
    lines = [f"scenario: {scenario} ({rows[0]['n_jobs']} jobs)", hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: r["policy"]):
        line = f"{r['policy']:<11}"
        for key, _, width, fmt in _COLS:
            line += f"{fmt.format(r[key]):>{width}}"
        lines.append(line)
    return "\n".join(lines)


def _by(rows: List[Dict], scenario: str, policy: str) -> Optional[Dict]:
    for r in rows:
        if r["scenario"] == scenario and r["policy"] == policy:
            return r
    return None


def verdicts(rows: List[Dict]) -> List[str]:
    """The paper's qualitative findings, checked against the metrics."""
    out = []
    mig = _by(rows, "mixed_dynamic", "all-mig")
    mps = _by(rows, "mixed_dynamic", "all-mps")
    if mig and mps:
        ok = mig["mean_queueing_delay_s"] > mps["mean_queueing_delay_s"]
        out.append(
            f"[{'OK' if ok else 'FAIL'}] MIG rigidity as queueing delay "
            f"(mixed dynamic): all-mig {mig['mean_queueing_delay_s']:.3f}s "
            f"> all-mps {mps['mean_queueing_delay_s']:.3f}s"
        )
    amig = _by(rows, "aligned_static", "all-mig")
    amps = _by(rows, "aligned_static", "all-mps")
    if amig and amps:
        ok = amig["makespan_s"] < amps["makespan_s"]
        out.append(
            f"[{'OK' if ok else 'FAIL'}] MIG wins partition-aligned static "
            f"trace: makespan all-mig {amig['makespan_s']:.2f}s "
            f"< all-mps {amps['makespan_s']:.2f}s"
        )
    migrated = [
        r for r in rows if r["policy"] == "best" and r["migrations"] > 0
    ]
    if migrated:
        r = max(migrated, key=lambda r: r["migrations"])
        dirs = {f"{e['from']}->{e['to']}" for e in r["migration_events"]}
        out.append(
            f"[OK] live reconfiguration ({r['scenario']}, best policy): "
            f"{r['migrations']} migrations ({', '.join(sorted(dirs))}), "
            f"{r['reconfig_cost_s']:.1f}s reconfig downtime charged, "
            f"{r['lost_steps']:.0f} steps re-done from checkpoints"
        )
    else:
        out.append("[FAIL] no mode-migration events under the best policy")
    return out


def main() -> int:
    cells = load_cluster()
    if not cells:
        print("# no artifacts/cluster cells — simulating in-process (seed 0)")
        from repro.launch.simulate import run_all

        cells = run_all(seed=0)
    rows = [cell_metrics(c) for c in cells if c.get("status") == "OK"]
    if not rows:
        print("no OK cluster cells", file=sys.stderr)
        return 1
    scenarios = sorted({r["scenario"] for r in rows})
    for sc in scenarios:
        print(format_scenario_table(sc, [r for r in rows if r["scenario"] == sc]))
        print()
    lines = verdicts(rows)
    print("\n".join(lines))
    return 1 if any(line.startswith("[FAIL]") for line in lines) else 0


if __name__ == "__main__":
    raise SystemExit(main())
