"""Cluster-simulation tables: makespan / JCT / queueing delay / utilization
/ SLO attainment / goodput per fleet-mode policy — the paper's dynamic
findings, and the mixed training+inference extension, as metrics.

Reads the (scenario x policy) cells written by ``launch/simulate.py`` from
``artifacts/cluster/``; if none exist, runs the simulation in-process
(seed 0 — it is pure Python and takes milliseconds). After the tables it
prints verdict lines tying the numbers back to the paper:

  * MIG rigidity: on the mixed dynamic trace the all-MIG fleet accrues
    more queueing delay than all-MPS ("MIG's rigid partitioning may create
    sub-optimal GPU utilization for more dynamic mixed workloads");
  * MIG alignment: on the partition-aligned static trace the all-MIG
    fleet wins makespan ("MIG can be beneficial ... when the sizes of the
    models align with the MIG partitioning options");
  * live reconfiguration: the best-mode-per-device policy performed mode
    migrations and was charged their reconfiguration cost (queueing-time
    analogue of MISO-style repartitioning);
  * inference flips the verdict: on the train_serve_mix trace the fleets
    are ordered SLO-first (SLO attainment, then goodput — a serving
    operator's preference), and that ordering differs from the
    training-only mixed_dynamic ordering: all-MIG's isolated slices keep
    every decode step inside its SLO while all-MPS — the training-only
    winner — sacrifices decode latency to the saturating training
    neighbours' dispatch-queue pressure (MIGPerf's finding);
  * the planner beats greedy first-fit: on the fragmentation trace the
    planner fleet (same all-MIG hardware, placements from the
    partition-tree optimizer in core/planner) strictly out-goodputs the
    greedy all-MIG fleet — greedy's lowest-offset 1g packing blocks every
    legal 2g start while free units remain — and on every other scenario
    the planner is never worse (docs/placement.md);
  * the optimizer knows what it left on the table: committed re-partition
    events carry the plan's optimality ("exact" | "beam") and its reported
    gap, and a deterministic probe drives the planner past its exact-search
    cap so the beam fallback's gap bound is printed on every run instead of
    dropped (core/planner/optimizer.py);
  * the hardware axis matters: on the hetero_sku trace a mixed-generation
    fleet (a100-40gb + a100-80gb + a30-24gb, core/device.py) drains the
    whole cross-generation mix — the big-memory serve sessions that OOM
    on every 40GB/24GB slice complete on the 80GB generation's tree with
    zero rejections (benchmarks/report.py devices prints the per-SKU
    verdict table).

Usage:
    PYTHONPATH=src python -m benchmarks.cluster_sim
"""
from __future__ import annotations

import sys
from typing import Dict, List, Optional, Tuple

from benchmarks.common import Column, format_table, load_cluster

_COLUMNS = (
    Column("policy", width=11, align="<"),
    Column("makespan_s", "makespan", "{:.2f}", 10),
    Column("mean_jct_s", "mean_jct", "{:.2f}", 10),
    Column("mean_queueing_delay_s", "mean_qdly", "{:.3f}", 11),
    Column("max_queueing_delay_s", "max_qdly", "{:.3f}", 10),
    Column("utilization_mean", "util", "{:.2f}", 7),
    Column("slo_attainment", "slo", "{:.3f}", 7),
    Column("goodput_steps_per_s", "goodput", "{:.0f}", 9),
    Column("migrations", "migr", "{:d}", 6),
    Column("reconfig_cost_s", "reconf_s", "{:.1f}", 10),
    Column("completed", "done", "{:d}", 6),
    Column("still_queued", "queued", "{:d}", 8),
)


def cell_metrics(cell: Dict) -> Dict:
    from repro.launch.simulate import summarize_cell

    # the summary metrics plus what the verdict lines need
    return {
        **summarize_cell(cell),
        "migration_events": cell["report"]["migration_events"],
        "forecast": cell["report"].get("forecast"),
    }


def format_scenario_table(scenario: str, rows: List[Dict]) -> str:
    body = format_table(
        _COLUMNS, sorted(rows, key=lambda r: r["policy"]), style="fixed"
    )
    return f"scenario: {scenario} ({rows[0]['n_jobs']} jobs)\n{body}"


def _by(rows: List[Dict], scenario: str, policy: str) -> Optional[Dict]:
    for r in rows:
        if r["scenario"] == scenario and r["policy"] == policy:
            return r
    return None


def fleet_ordering(rows: List[Dict], scenario: str) -> List[str]:
    """Fleets ranked SLO-first: meet the serving SLO, then maximize
    goodput. On a training-only trace every fleet ties at SLO 1.0 and the
    ordering degenerates to plain goodput."""
    mine = [r for r in rows if r["scenario"] == scenario]
    ranked = sorted(
        mine,
        key=lambda r: (-r["slo_attainment"], -r["goodput_steps_per_s"], r["policy"]),
    )
    return [r["policy"] for r in ranked]


def verdicts(rows: List[Dict]) -> List[str]:
    """The paper's qualitative findings, checked against the metrics."""
    out = []
    mig = _by(rows, "mixed_dynamic", "all-mig")
    mps = _by(rows, "mixed_dynamic", "all-mps")
    if mig and mps:
        ok = mig["mean_queueing_delay_s"] > mps["mean_queueing_delay_s"]
        out.append(
            f"[{'OK' if ok else 'FAIL'}] MIG rigidity as queueing delay "
            f"(mixed dynamic): all-mig {mig['mean_queueing_delay_s']:.3f}s "
            f"> all-mps {mps['mean_queueing_delay_s']:.3f}s"
        )
    amig = _by(rows, "aligned_static", "all-mig")
    amps = _by(rows, "aligned_static", "all-mps")
    if amig and amps:
        ok = amig["makespan_s"] < amps["makespan_s"]
        out.append(
            f"[{'OK' if ok else 'FAIL'}] MIG wins partition-aligned static "
            f"trace: makespan all-mig {amig['makespan_s']:.2f}s "
            f"< all-mps {amps['makespan_s']:.2f}s"
        )
    migrated = [
        r for r in rows if r["policy"] == "best" and r["migrations"] > 0
    ]
    if migrated:
        r = max(migrated, key=lambda r: r["migrations"])
        dirs = {f"{e['from']}->{e['to']}" for e in r["migration_events"]}
        out.append(
            f"[OK] live reconfiguration ({r['scenario']}, best policy): "
            f"{r['migrations']} migrations ({', '.join(sorted(dirs))}), "
            f"{r['reconfig_cost_s']:.1f}s reconfig downtime charged, "
            f"{r['lost_steps']:.0f} steps re-done from checkpoints"
        )
    else:
        out.append("[FAIL] no mode-migration events under the best policy")
    out.extend(mixed_workload_verdicts(rows))
    out.extend(planner_verdicts(rows))
    out.extend(beam_gap_verdicts(rows))
    out.extend(hetero_sku_verdicts(rows))
    return out


def hetero_sku_verdicts(rows: List[Dict]) -> List[str]:
    """Does the mixed-generation fleet drain a mix no single 40GB/24GB
    device could? (The device-model API's acceptance check.)"""
    out = []
    h = _by(rows, "hetero_sku", "all-mig")
    if h:
        ok = h["completed"] == h["n_jobs"] and h["rejected"] == 0
        out.append(
            f"[{'OK' if ok else 'FAIL'}] hetero-SKU fleet drains the "
            f"cross-generation mix (hetero_sku, all-mig): "
            f"{h['completed']}/{h['n_jobs']} completed, "
            f"{h['rejected']} rejected — the big-memory serve sessions "
            f"fit only the 80GB generation's full slice"
        )
    return out


def planner_verdicts(rows: List[Dict]) -> List[str]:
    """Does the placement planner recover what greedy first-fit strands?"""
    out = []
    frag_p = _by(rows, "fragmentation", "planner")
    frag_g = _by(rows, "fragmentation", "all-mig")
    if frag_p and frag_g:
        ok = frag_p["goodput_steps_per_s"] > frag_g["goodput_steps_per_s"]
        out.append(
            f"[{'OK' if ok else 'FAIL'}] planner beats greedy first-fit "
            f"(fragmentation): goodput planner "
            f"{frag_p['goodput_steps_per_s']:.0f} > all-mig "
            f"{frag_g['goodput_steps_per_s']:.0f} steps/s "
            f"(greedy 1g packing blocks every legal 2g start)"
        )
    worse = []
    for r in rows:
        if r["policy"] != "planner":
            continue
        g = _by(rows, r["scenario"], "all-mig")
        if g and r["goodput_steps_per_s"] < g["goodput_steps_per_s"]:
            worse.append(r["scenario"])
    if any(r["policy"] == "planner" for r in rows):
        out.append(
            f"[{'OK' if not worse else 'FAIL'}] planner never loses to "
            f"greedy on goodput"
            + (f" (worse on: {', '.join(sorted(worse))})" if worse else
               " (every scenario)")
        )
    return out


def beam_gap_verdicts(rows: List[Dict]) -> List[str]:
    """The optimizer reports how much its beam fallback leaves on the
    table — surface that gap instead of dropping it.

    Two lines: committed re-partitions in the grid carry the plan's
    ``optimality``/``gap`` on their replan events (aggregated when any
    fired), and a deterministic probe drives ``plan_placements`` past the
    exact-search cap on a fragmented layout so the beam path's reported
    gap is demonstrated on every run — the seed-0 grid drains without
    queue-pressure replans, which would otherwise leave the line empty."""
    out = []
    replans = [
        e
        for r in rows
        for e in r["migration_events"]
        if e.get("kind") == "replan"
    ]
    if replans:
        beam = [e for e in replans if e.get("optimality") == "beam"]
        missing = [e for e in replans if e.get("gap") is None]
        worst = max(e.get("gap") or 0.0 for e in replans)
        ok = not missing
        out.append(
            f"[{'OK' if ok else 'FAIL'}] committed re-partitions carry "
            f"their search optimality: {len(replans)} replans "
            f"({len(replans) - len(beam)} exact, {len(beam)} beam), "
            f"worst reported gap {worst:.1%}"
            + (f" — {len(missing)} events dropped the gap" if missing else "")
        )
    exact, beam_plan = _beam_gap_probe()
    ok = (
        exact.optimality == "exact"
        and exact.gap == 0.0
        and beam_plan.optimality == "beam"
        and 0.0 <= beam_plan.gap <= 1.0
    )
    out.append(
        f"[{'OK' if ok else 'FAIL'}] beam fallback reports its optimality "
        f"gap (fragmented tree, exact cap 6): {len(exact.assignments)} of 6 "
        f"jobs exact (gap {exact.gap:.1%}, provably optimal), 8 jobs -> "
        f"beam places {len(beam_plan.assignments)} with gap <= "
        f"{beam_plan.gap:.1%} of the conflict-free upper bound "
        f"({beam_plan.configs_evaluated} configs evaluated)"
    )
    return out


def _beam_gap_probe():
    """Exact plan at the cap vs beam plan past it, on a layout whose 1g
    residue (units 0/3/6) blocks every legal 2g start — the fragmentation
    scenario's shape, sized to straddle ``EXACT_MAX_JOBS``."""
    from repro.core.instance import JobSpec
    from repro.core.planner import PlanningCostModel, plan_placements
    from repro.core.profiles import Placement
    from repro.launch.simulate import SIM_SUITE, synthetic_char_db

    cost = PlanningCostModel(synthetic_char_db())
    existing = tuple(Placement("1g.5gb", u) for u in (0, 3, 6))
    jobs = [JobSpec(f"t{i}", "resnet_small", SIM_SUITE) for i in range(3)]
    jobs += [JobSpec(f"g{i}", "stablelm-12b", SIM_SUITE) for i in range(3)]
    exact = plan_placements(jobs, cost, existing=existing)
    jobs += [JobSpec(f"x{i}", "granite-3-2b", SIM_SUITE) for i in range(2)]
    beam_plan = plan_placements(jobs, cost, existing=existing)
    return exact, beam_plan


def mixed_workload_verdicts(rows: List[Dict]) -> List[str]:
    """Does adding inference change which fleet wins? (MIGPerf)"""
    out = []
    smig = _by(rows, "train_serve_mix", "all-mig")
    smps = _by(rows, "train_serve_mix", "all-mps")
    if not (smig and smps):
        return out
    ok = smig["slo_attainment"] > smps["slo_attainment"]
    out.append(
        f"[{'OK' if ok else 'FAIL'}] MIG protects inference latency "
        f"(train_serve_mix): SLO attainment all-mig "
        f"{smig['slo_attainment']:.3f} > all-mps {smps['slo_attainment']:.3f} "
        f"(isolated slices vs shared dispatch queue)"
    )
    train_order = fleet_ordering(rows, "mixed_dynamic")
    mix_order = fleet_ordering(rows, "train_serve_mix")
    if train_order and mix_order:
        differs = train_order != mix_order
        out.append(
            f"[{'OK' if differs else 'FAIL'}] inference changes the "
            f"collocation verdict: fleet ordering (SLO-first) "
            f"training-only [{' > '.join(train_order)}] vs "
            f"train+serve [{' > '.join(mix_order)}]"
        )
    return out


def main() -> int:
    cells = load_cluster()
    if not cells:
        print("# no artifacts/cluster cells — simulating in-process (seed 0)")
        from repro.launch.simulate import run_all

        cells = run_all(seed=0)
    rows = [cell_metrics(c) for c in cells if c.get("status") == "OK"]
    if not rows:
        print("no OK cluster cells", file=sys.stderr)
        return 1
    scenarios = sorted({r["scenario"] for r in rows})
    for sc in scenarios:
        print(format_scenario_table(sc, [r for r in rows if r["scenario"] == sc]))
        print()
    lines = verdicts(rows)
    print("\n".join(lines))
    return 1 if any(line.startswith("[FAIL]") for line in lines) else 0


if __name__ == "__main__":
    raise SystemExit(main())
