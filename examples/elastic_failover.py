"""Elastic failover, live: slice units die mid-training; the controller
kills the affected instances, repacks their jobs onto surviving units, and
the jobs RESUME FROM CHECKPOINT on different hardware — while untouched
neighbours keep training without interruption (the paper's isolation
guarantee doing real work).

    PYTHONPATH=src python examples/elastic_failover.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ShapeSuite
from repro.configs.registry import get_config
from repro.core.collocation import CollocationScheduler
from repro.core.elastic import ElasticController
from repro.core.instance import JobSpec
from repro.core.partitioner import device_grid, instance_mesh
from repro.data import synthetic
from repro.models.model_api import build_model
from repro.optim import adamw
from repro.runtime import train_step as ts

STEPS_BEFORE, STEPS_AFTER = 4, 4


def train_steps(inst, cfg, suite, store, job_name, n_steps, seed=0):
    """Run n steps on an instance, resuming from the store if possible."""
    model = build_model(cfg)
    opt = adamw.AdamWConfig(warmup_steps=2, total_steps=STEPS_BEFORE + STEPS_AFTER)
    jitted, st_sh, b_sh, _ = ts.jit_train_step(model, inst.mesh, suite, opt)
    state = ts.init_train_state(model, jax.random.key(seed), opt)
    start = 0
    latest = store.latest_step()
    if latest is not None:
        state, _ = store.restore(state, latest, shardings=st_sh)
        start = latest
        print(f"  [{job_name}] resumed from step {latest} on {inst.label}")
    else:
        state = jax.device_put(state, st_sh)
    losses = []
    for i in range(start, start + n_steps):
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic.batch_for(cfg, suite, seed=seed, step=i).items()}
        state, m = jitted(state, jax.device_put(batch, b_sh))
        losses.append(float(m["loss"]))
    store.save(start + n_steps, state)
    return losses


def main():
    cfg = get_config("granite-3-2b").reduced()
    suite = ShapeSuite("ft", 32, 4, "train")
    grid = device_grid(rows=8)

    db = {
        (cfg.name, suite.name, p): {"fits": True, "step_s": 0.1,
                                    "peak_bytes_per_device": 0}
        for p in ("1g.5gb", "2g.10gb", "3g.20gb", "4g.20gb", "7g.40gb")
    }
    sched = CollocationScheduler(db)
    jobs = [JobSpec(f"job{i}", cfg.name, suite) for i in range(3)]
    schedule = sched.schedule(jobs)
    print("initial schedule:")
    for a in schedule.assignments:
        print(f"  {a.job.name} -> {a.profile}@{a.placement.start}")

    tmp = Path(tempfile.mkdtemp(prefix="elastic_"))
    stores = {j.name: CheckpointStore(tmp / j.name) for j in jobs}
    traces = {}

    # phase 1: everyone trains and checkpoints
    for a in schedule.assignments:
        inst = instance_mesh(grid, a.placement)
        traces[a.job.name] = train_steps(
            inst, cfg, suite, stores[a.job.name], a.job.name, STEPS_BEFORE,
            seed=hash(a.job.name) % 1000,
        )
    print(f"phase 1 done: {STEPS_BEFORE} steps each, checkpoints written")

    # phase 2: slice unit 0 fails -> repack
    ctrl = ElasticController(sched)
    ctrl.mark_failed([0])
    event = ctrl.repack(schedule)
    print(f"\nunit 0 FAILED: killed={list(event.killed_jobs)} "
          f"survivors={list(event.survivors)}")
    print("repacked schedule:")
    for a in event.new_schedule.assignments:
        print(f"  {a.job.name} -> {a.profile}@{a.placement.start}")

    # phase 3: everyone continues — killed jobs resume from their checkpoint
    # on a DIFFERENT instance; survivors were never interrupted
    for a in event.new_schedule.assignments:
        inst = instance_mesh(grid, a.placement)
        traces[a.job.name] += train_steps(
            inst, cfg, suite, stores[a.job.name], a.job.name, STEPS_AFTER,
            seed=hash(a.job.name) % 1000,
        )

    print("\nloss traces (8 contiguous steps each — no resets, no divergence):")
    for name, tr in sorted(traces.items()):
        print(f"  {name}: " + " ".join(f"{v:.3f}" for v in tr))
        assert len(tr) == STEPS_BEFORE + STEPS_AFTER


if __name__ == "__main__":
    main()
