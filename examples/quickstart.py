"""Quickstart: the public API in ~60 lines.

Builds a reduced LM from the assigned-architecture registry, trains it a few
steps on deterministic synthetic data, saves a checkpoint, restores it, and
generates tokens — everything the framework does, at CPU scale.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ShapeSuite
from repro.configs.registry import get_config
from repro.data import synthetic
from repro.models.model_api import build_model
from repro.optim import adamw
from repro.runtime import train_step as ts
from repro.runtime.serve_step import greedy_generate
from repro.sharding.plan import make_plan


def main():
    # 1. pick an assigned architecture, shrink it to CPU scale
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg)
    plan = make_plan(cfg, None)  # no mesh: single device
    suite = ShapeSuite("quickstart", seq_len=64, global_batch=4, kind="train")

    # 2. train a few steps
    opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=5, total_steps=30)
    state = ts.init_train_state(model, jax.random.key(0), opt_cfg)
    step = jax.jit(ts.build_train_step(model, plan, opt_cfg))
    for i in range(30):
        batch = {
            k: jnp.asarray(v)
            for k, v in synthetic.batch_for(cfg, suite, seed=0, step=i).items()
        }
        state, metrics = step(state, batch)
        if (i + 1) % 10 == 0:
            print(f"step {i + 1:3d}  loss={float(metrics['loss']):.4f}  "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")

    # 3. checkpoint round-trip
    store = CheckpointStore("/tmp/quickstart_ckpt")
    store.save(30, state)
    state, _ = store.restore(state)
    print(f"checkpoint saved + restored at step {store.latest_step()}")

    # 4. generate with the KV-cached serving path
    prompt = jnp.asarray(
        synthetic.token_batch(cfg.vocab, 2, 8, seed=1)["tokens"]
    )
    tokens = greedy_generate(model, state["params"], prompt, max_new=8, plan=plan)
    print(f"generated tokens:\n{tokens}")


if __name__ == "__main__":
    main()
