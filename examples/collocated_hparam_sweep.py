"""The paper's headline use case, live: a hyperparameter sweep collocated on
MIG-style instances of one device pool.

Seven learning-rate variants of the same reduced model train IN PARALLEL
(python threads; jax dispatch overlaps) on seven disjoint 1-unit instances
carved from an 8-unit pool — the analogue of the paper's 7x 1g.5gb
experiment. The scheduler performs admission + packing, the partitioner
carves the sub-meshes, and per-job losses demonstrate isolation: each job's
loss trace is identical to what it produces running alone (F3).

Run (the XLA flag below creates 8 placeholder CPU devices; must be set
before jax initializes, which is why it's at the very top):

    PYTHONPATH=src python examples/collocated_hparam_sweep.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSuite
from repro.configs.registry import get_config
from repro.core.collocation import CollocationScheduler
from repro.core.instance import JobSpec
from repro.core.partitioner import device_grid, partition, verify_disjoint
from repro.core.profiles import Placement
from repro.data import synthetic
from repro.models.model_api import build_model
from repro.optim import adamw
from repro.runtime import train_step as ts

STEPS = 8
LRS = [3e-4 * (2**i) for i in range(-3, 4)]  # 7 variants


def main():
    cfg = get_config("granite-3-2b").reduced()
    suite = ShapeSuite("sweep", 32, 4, "train")

    # --- schedule: 7 jobs -> 7x 1g instances (admission via a tiny char DB)
    db = {
        (cfg.name, suite.name, p): {"fits": True, "step_s": 0.1, "peak_bytes_per_device": 0}
        for p in ("1g.5gb", "2g.10gb", "3g.20gb", "4g.20gb", "7g.40gb")
    }
    sched = CollocationScheduler(db)
    jobs = [JobSpec(f"lr={lr:.1e}", cfg.name, suite) for lr in LRS]
    schedule = sched.schedule(jobs)
    assert len(schedule.assignments) == 7 and not schedule.rejections
    print("schedule:")
    for a in schedule.assignments:
        print(f"  {a.job.name:<12} -> {a.profile}@{a.placement.start}")

    # --- carve instances (1 device per slice unit on this 8-device pool)
    grid = device_grid(rows=8)
    instances = partition(grid, [a.placement for a in schedule.assignments])
    verify_disjoint(instances)

    # --- run all jobs in parallel, one thread per instance
    results = {}

    def run_job(inst, lr, name):
        model = build_model(cfg)
        opt = adamw.AdamWConfig(lr_peak=lr, warmup_steps=2, total_steps=STEPS)
        jitted, st_sh, b_sh, _ = ts.jit_train_step(model, inst.mesh, suite, opt)
        state = jax.device_put(ts.init_train_state(model, jax.random.key(0), opt), st_sh)
        losses = []
        for i in range(STEPS):
            batch = {
                k: jnp.asarray(v)
                for k, v in synthetic.batch_for(cfg, suite, seed=0, step=i).items()
            }
            state, metrics = jitted(state, jax.device_put(batch, b_sh))
            losses.append(float(metrics["loss"]))
        results[name] = losses

    t0 = time.perf_counter()
    threads = [
        threading.Thread(target=run_job, args=(inst, lr, job.name))
        for inst, lr, job in zip(instances, LRS, jobs)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    print(f"\n7 models trained in parallel in {wall:.1f}s wall "
          f"({STEPS} steps each, same data, different lr):")
    best = min(results, key=lambda k: results[k][-1])
    for name, losses in sorted(results.items()):
        tag = "  <-- winner" if name == best else ""
        print(f"  {name:<12} final loss {losses[-1]:.4f}{tag}")


if __name__ == "__main__":
    main()
