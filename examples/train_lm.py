"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the real launcher (checkpointing, host pipeline, resume) with a
~100M-param llama-style config — the deliverable (b) "train ~100M model"
driver. On CPU this takes a few minutes with the default 200 steps; pass
--steps to shorten.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs.base import ModelConfig
from repro.configs import registry
from repro.launch import train as train_cli

# ~100M params: 12L, d=512, 8 heads, ffn 2048, 32k vocab
LM100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32_000,
    remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args_in = ap.parse_args()

    registry.CONFIGS["lm-100m"] = LM100M  # register for the launcher

    from repro.models.model_api import build_model

    n = build_model(LM100M).param_count()
    print(f"lm-100m: {n/1e6:.1f}M parameters")

    args = train_cli.build_argparser().parse_args(
        [
            "--arch", "lm-100m",
            "--steps", str(args_in.steps),
            "--batch", str(args_in.batch),
            "--seq", str(args_in.seq),
            "--ckpt-dir", args_in.ckpt_dir,
            "--ckpt-every", "50",
            "--log-every", "10",
            "--workers", "2",
            "--lr", "6e-4",
        ]
    )
    result = train_cli.run(args)
    print(
        f"\ntrained {result['steps']} steps: loss "
        f"{result['first_loss']:.3f} -> {result['final_loss']:.3f} "
        f"({result['mean_step_ms']:.0f} ms/step, "
        f"input-wait {result['pipeline']['input_wait_per_batch_ms']:.2f} ms/batch)"
    )
    assert result["final_loss"] < result["first_loss"], "loss did not improve"


if __name__ == "__main__":
    main()
