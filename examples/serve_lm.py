"""Batched serving example: prefill a batch of prompts once, then decode
tokens step-by-step against the shared KV cache — the serving path the
decode_32k / long_500k dry-run cells lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data import synthetic
from repro.models.model_api import build_model
from repro.runtime.serve_step import pad_cache
from repro.sharding.plan import make_plan


def main():
    cfg = get_config("qwen2-72b").reduced()
    model = build_model(cfg)
    plan = make_plan(cfg, None)
    params = model.init(jax.random.key(0))

    B, S, NEW = 8, 32, 16
    prompts = jnp.asarray(synthetic.token_batch(cfg.vocab, B, S, seed=7)["tokens"])

    # prefill: one pass over the prompt batch, builds the KV cache
    t0 = time.perf_counter()
    last, cache = model.prefill(params, {"tokens": prompts}, plan)
    cache = pad_cache(cache, NEW)
    t_prefill = time.perf_counter() - t0

    # decode: one token per step for the whole batch
    decode = jax.jit(
        lambda params, tok, cache, pos: model.decode(
            params, {"token": tok}, cache, pos, plan
        ),
        static_argnames=("pos",),
    )
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    out = [tok]
    t1 = time.perf_counter()
    for i in range(NEW - 1):
        logits, cache = decode(params, out[-1], cache, S + i)
        out.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
    t_decode = time.perf_counter() - t1

    tokens = jnp.stack(out, axis=1)
    print(f"prefill: {B} x {S} tokens in {t_prefill*1e3:.0f} ms")
    print(
        f"decode:  {B} x {NEW} tokens in {t_decode*1e3:.0f} ms "
        f"({B * NEW / max(t_decode, 1e-9):.0f} tok/s batched)"
    )
    print(f"sampled continuation (first request): {tokens[0].tolist()}")


if __name__ == "__main__":
    main()
