"""Placement planner: partition-tree enumeration invariants, predictive
slice fitting, exact-optimality proof, fragmentation recovery, and the
cluster's plan-driven re-partitions."""
import itertools
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeSuite
from repro.core.collocation import CollocationScheduler
from repro.core.cluster import Cluster
from repro.core.instance import JobSpec, compute_discount
from repro.core.planner import (
    PlanningCostModel,
    canonical_form,
    enumerate_configs,
    expansions,
    flexibility,
    free_placements,
    maximal_configs,
    plan_placements,
    profile_multisets,
    transition,
)
from repro.core.planner.costmodel import predict_record
from repro.core.planner.optimizer import PROFILE_ORDER
from repro.core.profiles import (
    N_COMPUTE_SLICES,
    N_UNITS,
    PROFILES,
    Placement,
    validate_layout,
)
from repro.core.sharing import CollocationMode
from repro.core.workload import DECODE_DEMAND, STEADY_DEMAND, serve_workload
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")


def make_db(arch, *, step_by_prof=None, fits_by_prof=None, peak_frac=0.1):
    step_by_prof = step_by_prof or {}
    fits_by_prof = fits_by_prof or {}
    db = {}
    for prof in PROFILE_ORDER:
        db[(arch, SUITE.name, prof)] = {
            "fits": fits_by_prof.get(prof, True),
            "step_s": step_by_prof.get(prof, 1.0),
            "peak_bytes_per_device": peak_frac * HBM_PER_CHIP,
        }
    return db


# -- enumeration invariants ------------------------------------------------------


def test_every_config_is_a_valid_layout_with_budgeted_compute():
    cfgs = enumerate_configs()
    assert cfgs, "enumeration produced nothing"
    for cfg in cfgs:
        ok, why = validate_layout(cfg)
        assert ok, f"{cfg}: {why}"
        assert (
            sum(PROFILES[pl.profile].compute_slices for pl in cfg)
            <= N_COMPUTE_SLICES
        )


def test_every_config_passes_verify_disjoint():
    """The partitioner invariant, on a stand-in device grid: one distinct
    chip object per slice unit, each placement owning its span's rows —
    exactly how partitioner.instance_mesh carves the real grid."""
    from repro.core.partitioner import verify_disjoint

    for cfg in enumerate_configs():
        chips = np.array([object() for _ in range(N_UNITS)], dtype=object)
        instances = []
        for pl in cfg:
            s0, s1 = pl.span
            instances.append(
                SimpleNamespace(
                    mesh=SimpleNamespace(devices=chips[s0:s1]),
                    label=f"{pl.profile}@{pl.start}",
                )
            )
        verify_disjoint(instances)  # raises on any overlap


def test_enumeration_is_deterministic_memoized_and_duplicate_free():
    a = enumerate_configs()
    b = enumerate_configs()
    assert a is b  # memoized canonical forms
    keys = [tuple((pl.start, pl.profile) for pl in cfg) for cfg in a]
    assert len(keys) == len(set(keys))  # duplicate-free
    assert all(cfg == canonical_form(cfg) for cfg in a)  # canonical order


def test_partition_tree_counts_match_the_a100_analogue():
    """296 valid layouts collapse to 18 maximal configs — the analogue of
    the A100's ~19 canonical partition profiles under our algebra (the
    4g+3g exclusion and 7-slice budget trim the published tree)."""
    assert len(enumerate_configs()) == 296
    assert len(maximal_configs()) == 18
    assert len(profile_multisets()) == 36
    for cfg in maximal_configs():
        assert not free_placements(cfg), f"{cfg} is not maximal"


def test_expansions_are_supersets_avoiding_blocked_units():
    existing = (Placement("1g.5gb", 0), Placement("2g.10gb", 2))
    out = expansions(existing, blocked_units=frozenset({5}))
    assert canonical_form(existing) in out  # zero-transition plan included
    for cfg in out:
        assert set(existing) <= set(cfg)
        ok, why = validate_layout(cfg)
        assert ok, why
        for pl in set(cfg) - set(existing):
            s0, s1 = pl.span
            assert 5 not in range(s0, s1)


def test_expansions_reject_invalid_existing_layout():
    with pytest.raises(ValueError, match="invalid"):
        expansions((Placement("4g.20gb", 0), Placement("3g.20gb", 4)))


def test_transition_reports_kept_destroyed_created():
    cur = (Placement("1g.5gb", 0), Placement("1g.5gb", 1))
    tgt = (Placement("1g.5gb", 0), Placement("2g.10gb", 2))
    kept, destroyed, created = transition(cur, tgt)
    assert kept == (Placement("1g.5gb", 0),)
    assert destroyed == (Placement("1g.5gb", 1),)
    assert created == (Placement("2g.10gb", 2),)


layouts_st = st.sampled_from(enumerate_configs())


@given(layouts_st)
@settings(max_examples=60, deadline=None)
def test_free_placements_are_individually_addable(cfg):
    for cand in free_placements(cfg):
        ok, why = validate_layout(list(cfg) + [cand])
        assert ok, why
    # and flexibility is exactly their count
    assert flexibility(cfg) == len(free_placements(cfg))


# -- predictive cost model -------------------------------------------------------


def test_estimate_matches_record_step_exactly_and_memoizes():
    db = make_db("a", step_by_prof={p: 0.25 for p in PROFILE_ORDER})
    cost = PlanningCostModel(db)
    job = JobSpec("j", "a", SUITE)
    est = cost.estimate(job, "1g.5gb")
    assert est.fits and est.step_s == 0.25 and est.goodput == 4.0
    assert not est.predicted
    assert cost.estimate(job, "1g.5gb") is est  # memoized


def test_miso_prediction_from_full_device_record():
    """No record for the slice: the estimate is derived from the 7g record
    by inverse-fraction roofline scaling plus the F6 discount ratio."""
    full = {
        "fits": True,
        "step_s": 0.8 + 0.01,  # busy 0.8 (compute-bound) + 0.01 latency
        "compute_s": 0.8,
        "memory_s": 0.2,
        "collective_s": 0.0,
        "peak_bytes_per_device": 0.05 * HBM_PER_CHIP,
    }
    db = {("a", SUITE.name, "7g.40gb"): full}
    cost = PlanningCostModel(db)
    est = cost.estimate(JobSpec("j", "a", SUITE), "2g.10gb")
    assert est.fits and est.predicted
    rec = predict_record(full, "2g.10gb")
    # 2g owns 2/8 of the chips (vs 7g's 8/8) and has no extra F6 discount
    # relative to its mem units: compute scales by 4 / (1 / (7/8))
    scale = (8 / 2)
    disc = compute_discount("2g.10gb") / compute_discount("7g.40gb")
    assert rec["compute_s"] == pytest.approx(0.8 * scale / disc)
    assert rec["memory_s"] == pytest.approx(0.2 * scale)
    assert est.step_s == pytest.approx(max(rec["compute_s"], rec["memory_s"]) + 0.01)


def test_estimate_without_any_record_does_not_fit():
    cost = PlanningCostModel({})
    est = cost.estimate(JobSpec("j", "ghost", SUITE), "1g.5gb")
    assert not est.fits and "no characterization" in est.reason


def test_admission_predicate_is_shared_with_the_greedy_scheduler():
    """One predicate, two callers: a measured record with no 'fits' key is
    rejected by both paths (the record never proved the job fits) — the
    planner cannot admit where greedy rejects."""
    rec = {"step_s": 0.5, "peak_bytes_per_device": 0.01 * HBM_PER_CHIP}
    db = {("a", SUITE.name, "1g.5gb"): rec}
    job = JobSpec("j", "a", SUITE)
    s = CollocationScheduler(db)
    ok, _ = s.admissible(job, "1g.5gb")
    est = PlanningCostModel(db).estimate(job, "1g.5gb")
    assert ok is False and est.fits is False


def test_predict_step_raises_loudly_for_unpredictable_slice():
    """Old contract preserved: no record and nothing to predict from is a
    caller bug, never a cached 0.0."""
    s = CollocationScheduler({})
    with pytest.raises(KeyError):
        s.predict_step(JobSpec("j", "ghost", SUITE), "1g.5gb")


def test_slo_gating_zeroes_goodput_but_counts_placement():
    db = make_db("sv", step_by_prof={p: 2.0e-3 for p in PROFILE_ORDER})
    cost = PlanningCostModel(db)
    wl = serve_workload("s", "sv", SUITE, slo_step_s=1.0e-3)
    est = cost.estimate(wl, "1g.5gb", STEADY_DEMAND)
    assert est.fits and est.slo_ok is False and est.goodput == 0.0
    # throughput stays SLO-blind (rank_modes' currency)
    assert est.throughput == pytest.approx(500.0)
    plan = plan_placements([wl], cost)
    assert "s" in plan.assignments  # placed (F5) even though SLO-missed
    assert plan.goodput == 0.0


# -- optimizer: exact optimality proof -------------------------------------------


def brute_force_best_score(jobs, cost, existing=()):
    """Ground truth: try every (config, slot->job bijection) and score with
    the optimizer's published objective."""
    from repro.core.planner.optimizer import _compute_slices

    existing_cfg = canonical_form(existing)
    existing_set = set(existing_cfg)
    best = (-1.0, -1.0, -1, 1 << 10, -1.0)
    for cfg in expansions(existing_cfg):
        slots = [pl for pl in cfg if pl not in existing_set]
        if len(slots) > len(jobs):
            continue
        for combo in itertools.permutations(range(len(jobs)), len(slots)):
            w = k = g = 0.0
            feasible = True
            for slot, ji in zip(slots, combo):
                est = cost.estimate(jobs[ji], slot.profile)
                floor = jobs[ji].min_profile
                if floor and PROFILE_ORDER.index(slot.profile) < PROFILE_ORDER.index(floor):
                    feasible = False
                    break
                if not est.fits:
                    feasible = False
                    break
                w += 1.0 + jobs[ji].priority
                g += est.goodput
            if not feasible:
                continue
            score = (w, k, flexibility(cfg), -_compute_slices(cfg), g)
            best = max(best, score)
    return best


def _plan_score(plan, blocked=frozenset()):
    from repro.core.planner.optimizer import _compute_slices

    return (
        plan.placed_weight,
        plan.kept_weight,
        plan.flexibility,
        -_compute_slices(plan.layout),
        plan.goodput,
    )


@pytest.mark.parametrize("n_jobs", [1, 2, 4, 6])
def test_exact_optimizer_matches_brute_force(n_jobs):
    """The acceptance criterion: the optimizer proves optimality for <= 6
    job instances — its plan's score equals exhaustive search's."""
    db = {}
    db.update(make_db("small", step_by_prof={
        "1g.5gb": 8.0, "2g.10gb": 4.0, "3g.20gb": 2.7, "4g.20gb": 2.0,
        "7g.40gb": 1.0}))
    db.update(make_db("mid", fits_by_prof={"1g.5gb": False},
                      step_by_prof={p: 3.0 for p in PROFILE_ORDER},
                      peak_frac=0.3))
    cost = PlanningCostModel(db)
    jobs = [
        JobSpec(f"j{i}", "small" if i % 2 == 0 else "mid", SUITE,
                priority=i % 3)
        for i in range(n_jobs)
    ]
    plan = plan_placements(jobs, cost)
    assert plan.optimality == "exact" and plan.gap == 0.0
    assert plan.score == _plan_score(plan)  # public score == full objective
    assert _plan_score(plan)[:2] + _plan_score(plan)[2:] == pytest.approx(
        brute_force_best_score(jobs, cost)
    )
    ok, why = validate_layout(plan.layout)
    assert ok, why


def test_exact_optimizer_matches_brute_force_with_existing():
    db = make_db("small")
    cost = PlanningCostModel(db)
    existing = [Placement("2g.10gb", 0)]
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(3)]
    plan = plan_placements(jobs, cost, existing=existing)
    assert _plan_score(plan) == pytest.approx(
        brute_force_best_score(jobs, cost, existing)
    )
    assert set(existing) <= set(plan.layout)


def test_beam_fallback_reports_tier_and_bounded_gap():
    db = make_db("small")
    cost = PlanningCostModel(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(9)]
    plan = plan_placements(jobs, cost)
    assert plan.optimality == "beam"
    assert 0.0 <= plan.gap <= 1.0
    # 7 of 9 slice-sized jobs fit the tree; the beam finds the full pack,
    # so only the conflict-free goodput bound reports slack
    assert len(plan.assignments) == 7
    assert len(plan.unplaced) == 2
    ok, why = validate_layout(plan.layout)
    assert ok, why


def test_min_profile_floor_respected_by_planner():
    db = make_db("small")
    cost = PlanningCostModel(db)
    job = JobSpec("j", "small", SUITE, min_profile="3g.20gb")
    plan = plan_placements([job], cost)
    assert plan.assignments["j"].profile in ("3g.20gb", "4g.20gb", "7g.40gb")


def test_preferred_placements_are_kept_when_possible():
    """Retention: a from-scratch plan pins running jobs to their current
    instances unless moving one is the only way to serve more jobs."""
    db = make_db("small")
    cost = PlanningCostModel(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(3)]
    preferred = {
        "j0": Placement("1g.5gb", 2),
        "j1": Placement("1g.5gb", 3),
        "j2": Placement("1g.5gb", 5),
    }
    plan = plan_placements(jobs, cost, preferred=preferred)
    assert dict(plan.assignments) == preferred
    assert plan.kept_weight == 3.0


def test_fragmentation_planner_keeps_a_2g_start_open():
    """The tentpole behaviour, scheduler-level: greedy first-fit packs five
    1g jobs at offsets 0-4 (blocking every legal 2g start); the planner's
    flexibility term keeps one open, so the 2g-class job places."""
    db = {}
    db.update(make_db("small"))
    db.update(make_db("twog", fits_by_prof={"1g.5gb": False}, peak_frac=0.3))
    greedy = CollocationScheduler(db)
    planner = CollocationScheduler(db, use_planner=True)
    outcomes = {}
    for tag, sched in (("greedy", greedy), ("planner", planner)):
        existing = []
        for i in range(5):
            s = sched.schedule([JobSpec(f"s{i}", "small", SUITE)], existing=existing)
            existing.append(s.assignments[0].placement)
        after = sched.schedule([JobSpec("big", "twog", SUITE)], existing=existing)
        outcomes[tag] = (existing, after)
    g_exist, g_after = outcomes["greedy"]
    p_exist, p_after = outcomes["planner"]
    assert sorted(pl.start for pl in g_exist) == [0, 1, 2, 3, 4]
    assert not g_after.assignments  # stranded: all 2g starts blocked
    assert p_after.assignments and p_after.assignments[0].profile == "2g.10gb"
    assert p_after.plan is not None and p_after.plan.optimality == "exact"


def test_planned_schedules_are_always_valid_layouts():
    db = {}
    db.update(make_db("small"))
    db.update(make_db("mid", fits_by_prof={"1g.5gb": False}, peak_frac=0.3))
    s = CollocationScheduler(db, use_planner=True)
    jobs = [
        JobSpec(f"j{i}", "small" if i % 2 else "mid", SUITE, priority=i % 3)
        for i in range(8)
    ]
    sched = s.schedule(jobs)
    ok, why = validate_layout([a.placement for a in sched.assignments])
    assert ok, why
    placed = {a.job.name for a in sched.assignments}
    rejected = {r.job.name for r in sched.rejections}
    assert placed | rejected == {j.name for j in jobs}
    assert not placed & rejected
    for a in sched.assignments:
        assert s.admissible(a.job, a.profile)[0]


def test_best_mode_consumes_the_placement_plan():
    db = make_db("small")
    s = CollocationScheduler(db, use_planner=True)
    decision = s.best_mode([JobSpec("j", "small", SUITE)])
    mig = decision.schedules[CollocationMode.MIG]
    assert mig.plan is not None
    assert mig.plan.optimality == "exact" and mig.plan.gap == 0.0


# -- scheduler memoization (perf satellite) --------------------------------------


def test_predict_step_and_solo_profile_are_memoized():
    db = make_db("a", step_by_prof={p: 0.5 for p in PROFILE_ORDER})
    s = CollocationScheduler(db)
    job = JobSpec("j", "a", SUITE)
    assert s.predict_step(job, "1g.5gb") == 0.5
    solo1 = s.solo_profile(job)
    # corrupt the DB record: memoized paths must not re-read it
    db[("a", SUITE.name, "1g.5gb")]["step_s"] = 99.0
    db[("a", SUITE.name, "7g.40gb")]["step_s"] = 99.0
    assert s.predict_step(job, "1g.5gb") == 0.5
    assert s.solo_profile(job).step_s == solo1.step_s
    # the cached arch profile is re-labelled per job
    other = s.solo_profile(JobSpec("k", "a", SUITE))
    assert other.name == "k" and other.step_s == solo1.step_s


def test_predict_step_distinguishes_demand_vectors():
    db = {
        ("a", SUITE.name, "1g.5gb"): {
            "fits": True, "step_s": 1.0, "compute_s": 1.0, "memory_s": 0.0,
            "collective_s": 0.0, "peak_bytes_per_device": 0.1 * HBM_PER_CHIP,
        }
    }
    s = CollocationScheduler(db)
    job = JobSpec("j", "a", SUITE)
    assert s.predict_step(job, "1g.5gb", STEADY_DEMAND) == 1.0
    # decode demand scales the compute-only record's busy term by 0.05 —
    # a different DemandTrace must be a different memoization key
    assert s.predict_step(job, "1g.5gb", DECODE_DEMAND) == pytest.approx(0.05)


# -- cluster: planner policy -----------------------------------------------------


def _frag_db():
    db = {}
    db.update(make_db("small", step_by_prof={p: 0.01 for p in PROFILE_ORDER}))
    db.update(
        make_db("twog", fits_by_prof={"1g.5gb": False},
                step_by_prof={p: 0.01 for p in PROFILE_ORDER}, peak_frac=0.3)
    )
    return db


def test_planner_policy_beats_greedy_on_fragmented_device():
    results = {}
    for policy in ("static", "planner"):
        c = Cluster(_frag_db(), [("d0", CollocationMode.MIG)], policy=policy,
                    reconfig_cost_s=0.05)
        for i in range(5):
            c.submit(JobSpec(f"s{i}", "small", SUITE), 0.001 * i, epochs=3,
                     samples_per_epoch=320)
        c.submit(JobSpec("big", "twog", SUITE), 0.05, epochs=1,
                 samples_per_epoch=320)
        rep = c.run()
        assert rep.completed == 6
        big = next(j for j in rep.jobs if j["name"] == "big")
        results[policy] = (rep.goodput_steps_per_s, big["queueing_delay_s"])
    assert results["planner"][0] > results["static"][0]  # strictly better
    assert results["planner"][1] == pytest.approx(0.0)  # no strand at all
    assert results["static"][1] > 0.1


def test_replan_shuffles_without_evicting_and_charges_costs():
    """A fragmented residue (completions freed units 4 and 6) strands a 2g
    job; the committed re-partition moves exactly one 1g job, keeps the
    rest in place, charges rollback + downtime, and never evicts."""
    c = Cluster(_frag_db(), [("d0", CollocationMode.MIG)], policy="planner",
                reconfig_cost_s=0.01, migration_cooldown_s=0.001)
    for i in range(7):
        c.submit(JobSpec(f"s{i}", "small", SUITE), 0.001 * i,
                 epochs=1 if i < 2 else 5, samples_per_epoch=320)
    c.submit(JobSpec("big", "twog", SUITE), 0.15, epochs=1,
             samples_per_epoch=320)
    rep = c.run()
    assert rep.completed == 8 and rep.still_queued == 0
    assert rep.migrations == 1
    ev = rep.migration_events[0]
    assert ev["kind"] == "replan" and ev["optimality"] == "exact"
    assert set(ev["requeued"]) <= set(ev["placed"])  # shuffle, no eviction
    assert len(ev["kept"]) == 4 and len(ev["requeued"]) == 1
    assert "big" in ev["placed"]
    assert rep.reconfig_cost_s == pytest.approx(0.01)
    assert rep.lost_steps > 0  # the moved job rolled back to its checkpoint
    big = next(j for j in rep.jobs if j["name"] == "big")
    assert big["queueing_delay_s"] == pytest.approx(0.01)  # just the downtime


def test_update_progress_never_rewinds_a_future_bound_job():
    """A job bound during a re-partition carries last_update_s in the
    future; a neighbour's event inside the window must not rewind its
    progress or re-score the downtime as executed steps."""
    c = Cluster(_frag_db(), [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("a", "small", SUITE), 0.0, epochs=1,
             samples_per_epoch=320)
    c.run_until(0.0)  # placed
    dev = c.devices["d0"]
    cj = c.jobs["a"]
    cj.steps_done = 3.0
    cj.last_update_s = 1.0  # bound inside a reconfig window ending at 1.0
    c._update_progress(dev, 0.5)  # neighbour event mid-window
    assert cj.steps_done == 3.0  # no negative delta applied
    assert cj.last_update_s == 1.0  # binding not rewound


def test_planner_policy_without_pressure_never_replans():
    c = Cluster(_frag_db(), [("d0", CollocationMode.MIG)], policy="planner")
    for i in range(4):
        c.submit(JobSpec(f"s{i}", "small", SUITE), 0.01 * i, epochs=1,
                 samples_per_epoch=320)
    rep = c.run()
    assert rep.migrations == 0 and rep.completed == 4


# -- simulate-level acceptance ---------------------------------------------------


def test_simulate_planner_beats_greedy_on_fragmentation_and_never_loses():
    """The PR's acceptance criteria on the real traces (seed 0): strictly
    better goodput on fragmentation, never worse anywhere."""
    from repro.launch.simulate import SCENARIOS, run_all, summarize_cell

    cells = {
        (c["scenario"], c["policy"]): summarize_cell(c)
        for c in run_all(seed=0, n_jobs=40, n_devices=2,
                         policies=("all-mig", "planner"))
    }
    frag_g = cells[("fragmentation", "all-mig")]
    frag_p = cells[("fragmentation", "planner")]
    assert frag_p["goodput_steps_per_s"] > frag_g["goodput_steps_per_s"]
    assert (
        frag_p["mean_queueing_delay_s"] <= frag_g["mean_queueing_delay_s"]
    )
    for sc in SCENARIOS:
        g, p = cells[(sc, "all-mig")], cells[(sc, "planner")]
        assert p["goodput_steps_per_s"] >= g["goodput_steps_per_s"], sc
        assert p["completed"] == g["completed"], sc
        assert p["still_queued"] == 0 and p["rejected"] == g["rejected"], sc
