import sys
from pathlib import Path

# allow `pytest tests/` without PYTHONPATH=src
SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real device. Multi-device tests spawn
# subprocesses with their own XLA_FLAGS (tests/test_multidevice.py).
