"""Perf-regression smoke tests for the simulator event loop.

No absolute wall-clock asserts anywhere: the throughput check normalizes
events/sec by a synthetic heap-workload calibration run on the *same*
machine and compares that dimensionless ratio against the committed
baseline (benchmarks/sim_perf_baseline.json) with a generous factor, so CI
stays non-flaky across hardware. The complexity guard counts re-timing
*work* (the instrumented ``retime_jobs_repriced`` counter), which is
machine-independent by construction.
"""
import json
from pathlib import Path

import pytest

from benchmarks.sim_perf import (
    BASELINE_PATH,
    SMOKE_CELL,
    SimPerfCell,
    machine_calibration,
    run_perf_cell,
    strip_volatile,
)

#: How much slower than the committed normalized baseline we tolerate
#: before calling it a regression. The normalization cancels machine speed
#: to first order; the slack absorbs interpreter-version and load noise.
_SLOWDOWN_FACTOR = 4.0


def test_events_per_sec_within_relative_factor_of_baseline():
    baseline = json.loads(Path(BASELINE_PATH).read_text())
    assert baseline["cell"] == SMOKE_CELL.name  # stale-baseline guard
    calib = machine_calibration()
    row = run_perf_cell(SMOKE_CELL, seed=baseline["seed"])
    normalized = row["perf"]["events_per_s"] / calib
    floor = baseline["events_per_s_normalized"] / _SLOWDOWN_FACTOR
    assert normalized > floor, (
        f"simulator throughput regressed: {normalized:.6f} normalized "
        f"events/s vs committed baseline "
        f"{baseline['events_per_s_normalized']:.6f} "
        f"(floor {floor:.6f} = baseline/{_SLOWDOWN_FACTOR:.0f}); "
        f"re-baseline with benchmarks/sim_perf.py --write-baseline only "
        f"if the slowdown is intended"
    )


def test_retime_work_grows_subquadratically():
    """The O(.) guard on the incremental engine: doubling the job count
    must not quadruple re-pricing work (full re-timing of every
    co-resident on every event is the quadratic failure mode this PR
    removed). Counted work, not wall-clock — machine-independent."""
    small = run_perf_cell(
        SimPerfCell("oguard_small", "city_diurnal", "all-mps", 600, 8)
    )
    big = run_perf_cell(
        SimPerfCell("oguard_big", "city_diurnal", "all-mps", 1200, 8)
    )
    w_small = small["determinism"]["retime_jobs_repriced"]
    w_big = big["determinism"]["retime_jobs_repriced"]
    assert w_small > 0
    growth = w_big / w_small
    assert growth < 3.0, (
        f"re-timing work grew {growth:.2f}x for 2x jobs "
        f"({w_small} -> {w_big} jobs repriced) — super-linear blowup"
    )


def test_scoreboard_determinism_block_reproduces():
    """Two runs of the same cell agree on every non-volatile field — the
    per-cell analogue of CI's strip-volatile byte-compare of two full
    BENCH_simperf.json documents."""
    cell = SimPerfCell("det_check", "city_burst", "all-mig", 800, 4)
    a = run_perf_cell(cell)
    b = run_perf_cell(cell)
    doc_a = {"schema": "sim_perf/v1", "cells": [a]}
    doc_b = {"schema": "sim_perf/v1", "cells": [b]}
    assert strip_volatile(doc_a) == strip_volatile(doc_b)
    assert a["determinism"]["events_processed"] > 0
    assert a["determinism"]["peak_queue_depth"] >= 1
    # the volatile keys really are stripped (wall-clock never compared)
    assert "perf" not in strip_volatile(doc_a)["cells"][0]
