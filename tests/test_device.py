"""Device-model API: SKU registry + default-SKU compatibility shims,
per-SKU partition-tree enumeration invariants, memo-key isolation between
generations, and the hetero_sku simulation's determinism."""
import json

import pytest

from repro.core import profiles
from repro.core.collocation import CollocationScheduler
from repro.core.device import (
    DEFAULT_SKU,
    SKUS,
    DeviceSKU,
    InstanceProfile,
    Placement,
    format_gib,
    get_sku,
)
from repro.core.instance import JobSpec
from repro.core.planner.enumerator import enumerate_configs, maximal_configs
from repro.core.sharing import CollocationMode
from repro.telemetry.constants import HBM_PER_CHIP

ALL_SKUS = sorted(SKUS)

#: Pinned per-SKU partition-tree sizes: (valid layouts, maximal configs).
#: a100-40gb is the documented 296/18 (the A100's ~19 canonical configs
#: under our algebra); the others are this PR's reference counts — a
#: placement-tree edit that moves them should have to say so here.
TREE_SIZES = {
    "a100-40gb": (296, 18),
    "a100-80gb": (296, 18),
    "h100-80gb": (721, 77),
    "a30-24gb": (25, 5),
}


# -- registry + default-SKU shims ------------------------------------------------


def test_registry_has_the_four_generations():
    assert set(SKUS) == set(TREE_SIZES)
    assert get_sku(None) is DEFAULT_SKU is SKUS["a100-40gb"]
    assert get_sku("a30-24gb") is SKUS["a30-24gb"]
    assert get_sku(SKUS["h100-80gb"]) is SKUS["h100-80gb"]
    with pytest.raises(KeyError, match="a100-40gb"):  # lists the choices
        get_sku("v100-16gb")


def test_module_globals_alias_the_default_sku():
    assert profiles.PROFILES is DEFAULT_SKU.profiles_by_name
    assert profiles.N_UNITS == DEFAULT_SKU.n_units == 8
    assert profiles.N_COMPUTE_SLICES == DEFAULT_SKU.n_compute_slices == 7
    assert profiles.EXCLUSIONS == DEFAULT_SKU.exclusions
    assert DEFAULT_SKU.slice_bytes == HBM_PER_CHIP


def test_default_tree_is_byte_faithful_to_the_paper_table():
    """The pre-device-model literal table, pinned: the default SKU must
    reproduce the old module globals exactly."""
    want = {
        "1g.5gb": (1, 1, (0, 1, 2, 3, 4, 5, 6)),
        "2g.10gb": (2, 2, (0, 2, 4)),
        "3g.20gb": (3, 4, (0, 4)),
        "4g.20gb": (4, 4, (0,)),
        "7g.40gb": (7, 8, (0,)),
    }
    assert {
        p.name: (p.compute_slices, p.mem_units, p.starts)
        for p in DEFAULT_SKU.profiles
    } == want
    assert DEFAULT_SKU.profile_order == (
        "1g.5gb", "2g.10gb", "3g.20gb", "4g.20gb", "7g.40gb"
    )
    assert DEFAULT_SKU.full_profile == "7g.40gb"
    assert DEFAULT_SKU.exclusions == (frozenset({"4g.20gb", "3g.20gb"}),)


def test_placement_span_shim_and_per_sku_geometry():
    # the old Placement.span behaviour (default-SKU lookup) still works
    assert Placement("3g.20gb", 4).span == (4, 8)
    # a foreign profile name needs its owning SKU's geometry
    with pytest.raises(KeyError, match="a100-40gb"):
        Placement("2g.12gb", 2).span
    a30 = SKUS["a30-24gb"]
    assert a30.span(Placement("2g.12gb", 2)) == (2, 4)
    assert a30.units(Placement("4g.24gb", 0)) == frozenset(range(4))


def test_sku_constructor_rejects_malformed_trees():
    one = InstanceProfile("1g.1gb", 1, 1, (0,))
    with pytest.raises(ValueError, match="full profile must own"):
        DeviceSKU("bad", 2, 2, 1, profiles=(one,), full_profile="1g.1gb")
    with pytest.raises(ValueError, match="overflows"):
        DeviceSKU(
            "bad2", 2, 2, 1,
            profiles=(InstanceProfile("2g.2gb", 2, 2, (1,)),),
            full_profile="2g.2gb",
        )


# -- per-SKU layout algebra + enumeration invariants -----------------------------


@pytest.mark.parametrize("name", ALL_SKUS)
def test_full_profile_owns_the_device_and_homogeneous_layouts_validate(name):
    sku = SKUS[name]
    full = sku.profile(sku.full_profile)
    assert full.mem_units == sku.n_units
    for p in sku.profiles:
        layout = sku.homogeneous_layout(p.name)
        ok, why = sku.validate_layout(layout)
        assert ok, f"{name}/{p.name}: {why}"


@pytest.mark.parametrize("name", ALL_SKUS)
def test_enumeration_disjoint_budget_and_counts(name):
    sku = SKUS[name]
    configs = enumerate_configs(sku=sku)
    assert (len(configs), len(maximal_configs(sku=sku))) == TREE_SIZES[name]
    seen = set()
    for cfg in configs:
        key = tuple((pl.start, pl.profile) for pl in cfg)
        assert key not in seen, f"duplicate config {key}"
        seen.add(key)
        # disjoint spans (the partitioner's verify_disjoint invariant)
        spans = sorted(sku.span(pl) for pl in cfg)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1, f"{name}: overlap in {cfg}"
        # compute-slice budget
        used = sum(sku.profile(pl.profile).compute_slices for pl in cfg)
        assert used <= sku.n_compute_slices
        # exclusions honoured
        names = {pl.profile for pl in cfg}
        for bad in sku.exclusions:
            assert not bad <= names


@pytest.mark.parametrize("name", ALL_SKUS)
def test_enumeration_is_deterministic_and_memo_keyed_per_sku(name):
    sku = SKUS[name]
    first = enumerate_configs(sku=sku)
    assert enumerate_configs(sku=sku) is first  # memo hit
    # an equal-but-rebuilt descriptor hashes to the same memo entry
    clone = DeviceSKU(**{
        f.name: getattr(sku, f.name)
        for f in type(sku).__dataclass_fields__.values()
    })
    assert enumerate_configs(sku=clone) == first


def test_h100_1g20gb_is_why_its_tree_is_bigger():
    """The Hopper-only double-width 1g.20gb profile is what inflates the
    h100 tree past the a100-80gb's (same ladder otherwise)."""
    h100, a80 = SKUS["h100-80gb"], SKUS["a100-80gb"]
    only_h = {p.name for p in h100.profiles} - {p.name for p in a80.profiles}
    assert only_h == {"1g.20gb"}
    assert len(enumerate_configs(sku=h100)) > len(enumerate_configs(sku=a80))


# -- memo-key isolation between generations --------------------------------------


def _db(sku_name):
    from repro.launch.simulate import synthetic_char_db

    return synthetic_char_db(sku=sku_name)


def test_predict_step_and_solo_profile_caches_carry_the_sku():
    """Satellite: two SKUs can't cross-contaminate the scheduler's memo.

    a100-80gb and h100-80gb share profile *names* (2g.20gb, 7g.80gb), so
    without the SKU in the key a scheduler re-homed onto the other
    generation would serve the stale generation's step time bit-for-bit.
    """
    from repro.launch.simulate import SIM_SUITE

    job = JobSpec("j", "llama3-8b", SIM_SUITE)
    sched = CollocationScheduler(_db("a100-80gb"), sku="a100-80gb")
    step_a = sched.predict_step(job, "2g.20gb")
    solo_a = sched.solo_profile(job)
    # re-home onto the H100: same profile names, different silicon
    # (2x compute_scale, lower latency floor)
    sched.sku = get_sku("h100-80gb")
    sched.char_db = _db("h100-80gb")
    sched._cost_model = None
    step_h = sched.predict_step(job, "2g.20gb")
    solo_h = sched.solo_profile(job)
    assert step_h != step_a  # a stale cache hit would make these equal
    assert step_h < step_a  # the H100 is the faster part
    assert solo_h.latency_s != solo_a.latency_s
    # ...and coming home again still serves the original values
    sched.sku = get_sku("a100-80gb")
    sched.char_db = _db("a100-80gb")
    sched._cost_model = None
    assert sched.predict_step(job, "2g.20gb") == step_a


def test_foreign_min_profile_floor_does_not_bind_or_crash():
    """A straggler-repack floor names one generation's profile; retried on
    another generation's tree (mixed fleet) it must neither crash nor
    block placement."""
    from repro.launch.simulate import SIM_SUITE

    job = JobSpec("j", "granite-3-2b", SIM_SUITE, min_profile="2g.10gb")
    a30 = CollocationScheduler(_db("a30-24gb"), sku="a30-24gb")
    assert a30.smallest_admissible(job) == "1g.6gb"  # floor is foreign here
    default = CollocationScheduler(_db("a100-40gb"))
    assert default.smallest_admissible(job) == "2g.10gb"  # floor binds


def test_planning_cost_model_estimates_are_per_sku():
    from repro.core.planner import PlanningCostModel
    from repro.launch.simulate import SIM_SUITE

    job = JobSpec("j", "llama3-8b", SIM_SUITE)
    est_a = PlanningCostModel(_db("a100-80gb"), sku="a100-80gb").estimate(
        job, "2g.20gb"
    )
    est_h = PlanningCostModel(_db("h100-80gb"), sku="h100-80gb").estimate(
        job, "2g.20gb"
    )
    assert est_a.fits and est_h.fits
    assert est_h.step_s < est_a.step_s


# -- admission messages use the one GiB formatter --------------------------------


def test_admission_messages_quote_the_skus_actual_budget():
    from repro.launch.simulate import SIM_SUITE

    sched = CollocationScheduler(_db("a100-40gb"))
    big = JobSpec("big", "qwen2-72b", SIM_SUITE)
    ok, msg = sched.admissible(big, "1g.5gb")
    assert not ok
    assert f"> {format_gib(DEFAULT_SKU.slice_bytes)} GiB HBM" in msg
    # the serve session (halved working set) is admitted by the 80GB
    # generation's full slice — and only there
    from repro.core.workload import serve_workload
    from repro.launch.simulate import SERVE_SLO_S, SERVE_SUITE

    serve = serve_workload(
        "bigserve", "qwen2-72b", SERVE_SUITE,
        slo_step_s=SERVE_SLO_S["qwen2-72b"], prefill_steps=4,
    )
    sched80 = CollocationScheduler(_db("a100-80gb"), sku="a100-80gb")
    assert sched80.admissible(serve, "7g.80gb")[0]
    assert not sched80.admissible(serve, "3g.40gb")[0]
    assert not sched.admissible(serve, "7g.40gb")[0]
    # shared-mode aggregate rejection quotes the same formatter
    many = [JobSpec(f"m{i}", "resnet_large", SIM_SUITE) for i in range(4)]
    shared = sched.schedule(many, mode=CollocationMode.MPS)
    agg = [r for r in shared.rejections if "shared HBM" in r.reason]
    assert agg and f"> {format_gib(DEFAULT_SKU.slice_bytes)} GiB" in agg[0].reason


# -- the hetero_sku scenario ------------------------------------------------------


def test_hetero_cluster_routes_each_job_to_the_tree_that_fits():
    """The queue — not the operator — drains jobs onto whichever
    generation admits them; the big-memory serve session lands only on
    the 80GB device, and 40GB/24GB-only fleets reject it outright."""
    from repro.core.cluster import Cluster
    from repro.core.workload import serve_workload
    from repro.launch.simulate import (
        HETERO_FLEET_SKUS,
        SERVE_SLO_S,
        SERVE_SUITE,
        synthetic_sku_dbs,
    )

    def big_serve(name):
        return serve_workload(
            name, "qwen2-72b", SERVE_SUITE,
            slo_step_s=SERVE_SLO_S["qwen2-72b"], prefill_steps=4, priority=1,
        )

    dbs = synthetic_sku_dbs(HETERO_FLEET_SKUS)
    devices = [
        (f"d{i}", CollocationMode.MIG, HETERO_FLEET_SKUS[i]) for i in range(3)
    ]
    cl = Cluster(dbs, devices)
    cl.submit(big_serve("hx0"), 0.0)
    cl.tick()  # process the arrival
    placed_on = {
        d.sku.name for d in cl.devices.values() if "hx0" in d.assignments
    }
    assert placed_on == {"a100-80gb"}
    report = cl.run()
    assert report.completed == 1 and report.rejected == 0
    assert report.slo_attainment == 1.0  # isolated 80GB slice meets the SLO

    for lone in ("a100-40gb", "a30-24gb"):
        cl1 = Cluster(
            synthetic_sku_dbs((lone,)),
            [("d0", CollocationMode.MIG, lone)],
        )
        cl1.submit(big_serve("hx1"), 0.0)
        cl1.tick()
        assert cl1.rejected and "OOM" in cl1.rejected[0][1]


def test_hetero_sku_seed0_cells_are_byte_deterministic():
    """Satellite: the seed-0 hetero_sku simulation is reproducible to the
    byte — same dict, same JSON serialization, across two full runs."""
    from repro.launch.simulate import _rounded, run_cell

    kw = dict(seed=0, n_jobs=24, n_devices=3)
    a = run_cell("hetero_sku", "all-mig", **kw)
    b = run_cell("hetero_sku", "all-mig", **kw)
    ja = json.dumps(_rounded(a), indent=2, sort_keys=True)
    jb = json.dumps(_rounded(b), indent=2, sort_keys=True)
    assert ja == jb
    assert a["fleet_skus"] == ["a100-40gb", "a100-80gb", "a30-24gb"]
    assert a["report"]["rejected"] == 0
    assert a["report"]["completed"] == a["n_jobs"]
    # device rows of non-default generations carry their SKU
    dev_skus = {d.get("sku", "a100-40gb") for d in a["report"]["devices"]}
    assert dev_skus == set(a["fleet_skus"])


def test_reconfig_downtime_scales_with_the_device_generation():
    """The SKU's reconfig knob composes with the cluster's configured
    cost: an H100 re-partitions at 1.5/2.0 of the baseline downtime, the
    default SKU at exactly the configured cost (byte-compat)."""
    from repro.core.cluster import Cluster

    cl = Cluster(
        {"a100-40gb": _db("a100-40gb"), "h100-80gb": _db("h100-80gb")},
        [("d0", CollocationMode.MIG, "h100-80gb"),
         ("d1", CollocationMode.MIG, "a100-40gb")],
        reconfig_cost_s=2.0,
    )
    assert cl._device_reconfig_cost(cl.devices["d0"]) == 1.5
    assert cl._device_reconfig_cost(cl.devices["d1"]) == 2.0


def test_flat_measured_db_is_rejected_for_non_default_fleets():
    from repro.launch.simulate import run_cell, synthetic_char_db

    with pytest.raises(ValueError, match="flat characterization DB"):
        run_cell(
            "hetero_sku", "all-mig", seed=0, n_jobs=4, n_devices=3,
            char_db=synthetic_char_db(),
        )


def test_default_sku_cell_schema_is_unchanged():
    """The a100-40gb compatibility contract: default-SKU cells carry no
    new keys, so pre-device-model artifacts stay byte-identical."""
    from repro.launch.simulate import run_cell

    cell = run_cell("aligned_static", "all-mig", seed=0, n_jobs=4, n_devices=1)
    assert "sku" not in cell and "fleet_skus" not in cell
    assert all("sku" not in d for d in cell["report"]["devices"])
