"""GPipe pipeline stage + ring collective-matmul: validated against their
single-device / all-gather oracles on 8 placeholder devices (subprocess),
plus the edge shapes the gang comms model prices (core/gang/comms.py):
world_size 1 (a 1-ring is a no-op — zero links, zero overhead) and an odd
stage count (a 3-ring closes, so every stage boundary is a priced link)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_ring_matmuls_match_oracles():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro.runtime.compat import shard_map
        from repro.runtime.ring import ring_ag_matmul, ring_rs_matmul

        mesh = jax.make_mesh((4,), ("m",))
        B, d, f = 8, 16, 32  # f_local = f // 4
        x = jax.random.normal(jax.random.key(0), (B, d))
        w = jax.random.normal(jax.random.key(1), (d, f))

        def ag(xl, wl):
            return ring_ag_matmul(xl, wl, "m")

        y = shard_map(ag, mesh=mesh, in_specs=(P("m", None), P(None, "m")),
                      out_specs=P("m", None), check_vma=False)(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-5, atol=1e-5)

        # reduce flavour: x (B, f) sharded on batch, w (f, d) row-sharded
        x2 = jax.random.normal(jax.random.key(2), (B, f))
        w2 = jax.random.normal(jax.random.key(3), (f, d))

        def rs(xl, wl):
            return ring_rs_matmul(xl, wl, "m")

        y2 = shard_map(rs, mesh=mesh, in_specs=(P("m", None), P("m", None)),
                       out_specs=P("m", None), check_vma=False)(x2, w2)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(x2 @ w2),
                                   rtol=2e-5, atol=1e-5)
        print(json.dumps({"ok": True}))
    """)
    assert json.loads(out.strip().splitlines()[-1])["ok"]


def test_gpipe_pipeline_matches_plain_forward():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.registry import get_config
        from repro.models.model_api import build_model
        from repro.models import transformer as tfm
        from repro.runtime.pipeline import pipeline_forward
        from repro.sharding.plan import make_plan

        cfg = get_config("granite-3-2b").reduced(n_layers=4)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        plan = make_plan(cfg, None)
        M, mb, S = 3, 2, 16  # 3 microbatches of 2 sequences
        toks = jax.random.randint(jax.random.key(1), (M, mb, S), 0, cfg.vocab, jnp.int32)

        ref = tfm.forward(cfg, params, toks.reshape(M * mb, S), plan)
        mesh = jax.make_mesh((4,), ("stage",))
        got = pipeline_forward(cfg, params, toks, mesh)
        got = got.reshape(M * mb, S, -1)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32))))
        print(json.dumps({"err": err}))
    """, devices=4)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["err"] < 6e-2, r


def test_ring_matmuls_world_size_one_degenerate():
    """A 1-wide ring (gang world_size 1): one scan step, the ppermute is a
    self-loop, and both flavours reduce to a plain local matmul — the
    runtime-side mirror of comm_overhead_s() == 0 for a degree-1 axis."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro.runtime.compat import shard_map
        from repro.runtime.ring import ring_ag_matmul, ring_rs_matmul

        mesh = jax.make_mesh((1,), ("m",))
        B, d, f = 4, 8, 16
        x = jax.random.normal(jax.random.key(0), (B, d))
        w = jax.random.normal(jax.random.key(1), (d, f))
        y = shard_map(lambda xl, wl: ring_ag_matmul(xl, wl, "m"), mesh=mesh,
                      in_specs=(P("m", None), P(None, "m")),
                      out_specs=P("m", None), check_vma=False)(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-5, atol=1e-5)
        x2 = jax.random.normal(jax.random.key(2), (B, f))
        w2 = jax.random.normal(jax.random.key(3), (f, d))
        y2 = shard_map(lambda xl, wl: ring_rs_matmul(xl, wl, "m"), mesh=mesh,
                       in_specs=(P("m", None), P("m", None)),
                       out_specs=P("m", None), check_vma=False)(x2, w2)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(x2 @ w2),
                                   rtol=2e-5, atol=1e-5)
        print(json.dumps({"ok": True}))
    """, devices=1)
    assert json.loads(out.strip().splitlines()[-1])["ok"]


def test_gpipe_odd_stage_count_matches_plain_forward():
    """Three pipeline stages (odd ring — the wrap link is real, unlike the
    even 2-stage chain) over a 3-layer reduction: the GPipe schedule still
    reproduces the plain scanned forward."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.registry import get_config
        from repro.models.model_api import build_model
        from repro.models import transformer as tfm
        from repro.runtime.pipeline import pipeline_forward
        from repro.sharding.plan import make_plan

        cfg = get_config("granite-3-2b").reduced(n_layers=3)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        plan = make_plan(cfg, None)
        M, mb, S = 4, 2, 16
        toks = jax.random.randint(jax.random.key(1), (M, mb, S), 0, cfg.vocab, jnp.int32)

        ref = tfm.forward(cfg, params, toks.reshape(M * mb, S), plan)
        mesh = jax.make_mesh((3,), ("stage",))
        got = pipeline_forward(cfg, params, toks, mesh)
        got = got.reshape(M * mb, S, -1)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32))))
        print(json.dumps({"err": err}))
    """, devices=3)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["err"] < 6e-2, r


def test_edge_shapes_feed_matching_comms_bandwidth_terms():
    """The scheduling-side mirror of the two edge shapes above: the comms
    model prices a world_size-1 axis at exactly zero and a 3-stage
    pipeline ring over its three closed-ring links with (d-1)/d traffic
    scaling — the bandwidth terms the gang step time charges."""
    from repro.core.gang.comms import (
        AXIS_TRAFFIC, DEFAULT_LINK, comm_overhead_s, ring_links,
    )
    from repro.core.gang.parallelism import Parallelism, axis_rank_groups

    # world_size 1: no groups, no links, no overhead (matches the 1-ring)
    assert axis_rank_groups(Parallelism()) == {}
    assert ring_links([0]) == ()
    assert comm_overhead_s(Parallelism(), {0: "d0"}, 1e-3) == 0.0

    # odd pipeline: 3 stages close a ring — 3 links, 2/3 of the ring
    # all-reduce bytes, weighted by the pipeline axis traffic share
    pp3 = Parallelism(pipeline=3)
    (group,) = axis_rank_groups(pp3)["pipeline"]
    assert len(ring_links(group)) == 3
    colocated = comm_overhead_s(pp3, {0: "d0", 1: "d0", 2: "d0"}, 1e-3)
    assert colocated == pytest.approx(AXIS_TRAFFIC["pipeline"] * 1e-3 * (2 / 3))
    # scattering the odd ring prices every link at the cross rate + latency
    scattered = comm_overhead_s(pp3, {0: "d0", 1: "d1", 2: "d2"}, 1e-3)
    assert scattered == pytest.approx(
        colocated / DEFAULT_LINK.cross_bandwidth_frac
        + 3 * DEFAULT_LINK.cross_latency_s
    )
