"""Multi-device semantics, run in subprocesses with placeholder CPU devices
(XLA_FLAGS must be set before jax initializes, so these cannot run in the
main pytest process)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """The same train step on a 2x4 mesh and on one device must produce
    numerically close losses and parameters (GSPMD is semantics-preserving)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSuite
        from repro.models.model_api import build_model
        from repro.optim import adamw
        from repro.runtime import train_step as ts
        from repro.sharding.plan import make_plan
        from repro.data import synthetic

        cfg = get_config("granite-3-2b").reduced()
        suite = ShapeSuite("t", 32, 8, "train")
        model = build_model(cfg)
        opt = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic.batch_for(cfg, suite, seed=0).items()}

        # single-device reference
        plan0 = make_plan(cfg, None)
        step0 = jax.jit(ts.build_train_step(model, plan0, opt))
        st0 = ts.init_train_state(model, jax.random.key(0), opt)
        st0, m0 = step0(st0, batch)
        st0, m0b = step0(st0, batch)

        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        jitted, st_sh, b_sh, plan = ts.jit_train_step(model, mesh, suite, opt)
        st = ts.init_train_state(model, jax.random.key(0), opt)
        st = jax.device_put(st, st_sh)
        b = jax.device_put(batch, b_sh)
        st, m1 = jitted(st, b)
        st, m1b = jitted(st, b)

        print(json.dumps({
            "loss0": float(m0["loss"]), "loss1": float(m1["loss"]),
            "loss0b": float(m0b["loss"]), "loss1b": float(m1b["loss"]),
        }))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert abs(r["loss0"] - r["loss1"]) < 3e-2, r
    assert abs(r["loss0b"] - r["loss1b"]) < 3e-2, r


def test_partitioner_carves_disjoint_contiguous_instances():
    out = run_sub("""
        import jax, json
        from repro.core.partitioner import device_grid, partition_homogeneous, verify_disjoint
        grid = device_grid(rows=8)  # 8x1 grid, 1 row per slice unit
        insts = partition_homogeneous(grid, "2g.10gb")
        verify_disjoint(insts)
        ids = [[int(d.id) for d in i.mesh.devices.flat] for i in insts]
        print(json.dumps(ids))
    """)
    ids = json.loads(out.strip().splitlines()[-1])
    assert len(ids) == 3  # 3x 2g.10gb
    flat = [d for grp in ids for d in grp]
    assert len(flat) == len(set(flat))
    for grp in ids:
        assert grp == sorted(grp) and grp[-1] - grp[0] == len(grp) - 1, (
            "instance not a contiguous block"
        )


def test_collectives_stay_inside_instance():
    """V2 isolation: a job compiled on one instance emits no collective that
    addresses devices outside the instance."""
    out = run_sub("""
        import jax, jax.numpy as jnp, json
        from repro.core.partitioner import device_grid, partition_homogeneous
        from repro.core.interference import check_collective_containment
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSuite
        from repro.models.model_api import build_model
        from repro.optim import adamw
        from repro.runtime import train_step as ts

        grid = device_grid(rows=8)
        insts = partition_homogeneous(grid, "2g.10gb")
        inst = insts[1]  # middle instance: devices 2,3
        cfg = get_config("granite-3-2b").reduced()
        suite = ShapeSuite("t", 32, 4, "train")
        model = build_model(cfg)
        jitted, st_sh, b_sh, plan = ts.jit_train_step(
            model, inst.mesh, suite, adamw.AdamWConfig())
        state_shape = jax.eval_shape(
            lambda k: ts.init_train_state(model, k, adamw.AdamWConfig()),
            jax.random.key(0))
        lowered = jitted.lower(state_shape, model.input_specs(suite))
        hlo = lowered.compile().as_text()
        ok, why = check_collective_containment(
            hlo, [d.id for d in inst.mesh.devices.flat], inst.n_chips)
        print(json.dumps({"ok": ok, "why": why}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["ok"], r["why"]


def test_live_collocated_training_no_interference():
    """Two models really training in parallel on disjoint 4-device instances
    produce exactly the same losses as the same jobs run alone (F3, live)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, json, threading
        from repro.core.partitioner import device_grid, partition
        from repro.core.profiles import Placement
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSuite
        from repro.models.model_api import build_model
        from repro.optim import adamw
        from repro.runtime import train_step as ts
        from repro.data import synthetic

        grid = device_grid(rows=8)
        insts = partition(grid, [Placement("3g.20gb", 0), Placement("3g.20gb", 4)])
        cfg = get_config("granite-3-2b").reduced()
        suite = ShapeSuite("t", 32, 4, "train")
        opt = adamw.AdamWConfig(warmup_steps=1, total_steps=20)

        def run_job(inst, seed, steps, out):
            model = build_model(cfg)
            jitted, st_sh, b_sh, plan = ts.jit_train_step(model, inst.mesh, suite, opt)
            st = jax.device_put(ts.init_train_state(model, jax.random.key(seed), opt), st_sh)
            losses = []
            for i in range(steps):
                batch = {k: jnp.asarray(v) for k, v in
                         synthetic.batch_for(cfg, suite, seed=seed, step=i).items()}
                batch = jax.device_put(batch, b_sh)
                st, m = jitted(st, batch)
                losses.append(float(m["loss"]))
            out[seed] = losses

        solo = {}
        run_job(insts[0], 1, 4, solo)
        run_job(insts[1], 2, 4, solo)

        par = {}
        t1 = threading.Thread(target=run_job, args=(insts[0], 1, 4, par))
        t2 = threading.Thread(target=run_job, args=(insts[1], 2, 4, par))
        t1.start(); t2.start(); t1.join(); t2.join()
        print(json.dumps({"solo": solo, "par": par}))
    """, devices=8)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["solo"]["1"] == r["par"]["1"], "job 1 diverged under collocation"
    assert r["solo"]["2"] == r["par"]["2"], "job 2 diverged under collocation"
