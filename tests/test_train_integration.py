"""End-to-end train-loop integration: loss goes down, resume is exact."""
import argparse
import json

import numpy as np
import pytest

from repro.launch.train import build_argparser, run


def _args(**overrides):
    base = dict(
        arch="granite-3-2b", reduced=True, steps=20, batch=4, seq=32,
        grad_accum=1, lr=1e-3, warmup=5, seed=0, workers=2, max_queue_size=4,
        ckpt_dir="", ckpt_every=50, log_every=100, mesh="none", metrics_out="",
        total_steps=20,  # pin the LR schedule across interrupted runs
    )
    base.update(overrides)
    return argparse.Namespace(**base)


def test_loss_decreases_over_training():
    # Compare 5-step window means, not single steps: per-batch losses on the
    # stochastic synthetic stream are noisy enough that first-vs-last single
    # steps flip sign across seeds (seed 0 happened to rise 5.840 -> 5.868
    # while seed 1 fell 5.948 -> 5.781 over the same 30 steps).
    r = run(_args(steps=30))
    assert r["tail_mean_loss"] < r["head_mean_loss"], r
    assert np.isfinite(r["final_loss"])


def test_resume_is_bit_identical_to_uninterrupted(tmp_path):
    """A run interrupted at step 10 and resumed must reach the same final
    loss as an uninterrupted run — data stream + optimizer are deterministic."""
    full = run(_args(steps=20, ckpt_dir=str(tmp_path / "full"), ckpt_every=100))

    part1 = run(_args(steps=10, ckpt_dir=str(tmp_path / "resume"), ckpt_every=10))
    part2 = run(_args(steps=20, ckpt_dir=str(tmp_path / "resume"), ckpt_every=100))
    assert part2["steps"] == 10  # resumed from 10
    np.testing.assert_allclose(part2["final_loss"], full["final_loss"], rtol=1e-5)


def test_grad_accum_matches_full_batch():
    """grad_accum=2 over batch 8 == one step over the same batch 8 (same
    data), up to f32 accumulation order."""
    a = run(_args(steps=5, batch=8, grad_accum=1, seed=3))
    b = run(_args(steps=5, batch=8, grad_accum=2, seed=3))
    np.testing.assert_allclose(a["final_loss"], b["final_loss"], rtol=2e-3)
