"""Unit tests for the module substrate + sharding plan rules."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models import module as nn


def test_rmsnorm_matches_manual():
    x = jax.random.normal(jax.random.key(0), (2, 5, 8))
    p = nn.rmsnorm_init(8)
    got = nn.rmsnorm_apply(p, x)
    want = x / np.sqrt(np.mean(np.square(np.asarray(x)), -1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_layernorm_zero_mean_unit_var():
    x = jax.random.normal(jax.random.key(1), (4, 16)) * 5 + 3
    p = nn.layernorm_init(16)
    y = np.asarray(nn.layernorm_apply(p, x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(-1), 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.key(2), (1, 6, 2, 16))
    pos = jnp.arange(6)
    y = nn.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(3), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(4), (1, 1, 1, 16))
    def dot(i, j):
        qi = nn.apply_rope(q, jnp.array([i]))
        kj = nn.apply_rope(k, jnp.array([j]))
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot(3, 1), dot(7, 5), rtol=1e-4)


def test_scan_layers_equals_python_loop():
    def layer_init(key):
        return {"w": jax.random.normal(key, (8, 8)) * 0.1}

    stacked = nn.stack_layer_init(layer_init, jax.random.key(0), 5)
    x = jax.random.normal(jax.random.key(1), (2, 8))

    def body(c, lp):
        return jnp.tanh(c @ lp["w"])

    got = nn.scan_layers(body, x, stacked)
    want = x
    for i in range(5):
        want = jnp.tanh(want @ stacked["w"][i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    # remat path identical
    got_r = nn.scan_layers(body, x, stacked, remat=True)
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(got), rtol=1e-6)


def test_mask_pad_logits():
    from repro.configs.registry import get_config
    from repro.models.transformer import mask_pad_logits

    cfg = get_config("granite-3-2b")  # vocab 49155 -> padded 49168
    assert cfg.padded_vocab % 16 == 0 and cfg.padded_vocab >= cfg.vocab
    logits = jnp.zeros((1, cfg.padded_vocab))
    masked = mask_pad_logits(cfg, logits)
    assert float(masked[0, cfg.vocab - 1]) == 0.0
    assert float(masked[0, cfg.vocab]) < -1e29
    p = jax.nn.softmax(masked, -1)
    np.testing.assert_allclose(float(jnp.sum(p[0, cfg.vocab:])), 0.0, atol=1e-12)


@given(st.integers(1, 16), st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_param_pspec_axes_divide_or_replicate(rows_mult, cols_mult):
    """validate_pspecs never assigns an axis that does not divide the dim."""
    import jax
    from jax.sharding import Mesh
    from repro.sharding.plan import param_pspecs, validate_pspecs

    params = {
        "wq": jnp.zeros((rows_mult * 3, cols_mult * 5)),
        "table": jnp.zeros((rows_mult * 7, cols_mult * 2)),
        "scale": jnp.zeros((rows_mult,)),
    }
    devs = np.array(jax.devices() * 1, dtype=object)  # 1 device, shape (1,1)
    mesh = Mesh(devs.reshape(1, 1), ("data", "model"))
    specs = validate_pspecs(params, param_pspecs(params), mesh)
    for leaf, spec in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    ):
        pass  # structure check only: validate_pspecs ran without error


def test_fit_spec_drops_nondividing_axes():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.runtime.serve_step import _fit_spec

    devs = np.array(jax.devices() * 1, dtype=object).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))  # sizes 1,1 always divide

    spec = _fit_spec(P("data", "model"), (3, 5), mesh)
    assert tuple(spec) == ("data", "model")  # size-1 axes always fit
    # longer spec than rank is trimmed
    spec = _fit_spec(P("data", None, "model"), (4, 2), mesh)
    assert len(spec) == 2


def test_losses_cross_entropy_uniform():
    from repro.models import losses

    V = 16
    logits = jnp.zeros((2, 3, V))
    labels = jnp.zeros((2, 3), jnp.int32)
    loss, metrics = losses.softmax_cross_entropy(logits, labels)
    # total includes the z-loss regularizer; pure CE is in metrics["ce"]
    np.testing.assert_allclose(float(metrics["ce"]), np.log(V), rtol=1e-5)
    np.testing.assert_allclose(
        float(loss), np.log(V) + 1e-4 * np.log(V) ** 2, rtol=1e-5
    )
