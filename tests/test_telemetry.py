"""Telemetry unit tests: HLO collective parser + roofline algebra."""
import jax.numpy as jnp

from repro.telemetry import constants as C
from repro.telemetry.hlo import (
    CollectiveOp,
    collective_summary,
    computation_multipliers,
    shape_bytes,
)
from repro.telemetry.roofline import RooflineReport

HLO = """\
HloModule jit_step

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %x = f32[128,256] get-tuple-element(%p), index=1
  %ag = f32[128,256] all-gather(%x), replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar = f32[128,256] all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%x, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (arg: f32[128,256]) -> f32[128,256] {
  %arg = f32[128,256] parameter(0)
  %w = (s32[], f32[128,256]) while(%arg), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"40"}}
  %rs = f32[8,256] reduce-scatter(%arg), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}, to_apply=%add
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], bf16[8])") == 16 + 16


def test_while_trip_count_multiplies_collectives():
    mults = computation_multipliers(HLO)
    assert mults.get("body", mults.get("%body")) == 40
    assert mults.get("cond") == 41  # trip_count + 1 evaluations
    assert mults.get("add") == 40  # reached through the loop body


def test_collective_summary_counts_and_ring_costs():
    s = collective_summary(HLO)
    kinds = s["by_kind"]
    # all-gather + all-reduce execute 40x inside the while loop
    assert kinds["all-gather"]["count"] == 40
    assert kinds["all-reduce"]["count"] == 40
    assert kinds["reduce-scatter"]["count"] == 1
    bytes_x = 128 * 256 * 4
    # ring all-reduce: 2 * R * (n-1)/n per device
    assert abs(kinds["all-reduce"]["wire_bytes"] - 40 * 2 * bytes_x * 3 / 4) < 1
    # all-gather of result R over 16: R * 15/16
    assert abs(kinds["all-gather"]["wire_bytes"] - 40 * bytes_x * 15 / 16) < 1
    # reduce-scatter: shard result R -> input n*R, wire R*(n-1)
    assert abs(kinds["reduce-scatter"]["wire_bytes"] - (8 * 256 * 4) * 15) < 1


HLO_DOT = """\
HloModule jit_f

%body (p: (s32[], f32[8,16], f32[16,32])) -> (s32[], f32[8,16], f32[16,32]) {
  %p = (s32[], f32[8,16], f32[16,32]) parameter(0)
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,32] get-tuple-element(%p), index=2
  %d = f32[8,32] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,16], f32[16,32]) tuple(%x, %w)
}

%cond (p: (s32[], f32[8,16], f32[16,32])) -> pred[] {
  %p = (s32[], f32[8,16], f32[16,32]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,16], b: f32[16,32]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,32] parameter(1)
  %w = (s32[], f32[8,16], f32[16,32]) while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""


def test_hlo_flops_multiplied_by_trip_count():
    from repro.telemetry.hlo import hlo_flops_bytes

    est = hlo_flops_bytes(HLO_DOT)
    # one dot of 2*8*32*16 flops, executed 12x by the while loop
    assert est["flops"] == 12 * 2 * 8 * 32 * 16
    # bytes include the dot's operands+result (x12) and entry parameters once
    dot_bytes = (8 * 16 + 16 * 32 + 8 * 32) * 4
    params = (8 * 16 + 16 * 32) * 4
    assert est["bytes"] == 12 * dot_bytes + params


def test_roofline_bound_selection():
    r = RooflineReport(
        arch="x", shape="y", mesh="16x16", chips=256,
        flops_per_device=C.PEAK_FLOPS_BF16,          # 1 s of compute
        hbm_bytes_per_device=C.HBM_BW / 2,           # 0.5 s of memory
        wire_bytes_per_device=C.ICI_LINK_BW / 4,     # 0.25 s of collective
        model_flops_global=C.PEAK_FLOPS_BF16 * 256,  # perfectly useful
        peak_mem_bytes_per_device=1.0,
    )
    assert r.bound == "compute"
    assert abs(r.step_s - 1.0) < 1e-9
    assert abs(r.mfu - 1.0) < 1e-9
    assert abs(r.frac_of_roofline - 1.0) < 1e-9
