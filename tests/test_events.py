"""Property tests for core/events.py: the (time, seq) tie-break the whole
determinism contract hangs on, tombstone (lazy-deletion) behavior, the
compaction bound that fixes the stale-event heap leak, and heap-order
invariance across push orders."""
import random

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.events import Event, EventKind, EventQueue


def _drain(q: EventQueue):
    out = []
    while q:
        out.append(q.pop())
    return out


# -- (time, seq) tie-break determinism ---------------------------------------------


def test_equal_time_events_pop_in_push_order():
    q = EventQueue()
    pushed = [q.push(1.0, EventKind.ARRIVAL, (f"j{i}",)) for i in range(50)]
    assert [ev.payload for ev in _drain(q)] == [ev.payload for ev in pushed]


def test_tie_break_holds_under_interleaved_times():
    q = EventQueue()
    # two same-timestamp batches interleaved with other times: each batch
    # must still come out in its own push order
    q.push(2.0, EventKind.ARRIVAL, ("late0",))
    a = [q.push(1.0, EventKind.ARRIVAL, (f"a{i}",)) for i in range(5)]
    q.push(0.5, EventKind.ARRIVAL, ("early",))
    b = [q.push(1.0, EventKind.COMPLETION, (f"b{i}",)) for i in range(5)]
    order = [ev.payload[0] for ev in _drain(q)]
    assert order[0] == "early"
    assert order[-1] == "late0"
    batch = order[1:-1]
    assert batch == [f"a{i}" for i in range(5)] + [f"b{i}" for i in range(5)]


def test_heap_order_invariant_across_push_orders():
    """Any arrival order of the same timestamps drains time-sorted, with
    push order breaking ties — the sort key is total, so the drained
    sequence is a pure function of the push sequence."""
    times = [3.0, 1.0, 1.0, 2.0, 0.0, 2.0, 1.0, 5.0, 0.0]
    for trial in range(10):
        rng = random.Random(trial)
        shuffled = times[:]
        rng.shuffle(shuffled)
        q = EventQueue()
        for i, t in enumerate(shuffled):
            q.push(t, EventKind.ARRIVAL, (i,))
        drained = _drain(q)
        assert [e.time_s for e in drained] == sorted(shuffled)
        assert drained == sorted(drained, key=Event.sort_key)
        # ties resolved by seq == push order
        for x, y in zip(drained, drained[1:]):
            if x.time_s == y.time_s:
                assert x.seq < y.seq


# -- tombstones ---------------------------------------------------------------------


def test_tombstoned_event_never_pops_and_len_counts_live():
    q = EventQueue()
    keep = q.push(1.0, EventKind.ARRIVAL, ("keep",))
    dead = q.push(0.5, EventKind.COMPLETION, ("dead",))
    assert len(q) == 2
    assert q.tombstone(dead) is True
    assert q.tombstone(dead) is False  # idempotent, reported
    assert len(q) == 1 and bool(q)
    assert q.peek_time() == 1.0  # skims the tombstoned head
    assert q.pop() is keep
    assert not q
    with pytest.raises(IndexError):
        q.pop()


def test_max_time_pushed_includes_tombstoned():
    """The horizon the report compensates with: the old eager-pop loop
    advanced the clock over stale events too, so the latest time ever
    pushed must survive the event's death."""
    q = EventQueue()
    assert q.max_time_pushed == float("-inf")
    far = q.push(99.0, EventKind.COMPLETION, ("far",))
    q.push(1.0, EventKind.ARRIVAL, ("near",))
    q.tombstone(far)
    _drain(q)
    assert q.max_time_pushed == 99.0


def test_compaction_bounds_heap_at_twice_live():
    """The leak fix: a re-timing-heavy pattern (push + tombstone + replace,
    never popping) must not grow the heap unboundedly."""
    q = EventQueue()
    live = [q.push(float(i), EventKind.COMPLETION, (i,)) for i in range(64)]
    for round_ in range(200):
        for i in range(64):
            q.tombstone(live[i])
            live[i] = q.push(float(i) + round_ + 1, EventKind.COMPLETION, (i,))
    assert q.compactions > 0
    assert len(q) == 64
    # physical heap stays O(live): the half-full threshold caps dead weight
    assert len(q._heap) <= 2 * 64 + 1
    assert sorted(ev.payload[0] for ev in _drain(q)) == list(range(64))


def test_cluster_run_compacts_the_heap():
    """End-to-end pin: a phase-heavy cell actually exercises the tombstone
    threshold (every re-timing invalidates each neighbour's pending
    event), so compactions must occur during a plain simulation run."""
    from repro.launch.simulate import run_cell  # noqa: F401  (db plumbing)
    from repro.launch.simulate import SIM_SAMPLES_PER_EPOCH, make_fleet, make_trace, synthetic_sku_dbs
    from repro.core.cluster import Cluster

    devices, policy = make_fleet("all-mps", 4)
    cluster = Cluster(synthetic_sku_dbs(("a100-40gb",)), devices, policy=policy,
                      reconfig_cost_s=0.5, migration_cooldown_s=1.0)
    for arrival_s, spec, epochs in make_trace("train_serve_mix", 0, 60, 4):
        cluster.submit(spec, arrival_s, epochs=epochs,
                       samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    cluster.run()
    assert cluster.events.compactions > 0


# -- hypothesis: random op sequences against a reference model ---------------------

_OPS = st.lists(
    st.tuples(
        st.sampled_from(["push", "tombstone", "pop"]),
        st.floats(min_value=0.0, max_value=100.0,
                  allow_nan=False, allow_infinity=False),
    ),
    min_size=1,
    max_size=200,
)


@settings(max_examples=50, deadline=None)
@given(ops=_OPS)
def test_queue_matches_reference_model(ops):
    """Drive EventQueue with a random push/tombstone/pop sequence and
    compare against a brute-force model (a list re-sorted on every op):
    identical pop results, identical live counts, and a bounded heap."""
    q = EventQueue()
    model = []  # list of Event, the live set
    pending = []  # tombstone candidates (still-queued events)
    for op, t in ops:
        if op == "push":
            ev = q.push(t, EventKind.ARRIVAL, ())
            model.append(ev)
            pending.append(ev)
        elif op == "tombstone" and pending:
            ev = pending.pop(len(pending) // 2)
            assert q.tombstone(ev) is True
            model.remove(ev)
            # the tombstone threshold caps dead weight at the half-full
            # mark, so right after any tombstone call the physical heap is
            # O(live) (pops of live events can thin the heap below the
            # mark without re-triggering it, so the bound is only asserted
            # where it is enforced)
            assert len(q._tombstoned) * 2 <= len(q._heap)
        elif op == "pop" and model:
            expect = min(model, key=Event.sort_key)
            got = q.pop()
            assert got is expect
            model.remove(got)
            if got in pending:
                pending.remove(got)
        assert len(q) == len(model)
        assert bool(q) == bool(model)
    drained = _drain(q)
    assert drained == sorted(model, key=Event.sort_key)
