"""Calibration subsystem (core/calib/): records, harness, fit, online.

Pins the ISSUE-10 contracts: char-DB round-trip serialization with
provenance preserved, merge keeping the stronger provenance, stub-backend
byte-determinism (two runs identical), the calibrated-beats-seed error
reduction on every SKU, the trace-doc consumption path, the online EWMA
tightening predictions through a real Cluster run, and calibration-free
cells staying byte-identical (calibrator is opt-in).
"""
import json

import pytest

from repro.core.calib import (
    CharDB,
    CharRecord,
    OnlineCalibrator,
    StubBackend,
    calibration_report,
    fit_from_error_doc,
    fit_residuals,
    miso_probe_keys,
    refine_db,
    run_calibration,
    seed_provenance,
    step_error_doc,
    step_error_rows,
    with_profile_interpolation,
)
from repro.core.device import SKUS, get_sku
from repro.launch.simulate import synthetic_char_db


def _rec(arch="a", shape="sim", profile="1g.5gb", **kw):
    base = dict(
        arch=arch, shape=shape, profile=profile, step_s=1.0, compute_s=0.9,
        memory_s=0.3, collective_s=0.1, peak_bytes_per_device=1e9, fits=True,
    )
    base.update(kw)
    return CharRecord(**base)


# -- records: round-trip + provenance ---------------------------------------


def test_chardb_json_round_trip_preserves_everything():
    db = CharDB("a100-40gb", seed=7)
    db.add(_rec(provenance="measured", source="stub", n_samples=3))
    db.add(_rec(profile="7g.40gb", provenance="refined", source="fit"))
    again = CharDB.loads(db.dumps())
    assert again == db
    assert again.seed == 7
    assert again.records[("a", "sim", "1g.5gb")].provenance == "measured"
    assert again.records[("a", "sim", "1g.5gb")].n_samples == 3


def test_plain_db_round_trip_and_extrapolated_default():
    # a bare hand-seeded dict loads as extrapolated — the tentpole's pin
    plain = {("a", "sim", "1g.5gb"): {"fits": True, "step_s": 1.0,
                                      "compute_s": 0.9, "memory_s": 0.3,
                                      "collective_s": 0.1,
                                      "peak_bytes_per_device": 1e9}}
    db = CharDB.from_plain_db(plain, sku="a100-40gb")
    rec = db.records[("a", "sim", "1g.5gb")]
    assert rec.provenance == "extrapolated"
    out = db.to_plain_db()[("a", "sim", "1g.5gb")]
    # scheduler-facing keys survive; provenance rides along inertly
    for key in plain[("a", "sim", "1g.5gb")]:
        assert out[key] == plain[("a", "sim", "1g.5gb")][key]
    assert out["provenance"] == "extrapolated"


def test_seed_catalog_carries_per_sku_provenance():
    # satellite: h100/a30 entries are visibly extrapolated; only the
    # paper's device is measured
    for sku, expected in (("a100-40gb", "measured"),
                          ("h100-80gb", "extrapolated"),
                          ("a30-24gb", "extrapolated")):
        assert seed_provenance(sku) == expected
        db = synthetic_char_db(sku=sku)
        assert all(rec["provenance"] == expected for rec in db.values())


def test_unknown_provenance_rejected():
    with pytest.raises(ValueError):
        _rec(provenance="vibes")
    with pytest.raises(ValueError):
        CharDB.from_doc({"schema": "something/v9", "sku": "x", "records": []})


def test_merge_keeps_stronger_provenance():
    db = CharDB("a100-40gb")
    db.add(_rec(provenance="measured", step_s=1.0, n_samples=3))
    # weaker incoming record must not clobber the measurement
    changed = db.merge([_rec(provenance="refined", step_s=9.9)])
    assert changed == 0
    assert db.records[("a", "sim", "1g.5gb")].step_s == 1.0
    # stronger incoming record upgrades
    changed = db.merge([_rec(provenance="measured", step_s=2.0, n_samples=5)])
    assert changed == 1
    assert db.records[("a", "sim", "1g.5gb")].step_s == 2.0


# -- harness: stub backend + calibration loop --------------------------------


def test_stub_backend_byte_determinism():
    # two full passes, two separate backends, same seed -> identical JSON
    def one_pass():
        db = synthetic_char_db()
        backend = StubBackend(db, seed=3)
        return run_calibration(db, backend, seed=3).calibrated.dumps()

    assert one_pass() == one_pass()


def test_stub_backend_seed_changes_truth():
    db = synthetic_char_db()
    key = next(iter(sorted(db)))
    t0 = StubBackend(db, seed=0).true_step_s(key)
    t1 = StubBackend(db, seed=1).true_step_s(key)
    assert t0 != t1


@pytest.mark.parametrize("sku_name", sorted(SKUS))
def test_calibrated_beats_seed_on_every_sku(sku_name):
    # the acceptance inequality: strictly lower mean |rel err| than the
    # hand-seeded catalog against the stub's ground truth
    dev = get_sku(sku_name)
    db = synthetic_char_db(sku=dev)
    backend = StubBackend(db, sku=dev, seed=0)
    result = run_calibration(db, backend, sku=dev, seed=0)
    score = calibration_report(result, backend.true_step_s)
    assert score["calibrated_mean_abs_rel_err"] < score["seed_mean_abs_rel_err"]
    # and not marginally: the fit removes the systematic bias
    assert score["error_reduction"] > 0.5
    # measurements landed with measured provenance at the probe keys
    prov = score["provenance"]
    assert prov.get("measured", 0) == len(miso_probe_keys(db, dev))


def test_probe_plan_is_full_plus_smallest():
    dev = get_sku("a100-40gb")
    db = synthetic_char_db(sku=dev)
    keys = miso_probe_keys(db, dev)
    profiles = {k[2] for k in keys}
    assert profiles == {dev.profile_order[0], dev.full_profile}
    archs = {k[0] for k in keys}
    assert len(keys) == 2 * len(archs)


def test_refine_never_overwrites_backend_measurements():
    db = CharDB("a100-40gb")
    db.add(_rec(provenance="measured", step_s=1.0, n_samples=3))
    db.add(_rec(profile="7g.40gb", provenance="extrapolated", step_s=2.0))
    fit = fit_residuals([("a", "1g.5gb", 1.5, 1.0),
                         ("a", "7g.40gb", 3.0, 2.0)], sku="a100-40gb")
    out = refine_db(db, fit)
    assert out.records[("a", "sim", "1g.5gb")].step_s == 1.0  # untouched
    assert out.records[("a", "sim", "1g.5gb")].provenance == "measured"
    assert out.records[("a", "sim", "7g.40gb")].provenance == "refined"


# -- fit: residuals, interpolation, trace-doc consumption --------------------


def test_fit_recovers_systematic_scale():
    pairs = [("m1", "1g.5gb", 1.3, 1.0), ("m1", "7g.40gb", 1.3, 1.0),
             ("m2", "1g.5gb", 2.6, 2.0), ("m2", "7g.40gb", 2.6, 2.0)]
    fit = fit_residuals(pairs, sku="a100-40gb")
    assert fit.correction("m1", "1g.5gb") == pytest.approx(1.3)
    assert fit.correction("m2", "7g.40gb") == pytest.approx(1.3)
    assert fit.correction("unseen-arch", "unseen-prof") == 1.0


def test_profile_interpolation_fills_between_endpoints():
    fit = fit_residuals(
        [("m", "1g.5gb", 1.2, 1.0), ("m", "7g.40gb", 1.0, 1.0)],
        sku="a100-40gb",
    )
    fracs = {"1g.5gb": 1 / 8, "2g.10gb": 2 / 8, "3g.20gb": 4 / 8,
             "7g.40gb": 1.0}
    filled = with_profile_interpolation(fit, fracs)
    c1, c2, c3, c7 = (filled.correction("m", p) for p in
                      ("1g.5gb", "2g.10gb", "3g.20gb", "7g.40gb"))
    # measured endpoints reproduce exactly (the arch scale and the profile
    # residual compose back to the observed ratio), interpolated profiles
    # land strictly between and monotone along the slice fraction
    assert c1 == pytest.approx(1.2) and c7 == pytest.approx(1.0)
    assert c1 > c2 > c3 > c7


def test_step_error_doc_round_trip_feeds_fit():
    # the report's machine-readable table is exactly what the harness fits
    # from (satellite: no re-derived aggregation)
    samples = [
        {"arch": "m", "profile": "1g.5gb", "measured_s": 1.2, "predicted_s": 1.0},
        {"arch": "m", "profile": "1g.5gb", "measured_s": 1.2, "predicted_s": 1.0},
        {"arch": "m", "profile": "7g.40gb", "measured_s": 0.9, "predicted_s": 1.0},
    ]
    rows = step_error_rows(samples)
    assert [r["n"] for r in rows] == [2, 1]
    doc = json.loads(json.dumps(step_error_doc(samples, meta={"seed": 0})))
    fit = fit_from_error_doc(doc, sku="a100-40gb")
    assert fit.correction("m", "1g.5gb") == pytest.approx(1.2)
    assert fit.correction("m", "7g.40gb") == pytest.approx(0.9)
    with pytest.raises(ValueError):
        fit_from_error_doc({"schema": "nope", "rows": []}, sku="a100-40gb")


# -- online: EWMA refinement ------------------------------------------------


def test_online_calibrator_converges_and_is_deterministic():
    def run():
        c = OnlineCalibrator()
        errs = []
        base, true = 1.0, 1.4  # seed underpredicts by 40%
        for t in range(40):
            pred = c.correct(base, sku="s", arch="m", profile="p")
            errs.append(abs(pred - true) / true)
            c.observe(sku="s", arch="m", profile="p",
                      measured_s=true, predicted_s=pred, t_s=float(t))
        return errs, c.snapshot()

    errs1, snap1 = run()
    errs2, snap2 = run()
    assert errs1 == errs2 and snap1 == snap2  # pure fold, no clocks
    assert errs1[-1] < 0.01 < errs1[0]  # converged onto the true bias
    assert snap1["residuals"][0]["residual"] == pytest.approx(1.4, rel=0.01)


def test_online_calibrator_clamps_corrupt_samples():
    c = OnlineCalibrator(alpha=1.0, bound=2.0)
    c.observe(sku="s", arch="m", profile="p", measured_s=1e9, predicted_s=1.0)
    assert c.residual(sku="s", arch="m", profile="p") == 2.0
    # non-positive samples are ignored entirely
    c2 = OnlineCalibrator()
    c2.observe(sku="s", arch="m", profile="p", measured_s=0.0, predicted_s=1.0)
    assert c2.n_observed == 0


def test_cluster_observe_step_feeds_calibrator():
    # the integration hook: a Cluster run with a calibrator attached folds
    # observe_step samples in, and predict_step output moves accordingly
    from repro.core.cluster import Cluster
    from repro.core.instance import JobSpec
    from repro.core.sharing import CollocationMode
    from repro.launch.simulate import SIM_SUITE

    db = synthetic_char_db()
    calib = OnlineCalibrator()
    cl = Cluster(db, [("d0", CollocationMode.MIG)], calibrator=calib)
    spec = JobSpec("j0", "granite-3-2b", SIM_SUITE)
    cl.submit(spec, 0.0, epochs=1)
    cl.run_until(0.0)
    dev = cl.devices["d0"]
    assert dev.scheduler.calibrator is calib
    prof = dev.assignments["j0"].placement.profile
    base = dev.scheduler.predict_step(spec, prof)
    # the device consistently runs 30% slower than the char DB claims
    true_s = base * 1.3
    for i in range(30):
        cl.observe_step("j0", true_s, at_s=0.001 * (i + 1))
    assert calib.n_observed == 30
    corrected = dev.scheduler.predict_step(spec, prof)
    assert abs(corrected - true_s) / true_s < 0.02  # tightened onto truth
    assert abs(base - true_s) / true_s > 0.2


def test_cluster_without_calibrator_is_byte_identical():
    # the acceptance bar: calibration-free cells do not move at all
    from repro.launch.simulate import run_cell

    a = run_cell("train_serve_mix", "all-mig", seed=0, n_jobs=10, n_devices=2)
    b = run_cell("train_serve_mix", "all-mig", seed=0, n_jobs=10, n_devices=2)
    assert json.dumps(a, sort_keys=True, default=str) == json.dumps(
        b, sort_keys=True, default=str
    )


# -- CLI artifacts -----------------------------------------------------------


def test_calibrate_cli_writes_deterministic_artifacts(tmp_path):
    from repro.launch.calibrate import main

    out1, out2 = tmp_path / "one", tmp_path / "two"
    assert main(["--out", str(out1), "--skus", "a100-40gb,a30-24gb"]) == 0
    assert main(["--out", str(out2), "--skus", "a100-40gb,a30-24gb"]) == 0
    names = sorted(p.name for p in out1.iterdir())
    assert names == ["_summary.json", "calib_db__a100-40gb.json",
                     "calib_db__a30-24gb.json"]
    for name in names:
        assert (out1 / name).read_bytes() == (out2 / name).read_bytes()
    summary = json.loads((out1 / "_summary.json").read_text())
    for sku, s in summary["skus"].items():
        card = s["scorecard"]
        assert card["calibrated_mean_abs_rel_err"] < card["seed_mean_abs_rel_err"]
        online = s["online"]
        assert (online["last_step_mean_abs_rel_err"]
                < online["first_step_mean_abs_rel_err"])
    # the written DB is a valid versioned document that loads back
    db = CharDB.loads((out1 / "calib_db__a100-40gb.json").read_text())
    assert db.sku == "a100-40gb" and len(db) == 40
