"""AdamW unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim import adamw


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr_peak=0.1, lr_min=0.01, warmup_steps=5,
                            total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = adamw.init_state(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((9,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = adamw.global_norm(clipped)
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


@given(st.integers(1, 400))
@settings(max_examples=60, deadline=None)
def test_schedule_warmup_then_bounded(step):
    cfg = adamw.AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=100,
                            total_steps=400)
    lr = float(adamw.cosine_schedule(cfg, jnp.int32(step)))
    assert 0.0 <= lr <= cfg.lr_peak + 1e-9
    if step < cfg.warmup_steps:
        np.testing.assert_allclose(lr, cfg.lr_peak * step / cfg.warmup_steps, rtol=1e-5)
    if step >= cfg.total_steps:
        np.testing.assert_allclose(lr, cfg.lr_min, rtol=1e-5)


def test_decay_mask_skips_norm_params():
    cfg = adamw.AdamWConfig(lr_peak=0.0, lr_min=0.0, warmup_steps=1,
                            total_steps=2, weight_decay=1.0)
    # lr=0 => update is exactly 0 regardless of decay; instead use lr>0 and
    # zero grads so the only update source is decoupled weight decay.
    cfg = adamw.AdamWConfig(lr_peak=0.1, lr_min=0.1, warmup_steps=0,
                            total_steps=2, weight_decay=1.0, clip_norm=1e9)
    params = {"w": jnp.ones((3,)), "scale": jnp.ones((3,))}
    grads = {"w": jnp.zeros((3,)), "scale": jnp.zeros((3,))}
    state = adamw.init_state(params, cfg)
    new, _, _ = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(new["scale"] - 1.0))) == 0.0, "norm param decayed"
    assert float(jnp.max(jnp.abs(new["w"] - 1.0))) > 0.0, "kernel not decayed"


def test_gradient_compression_error_feedback():
    """EF property: dequantized mean + residual == input, exactly."""
    from jax.sharding import PartitionSpec as P

    from repro.optim import compression

    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(jax.random.key(0), (256,)) * 0.1}
    e0 = compression.init_error_state(g)

    def body(g, e):
        return compression.ef_int8_psum(g, e, "pod")

    from repro.runtime.compat import shard_map

    mean, err = shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False,
    )(g, e0)
    np.testing.assert_allclose(
        np.asarray(mean["w"]) + np.asarray(err["w"]), np.asarray(g["w"]), atol=1e-6
    )
    # int8 quantization error is bounded by the tensor scale
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(err["w"]))) <= scale * 0.5 + 1e-9


def test_compression_wire_bytes_accounting():
    from repro.optim import compression

    g = {"a": jnp.zeros((100,)), "b": jnp.zeros((28,))}
    full, comp = compression.compression_wire_bytes(g)
    assert full == 4 * 128
    assert comp == 128 + 4 * 2
