"""Collocation-mode subsystem: contention models, interference, best_mode.

Covers the acceptance triplet: (a) MIG predicts zero interference, (b) MPS
aggregate throughput >= naive on the paper's workload grid, (c) best_mode
picks MPS for the single-user homogeneous scenario and MIG for the
partition-aligned one.
"""
import dataclasses

import pytest

from repro.configs.base import ShapeSuite
from repro.core.collocation import CollocationScheduler, _PROFILE_ORDER
from repro.core.interference import quantify_interference
from repro.core.instance import JobSpec
from repro.core.sharing import (
    CollocationMode,
    SoloProfile,
    mps_contention,
    naive_contention,
    sequential_time_s,
    shared_mode_report,
)
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")


def make_profiles(k, compute_s, memory_s=0.0, collective_s=0.0, peak=0.0):
    return [
        SoloProfile(f"j{i}", compute_s, memory_s, collective_s,
                    peak_bytes_per_device=peak)
        for i in range(k)
    ]


def full_db(arch, step_by_prof=None, fits_by_prof=None, full_terms=None,
            peak_frac=0.01):
    """Characterization DB over every profile; ``full_terms`` overrides the
    7g.40gb record with roofline terms for the shared-mode path."""
    step_by_prof = step_by_prof or {}
    fits_by_prof = fits_by_prof or {}
    db = {}
    for p in _PROFILE_ORDER:
        rec = {
            "fits": fits_by_prof.get(p, True),
            "step_s": step_by_prof.get(p, 1.0),
            "peak_bytes_per_device": HBM_PER_CHIP * peak_frac,
        }
        if p == "7g.40gb" and full_terms:
            rec.update(full_terms)
        db[(arch, SUITE.name, p)] = rec
    return db


# -- (a) MIG: zero predicted interference ------------------------------------


def test_mig_predicts_zero_interference():
    jobs = make_profiles(7, 2e-3, 1e-3, 5e-4)
    q = quantify_interference(CollocationMode.MIG, jobs)
    assert q.interference_free
    assert q.slowdown == {j.name: 1.0 for j in jobs}
    assert q.contended == []
    assert q.max_slowdown == 1.0


def test_shared_modes_predict_nonzero_interference_when_contended():
    # two jobs each saturating memory bandwidth -> MPS must stretch them
    jobs = make_profiles(2, 1e-4, 2e-3)
    q_mps = quantify_interference(CollocationMode.MPS, jobs)
    assert not q_mps.interference_free
    assert "memory_s" in q_mps.contended
    q_naive = quantify_interference(CollocationMode.NAIVE, jobs)
    assert not q_naive.interference_free
    assert q_naive.contended == ["device"]
    assert q_naive.max_slowdown > 2.0  # serializes both steps + overhead


def test_mps_subsaturating_mix_is_interference_free():
    # aggregate demand below capacity on every resource -> free collocation,
    # the paper's headline win for small workloads
    jobs = make_profiles(4, 1e-4, 5e-5)  # busy << latency floor
    rep = mps_contention(jobs)
    assert all(f == 1.0 for f in rep.contention.values())
    assert rep.max_interference == pytest.approx(1.0)
    # aggregate throughput ~= k * solo rate
    solo_rate = 1.0 / jobs[0].step_s
    assert rep.throughput_jobs_per_s == pytest.approx(4 * solo_rate)


# -- (b) MPS >= naive on the paper workload grid ------------------------------

# the paper's grid: small / medium / large resnet-like solo profiles
# (compute_s, memory_s, collective_s) on the full device, swept at the
# paper's collocation counts 2..7
PAPER_GRID = {
    "resnet_small": (2e-4, 1e-4, 2e-5),
    "resnet_medium": (1.5e-3, 8e-4, 1e-4),
    "resnet_large": (9e-3, 5e-3, 6e-4),
}


def test_mps_throughput_at_least_naive_on_paper_grid():
    for name, (c, m, l) in PAPER_GRID.items():
        for k in (2, 3, 4, 7):
            jobs = make_profiles(k, c, m, l)
            mps = mps_contention(jobs)
            naive = naive_contention(jobs)
            assert mps.throughput_jobs_per_s >= naive.throughput_jobs_per_s, (
                name, k,
            )
    # heterogeneous mix of all three
    jobs = [
        SoloProfile(n, *PAPER_GRID[w])
        for n, w in zip("abc", PAPER_GRID)
    ]
    assert (
        mps_contention(jobs).throughput_jobs_per_s
        >= naive_contention(jobs).throughput_jobs_per_s
    )


def test_naive_never_beats_sequential():
    for k in (2, 4, 7):
        jobs = make_profiles(k, 1e-3, 5e-4)
        naive = naive_contention(jobs)
        # all jobs finish one step per round; the round is >= sequential time
        round_s = max(naive.effective_step_s.values())
        assert round_s >= sequential_time_s(jobs)


# -- (c) best_mode scenarios ---------------------------------------------------


def _homogeneous_scheduler():
    """Seven copies of one small training job, everything fits everywhere:
    the paper's single-user hyperparameter-sweep scenario."""
    db = full_db(
        "small",
        step_by_prof={"1g.5gb": 8e-3, "2g.10gb": 4e-3, "3g.20gb": 3e-3,
                      "4g.20gb": 2e-3, "7g.40gb": 1e-3},
        full_terms={"compute_s": 1e-3, "memory_s": 5e-4, "collective_s": 1e-4},
    )
    return CollocationScheduler(db)


def test_best_mode_is_mps_for_single_user_homogeneous():
    s = _homogeneous_scheduler()
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(7)]
    dec = s.best_mode(jobs)
    assert dec.mode == CollocationMode.MPS
    scores = dec.scores()
    # all three modes place all seven jobs; MPS wins on throughput outright
    assert all(n == 7 for n, _t in scores.values())
    assert scores[CollocationMode.MPS][1] > scores[CollocationMode.MIG][1]
    assert scores[CollocationMode.MPS][1] > scores[CollocationMode.NAIVE][1]


def test_best_mode_is_mig_for_partition_aligned():
    """Three jobs whose working set is ~60% of per-chip HBM: any two
    co-resident under a shared mode OOM, but each aligns with a 2g.10gb
    slice — MIG's partitioning serves all three (the paper's 'model sizes
    align with the MIG partitioning options')."""
    db = full_db(
        "aligned",
        step_by_prof={"2g.10gb": 4e-3, "7g.40gb": 1e-3},
        fits_by_prof={"1g.5gb": False},
        full_terms={"compute_s": 1e-3, "memory_s": 9e-4, "collective_s": 1e-4},
        peak_frac=0.6,
    )
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"j{i}", "aligned", SUITE) for i in range(3)]
    dec = s.best_mode(jobs)
    assert dec.mode == CollocationMode.MIG
    scores = dec.scores()
    assert scores[CollocationMode.MIG][0] == 3
    assert scores[CollocationMode.MPS][0] == 1  # OOM rejects the other two
    assert scores[CollocationMode.NAIVE][0] == 1
    # and the shared schedules carry the OOM rejections
    mps_sched = dec.schedules[CollocationMode.MPS]
    assert len(mps_sched.rejections) == 2
    assert all("OOM" in r.reason for r in mps_sched.rejections)


# -- shared scheduling path ----------------------------------------------------


def test_shared_schedule_reports_mode_and_effective_steps():
    s = _homogeneous_scheduler()
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(3)]
    sched = s.schedule(jobs, mode=CollocationMode.MPS)
    assert sched.mode == CollocationMode.MPS
    assert len(sched.assignments) == 3 and not sched.rejections
    assert sched.shared_report is not None
    for a in sched.assignments:
        assert a.predicted_step_s == pytest.approx(
            sched.shared_report.effective_step_s[a.job.name]
        )
        assert a.placement.profile == "7g.40gb"  # the full shared device


def test_shared_schedule_undiscounts_f6():
    """The 7g record was characterized with MIG's reserved slice; shared
    modes run with MIG off, so the solo profile must claw back the 1/8."""
    s = _homogeneous_scheduler()
    prof = s.solo_profile(JobSpec("j", "small", SUITE))
    assert prof.compute_s == pytest.approx(1e-3 * 7 / 8)


def test_best_mode_leaves_predictions_of_winning_mode():
    """best_mode trials every mode; straggler detection must end up
    comparing against the *deployed* mode's predictions, not whichever
    trial ran last."""
    s = _homogeneous_scheduler()
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(7)]
    dec = s.best_mode(jobs)
    assert dec.mode == CollocationMode.MPS
    winner_steps = {
        a.job.name: a.predicted_step_s for a in dec.schedule.assignments
    }
    mig_steps = {
        a.job.name: a.predicted_step_s
        for a in dec.schedules[CollocationMode.MIG].assignments
    }
    assert winner_steps != mig_steps  # scenario distinguishes the modes
    # run one job at 2x its MPS prediction: a straggler under the deployed
    # mode, but invisible against the slower stale MIG predictions
    worst = max(winner_steps)
    for name, step in winner_steps.items():
        s.observe_step(name, step * (2.0 if name == worst else 1.0))
    assert s.stragglers() == [worst]


def test_scheduler_mode_default_dispatch():
    db = full_db("small")
    s = CollocationScheduler(db, mode=CollocationMode.NAIVE)
    sched = s.schedule([JobSpec("j0", "small", SUITE)])
    assert sched.mode == CollocationMode.NAIVE
    s_mig = CollocationScheduler(db)
    assert s_mig.schedule([JobSpec("j0", "small", SUITE)]).mode == CollocationMode.MIG
