"""Scenario/fleet registration: unknown names fail fast with the
registered choices listed, never a KeyError traceback mid-run."""
import pytest

from repro.launch import simulate


def test_unknown_scenario_errors_with_choices(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--scenarios", "bogus_scenario"])
    assert exc.value.code == 2  # argparse error, not a traceback
    err = capsys.readouterr().err
    assert "bogus_scenario" in err
    for known in simulate.SCENARIOS:
        assert known in err


def test_unknown_policy_errors_with_choices(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--policies", "all-tpu"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "all-tpu" in err
    for known in simulate.POLICIES:
        assert known in err


def test_empty_selection_errors(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--scenarios", ","])
    assert exc.value.code == 2
    assert "at least one" in capsys.readouterr().err


def test_make_trace_and_make_fleet_raise_value_error_with_choices():
    with pytest.raises(ValueError, match="aligned_static.*train_serve_mix"):
        simulate.make_trace("nope", 0, 10, 2)
    with pytest.raises(ValueError, match="all-mig.*best"):
        simulate.make_fleet("nope", 2)


def test_list_prints_every_scenario_and_fleet_and_exits_zero(capsys):
    """--list complements the unknown-name error path: the registry is
    printable without running anything."""
    assert simulate.main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in simulate.SCENARIOS:
        assert name in out
    for name in simulate.POLICIES:
        assert name in out
    for name in simulate.SKUS:  # device generations (core/device.py)
        assert name in out
    assert "scenarios:" in out and "fleet policies:" in out
    assert "device SKUs:" in out and "(default)" in out
    # helps stay in sync: every registered name has a help line
    assert set(simulate.SCENARIO_HELP) == set(simulate.SCENARIOS)
    assert set(simulate.POLICY_HELP) == set(simulate.POLICIES)


def test_list_surfaces_forecast_family(capsys):
    """The autoscaling family is opt-in (not in the default grid) but must
    still be discoverable: --list prints the scenario under its own family
    header and the forecast fleet policy in the main registry."""
    assert simulate.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "forecast scenarios" in out and "diurnal_serve" in out
    assert "forecast-driven autoscaling" in out
    assert "forecast" in simulate.POLICIES
    assert "diurnal_serve" not in simulate.SCENARIOS  # opt-in, not default
    assert set(simulate.FORECAST_SCENARIOS) == set(simulate.FORECAST_SCENARIO_HELP)


def test_opt_in_diurnal_serve_forecast_cell_runs_via_cli(tmp_path, capsys):
    """--scenarios diurnal_serve --policies forecast is a runnable cell
    end to end through main(), and its artifact carries the forecast
    report block."""
    import json

    rc = simulate.main([
        "--steps", "6", "--seed", "0",
        "--scenarios", "diurnal_serve", "--policies", "forecast",
        "--out", str(tmp_path / "out"),
    ])
    assert rc == 0
    assert "[FAIL]" not in capsys.readouterr().out
    cell = json.loads(
        (tmp_path / "out" / "diurnal_serve__forecast.json").read_text()
    )
    assert cell["status"] == "OK"
    assert cell["report"]["forecast"]["ticks"] > 0


def test_db_flag_skips_hetero_sku_instead_of_failing(tmp_path, capsys):
    """A flat measured DB (--db) cannot price the mixed-generation fleet;
    the hetero_sku scenario must be a documented skip, not a failed cell
    that flips the whole run's exit code."""
    import json

    db = simulate.synthetic_char_db()
    cell = {
        "mode": "mig",
        "records": [
            {"arch": a, "shape": sh, "profile": p, **rec}
            for (a, sh, p), rec in db.items()
        ],
    }
    (tmp_path / "fake.json").write_text(json.dumps(cell))
    rc = simulate.main([
        "--steps", "4", "--seed", "0",
        "--scenarios", "aligned_static,hetero_sku",
        "--policies", "all-mig",
        "--out", str(tmp_path / "out"), "--db", str(tmp_path),
    ])
    out = capsys.readouterr().out
    assert rc == 0
    assert "[SKIP] hetero_sku" in out and "[FAIL]" not in out
    summary = json.loads((tmp_path / "out" / "_summary.json").read_text())
    assert summary["failures"] == 0
    assert {c["scenario"] for c in summary["cells"]} == {"aligned_static"}


def test_db_flag_rejects_non_default_sku(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--db", "/nonexistent", "--sku", "a100-80gb"])
    assert exc.value.code == 2
    assert "a100-40gb profile names only" in capsys.readouterr().err


def test_unknown_sku_errors_with_choices(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--sku", "v100-16gb"])
    assert exc.value.code == 2  # argparse choices error, not a traceback
    err = capsys.readouterr().err
    for known in simulate.SKUS:
        assert known in err
