"""Sharding-variant semantics: zero/sp/serve must be numerically equivalent
to baseline (they change WHERE tensors live, never WHAT is computed)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_all_variants_match_baseline_loss():
    out = run_sub("""
        import jax, jax.numpy as jnp, json
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSuite
        from repro.models.model_api import build_model
        from repro.optim import adamw
        from repro.runtime import train_step as ts
        from repro.data import synthetic

        cfg = get_config("granite-3-2b").reduced()
        suite = ShapeSuite("t", 32, 8, "train")
        model = build_model(cfg)
        opt = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic.batch_for(cfg, suite, seed=0).items()}
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        losses = {}
        for variant in ("baseline", "sp", "zero"):
            jitted, st_sh, b_sh, plan = ts.jit_train_step(
                model, mesh, suite, opt, variant=variant)
            st = jax.device_put(ts.init_train_state(model, jax.random.key(0), opt), st_sh)
            b = jax.device_put(batch, b_sh)
            st, m = jitted(st, b)
            st, m2 = jitted(st, b)
            losses[variant] = [float(m["loss"]), float(m2["loss"])]
        print(json.dumps(losses))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    for variant in ("sp", "zero"):
        for a, b in zip(r["baseline"], r[variant]):
            assert abs(a - b) < 3e-2, (variant, r)


def test_serve_variant_decode_matches_baseline():
    out = run_sub("""
        import jax, jax.numpy as jnp, json, numpy as np
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSuite
        from repro.models.model_api import build_model
        from repro.runtime import serve_step as serve
        from repro.sharding.plan import make_plan
        from repro.runtime.serve_step import pad_cache

        cfg = get_config("granite-3-2b").reduced()
        model = build_model(cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        suite = ShapeSuite("d", 32, 8, "decode")
        params = model.init(jax.random.key(0))
        plan0 = make_plan(cfg, None)
        B, S = 8, 31
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab, jnp.int32)
        last, cache = model.prefill(params, {"tokens": toks}, plan0)
        cache = pad_cache(cache, 1)
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        ref, _ = model.decode(params, {"token": tok}, cache, S, plan0)

        outs = {}
        for variant in ("baseline", "serve"):
            jitted, p_sh, tok_sh, c_sh, plan = serve.jit_decode_step(
                model, mesh, suite, variant=variant)
            p = jax.device_put(params, p_sh)
            c = jax.device_put(cache, c_sh)
            t = jax.device_put({"token": tok}, tok_sh)
            logits, _ = jitted(p, t, c)
            outs[variant] = np.asarray(logits, np.float32)
        err_b = float(np.max(np.abs(outs["baseline"] - np.asarray(ref, np.float32))))
        err_s = float(np.max(np.abs(outs["serve"] - np.asarray(ref, np.float32))))
        print(json.dumps({"baseline": err_b, "serve": err_s}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["baseline"] < 6e-2, r
    assert r["serve"] < 6e-2, r
