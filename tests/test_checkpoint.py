"""Checkpoint store: round-trip, atomicity, integrity, GC, async."""
import json
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import MANIFEST, CheckpointStore


def make_tree(seed=0):
    k = jax.random.key(seed)
    ks = jax.random.split(k, 4)
    return {
        "params": {
            "w": jax.random.normal(ks[0], (8, 16), jnp.float32),
            "emb": jax.random.normal(ks[1], (32, 8)).astype(jnp.bfloat16),
        },
        "opt": {
            "step": jnp.int32(7),
            "m": jax.random.normal(ks[2], (8, 16), jnp.float32),
        },
    }


def assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_including_bf16(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = make_tree()
    store.save(10, tree, extra={"loss": 1.5})
    restored, extra = store.restore(jax.eval_shape(lambda: tree))
    assert_tree_equal(tree, restored)
    assert extra == {"loss": 1.5}
    assert store.latest_step() == 10


def test_manifestless_checkpoint_is_invisible(tmp_path):
    """Atomicity contract: a save without manifest (killed writer) is skipped."""
    store = CheckpointStore(tmp_path)
    tree = make_tree()
    store.save(1, tree)
    store.save(2, tree)
    (tmp_path / "step_00000002" / MANIFEST).unlink()  # simulate torn write
    assert store.latest_step() == 1
    restored, _ = store.restore(tree)  # falls back to step 1
    assert_tree_equal(tree, restored)


def test_crc_corruption_detected(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    path = store.save(3, tree)
    # flip bytes in the leaf file
    f = next(p for p in path.iterdir() if p.name.endswith(".npy"))
    raw = bytearray(f.read_bytes())
    raw[-4] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="crc"):
        store.restore(tree)


def test_gc_keeps_last_k(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        store.save(s, tree)
    steps = [c.step for c in store.list()]
    assert steps == [3, 4]


def test_async_save_joins_and_is_valid(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = make_tree(1)
    store.save(5, tree, async_save=True)
    store.wait()
    restored, _ = store.restore(tree)
    assert_tree_equal(tree, restored)


def test_async_save_snapshot_semantics(tmp_path):
    """The async save must capture values at call time, not at write time."""
    store = CheckpointStore(tmp_path)
    tree = {"w": np.arange(8, dtype=np.float32)}
    store.save(6, tree, async_save=True)
    tree["w"][:] = -1  # caller mutates immediately after
    store.wait()
    restored, _ = store.restore({"w": np.zeros(8, dtype=np.float32)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))


def test_restore_specific_step(tmp_path):
    store = CheckpointStore(tmp_path)
    for s in (1, 2):
        store.save(s, {"w": jnp.full((4,), float(s))})
    restored, _ = store.restore({"w": jnp.zeros((4,))}, step=1)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones(4))
