"""Optional-hypothesis shim: property tests skip cleanly when hypothesis is
not installed, and the rest of each test file still collects and runs.

Usage in a test module::

    from _hypothesis_compat import given, settings, st

When hypothesis is available these are the real thing. When it is not,
``st.<anything>(...)`` returns inert placeholder strategies (so module-level
strategy definitions still evaluate) and ``@given(...)`` marks the test as
skipped. ``hypothesis`` is declared as the ``[test]`` extra in
pyproject.toml, not a hard dependency.
"""
from __future__ import annotations

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder for a hypothesis strategy."""

        def __init__(self, name: str):
            self._name = name

        def __repr__(self):
            return f"<stub strategy {self._name}>"

    class _StrategiesStub:
        def __getattr__(self, name: str):
            return lambda *args, **kwargs: _Strategy(name)

    st = _StrategiesStub()

    def given(*args, **kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install .[test])"
            )(fn)

        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
