"""Collocation scheduler + elastic repack: admission, packing, stragglers."""
import dataclasses

from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeSuite
from repro.core.collocation import CollocationScheduler, _PROFILE_ORDER
from repro.core.elastic import ElasticController
from repro.core.instance import JobSpec
from repro.core.profiles import N_UNITS, PROFILES, validate_layout
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")


def make_db(fits_map):
    """fits_map: {(arch, profile): (fits, step_s)}."""
    db = {}
    for (arch, prof), (fits, step_s) in fits_map.items():
        db[(arch, SUITE.name, prof)] = {
            "fits": fits,
            "step_s": step_s,
            "peak_bytes_per_device": HBM_PER_CHIP * (4 if not fits else 0.5),
        }
    return db


def full_db(arch, step_by_prof=None, fits_by_prof=None):
    step_by_prof = step_by_prof or {}
    fits_by_prof = fits_by_prof or {}
    return make_db(
        {
            (arch, p): (fits_by_prof.get(p, True), step_by_prof.get(p, 1.0))
            for p in _PROFILE_ORDER
        }
    )


def test_admission_rejects_oom_profile():
    """F5: medium/large workloads OOM on 1g.5gb -> scheduler rejection."""
    db = full_db("big", fits_by_prof={"1g.5gb": False, "2g.10gb": False})
    s = CollocationScheduler(db)
    ok, why = s.admissible(JobSpec("j", "big", SUITE), "1g.5gb")
    assert not ok and "OOM" in why
    assert s.smallest_admissible(JobSpec("j", "big", SUITE)) == "3g.20gb"


def test_packs_seven_small_jobs_on_1g():
    """The paper's headline: 7 hyperparameter variants on 7x 1g.5gb."""
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    assert len(sched.assignments) == 7
    assert all(a.profile == "1g.5gb" for a in sched.assignments)
    assert not sched.rejections
    ok, why = validate_layout([a.placement for a in sched.assignments])
    assert ok, why


def test_overflow_jobs_are_rejected_not_overpacked():
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(9)]
    sched = s.schedule(jobs)
    assert len(sched.assignments) == 7
    assert len(sched.rejections) == 2


jobs_st = st.lists(
    st.tuples(st.sampled_from(["small", "mid", "big"]), st.integers(0, 3)),
    min_size=1,
    max_size=10,
)


@given(jobs_st)
@settings(max_examples=200, deadline=None)
def test_schedules_are_always_valid_layouts(job_descs):
    db = {}
    db.update(full_db("small"))
    db.update(full_db("mid", fits_by_prof={"1g.5gb": False}))
    db.update(
        full_db("big", fits_by_prof={p: p in ("4g.20gb", "7g.40gb") for p in _PROFILE_ORDER})
    )
    s = CollocationScheduler(db)
    jobs = [
        JobSpec(f"j{i}", arch, SUITE, priority=pr)
        for i, (arch, pr) in enumerate(job_descs)
    ]
    sched = s.schedule(jobs)
    ok, why = validate_layout([a.placement for a in sched.assignments])
    assert ok, why
    # every job is either placed or rejected, never both / lost
    placed = {a.job.name for a in sched.assignments}
    rejected = {r.job.name for r in sched.rejections}
    assert placed | rejected == {j.name for j in jobs}
    assert not placed & rejected
    # admission respected
    for a in sched.assignments:
        assert s.admissible(a.job, a.profile)[0]


def test_straggler_detection_and_repack_plan():
    db = full_db("small", step_by_prof={p: 1.0 for p in _PROFILE_ORDER})
    s = CollocationScheduler(db, straggler_tol=1.5, ema_alpha=1.0)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(3)]
    sched = s.schedule(jobs)
    s.observe_step("j0", 1.0)   # on target
    s.observe_step("j1", 2.5)   # straggling
    assert s.stragglers() == ["j1"]
    plan = s.repack_plan(sched)
    assert "j1" in plan and plan["j1"] != sched.assignments[0].profile
    assert "j0" not in plan


def test_elastic_repack_preserves_survivors():
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    ctrl = ElasticController(s)
    ctrl.mark_failed([0, 1])  # two slice units die
    ev = ctrl.repack(sched)
    # jobs on units 0-1 are killed; others survive untouched
    assert set(ev.killed_jobs) == {
        a.job.name for a in sched.assignments if a.placement.start in (0, 1)
    }
    for a in ev.new_schedule.assignments:
        span = (
            set(range(N_UNITS))
            if a.profile == "7g.40gb"
            else set(range(*a.placement.span))
        )
        assert not span & {0, 1}, f"{a.job.name} re-placed on failed unit"
    ok, why = validate_layout([a.placement for a in ev.new_schedule.assignments])
    assert ok, why


@given(st.sets(st.integers(0, N_UNITS - 1), max_size=6))
@settings(max_examples=100, deadline=None)
def test_elastic_repack_never_uses_failed_units(failed):
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    ctrl = ElasticController(s)
    ctrl.mark_failed(sorted(failed))
    ev = ctrl.repack(sched)
    for a in ev.new_schedule.assignments:
        span = (
            set(range(N_UNITS))
            if a.profile == "7g.40gb"
            else set(range(*a.placement.span))
        )
        assert not span & failed
    # no job is both survivor and killed
    assert not set(ev.killed_jobs) & set(ev.survivors)
