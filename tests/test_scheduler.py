"""Collocation scheduler + elastic repack: admission, packing, stragglers."""
import dataclasses

from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeSuite
from repro.core.collocation import (
    _MODE_PREFERENCE,
    _PROFILE_ORDER,
    MODE_PREFERENCE,
    CollocationScheduler,
)
from repro.core.elastic import ElasticController
from repro.core.instance import JobSpec
from repro.core.profiles import N_UNITS, PROFILES, Placement, validate_layout
from repro.core.sharing import CollocationMode
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")


def make_db(fits_map):
    """fits_map: {(arch, profile): (fits, step_s)}."""
    db = {}
    for (arch, prof), (fits, step_s) in fits_map.items():
        db[(arch, SUITE.name, prof)] = {
            "fits": fits,
            "step_s": step_s,
            "peak_bytes_per_device": HBM_PER_CHIP * (4 if not fits else 0.5),
        }
    return db


def full_db(arch, step_by_prof=None, fits_by_prof=None):
    step_by_prof = step_by_prof or {}
    fits_by_prof = fits_by_prof or {}
    return make_db(
        {
            (arch, p): (fits_by_prof.get(p, True), step_by_prof.get(p, 1.0))
            for p in _PROFILE_ORDER
        }
    )


def test_admission_rejects_oom_profile():
    """F5: medium/large workloads OOM on 1g.5gb -> scheduler rejection."""
    db = full_db("big", fits_by_prof={"1g.5gb": False, "2g.10gb": False})
    s = CollocationScheduler(db)
    ok, why = s.admissible(JobSpec("j", "big", SUITE), "1g.5gb")
    assert not ok and "OOM" in why
    assert s.smallest_admissible(JobSpec("j", "big", SUITE)) == "3g.20gb"


def test_packs_seven_small_jobs_on_1g():
    """The paper's headline: 7 hyperparameter variants on 7x 1g.5gb."""
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    assert len(sched.assignments) == 7
    assert all(a.profile == "1g.5gb" for a in sched.assignments)
    assert not sched.rejections
    ok, why = validate_layout([a.placement for a in sched.assignments])
    assert ok, why


def test_overflow_jobs_are_rejected_not_overpacked():
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"hp{i}", "small", SUITE) for i in range(9)]
    sched = s.schedule(jobs)
    assert len(sched.assignments) == 7
    assert len(sched.rejections) == 2


jobs_st = st.lists(
    st.tuples(st.sampled_from(["small", "mid", "big"]), st.integers(0, 3)),
    min_size=1,
    max_size=10,
)


@given(jobs_st)
@settings(max_examples=200, deadline=None)
def test_schedules_are_always_valid_layouts(job_descs):
    db = {}
    db.update(full_db("small"))
    db.update(full_db("mid", fits_by_prof={"1g.5gb": False}))
    db.update(
        full_db("big", fits_by_prof={p: p in ("4g.20gb", "7g.40gb") for p in _PROFILE_ORDER})
    )
    s = CollocationScheduler(db)
    jobs = [
        JobSpec(f"j{i}", arch, SUITE, priority=pr)
        for i, (arch, pr) in enumerate(job_descs)
    ]
    sched = s.schedule(jobs)
    ok, why = validate_layout([a.placement for a in sched.assignments])
    assert ok, why
    # every job is either placed or rejected, never both / lost
    placed = {a.job.name for a in sched.assignments}
    rejected = {r.job.name for r in sched.rejections}
    assert placed | rejected == {j.name for j in jobs}
    assert not placed & rejected
    # admission respected
    for a in sched.assignments:
        assert s.admissible(a.job, a.profile)[0]


def test_mode_preference_covers_every_mode_at_import_time():
    """The hardening satellite: MODE_PREFERENCE must rank every
    CollocationMode exactly once (asserted at import time in
    core/collocation.py, mirrored here so the contract is test-visible) —
    adding a mode can't silently change tie-broken verdicts."""
    from repro.core.collocation import _PREFERENCE_RANK

    assert set(MODE_PREFERENCE) == set(CollocationMode)
    assert len(MODE_PREFERENCE) == len(CollocationMode)
    assert _PREFERENCE_RANK == {m: i for i, m in enumerate(MODE_PREFERENCE)}


def test_best_mode_tie_breaks_by_mode_preference():
    """Exact (jobs placed, throughput) ties fall back to the paper's
    recommendation order: MPS > MIG > naive."""
    assert MODE_PREFERENCE == (
        CollocationMode.MPS, CollocationMode.MIG, CollocationMode.NAIVE
    )
    assert _MODE_PREFERENCE is MODE_PREFERENCE  # compat alias
    # nothing fits anywhere -> all three modes tie at (0 placed, 0 jobs/s)
    db = full_db("huge", fits_by_prof={p: False for p in _PROFILE_ORDER})
    s = CollocationScheduler(db)
    decision = s.best_mode([JobSpec("j", "huge", SUITE)])
    scores = decision.scores()
    assert len(set(scores.values())) == 1  # exact three-way tie
    assert decision.mode == CollocationMode.MPS


def test_best_mode_single_job_mps_beats_naive_on_tie():
    """With one job, MPS and naive degenerate to the same effective step
    (no neighbours, no switch overhead) — the preference picks MPS."""
    db = full_db("solo", step_by_prof={p: 8.0 for p in _PROFILE_ORDER})
    s = CollocationScheduler(db)
    decision = s.best_mode([JobSpec("j", "solo", SUITE)])
    scores = decision.scores()
    assert scores[CollocationMode.MPS] == scores[CollocationMode.NAIVE]
    # the F6 un-discount makes the shared step < the MIG record's 8.0s,
    # so the tie is between the shared modes and MPS wins it
    assert decision.mode == CollocationMode.MPS


def test_min_profile_floor_respected():
    """A straggler re-queued with min_profile lands on the bigger slice
    even though a smaller one would fit."""
    db = full_db("small")
    s = CollocationScheduler(db)
    job = JobSpec("j", "small", SUITE, min_profile="3g.20gb")
    assert s.smallest_admissible(job) == "3g.20gb"
    sched = s.schedule([job])
    assert sched.assignments[0].profile == "3g.20gb"


def test_schedule_existing_placements_validate_jointly():
    """Incremental admission (the cluster path) must honour the placement
    tree across live + new instances: 4g + 3g is NVIDIA's documented
    invalid combination even though the units are free."""
    db = full_db("mid", fits_by_prof={p: p in ("3g.20gb", "4g.20gb", "7g.40gb")
                                      for p in _PROFILE_ORDER})
    db.update(full_db("small"))
    s = CollocationScheduler(db)
    live = [Placement("4g.20gb", 0)]
    blocked = s.schedule([JobSpec("m", "mid", SUITE)], existing=live)
    assert not blocked.assignments  # 3g would pair with live 4g -> excluded
    ok = s.schedule([JobSpec("t", "small", SUITE)], existing=live)
    assert ok.assignments and ok.assignments[0].placement.start >= 4
    layout = live + [ok.assignments[0].placement]
    valid, why = validate_layout(layout)
    assert valid, why


def test_straggler_detection_and_repack_plan():
    db = full_db("small", step_by_prof={p: 1.0 for p in _PROFILE_ORDER})
    s = CollocationScheduler(db, straggler_tol=1.5, ema_alpha=1.0)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(3)]
    sched = s.schedule(jobs)
    s.observe_step("j0", 1.0)   # on target
    s.observe_step("j1", 2.5)   # straggling
    assert s.stragglers() == ["j1"]
    plan = s.repack_plan(sched)
    assert "j1" in plan and plan["j1"] != sched.assignments[0].profile
    assert "j0" not in plan


def test_repack_plan_handles_many_stragglers():
    """The straggler set is computed once (not per assignment): every
    flagged job gets its upgrade suggestion in a single pass."""
    db = full_db("small", step_by_prof={p: 1.0 for p in _PROFILE_ORDER})
    s = CollocationScheduler(db, straggler_tol=1.5, ema_alpha=1.0)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    for i in range(7):
        s.observe_step(f"j{i}", 3.0 if i % 2 == 0 else 1.0)
    plan = s.repack_plan(sched)
    assert set(plan) == {f"j{i}" for i in range(7) if i % 2 == 0}
    assert all(PROFILES[p].mem_units > 1 for p in plan.values())


def test_elastic_repack_preserves_survivors():
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    ctrl = ElasticController(s)
    ctrl.mark_failed([0, 1])  # two slice units die
    ev = ctrl.repack(sched)
    # jobs on units 0-1 are killed; others survive untouched
    assert set(ev.killed_jobs) == {
        a.job.name for a in sched.assignments if a.placement.start in (0, 1)
    }
    for a in ev.new_schedule.assignments:
        span = (
            set(range(N_UNITS))
            if a.profile == "7g.40gb"
            else set(range(*a.placement.span))
        )
        assert not span & {0, 1}, f"{a.job.name} re-placed on failed unit"
    ok, why = validate_layout([a.placement for a in ev.new_schedule.assignments])
    assert ok, why


def test_elastic_repack_bumps_priority_and_keeps_survivors_untouched():
    """Killed jobs re-enter with +10 priority; surviving assignments are
    the *same objects* (their instances were never touched — F3)."""
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(5)]
    sched = s.schedule(jobs)  # 1g slices at units 0..4; units 5, 6 stay free
    survivors_before = [a for a in sched.assignments if a.placement.start >= 2]
    ctrl = ElasticController(s)
    ctrl.mark_failed([0, 1])
    ev = ctrl.repack(sched)
    assert set(ev.killed_jobs) == {"j0", "j1"}
    # killed jobs were re-placed with bumped priority, and resumed from
    # their checkpoints
    replaced = [a for a in ev.new_schedule.assignments
                if a.job.name in ev.killed_jobs]
    assert replaced and all(a.job.priority == 10 for a in replaced)
    assert set(ev.resumed_from_checkpoint) == set(ev.killed_jobs)
    # survivors: identical Assignment objects, placements untouched
    for a in survivors_before:
        assert a in ev.new_schedule.assignments
    assert ev.new_schedule.mode == CollocationMode.MIG


def test_elastic_repack_shared_mode_kills_everything():
    """No isolation outside MIG: a unit failure on a shared device takes
    every job down and nothing is re-placed on the degraded device."""
    db = full_db("small")
    s = CollocationScheduler(db, mode=CollocationMode.MPS)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(2)]
    sched = s.schedule(jobs)
    assert sched.mode == CollocationMode.MPS
    assert len(sched.assignments) == 2
    ctrl = ElasticController(s)
    ctrl.mark_failed([5])
    ev = ctrl.repack(sched)
    assert set(ev.killed_jobs) == {"j0", "j1"}
    assert ev.survivors == ()
    assert not ev.new_schedule.assignments
    assert ev.new_schedule.mode == CollocationMode.MPS


@given(st.sets(st.integers(0, N_UNITS - 1), max_size=6))
@settings(max_examples=100, deadline=None)
def test_elastic_repack_never_uses_failed_units(failed):
    db = full_db("small")
    s = CollocationScheduler(db)
    jobs = [JobSpec(f"j{i}", "small", SUITE) for i in range(7)]
    sched = s.schedule(jobs)
    ctrl = ElasticController(s)
    ctrl.mark_failed(sorted(failed))
    ev = ctrl.repack(sched)
    for a in ev.new_schedule.assignments:
        span = (
            set(range(N_UNITS))
            if a.profile == "7g.40gb"
            else set(range(*a.placement.span))
        )
        assert not span & failed
    # no job is both survivor and killed
    assert not set(ev.killed_jobs) & set(ev.survivors)
