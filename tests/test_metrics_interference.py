"""Unit tests for core/metrics device-group aggregation and the
interference verifier's HLO group parsing."""
import numpy as np

from repro.core.instance import InstanceRecord
from repro.core.interference import (
    check_collective_containment,
    check_program_equivalence,
    collective_groups,
)
from repro.core.metrics import (
    collocation_speedup,
    device_group_report,
    epoch_time_s,
    throughput_jobs_per_s,
)


def rec(job="w#0", profile="1g.5gb", step_s=1.0, fp="abc", chips=32):
    return InstanceRecord(
        job=job, arch="w", shape="t", profile=profile, start=0, chips=chips,
        hbm_budget_bytes=1, peak_bytes_per_device=1.0, fits=True,
        step_s=step_s, compute_s=step_s / 2, memory_s=step_s / 4,
        collective_s=step_s, bound="collective", mfu=0.1,
        dcgm={"gract": 0.8, "smact": 0.5, "smocc_proxy": 0.4, "drama": 0.6},
        hlo_fingerprint=fp,
    )


def test_device_group_weighting():
    # 2 instances of 1g (1 unit each) on an 8-unit pod: device-level = 2/8
    r = device_group_report("1g.5gb parallel", "w", [rec(), rec(job="w#1")])
    np.testing.assert_allclose(r.device_metrics["gract"], 0.8 * 2 / 8)
    assert r.occupied_units == 2
    # full-device profile: device-level == instance-level
    r7 = device_group_report("7g.40gb one", "w", [rec(profile="7g.40gb")])
    np.testing.assert_allclose(r7.device_metrics["gract"], 0.8)


def test_epoch_time_and_speedup():
    r = rec(step_s=2.0)
    assert epoch_time_s(r, samples_per_epoch=100, batch=32) == 2.0 * 4  # ceil
    full = rec(profile="7g.40gb", step_s=1.0)
    par = [rec(job=f"w#{i}", step_s=3.0) for i in range(7)]
    np.testing.assert_allclose(collocation_speedup(par, full), 7 / 3)
    np.testing.assert_allclose(throughput_jobs_per_s(par), 7 / 3.0)


def test_program_equivalence_detects_divergence():
    ok, _ = check_program_equivalence([rec(), rec(job="w#1")])
    assert ok
    ok, why = check_program_equivalence([rec(), rec(job="w#1", fp="zzz")])
    assert not ok and "fingerprint" in why


def test_collective_containment():
    hlo = 'x = f32[4] all-reduce(%y), replica_groups={{0,1},{2,3}}, to_apply=%add'
    groups = collective_groups(hlo)
    assert [0, 1] in groups and [2, 3] in groups
    ok, _ = check_collective_containment(hlo, [10, 11, 12, 13], 4)
    assert ok
    ok, why = check_collective_containment(hlo, [10, 11], 2)
    assert not ok  # group {2,3} exceeds a 2-device instance
