"""The PR's center of gravity: the incremental re-timing engine
(``Cluster(retime="incremental")``, the default) must be *behavior-identical*
to the full reference engine (``retime="full"``, the pre-optimization code
path) — identical live event streams, identical metrics, and byte-identical
artifact cells for every scenario x fleet-policy combination at the pinned
seed-0 defaults (the 30 cells of the committed artifact grid, plus the
city_scale family).

Event streams are compared as per-timestamp multisets: within one timestamp
the engines may *pop* live events in different seq orders (the deferred
batch re-push assigns later seq numbers than the eager path's interleaved
pushes), but the set of live events fired at each instant — and therefore
every piece of simulated state — must agree exactly.
"""
import json

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.cluster import Cluster
from repro.core.instance import JobSpec
from repro.launch.simulate import (
    ALL_SCENARIOS,
    GANG_FLEET_SKUS,
    HETERO_FLEET_SKUS,
    POLICIES,
    SERVE_SLO_S,
    SERVE_SUITE,
    SIM_SAMPLES_PER_EPOCH,
    SIM_SUITE,
    _rounded,
    make_fleet,
    make_trace,
    run_cell,
    synthetic_sku_dbs,
)
from repro.core.workload import serve_workload, train_workload

# one per-SKU DB set shared by every cell in the module (what run_all does)
_DB = synthetic_sku_dbs(("a100-40gb",) + HETERO_FLEET_SKUS)

_CELLS = [(sc, po) for sc in ALL_SCENARIOS for po in POLICIES]


def _artifact_bytes(cell: dict) -> bytes:
    """Exactly what launch/simulate.py writes to disk for a cell."""
    return (json.dumps(_rounded(cell), indent=2, sort_keys=True) + "\n").encode()


def _stream_multisets(stream):
    """Group the live-event log by rounded timestamp, order-insensitively
    within each instant (see module docstring)."""
    groups = {}
    for t, kind, payload in stream:
        groups.setdefault(t, []).append((kind, payload))
    return {t: sorted(evs) for t, evs in groups.items()}


# diurnal_serve multiplies each --steps unit into 20 session arrivals at a
# fixed 10x interarrival rate (launch/traces.py), so the default n=60 cell
# is a 1200-arrival trace and the all-naive fleet stacks dozens of
# co-resident jobs per device (quadratic re-timing). Sweep the engines at a
# size that still spans all three synthetic days but keeps the suite's
# runtime bounded (CI byte-pins the full-size cell in its forecast job).
_CELL_N_JOBS = {"diurnal_serve": 6}


@pytest.mark.parametrize("scenario,policy", _CELLS)
def test_artifact_cell_bytes_identical(scenario, policy):
    """The acceptance criterion: every seed-0 default-grid cell reproduces
    byte-for-byte on the incremental path (the cell dict embeds the whole
    report, so metrics equality is implied by bytes equality)."""
    n = _CELL_N_JOBS.get(scenario, 60)
    full = run_cell(scenario, policy, seed=0, n_jobs=n, char_db=_DB,
                    retime="full")
    inc = run_cell(scenario, policy, seed=0, n_jobs=n, char_db=_DB,
                   retime="incremental")
    assert _artifact_bytes(inc) == _artifact_bytes(full)


def _drive(scenario, policy, retime, *, seed=0, n_jobs=40, n_devices=2):
    """Run one cell on a bare Cluster with the live-event log enabled;
    returns (event stream, report dict)."""
    fleet_skus = (
        HETERO_FLEET_SKUS if scenario == "hetero_sku"
        else GANG_FLEET_SKUS if scenario == "gang_pipeline"
        else ("a100-40gb",)
    )
    devices, cluster_policy = make_fleet(policy, n_devices, fleet_skus)
    cluster = Cluster(
        _DB,
        devices,
        policy=cluster_policy,
        reconfig_cost_s=0.5,
        migration_cooldown_s=1.0,
        retime=retime,
        # the gang starvation bound, scaled to the simulator's second-scale
        # makespans (run_cell uses the same value) — inert for gang-free
        # traces: GANG_RESERVE events only ever fire for queued gangs
        gang_reserve_after_s=0.5,
    )
    cluster.event_log = []
    for arrival_s, spec, epochs in make_trace(scenario, seed, n_jobs, n_devices):
        cluster.submit(
            spec, arrival_s, epochs=epochs, samples_per_epoch=SIM_SAMPLES_PER_EPOCH
        )
    report = cluster.run()
    return cluster.event_log, _rounded(report.to_dict())


@pytest.mark.parametrize("scenario,policy", _CELLS)
def test_live_event_streams_identical(scenario, policy):
    n = _CELL_N_JOBS.get(scenario, 40)
    stream_full, report_full = _drive(scenario, policy, "full", n_jobs=n)
    stream_inc, report_inc = _drive(scenario, policy, "incremental", n_jobs=n)
    assert report_inc == report_full
    assert len(stream_inc) == len(stream_full)
    assert _stream_multisets(stream_inc) == _stream_multisets(stream_full)


def test_gang_phase_transition_streams_identical():
    """PHASE_TRANSITION x gangs: a phase-aware gang's boundary crossings
    re-time siblings through _reprice_gang on the incremental path and the
    reference path — the live streams must agree at every instant, and the
    trace must actually contain gang phase transitions to compare."""
    import dataclasses

    from repro.core.gang.parallelism import Parallelism

    def gang(name, arch, world, **kw):
        return dataclasses.replace(
            train_workload(name, arch, SIM_SUITE, **kw),
            world_size=world,
            parallelism=Parallelism(tensor=world),
        )

    results = []
    for retime in ("full", "incremental"):
        cluster = Cluster(
            _DB,
            [(f"d{i}", "mig", "a100-80gb") for i in range(2)],
            reconfig_cost_s=0.5,
            migration_cooldown_s=1.0,
            retime=retime,
            gang_reserve_after_s=0.5,
        )
        cluster.event_log = []
        cluster.submit(gang("g", "stablelm-12b", 2, warmup_steps=3,
                            checkpoint_steps=2), 0.0, epochs=2,
                       samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        cluster.submit(JobSpec("solo", "granite-3-2b", SIM_SUITE), 0.005,
                       epochs=1, samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        report = cluster.run()
        results.append((cluster.event_log, _rounded(report.to_dict())))
    (stream_full, report_full), (stream_inc, report_inc) = results
    assert report_inc == report_full
    assert _stream_multisets(stream_inc) == _stream_multisets(stream_full)
    gang_phase_evs = [
        e for e in stream_full if e[1] == "phase_transition" and e[2][1] == "g"
    ]
    assert gang_phase_evs  # the comparison actually exercised the seam


def test_retime_arg_is_validated():
    with pytest.raises(ValueError):
        Cluster(_DB, [("d0", "mps")], retime="bogus")


# -- hypothesis: random arrival/phase/departure interleavings ----------------------

_ARCHS = ("whisper-base", "granite-3-2b", "resnet_small", "llama3-8b")

_JOBS = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=3.0,
                  allow_nan=False, allow_infinity=False),  # arrival time
        st.integers(min_value=0, max_value=len(_ARCHS) - 1),
        st.integers(min_value=0, max_value=2),  # priority
        st.integers(min_value=1, max_value=2),  # epochs
        st.booleans(),  # phase-aware workload (serve/train) vs plain spec
    ),
    min_size=1,
    max_size=20,
)


def _job(i, arrival, arch_i, priority, serve):
    arch = _ARCHS[arch_i]
    if not serve:
        return JobSpec(f"p{i}", arch, SIM_SUITE, priority=priority)
    if arch in SERVE_SLO_S:
        return serve_workload(
            f"s{i}", arch, SERVE_SUITE, slo_step_s=SERVE_SLO_S[arch],
            prefill_steps=3, priority=priority,
        )
    return train_workload(
        f"t{i}", arch, SIM_SUITE, warmup_steps=2, checkpoint_steps=2,
        priority=priority,
    )


@settings(max_examples=30, deadline=None)
@given(jobs=_JOBS, policy=st.sampled_from(POLICIES))
def test_random_interleavings_incremental_equals_full(jobs, policy):
    """Generative equivalence: arbitrary arrival/priority/phase mixes —
    including same-timestamp pileups, the deferred batch's hard case —
    produce identical live streams and reports on both engines."""
    results = []
    for retime in ("full", "incremental"):
        devices, cluster_policy = make_fleet(policy, 2)
        cluster = Cluster(
            _DB,
            devices,
            policy=cluster_policy,
            reconfig_cost_s=0.5,
            migration_cooldown_s=1.0,
            retime=retime,
        )
        cluster.event_log = []
        for i, (arrival, arch_i, priority, epochs, serve) in enumerate(jobs):
            cluster.submit(
                _job(i, arrival, arch_i, priority, serve),
                round(arrival, 3),  # coarse grid => frequent exact-time ties
                epochs=epochs,
                samples_per_epoch=SIM_SAMPLES_PER_EPOCH,
            )
        report = cluster.run()
        results.append((_stream_multisets(cluster.event_log),
                        _rounded(report.to_dict())))
    (stream_full, report_full), (stream_inc, report_inc) = results
    assert report_inc == report_full
    assert stream_inc == stream_full
