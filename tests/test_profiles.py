"""Property tests for the MIG-faithful slice algebra (hypothesis)."""
from _hypothesis_compat import given, settings, st

from repro.core.profiles import (
    EXCLUSIONS,
    N_COMPUTE_SLICES,
    N_UNITS,
    PROFILES,
    Placement,
    enumerate_layouts,
    homogeneous_layout,
    validate_layout,
)

placements_st = st.lists(
    st.builds(
        Placement,
        profile=st.sampled_from(sorted(PROFILES)),
        start=st.integers(0, N_UNITS - 1),
    ),
    min_size=1,
    max_size=8,
)


@given(placements_st)
@settings(max_examples=300, deadline=None)
def test_valid_layouts_respect_all_invariants(pls):
    ok, why = validate_layout(pls)
    if not ok:
        return
    # invariant 1: placement-tree starts
    for pl in pls:
        assert pl.start in PROFILES[pl.profile].starts
    # invariant 2: no overlapping spans
    spans = sorted(pl.span for pl in pls)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert b0 >= a1
    # invariant 3: compute budget
    assert sum(PROFILES[p.profile].compute_slices for p in pls) <= N_COMPUTE_SLICES
    # invariant 4: documented exclusions
    names = {p.profile for p in pls}
    for bad in EXCLUSIONS:
        assert not bad <= names


def test_paper_documented_combinations():
    """§2.1's worked examples."""
    ok, _ = validate_layout([Placement("4g.20gb", 0), Placement("1g.5gb", 4)])
    assert ok, "4g + 1g is explicitly allowed"
    ok, _ = validate_layout(
        [Placement("4g.20gb", 0), Placement("2g.10gb", 4), Placement("1g.5gb", 6)]
    )
    assert ok, "4g + 2g + 1g is explicitly allowed"
    ok, why = validate_layout([Placement("4g.20gb", 0), Placement("3g.20gb", 4)])
    assert not ok, "4g + 3g is the documented exclusion"
    ok, _ = validate_layout([Placement("4g.20gb", 0), Placement("4g.20gb", 4)])
    assert not ok, "2x 4g exceeds compute slices"
    ok, _ = validate_layout([Placement("3g.20gb", 0), Placement("3g.20gb", 4)])
    assert ok, "2x 3g.20gb is a supported A100 split"


def test_homogeneous_layouts_match_paper_parallel_counts():
    """§3.4: max parallel instances per profile (7, 3, 2, 1, 1)."""
    want = {"1g.5gb": 7, "2g.10gb": 3, "3g.20gb": 2, "4g.20gb": 1, "7g.40gb": 1}
    for prof, n in want.items():
        lay = homogeneous_layout(prof)
        assert len(lay) == n, f"{prof}: {len(lay)} != {n}"
        ok, why = validate_layout(lay)
        assert ok, f"{prof} homogeneous layout invalid: {why}"


def test_enumerate_layouts_all_valid_and_nonempty():
    layouts = enumerate_layouts(max_results=64)
    assert len(layouts) >= 10
    for lay in layouts:
        ok, why = validate_layout(list(lay))
        assert ok, why


def test_compute_discount_algebra():
    from repro.core.instance import compute_discount

    assert compute_discount("7g.40gb") == 7 / 8  # F6: MIG overhead slice
    assert compute_discount("3g.20gb") == 3 / 4
    assert compute_discount("1g.5gb") == 1.0
    assert compute_discount("4g.20gb") == 1.0
    assert compute_discount("7g.40gb", partitioned=False) == 1.0  # non-MIG
