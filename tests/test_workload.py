"""Workload API v2: demand traces, phase resolution, the JobSpec adapter,
phase-peak admission, and active-phase contention."""
import dataclasses

import pytest

from repro.configs.base import ShapeSuite
from repro.core.collocation import _PROFILE_ORDER, CollocationScheduler
from repro.core.instance import JobSpec
from repro.core.sharing import CollocationMode, SoloProfile
from repro.core.workload import (
    CHECKPOINT_DEMAND,
    DECODE_DEMAND,
    STEADY_DEMAND,
    DemandTrace,
    Phase,
    Workload,
    WorkloadKind,
    as_workload,
    from_jobspec,
    peak_demand_multiplier,
    phase_step_s,
    serve_workload,
    span_at,
    train_workload,
)
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")


def full_db(arch, *, step_s=1.0, compute_s=None, memory_s=0.0,
            collective_s=0.0, peak_frac=0.1, fits_by_prof=None):
    fits_by_prof = fits_by_prof or {}
    return {
        (arch, SUITE.name, p): {
            "fits": fits_by_prof.get(p, True),
            "step_s": step_s,
            "compute_s": step_s if compute_s is None else compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "peak_bytes_per_device": peak_frac * HBM_PER_CHIP,
        }
        for p in _PROFILE_ORDER
    }


# -- DemandTrace + phase_step_s ------------------------------------------------


def test_steady_demand_is_identity():
    assert STEADY_DEMAND.is_identity
    assert not CHECKPOINT_DEMAND.is_identity
    with pytest.raises(ValueError):
        DemandTrace(compute=-0.1)


def test_phase_step_identity_reproduces_record_exactly():
    rec = {"step_s": 0.0123, "compute_s": 0.01, "memory_s": 0.004,
           "collective_s": 0.001}
    assert phase_step_s(rec, STEADY_DEMAND) == 0.0123


def test_phase_step_scales_terms_and_latency_residual():
    # busy = compute 0.01; residual latency = 0.002
    rec = {"step_s": 0.012, "compute_s": 0.01, "memory_s": 0.004,
           "collective_s": 0.0}
    d = DemandTrace(compute=0.1, memory=2.0, latency=3.0)
    # scaled busy = max(0.001, 0.008) = 0.008; latency 0.002 * 3
    assert phase_step_s(rec, d) == pytest.approx(0.006 + 0.008)


def test_phase_step_minimal_record_defaults_compute_to_step():
    rec = {"step_s": 1.0}  # hand-built DBs carry only step_s
    assert phase_step_s(rec, DemandTrace(compute=0.5)) == pytest.approx(0.5)


# -- phase resolution ----------------------------------------------------------


def test_resolve_elastic_phase_absorbs_remainder():
    wl = train_workload("t", "a", SUITE, warmup_steps=5, checkpoint_steps=3)
    spans = wl.resolve(100)
    assert [(s.name, s.start_step, s.end_step) for s in spans] == [
        ("warmup", 0, 5), ("steady", 5, 97), ("checkpoint", 97, 100)
    ]
    assert span_at(spans, 0.0).name == "warmup"
    assert span_at(spans, 5.0).name == "steady"  # boundary enters next span
    assert span_at(spans, 99.5).name == "checkpoint"
    assert span_at(spans, 250.0).name == "checkpoint"  # past the end: last


def test_resolve_clamps_when_total_smaller_than_fixed_phases():
    wl = train_workload("t", "a", SUITE, warmup_steps=5, checkpoint_steps=3)
    spans = wl.resolve(4)  # smaller than warmup alone
    assert spans[0].name == "warmup" and spans[0].steps == 4
    assert spans[-1].end_step == 4
    # spans partition [0, total) exactly for any total
    for total in (1, 2, 5, 7, 8, 9, 100):
        spans = wl.resolve(total)
        assert spans[0].start_step == 0 and spans[-1].end_step == total
        for a, b in zip(spans, spans[1:]):
            assert a.end_step == b.start_step


def test_resolve_without_elastic_phase_extends_tail():
    wl = Workload("t", "a", SUITE, phases=(Phase("p1", steps=2),
                                           Phase("p2", steps=3)))
    spans = wl.resolve(10)
    assert spans[-1].name == "p2" and spans[-1].end_step == 10


def test_at_most_one_elastic_phase():
    with pytest.raises(ValueError):
        Workload("t", "a", SUITE, phases=(Phase("p1"), Phase("p2")))
    with pytest.raises(ValueError):
        Workload("t", "a", SUITE, phases=())


# -- constructors + adapter ----------------------------------------------------


def test_train_and_serve_constructors():
    tr = train_workload("t", "a", SUITE)
    assert tr.kind == WorkloadKind.TRAIN and tr.objective == "throughput"
    assert [p.name for p in tr.phases] == ["warmup", "steady", "checkpoint"]
    sv = serve_workload("s", "a", SUITE, slo_step_s=1e-3)
    assert sv.kind == WorkloadKind.SERVE and sv.objective == "slo"
    assert sv.slo_step_s == 1e-3
    decode = sv.phases[-1]
    assert decode.latency_sensitive and decode.steps is None


def test_jobspec_adapter_roundtrip():
    spec = JobSpec("j", "a", SUITE, priority=3, min_profile="2g.10gb")
    wl = from_jobspec(spec)
    assert (wl.name, wl.arch, wl.priority, wl.min_profile) == (
        "j", "a", 3, "2g.10gb"
    )
    assert len(wl.phases) == 1 and wl.phases[0].demand.is_identity
    assert wl.peak_demand_multiplier == 1.0
    assert as_workload(wl) is wl
    assert peak_demand_multiplier(spec) == 1.0
    with pytest.raises(TypeError):
        as_workload("not a job")


def test_workload_supports_dataclasses_replace_like_jobspec():
    """The cluster's displacement paths replace priority/min_profile on the
    spec — a Workload must survive them with its phases intact."""
    wl = serve_workload("s", "a", SUITE, slo_step_s=1e-3)
    bumped = dataclasses.replace(wl, priority=10, min_profile="3g.20gb")
    assert bumped.priority == 10 and bumped.phases == wl.phases
    assert bumped.slo_step_s == wl.slo_step_s


# -- scheduler integration -----------------------------------------------------


def test_scheduler_predictions_identical_for_jobspec_and_adapter():
    db = full_db("a", step_s=0.01)
    s = CollocationScheduler(db)
    spec = JobSpec("j", "a", SUITE)
    for mode in CollocationMode:
        via_spec = s.schedule([spec], mode=mode)
        via_wl = s.schedule([from_jobspec(spec)], mode=mode)
        assert [a.predicted_step_s for a in via_spec.assignments] == [
            a.predicted_step_s for a in via_wl.assignments
        ]


def test_admission_uses_phase_peak_memory():
    """A workload whose checkpoint burst overflows a slice is rejected
    there even though its steady footprint fits."""
    db = full_db("a", step_s=0.01, peak_frac=0.97)  # steady fits everywhere
    s = CollocationScheduler(db)
    flat = JobSpec("flat", "a", SUITE)
    assert s.admissible(flat, "1g.5gb")[0]  # record's own fits bit
    bursty = train_workload("bursty", "a", SUITE)  # checkpoint mem_bytes 1.05
    ok, why = s.admissible(bursty, "1g.5gb")
    assert not ok and "phase peak" in why
    assert s.smallest_admissible(bursty) is None  # same record every profile


def test_admission_phase_peak_can_admit_below_steady():
    """A serve session's working set (~half of training) fits slices the
    training record OOMs on — phase-aware admission recovers them."""
    db = full_db("a", step_s=0.01, peak_frac=1.6,
                 fits_by_prof={p: False for p in _PROFILE_ORDER})
    s = CollocationScheduler(db)
    assert s.smallest_admissible(JobSpec("flat", "a", SUITE)) is None
    sv = serve_workload("sv", "a", SUITE, slo_step_s=1e-3)  # peak mult 0.5
    assert s.smallest_admissible(sv) == "1g.5gb"


def test_shared_schedule_times_jobs_at_active_phase():
    db = full_db("a", step_s=0.011, compute_s=0.01, memory_s=0.003,
                 collective_s=0.001, peak_frac=0.1)
    s = CollocationScheduler(db)
    sv = serve_workload("sv", "a", SUITE, slo_step_s=1e-3)
    steady = s.schedule([sv], mode=CollocationMode.MPS)
    decode = s.schedule(
        [sv], mode=CollocationMode.MPS, active_phases={"sv": DECODE_DEMAND}
    )
    # decode demand: compute x0.05, memory x0.6 -> far shorter steps
    assert decode.assignments[0].predicted_step_s < (
        0.5 * steady.assignments[0].predicted_step_s
    )


def test_solo_profile_scaled_by_demand():
    p = SoloProfile("j", compute_s=1e-3, memory_s=4e-4, collective_s=1e-4,
                    latency_s=1e-3, peak_bytes_per_device=100.0)
    assert p.scaled(STEADY_DEMAND) is p
    q = p.scaled(DECODE_DEMAND)
    assert q.compute_s == pytest.approx(5e-5)
    assert q.memory_s == pytest.approx(2.4e-4)
    assert q.peak_bytes_per_device == pytest.approx(45.0)
    assert q.latency_s == p.latency_s  # decode keeps the dispatch floor


def test_mps_dispatch_queue_inflates_latency_dominated_neighbour():
    """The MIGPerf mechanism: a saturating training neighbour stretches a
    decode step through the dispatch queue even with no bandwidth resource
    contended."""
    from repro.core.sharing import mps_contention

    trains = [
        SoloProfile(f"train{i}", compute_s=1e-2, memory_s=3e-3,
                    collective_s=1e-3)
        for i in range(2)
    ]
    decode = SoloProfile("decode", compute_s=5e-5, memory_s=3e-4,
                         collective_s=1e-5)
    solo = mps_contention([decode]).effective_step_s["decode"]
    contended = mps_contention([decode, *trains])
    assert contended.contention["latency_s"] > 1.5
    assert contended.effective_step_s["decode"] > 1.5 * solo
    # sub-saturating pairs stay free: one decode + one decode
    pair = mps_contention([decode, SoloProfile("d2", 5e-5, 3e-4, 1e-5)])
    assert pair.contention["latency_s"] == 1.0
