"""Trace/metrics layer (core/obs/): recorder determinism, the disabled
no-op contract, Perfetto export schema, and decision-provenance
completeness for every scheduler action the PROVENANCE registry names."""
import dataclasses
import json

import pytest

from repro.configs.base import ShapeSuite
from repro.core.cluster import Cluster
from repro.core.collocation import _PROFILE_ORDER
from repro.core.instance import JobSpec
from repro.core.obs import (
    EXPORTERS,
    PROVENANCE,
    TraceRecorder,
    export_counters,
    export_perfetto,
)
from repro.core.gang.parallelism import Parallelism
from repro.core.sharing import CollocationMode
from repro.core.workload import train_workload
from repro.launch.simulate import (
    GANG_FLEET_SKUS,
    SIM_SAMPLES_PER_EPOCH,
    SIM_SUITE,
    run_cell,
    synthetic_sku_dbs,
)
from repro.launch import simulate
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")
SAMPLES = 320


def make_db(arch, *, step_by_prof=None, fits_by_prof=None, peak_frac=0.1):
    step_by_prof = step_by_prof or {}
    fits_by_prof = fits_by_prof or {}
    db = {}
    for prof in _PROFILE_ORDER:
        db[(arch, SUITE.name, prof)] = {
            "fits": fits_by_prof.get(prof, True),
            "step_s": step_by_prof.get(prof, 0.01),
            "peak_bytes_per_device": peak_frac * HBM_PER_CHIP,
        }
    return db


def _dumps(doc):
    return json.dumps(doc, indent=2, sort_keys=True)


# -- shared traced cells (each scenario runs once per session) ---------------------


@pytest.fixture(scope="module")
def traced_tsm():
    rec = TraceRecorder()
    cell = run_cell("train_serve_mix", "all-mig", seed=0, trace=rec)
    return rec, cell


@pytest.fixture(scope="module")
def traced_gang():
    rec = TraceRecorder()
    run_cell("gang_pipeline", "all-mig", seed=0, trace=rec)
    return rec


@pytest.fixture(scope="module")
def traced_forecast():
    rec = TraceRecorder()
    run_cell("diurnal_serve", "forecast", seed=0, trace=rec)
    return rec


# -- determinism -------------------------------------------------------------------


def test_two_runs_export_byte_identical_documents(traced_tsm):
    rec1, _ = traced_tsm
    rec2 = TraceRecorder()
    run_cell("train_serve_mix", "all-mig", seed=0, trace=rec2)
    assert _dumps(export_perfetto(rec1)) == _dumps(export_perfetto(rec2))
    assert _dumps(export_counters(rec1)) == _dumps(export_counters(rec2))


def test_tracing_does_not_perturb_the_simulation(traced_tsm):
    _, traced_cell = traced_tsm
    plain_cell = run_cell("train_serve_mix", "all-mig", seed=0)
    assert _dumps(plain_cell) == _dumps(traced_cell)


# -- the disabled recorder is a strict no-op ---------------------------------------


def test_disabled_recorder_records_nothing():
    rec = TraceRecorder(enabled=False)
    rec.track("scheduler")
    rec.span("scheduler", "s", 0.0, 1.0)
    rec.instant("scheduler", "custom", 0.5)
    rec.counter("queue_depth", 0.0, 3)
    rec.step_sample(0.0, "j", "a", "1g.5gb", 0.01, 0.01, source="observe")
    assert len(rec) == 0
    assert rec.tracks == [] and rec.spans == [] and rec.instants == []
    assert rec.counters == {} and rec.samples == []
    # disabled validation never runs either — no ValueError on missing keys
    rec.instant("scheduler", "dispatch", 0.0)


def test_cluster_detaches_a_disabled_recorder():
    db = make_db("small")
    c = Cluster(db, [("d0", CollocationMode.MIG)],
                trace=TraceRecorder(enabled=False))
    assert c.trace is None  # no per-event hook overhead on the hot path
    c.submit(JobSpec("j0", "small", SUITE), 0.0, epochs=1,
             samples_per_epoch=SAMPLES)
    assert c.run().completed == 1


# -- provenance validation ---------------------------------------------------------


def test_instant_rejects_missing_provenance_keys():
    rec = TraceRecorder()
    with pytest.raises(ValueError, match="dispatch.*wait_s"):
        rec.instant("scheduler", "dispatch", 0.0,
                    args={"job": "j", "device": "d0"})
    # names outside the registry carry whatever they like
    rec.instant("scheduler", "custom_note", 0.0, args={"free": "form"})
    assert len(rec.instants_named("custom_note")) == 1


def test_every_recorded_instant_carries_its_required_keys(
        traced_tsm, traced_gang, traced_forecast):
    recs = [traced_tsm[0], traced_gang, traced_forecast]
    checked = 0
    for rec in recs:
        for _track, name, _cat, _t, args in rec.instants:
            required = PROVENANCE.get(name)
            if required is None:
                continue
            missing = [k for k in required if k not in (args or {})]
            assert not missing, (name, missing)
            checked += 1
    assert checked > 100  # the grid cells actually exercise the hooks


# -- per-kind provenance: the rarer decision paths ---------------------------------


def _frag_db():
    db = {}
    db.update(make_db("small", step_by_prof={p: 0.01 for p in _PROFILE_ORDER}))
    db.update(
        make_db("twog", fits_by_prof={"1g.5gb": False},
                step_by_prof={p: 0.01 for p in _PROFILE_ORDER}, peak_frac=0.3)
    )
    return db


def test_replan_instant_carries_layout_and_optimality():
    rec = TraceRecorder()
    c = Cluster(_frag_db(), [("d0", CollocationMode.MIG)], policy="planner",
                reconfig_cost_s=0.01, migration_cooldown_s=0.001, trace=rec)
    for i in range(7):
        c.submit(JobSpec(f"s{i}", "small", SUITE), 0.001 * i,
                 epochs=1 if i < 2 else 5, samples_per_epoch=SAMPLES)
    c.submit(JobSpec("big", "twog", SUITE), 0.15, epochs=1,
             samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.migrations == 1
    (inst,) = rec.instants_named("replan")
    args = inst[4]
    assert args["device"] == "d0" and args["optimality"] == "exact"
    assert "big" in args["placed"] and len(args["kept"]) == 4
    assert args["layout"] and all("@" in slot for slot in args["layout"])
    assert args["configs_evaluated"] > 0
    # the replan window is also a reconfig span on the device track
    assert any(s[2] == "reconfig" for s in rec.spans if s[0] == "dev:d0")


def test_straggler_repack_instant_names_the_promoted_profile():
    rec = TraceRecorder()
    db = make_db("small", step_by_prof={p: 1.0 for p in _PROFILE_ORDER})
    c = Cluster(db, [("d0", CollocationMode.MIG)],
                scheduler_kwargs={"straggler_tol": 1.5, "ema_alpha": 1.0},
                trace=rec)
    for i in range(3):
        c.submit(JobSpec(f"j{i}", "small", SUITE), 0.0, epochs=1,
                 samples_per_epoch=SAMPLES)
    c.run_until(0.0)
    c.observe_step("j1", 2.5, at_s=1.0)
    c.run()
    (inst,) = rec.instants_named("straggler_repack")
    assert inst[4]["job"] == "j1" and inst[4]["min_profile"] == "2g.10gb"
    # the live observation itself landed as a measured-vs-predicted sample
    obs = [s for s in rec.samples if s["source"] == "observe"]
    assert obs and obs[0]["job"] == "j1"
    assert obs[0]["measured_s"] == pytest.approx(2.5)


def test_reject_instant_carries_the_reason():
    rec = TraceRecorder()
    db = make_db("nofit", fits_by_prof={p: False for p in _PROFILE_ORDER})
    c = Cluster(db, [("d0", CollocationMode.MIG)], trace=rec)
    c.submit(JobSpec("j0", "nofit", SUITE), 0.0, epochs=1,
             samples_per_epoch=SAMPLES)
    assert c.run().rejected == 1
    (inst,) = rec.instants_named("reject")
    assert inst[4]["job"] == "j0" and inst[4]["reason"]


def test_gang_reject_instant_when_capacity_is_lost():
    """A gang rejected *after* admission (its capacity failed away) goes
    through _reject_queued_gang — the gang_reject provenance path. A gang
    unplaceable on arrival takes the plain reject path instead."""
    rec = TraceRecorder()
    dbs = synthetic_sku_dbs(GANG_FLEET_SKUS)
    gang = dataclasses.replace(
        train_workload("g", "qwen2-72b", SIM_SUITE),
        world_size=4,
        parallelism=Parallelism(tensor=2, pipeline=2),
    )
    c = Cluster(dbs, [("d0", CollocationMode.MIG, "a100-80gb"),
                      ("d1", CollocationMode.MIG, "a100-80gb")], trace=rec)
    c.submit(gang, 0.0, epochs=1, samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    c.inject_failure("d0", tuple(range(7)), 0.01)  # permanent: half the fleet
    rep = c.run()
    assert rep.rejected == 1
    (inst,) = rec.instants_named("gang_reject")
    assert inst[4]["gang"] == "g" and "capacity lost" in inst[4]["reason"]
    # the original placement was traced before the capacity vanished
    assert rec.instants_named("gang_place")


def test_provenance_registry_is_fully_exercised(
        traced_tsm, traced_gang, traced_forecast):
    """Every kind in PROVENANCE is recorded by some covered run — a new
    registry entry without a covering hook (or test) fails here."""
    seen = set()
    for rec in (traced_tsm[0], traced_gang, traced_forecast):
        seen |= {i[1] for i in rec.instants}
    # the four rarer paths have dedicated tests above
    seen |= {"replan", "straggler_repack", "reject", "gang_reject"}
    assert set(PROVENANCE) <= seen, sorted(set(PROVENANCE) - seen)


# -- span + counter content --------------------------------------------------------


def test_job_lifecycle_spans_and_counters(traced_tsm):
    rec, cell = traced_tsm
    cats = {s[2] for s in rec.spans}
    assert {"queue", "phase", "occupancy"} <= cats
    # every dispatched job closed a queued span on the queue track
    n_disp = len(rec.instants_named("dispatch"))
    queued = [s for s in rec.spans if s[0] == "queue"]
    assert queued and all(s[4] >= s[3] for s in rec.spans)
    assert len(queued) <= n_disp
    assert {"queue_depth", "running_jobs", "slo_attainment"} <= set(rec.counters)
    assert any(name.startswith("util:") for name in rec.counters)
    # counter series are time-ordered
    for series in rec.counters.values():
        assert all(a[0] <= b[0] for a, b in zip(series, series[1:]))


def test_forecast_ticks_carry_the_band_vs_realized(traced_forecast):
    ticks = traced_forecast.instants_named("forecast_tick")
    assert ticks
    for _track, _name, _cat, _t, args in ticks:
        assert args["abs_err_per_s"] == pytest.approx(
            abs(args["rate_per_s"] - args["realized_per_s"]))
        assert args["in_band"] == (
            args["lower_per_s"] <= args["realized_per_s"] <= args["upper_per_s"])


# -- Perfetto / counters export schema ---------------------------------------------


def test_perfetto_document_schema(traced_tsm):
    rec, _ = traced_tsm
    doc = export_perfetto(rec)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert {e["ph"] for e in events} <= {"M", "b", "e", "i", "C"}
    # process + one named thread per registered track
    meta = [e for e in events if e["ph"] == "M"]
    assert meta[0]["name"] == "process_name"
    thread_names = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert {"scheduler", "queue", "jobs"} <= thread_names
    assert any(t.startswith("dev:") for t in thread_names)
    assert thread_names == set(rec.tracks)
    # async begin/end pairs balance per id
    begins = [e["id"] for e in events if e["ph"] == "b"]
    ends = [e["id"] for e in events if e["ph"] == "e"]
    assert sorted(begins) == sorted(ends) == list(range(1, len(rec.spans) + 1))
    # instants are scoped, counters carry a value
    assert all(e["s"] == "t" for e in events if e["ph"] == "i")
    assert all("value" in e["args"] for e in events if e["ph"] == "C")
    json.dumps(doc)  # JSON-serializable end to end


def test_counters_export_schema(traced_tsm):
    rec, _ = traced_tsm
    doc = export_counters(rec)
    assert doc["schema"] == "obs_counters/v1"
    assert doc["totals"]["spans"] == len(rec.spans)
    assert doc["totals"]["instants"] == len(rec.instants)
    assert doc["totals"]["tracks"] == rec.tracks
    # the flat export keeps every sample (no duplicate collapse)
    assert {k: len(v) for k, v in doc["counters"].items()} == {
        k: len(v) for k, v in rec.counters.items()}
    assert all(s["source"] in ("observe", "completion") for s in doc["samples"])
    assert sorted(EXPORTERS) == ["counters", "perfetto"]


# -- CLI integration ---------------------------------------------------------------


def test_simulate_cli_trace_writes_loadable_exports(tmp_path):
    rc = simulate.main([
        "--steps", "6", "--seed", "0",
        "--scenarios", "train_serve_mix", "--policies", "all-mig",
        "--trace", "--out", str(tmp_path),
    ])
    assert rc == 0
    trace = tmp_path / "_trace__train_serve_mix__all-mig.json"
    counters = tmp_path / "_counters__train_serve_mix__all-mig.json"
    assert trace.exists() and counters.exists()
    doc = json.loads(trace.read_text())
    assert doc["traceEvents"]
    assert json.loads(counters.read_text())["schema"] == "obs_counters/v1"
    # the cell artifact itself ignores the recorder
    cell = json.loads((tmp_path / "train_serve_mix__all-mig.json").read_text())
    assert cell["status"] == "OK"


def test_simulate_cli_single_exporter_writes_only_that_file(tmp_path):
    rc = simulate.main([
        "--steps", "6", "--seed", "0",
        "--scenarios", "train_serve_mix", "--policies", "all-mig",
        "--trace", "--trace-exporter", "perfetto", "--out", str(tmp_path),
    ])
    assert rc == 0
    assert (tmp_path / "_trace__train_serve_mix__all-mig.json").exists()
    assert not (tmp_path / "_counters__train_serve_mix__all-mig.json").exists()


def test_simulate_cli_exporter_requires_trace_flag(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--trace-exporter", "perfetto"])
    assert exc.value.code == 2
    assert "--trace" in capsys.readouterr().err


def test_simulate_cli_unknown_exporter_lists_choices(capsys):
    with pytest.raises(SystemExit) as exc:
        simulate.main(["--trace", "--trace-exporter", "bogus"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "bogus" in err and "perfetto" in err and "counters" in err


def test_simulate_list_mentions_trace_exporters(capsys):
    assert simulate.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "trace exporters" in out
    assert "perfetto" in out and "counters" in out
