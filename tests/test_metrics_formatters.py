"""core/metrics.py table formatters: header-only on empty input, aligned
single-row rendering — the text surfaces EXPERIMENTS.md and the launch
scripts print."""
from repro.core.metrics import (
    DeviceGroupReport,
    ModeComparison,
    format_group_table,
    format_mode_table,
)


def _mode_row():
    return ModeComparison(
        workload="resnet_small",
        mode="mps",
        k_jobs=3,
        effective_step_s=0.0125,
        solo_step_s=0.01,
        fits=True,
        max_interference=1.25,
    )


def _group_row():
    return DeviceGroupReport(
        group="1g.5gb parallel",
        workload="resnet_small",
        instance_metrics=[
            {"gract": 0.14, "smact": 0.12, "smocc_proxy": 0.3, "drama": 0.05}
        ],
        device_metrics={
            "gract": 0.143,
            "smact": 0.125,
            "smocc_proxy": 0.301,
            "drama": 0.052,
        },
        occupied_units=1,
    )


def test_format_mode_table_empty_is_header_and_rule_only():
    out = format_mode_table([])
    lines = out.splitlines()
    assert len(lines) == 2  # header + rule, no data rows
    assert "workload" in lines[0] and "speedup" in lines[0]
    assert set(lines[1]) == {"-"}
    assert len(lines[1]) == len(lines[0])


def test_format_mode_table_single_row_values_and_alignment():
    out = format_mode_table([_mode_row()])
    lines = out.splitlines()
    assert len(lines) == 3
    row = lines[2]
    assert "resnet_small" in row and "mps" in row
    assert "0.01000" in row  # solo_step_s at 5 decimals
    assert "0.01250" in row  # effective_step_s
    assert "1.25x" in row  # interference rendered with the x suffix
    assert "True" in row
    # every data line is exactly as wide as the header grid
    assert all(len(line) <= len(lines[0]) for line in lines[1:])


def test_format_group_table_empty_is_header_and_rule_only():
    out = format_group_table([])
    lines = out.splitlines()
    assert len(lines) == 2
    assert "group" in lines[0] and "GRACT" in lines[0]
    assert lines[1] == "-" * len(lines[0])


def test_format_group_table_single_row_values():
    out = format_group_table([_group_row()])
    lines = out.splitlines()
    assert len(lines) == 3
    row = lines[2]
    assert "1g.5gb parallel" in row and "resnet_small" in row
    assert "0.143" in row and "0.125" in row and "0.301" in row
    assert "      1" in row  # n_inst column counts instance_metrics
