"""Forecast-driven autoscaling (core/forecast/ + Cluster(policy="forecast")).

Three layers, mirroring the subsystem:

- estimator math (pure, jax-free): windowed / EWMA / seasonal rate
  estimators are deterministic functions of the observation stream, emit
  sane confidence bands, and the seasonal estimator predicts the next
  ramp from *completed* periods only — cold start reports a zero lower
  band ("day one is for learning");
- policy math (pure): Little's-law warm-set sizing with release
  hysteresis, and the wave-amortization gate that prices a pre-warm flip
  against the forecast's conservative lower band;
- cluster integration: the FORECAST_TICK clock, pre-warm reservations in
  the queue, the drain guard, and the tentpole's acceptance inequality on
  the diurnal_serve trace — forecast strictly beats the reactive adaptive
  policy on SLO attainment, byte-deterministically.
"""
import json

import pytest

from repro.core.cluster import Cluster
from repro.core.forecast import (
    AutoscaleDecision,
    EWMARateEstimator,
    ForecastConfig,
    RateForecast,
    SeasonalRateEstimator,
    WindowedRateEstimator,
    make_estimator,
    next_tick,
    plan_autoscale,
    wave_amortizes,
)
from repro.core.queueing import AdmissionQueue
from repro.launch.simulate import _rounded, run_cell

# -- estimators -------------------------------------------------------------------


def test_windowed_rate_counts_and_evicts():
    e = WindowedRateEstimator(window_s=1.0)
    for t in (0.1, 0.2, 0.3, 0.9):
        e.observe(t)
    fc = e.forecast(1.0, 0.5)
    assert fc.rate_per_s == pytest.approx(4.0)
    assert 0.0 <= fc.lower_per_s <= fc.rate_per_s <= fc.upper_per_s
    # the window slides: at t=1.25 only 0.3 and 0.9 remain
    fc = e.forecast(1.25, 0.5)
    assert fc.rate_per_s == pytest.approx(2.0)


def test_windowed_empty_window_keeps_nondegenerate_upper_band():
    e = WindowedRateEstimator(window_s=1.0)
    fc = e.forecast(5.0, 0.5)
    assert fc.rate_per_s == 0.0
    assert fc.upper_per_s > 0.0  # "we could have just missed one"


def test_ewma_converges_to_regular_rate_and_decays_on_silence():
    e = EWMARateEstimator(tau_s=0.5)
    for i in range(200):
        e.observe(i * 0.1)  # 10/s
    live = e.forecast(20.0, 1.0)
    assert live.rate_per_s == pytest.approx(10.0, rel=0.05)
    # a long silence is evidence the rate collapsed
    silent = e.forecast(30.0, 1.0)
    assert silent.rate_per_s < 0.1 * live.rate_per_s


def test_estimators_are_deterministic_functions_of_the_stream():
    stream = [0.01 * i**1.5 for i in range(50)]
    for name in ("window", "ewma", "seasonal"):
        a, b = make_estimator(name), make_estimator(name)
        for t in stream:
            a.observe(t)
            b.observe(t)
        assert a.forecast(1.0, 0.25) == b.forecast(1.0, 0.25)


def test_seasonal_cold_start_reports_zero_lower_band():
    e = SeasonalRateEstimator(period_s=1.0, n_bins=4)
    for t in (0.05, 0.1, 0.15, 0.2):
        e.observe(t)
    fc = e.forecast(0.5, 0.25)  # still inside the first period
    assert fc.source == "seasonal:warmup"
    assert fc.lower_per_s == 0.0
    assert fc.periods == 0


def test_seasonal_predicts_next_ramp_from_completed_periods():
    """10 arrivals in the first quarter of day 0, then quiet. Approaching
    day 1's same quarter, the learned profile sees the ramp coming; mid-day
    the forecast is flat zero."""
    e = SeasonalRateEstimator(period_s=1.0, n_bins=4)
    for i in range(10):
        e.observe(0.02 * i)  # all inside bin 0 ([0, 0.25))
    e.observe(1.3)  # rolls day 0 into the profile (bin 1 of day 1)
    trough = e.forecast(1.3, 0.2)  # [1.3, 1.5): bins 1-2, quiet yesterday
    ramp = e.forecast(1.85, 0.2)  # [1.85, 2.05): wraps into day 2's bin 0
    assert trough.periods == 1 and ramp.periods == 1
    assert trough.source == "seasonal"
    assert trough.rate_per_s == pytest.approx(0.0)
    assert ramp.rate_per_s > 5.0  # bin-0 rate 40/s over a quarter of window
    assert ramp.upper_per_s >= ramp.rate_per_s >= ramp.lower_per_s >= 0.0


def test_seasonal_keeps_at_most_max_periods_profiles():
    e = SeasonalRateEstimator(period_s=1.0, n_bins=2, max_periods=3)
    for day in range(6):
        e.observe(day + 0.1)
    assert len(e._profiles) == 3


def test_make_estimator_rejects_unknown_name():
    with pytest.raises(ValueError):
        make_estimator("prophet")


# -- policy -----------------------------------------------------------------------


def _fc(rate, lower=None, upper=None, horizon=0.5):
    lower = rate if lower is None else lower
    upper = rate if upper is None else upper
    return RateForecast(
        at_s=0.0, horizon_s=horizon, rate_per_s=rate,
        lower_per_s=lower, upper_per_s=upper, source="test",
    )


def test_plan_autoscale_grows_the_warm_set_ahead_of_demand():
    cfg = ForecastConfig()
    d = plan_autoscale(
        _fc(10.0), session_s=1.0, device_caps=[4.0, 4.0, 4.0, 4.0],
        reserved=1, cfg=cfg,
    )
    # 10/s x 1s x 1.2 headroom = 12 sessions -> 3 devices of capacity 4
    assert d.predicted_sessions == pytest.approx(12.0)
    assert d.target_devices == 3
    assert d.prewarm == 2 and d.release == 0


def test_plan_autoscale_releases_only_past_the_hysteresis_margin():
    cfg = ForecastConfig(release_hysteresis=0.7)
    # trough: mean demand tiny, but the upper band still fills most of the
    # held capacity -> hold (no flapping at the band edge)
    hold = plan_autoscale(
        _fc(0.5, upper=6.0), session_s=1.0, device_caps=[4.0, 4.0],
        reserved=2, cfg=cfg,
    )
    assert hold.release == 0
    # the band collapses -> release down to the upper-band target
    shrink = plan_autoscale(
        _fc(0.5, upper=1.0), session_s=1.0, device_caps=[4.0, 4.0],
        reserved=2, cfg=cfg,
    )
    assert shrink.release == 1 and shrink.prewarm == 0


def test_plan_autoscale_without_session_estimate_is_a_noop():
    d = plan_autoscale(
        _fc(10.0), session_s=0.0, device_caps=[4.0], reserved=1,
        cfg=ForecastConfig(),
    )
    assert d == AutoscaleDecision(0.0, 0, 0, 1)


def test_wave_amortizes_gates_on_the_lower_band():
    cfg = ForecastConfig(amortize_factor=1.0)
    # free flips always pay
    assert wave_amortizes(
        _fc(0.0), session_s=1.0, share_devices=1, cost_s=0.0, cfg=cfg,
    )
    # cold start (lower band 0) never pays for downtime: day one learns
    assert not wave_amortizes(
        _fc(100.0, lower=0.0), session_s=1.0, share_devices=1, cost_s=0.5,
        cfg=cfg,
    )
    # a confident wave covers the flip
    assert wave_amortizes(
        _fc(100.0, lower=80.0), session_s=1.0, share_devices=2, cost_s=0.5,
        cfg=cfg,
    )


def test_forecast_config_validates():
    with pytest.raises(ValueError):
        ForecastConfig(estimator="prophet")
    with pytest.raises(ValueError):
        ForecastConfig(tick_s=0.0)
    with pytest.raises(ValueError):
        ForecastConfig(release_hysteresis=1.5)


def test_next_tick_advances_past_float_quantized_grid_points():
    """Regression: 0.0375 / 0.0025 floors to 14.999... -> naive floor+1
    lands back on 0.0375 and the tick clock re-arms itself at the same
    timestamp forever."""
    assert next_tick(0.0375, 0.0025) > 0.0375
    t, seen = 0.0, []
    for _ in range(100):
        t = next_tick(t, 0.0025)
        seen.append(t)
    assert all(b > a for a, b in zip(seen, seen[1:]))
    assert seen[-1] == pytest.approx(100 * 0.0025, rel=1e-9)


# -- queue reservations -----------------------------------------------------------


def test_prewarm_vetoes_other_kinds_but_not_the_warmed_kind():
    q = AdmissionQueue()
    assert q.prewarm("d0", "serve") is True
    assert q.prewarm("d0", "serve") is False  # idempotent, not fresh
    assert q.prewarm_blocks("d0", "train")
    assert not q.prewarm_blocks("d0", "serve")
    assert not q.prewarm_blocks("d1", "train")  # unwarmed device: no veto
    assert q.prewarmed_devices == frozenset({"d0"})
    assert q.prewarm_release("d0") is True
    assert q.prewarm_release("d0") is False
    assert not q.prewarm_blocks("d0", "train")
    assert q.prewarms_made == 1 and q.prewarms_released == 1


# -- cluster integration ----------------------------------------------------------


def _db():
    from repro.launch.simulate import synthetic_char_db

    return synthetic_char_db()


def test_forecast_config_requires_forecast_policy():
    with pytest.raises(ValueError):
        Cluster(_db(), [("d0", "mps")], policy="adaptive",
                forecast=ForecastConfig())


def test_forecast_report_block_only_under_forecast_policy():
    adaptive = run_cell("diurnal_serve", "best", n_jobs=6, seed=0)
    forecast = run_cell("diurnal_serve", "forecast", n_jobs=6, seed=0)
    assert "forecast" not in adaptive["report"]
    block = forecast["report"]["forecast"]
    assert block["estimator"] == "seasonal"
    assert block["ticks"] > 0
    assert block["serve_arrivals"] > 0


def test_acceptance_forecast_beats_adaptive_on_diurnal_serve():
    """The tentpole's bar (scaled to test size; CI pins the full n=60
    cell): strictly better SLO attainment than the reactive adaptive
    policy, no more SLO-miss-triggered (reactive) flips, and the drain
    guard leaves nothing stranded behind pre-warm reservations."""
    adaptive = run_cell("diurnal_serve", "best", n_jobs=6, seed=0)["report"]
    forecast = run_cell("diurnal_serve", "forecast", n_jobs=6, seed=0)["report"]
    assert forecast["slo_attainment"] > adaptive["slo_attainment"]
    assert forecast["forecast"]["reactive_migrations"] <= adaptive["migrations"]
    assert forecast["completed"] == adaptive["completed"] == 120
    assert forecast["still_queued"] == 0
    fc = forecast["forecast"]
    assert fc["prewarms_made"] == fc["prewarms_released"] > 0


def test_forecast_cell_is_byte_deterministic():
    def artifact():
        cell = run_cell("diurnal_serve", "forecast", n_jobs=6, seed=0)
        return (
            json.dumps(_rounded(cell), indent=2, sort_keys=True) + "\n"
        ).encode()

    assert artifact() == artifact()
