"""Tier-1 guard: the whole scheduling/simulation stack imports and runs
with jax masked out of sys.modules.

The CI cluster-sim job and launch/simulate.py depend on ``repro.core``
(scheduler, cluster, workload API) being importable without an accelerator
runtime — core/instance.py defers jax to InstanceRuntime method bodies and
nothing else under repro.core's import graph may pull it in at module
scope. This test locks that in by masking jax in a fresh interpreter
(``sys.modules[name] = None`` makes any ``import jax...`` raise
ImportError) and then importing the stack AND running a simulation cell
end to end.
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_PROBE = """
import sys
for name in ("jax", "jaxlib", "flax", "optax"):
    sys.modules[name] = None  # any `import jax...` now raises ImportError

import repro.core  # the public API surface
import repro.core.workload
import repro.core.collocation
import repro.core.cluster
import repro.core.sharing
import repro.core.queueing
import repro.core.events
import repro.core.planner
import repro.core.planner.enumerator
import repro.core.planner.costmodel
import repro.core.planner.optimizer
import repro.core.forecast
import repro.core.forecast.estimator
import repro.core.forecast.policy
import repro.core.obs
import repro.core.obs.recorder
import repro.core.obs.perfetto
import repro.core.calib
import repro.core.calib.records
import repro.core.calib.harness
import repro.core.calib.fit
import repro.core.calib.online

from repro.core.workload import serve_workload, train_workload  # noqa: F401
from repro.core.planner import enumerate_configs, plan_placements  # noqa: F401
from repro.core.forecast import make_estimator, plan_autoscale  # noqa: F401

assert len(enumerate_configs()) == 296  # the partition tree, jax-free

# and the trace-driven simulator actually runs, end to end
from repro.launch.simulate import run_cell

cell = run_cell("train_serve_mix", "all-mig", n_jobs=8, n_devices=2)
assert cell["status"] == "OK", cell
assert cell["report"]["completed"] + cell["report"]["rejected"] == cell["n_jobs"]

# the planner fleet + fragmentation scenario run jax-free too (the whole
# decision layer, optimizer included)
cell = run_cell("fragmentation", "planner", n_jobs=10, n_devices=2)
assert cell["status"] == "OK", cell
assert cell["report"]["still_queued"] == 0, cell

# forecast-driven autoscaling: the estimator/policy math and the
# FORECAST_TICK clock are pure stdlib too
cell = run_cell("diurnal_serve", "forecast", n_jobs=6, n_devices=2)
assert cell["status"] == "OK", cell
assert cell["report"]["forecast"]["ticks"] > 0, cell

# the trace layer records and exports jax-free as well
from repro.core.obs import TraceRecorder, export_counters, export_perfetto

rec = TraceRecorder()
cell = run_cell("train_serve_mix", "all-mig", n_jobs=8, n_devices=2, trace=rec)
assert cell["status"] == "OK", cell
assert len(rec.spans) > 0 and len(rec.instants) > 0
assert export_perfetto(rec)["traceEvents"]
assert export_counters(rec)["counters"]

# the calibration loop — measure (stub), fit, refine, score — is pure
# stdlib too, and the kernel backend only imports jax inside method bodies
from repro.core.calib import StubBackend, calibration_report, run_calibration
from repro.launch.simulate import synthetic_char_db

db = synthetic_char_db()
backend = StubBackend(db, seed=0)
result = run_calibration(db, backend, seed=0)
score = calibration_report(result, backend.true_step_s)
assert score["calibrated_mean_abs_rel_err"] < score["seed_mean_abs_rel_err"]
print("jax-free-ok")
"""


def test_scheduling_stack_imports_and_simulates_without_jax():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _PROBE],
        capture_output=True,
        text=True,
        cwd=REPO,
        env=env,
        timeout=120,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "jax-free-ok" in proc.stdout
