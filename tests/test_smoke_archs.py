"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeSuite
from repro.configs.registry import ASSIGNED, CONFIGS, PAPER_WORKLOADS
from repro.models.model_api import build_model
from repro.optim import adamw
from repro.runtime import train_step as ts
from repro.sharding.plan import make_plan

SUITE = ShapeSuite("smoke", 32, 2, "train")


def _batch(cfg, key):
    m = build_model(cfg)
    specs = m.input_specs(SUITE)
    ks = jax.random.split(key, len(specs))
    out = {}
    for (name, s), k in zip(specs.items(), ks):
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.n_classes if cfg.family == "resnet" else cfg.vocab
            out[name] = jax.random.randint(k, s.shape, 0, hi, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return m, out


@pytest.mark.parametrize("arch", sorted(CONFIGS))
def test_loss_and_grad_step(arch):
    cfg = CONFIGS[arch].reduced()
    model, batch = _batch(cfg, jax.random.key(0))
    plan = make_plan(cfg, None)
    opt_cfg = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
    state = ts.init_train_state(model, jax.random.key(1), opt_cfg)
    step = jax.jit(ts.build_train_step(model, plan, opt_cfg))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert loss > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state["params"], new_state["params"],
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0, f"{arch}: params unchanged"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_serving_shapes(arch):
    cfg = ASSIGNED[arch].reduced()
    model, _ = _batch(cfg, jax.random.key(0))
    plan = make_plan(cfg, None)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.n_patches:
        batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        batch["frames"] = jnp.zeros((B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    last, cache = model.prefill(params, batch, plan)
    assert last.shape == (B, cfg.padded_vocab)
    assert jnp.isfinite(last.astype(jnp.float32)).all()
    db = {"token": jnp.argmax(last, -1).astype(jnp.int32)}
    if cfg.enc_layers:
        db["frames"] = batch["frames"]
    logits, cache2 = model.decode(params, db, cache, S, plan)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


def test_resnet_trio_shapes():
    for name, cfg in PAPER_WORKLOADS.items():
        small = cfg.reduced()
        model = build_model(small)
        params = model.init(jax.random.key(0))
        x = jnp.zeros((2, small.img_size, small.img_size, 3), jnp.float32)
        from repro.models import resnet

        logits = resnet.forward(small, params, x, make_plan(small, None))
        assert logits.shape == (2, small.n_classes)
