"""Event-driven cluster: arrivals, queueing, contention re-timing, backfill,
mode migration, failures, stragglers, phase transitions, serve SLOs, and
byte-level determinism."""
import json

import pytest

from repro.configs.base import ShapeSuite
from repro.core.cluster import Cluster
from repro.core.collocation import _PROFILE_ORDER
from repro.core.events import EventKind, EventQueue
from repro.core.instance import JobSpec, compute_discount
from repro.core.queueing import AdmissionQueue
from repro.core.sharing import CollocationMode, shared_mode_report
from repro.core.workload import serve_workload, train_workload
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")
# 320 samples / batch 32 -> 10 steps per epoch
SAMPLES = 320


def make_db(arch, *, step_s=0.01, peak_frac=0.1, fits_by_prof=None,
            compute_s=None):
    fits_by_prof = fits_by_prof or {}
    db = {}
    for prof in _PROFILE_ORDER:
        db[(arch, SUITE.name, prof)] = {
            "fits": fits_by_prof.get(prof, True),
            "step_s": step_s,
            "compute_s": step_s if compute_s is None else compute_s,
            "memory_s": 0.0,
            "collective_s": 0.0,
            "peak_bytes_per_device": peak_frac * HBM_PER_CHIP,
        }
    return db


# -- plumbing --------------------------------------------------------------------


def test_event_queue_orders_by_time_then_push_order():
    q = EventQueue()
    q.push(2.0, EventKind.ARRIVAL, ("b",))
    q.push(1.0, EventKind.ARRIVAL, ("a",))
    q.push(1.0, EventKind.COMPLETION, ("c",))
    order = [q.pop().payload[0] for _ in range(3)]
    assert order == ["a", "c", "b"]  # equal times keep push order


def test_admission_queue_priority_then_fifo():
    q = AdmissionQueue()
    q.push("low", None, priority=0, enqueued_s=0.0)
    q.push("high", None, priority=5, enqueued_s=1.0)
    q.push("low2", None, priority=0, enqueued_s=0.5)
    assert q.keys() == ["high", "low", "low2"]
    q.remove("low")
    assert q.keys() == ["high", "low2"]
    with pytest.raises(KeyError):
        q.push("high", None, priority=1, enqueued_s=2.0)


# -- arrivals, queueing, completion ------------------------------------------------


def test_fifo_queueing_and_exact_completion_times():
    """Two full-device jobs on one MIG device: the second waits for the
    first — queueing delay replaces the one-shot 'reject forever'."""
    db = make_db("big", step_s=0.01,
                 fits_by_prof={p: p == "7g.40gb" for p in _PROFILE_ORDER})
    c = Cluster(db, [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("a", "big", SUITE), 0.0, epochs=1, samples_per_epoch=SAMPLES)
    c.submit(JobSpec("b", "big", SUITE), 0.05, epochs=1, samples_per_epoch=SAMPLES)
    rep = c.run()
    ja = next(j for j in rep.jobs if j["name"] == "a")
    jb = next(j for j in rep.jobs if j["name"] == "b")
    assert ja["finished_s"] == pytest.approx(0.1)  # 10 steps x 0.01
    assert jb["started_s"] == pytest.approx(0.1)   # waited for a's slot
    assert jb["queueing_delay_s"] == pytest.approx(0.05)
    assert jb["finished_s"] == pytest.approx(0.2)
    assert rep.completed == 2 and rep.rejected == 0 and rep.still_queued == 0


def test_queueing_delay_is_positive_when_device_busy():
    db = make_db("big", step_s=0.02,
                 fits_by_prof={p: p == "7g.40gb" for p in _PROFILE_ORDER})
    c = Cluster(db, [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("a", "big", SUITE), 0.0, epochs=1, samples_per_epoch=SAMPLES)
    c.submit(JobSpec("b", "big", SUITE), 0.05, epochs=1, samples_per_epoch=SAMPLES)
    rep = c.run()
    jb = next(j for j in rep.jobs if j["name"] == "b")
    assert jb["queueing_delay_s"] == pytest.approx(0.2 - 0.05)
    assert jb["finished_s"] == pytest.approx(0.4)


def test_shared_device_retimes_neighbours_processor_sharing():
    """MPS: an arrival stretches the incumbent's step (contention), the
    departure relaxes it — finish times match the processor-sharing math
    derived from the mode's own contention model."""
    db = make_db("sat", step_s=0.09, compute_s=0.1, peak_frac=0.3)
    c = Cluster(db, [("d0", CollocationMode.MPS)])
    specs = [JobSpec("a", "sat", SUITE), JobSpec("b", "sat", SUITE)]
    tb = 0.4
    c.submit(specs[0], 0.0, epochs=1, samples_per_epoch=SAMPLES)
    c.submit(specs[1], tb, epochs=1, samples_per_epoch=SAMPLES)

    # expected step times from the contention model itself
    sched = c.devices["d0"].scheduler
    solo_a = sched.solo_profile(specs[0])
    solo_b = sched.solo_profile(specs[1])
    s_solo = shared_mode_report(
        CollocationMode.MPS, [solo_a]).effective_step_s["a"]
    s_both = shared_mode_report(
        CollocationMode.MPS, [solo_a, solo_b]).effective_step_s["a"]
    assert s_both > s_solo  # saturating pair contends

    steps = 10
    done_at_tb = tb / s_solo
    t_a = tb + (steps - done_at_tb) * s_both  # a finishes first (head start)
    done_b = (t_a - tb) / s_both
    t_b = t_a + (steps - done_b) * s_solo  # b speeds back up alone

    rep = c.run()
    ja = next(j for j in rep.jobs if j["name"] == "a")
    jb = next(j for j in rep.jobs if j["name"] == "b")
    assert ja["finished_s"] == pytest.approx(t_a, rel=1e-9)
    assert jb["finished_s"] == pytest.approx(t_b, rel=1e-9)


def test_backfill_lets_small_jobs_overtake_blocked_head():
    db = {}
    db.update(make_db("big", step_s=0.05,
                      fits_by_prof={p: p == "7g.40gb" for p in _PROFILE_ORDER}))
    db.update(make_db("small", step_s=0.01))
    c = Cluster(db, [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("s0", "small", SUITE), 0.0, epochs=1, samples_per_epoch=SAMPLES)
    # high-priority full-device job is head-of-line blocked behind s0 ...
    c.submit(JobSpec("big", "big", SUITE, priority=5), 0.01,
             epochs=1, samples_per_epoch=SAMPLES)
    # ... and a later small job backfills around it
    c.submit(JobSpec("s1", "small", SUITE), 0.02, epochs=1, samples_per_epoch=SAMPLES)
    rep = c.run()
    s1 = next(j for j in rep.jobs if j["name"] == "s1")
    big = next(j for j in rep.jobs if j["name"] == "big")
    assert s1["started_s"] == pytest.approx(0.02)  # ran immediately
    assert big["started_s"] > s1["started_s"]
    assert rep.hol_blocked_events >= 1
    assert rep.completed == 3


def test_unplaceable_job_rejected_with_reason_others_wait():
    db = make_db("small")
    c = Cluster(db, [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("ok", "small", SUITE), 0.0, epochs=1, samples_per_epoch=SAMPLES)
    c.submit(JobSpec("ghost", "nochar", SUITE), 0.0, epochs=1, samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.rejected == 1
    ghost = next(j for j in rep.jobs if j["name"] == "ghost")
    assert "unplaceable" in ghost["rejected_reason"]
    assert rep.completed == 1


# -- mode migration ---------------------------------------------------------------


def aligned_db(arch="al"):
    """Slice-sized jobs: fit every profile, but the replicated working set
    (~0.205 of HBM each) lets a shared device admit only 4 at once while
    MIG tiles 7 across 1g slices."""
    return make_db(arch, step_s=0.002, compute_s=0.0001, peak_frac=0.205)


def test_adaptive_policy_migrates_and_charges_cost():
    db = aligned_db()
    c = Cluster(db, [("d0", CollocationMode.MPS)], policy="adaptive",
                reconfig_cost_s=0.5)
    for i in range(7):
        c.submit(JobSpec(f"al{i}", "al", SUITE), 0.0, epochs=2,
                 samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.migrations >= 1
    assert rep.reconfig_cost_s == pytest.approx(rep.migrations * 0.5)
    ev = rep.migration_events[0]
    assert ev["from"] == "mps" and ev["to"] == "mig"
    assert rep.devices[0]["mode"] == "mig"
    assert rep.completed == 7 and rep.still_queued == 0
    # every requeued job counted its migration
    requeued = sum(len(e["requeued"]) for e in rep.migration_events)
    assert sum(j["migrations"] for j in rep.jobs) == requeued


def test_static_policy_never_migrates():
    db = aligned_db()
    c = Cluster(db, [("d0", CollocationMode.MPS)], policy="static")
    for i in range(7):
        c.submit(JobSpec(f"al{i}", "al", SUITE), 0.0, epochs=2,
                 samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.migrations == 0 and rep.completed == 7


def test_migration_rollback_charges_lost_steps():
    """A migration mid-epoch rolls displaced jobs back to their last
    checkpoint: the re-done work shows up as lost_steps."""
    db = aligned_db()
    c = Cluster(db, [("d0", CollocationMode.MPS)], policy="adaptive",
                reconfig_cost_s=0.1, migration_cooldown_s=0.0)
    # 4 jobs fit under MPS; they make mid-epoch progress before the 5th..7th
    # arrive and tip best_mode to MIG
    for i in range(4):
        c.submit(JobSpec(f"al{i}", "al", SUITE), 0.0, epochs=5,
                 samples_per_epoch=SAMPLES)
    for i in range(4, 7):
        c.submit(JobSpec(f"al{i}", "al", SUITE), 0.004, epochs=5,
                 samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.migrations >= 1
    assert rep.lost_steps > 0
    assert rep.completed == 7


# -- failures (elastic repack as an event handler) ---------------------------------


def test_mig_failure_kills_intersecting_jobs_only():
    db = make_db("small", step_s=0.01)
    c = Cluster(db, [("d0", CollocationMode.MIG)])
    for i in range(7):
        c.submit(JobSpec(f"j{i}", "small", SUITE), 0.0, epochs=2,
                 samples_per_epoch=SAMPLES)
    c.inject_failure("d0", [0, 1], at_s=0.05)
    rep = c.run()
    ev = rep.failure_events[0]
    assert set(ev["killed"]) == {"j0", "j1"}  # 1g slices at units 0 and 1
    assert set(ev["survivors"]) == {f"j{i}" for i in range(2, 7)}
    # killed jobs were re-queued (priority bumped) and finished elsewhere
    assert rep.completed == 7
    for name in ("j0", "j1"):
        row = next(j for j in rep.jobs if j["name"] == name)
        assert row["priority"] >= 10
    # survivors untouched: they finished exactly on schedule
    j6 = next(j for j in rep.jobs if j["name"] == "j6")
    assert j6["finished_s"] == pytest.approx(0.2)  # 20 steps x 0.01


def test_shared_device_failure_kills_everything():
    db = make_db("small", step_s=0.0001, peak_frac=0.05)
    c = Cluster(db, [("d0", CollocationMode.MPS)])
    for i in range(3):
        c.submit(JobSpec(f"j{i}", "small", SUITE), 0.0, epochs=100,
                 samples_per_epoch=SAMPLES)
    c.inject_failure("d0", [3], at_s=0.01)
    c.inject_repair("d0", [3], at_s=0.05)
    rep = c.run()
    ev = rep.failure_events[0]
    assert set(ev["killed"]) == {"j0", "j1", "j2"}  # no isolation (F3 flip)
    assert ev["survivors"] == []
    assert rep.completed == 3  # repair let them finish


def test_degraded_mig_device_never_migrates_to_shared_mode():
    """A MIG device with failed units must not 'upgrade' to a shared mode
    it cannot actually run (shared placement refuses degraded devices) —
    regression: that migration stranded every job forever."""
    db = make_db("small", step_s=0.01)
    c = Cluster(db, [("d0", CollocationMode.MIG)], policy="adaptive",
                reconfig_cost_s=0.1)
    c.inject_failure("d0", [0], at_s=0.0)
    for i in range(8):  # more jobs than the 6 surviving 1g slots
        c.submit(JobSpec(f"j{i}", "small", SUITE), 0.01, epochs=1,
                 samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.completed == 8 and rep.still_queued == 0
    assert rep.devices[0]["mode"] == "mig"


# -- straggler EMA folded into the loop --------------------------------------------


def test_straggler_observation_triggers_live_repack():
    db = make_db("small", step_s=1.0)
    c = Cluster(db, [("d0", CollocationMode.MIG)],
                scheduler_kwargs={"straggler_tol": 1.5, "ema_alpha": 1.0})
    for i in range(3):
        c.submit(JobSpec(f"j{i}", "small", SUITE), 0.0, epochs=1,
                 samples_per_epoch=SAMPLES)
    c.run_until(0.0)  # place everyone
    c.observe_step("j1", 2.5, at_s=1.0)  # way past tol x predicted 1.0
    rep = c.run()
    assert rep.straggler_repacks >= 1
    j1 = c.jobs["j1"]
    assert j1.spec.min_profile == "2g.10gb"  # one profile up from 1g
    assert j1.straggler_repacks == 1
    assert rep.completed == 3


# -- phase transitions + serve SLOs ------------------------------------------------


def test_phase_plan_drives_per_phase_step_times_on_mig():
    """A training workload runs warmup (compute x1.25), steady (identity)
    and checkpoint (compute x0.15) at different step times; completion is
    the exact per-span sum and a PHASE_TRANSITION fired per boundary."""
    db = make_db("a", step_s=0.01)  # compute-only record, no residual
    c = Cluster(db, [("d0", CollocationMode.MIG)])
    wl = train_workload("w", "a", SUITE, warmup_steps=5, checkpoint_steps=3)
    c.submit(wl, 0.0, epochs=1, samples_per_epoch=SAMPLES)  # 10 steps
    rep = c.run()
    row = next(j for j in rep.jobs if j["name"] == "w")
    # spans: warmup [0,5) steady [5,7) checkpoint [7,10)
    expected = 5 * 0.01 * 1.25 + 2 * 0.01 + 3 * 0.01 * 0.15
    assert row["finished_s"] == pytest.approx(expected)
    assert row["phase_transitions"] == 2
    assert rep.phase_transitions == 2
    assert row["phases"] == ["warmup", "steady", "checkpoint"]


def test_checkpoint_burst_retimes_shared_neighbour():
    """On a shared device a neighbour entering its memory-heavy checkpoint
    phase stretches a memory-bound co-resident job — the contention model
    consumes *active* phases, not steady-state vectors."""
    terms = {
        # the trainer: balanced, far from compute saturation, so its
        # checkpoint's *memory* surge dominates its compute release
        "tr": {"compute_s": 4e-3, "memory_s": 4e-3, "step_s": 5e-3},
        # the neighbour: memory-bound — exposed to the burst
        "nb": {"compute_s": 1e-3, "memory_s": 8e-3, "step_s": 9e-3},
    }
    db = {}
    for arch, t in terms.items():
        for prof in _PROFILE_ORDER:
            db[(arch, SUITE.name, prof)] = {
                "fits": True,
                **t,
                "collective_s": 0.0,
                "peak_bytes_per_device": 0.2 * HBM_PER_CHIP,
            }
    c = Cluster(db, [("d0", CollocationMode.MPS)])
    wl = train_workload("tr", "tr", SUITE, warmup_steps=0, checkpoint_steps=5)
    c.submit(wl, 0.0, epochs=1, samples_per_epoch=SAMPLES)
    c.submit(JobSpec("nb", "nb", SUITE), 0.0, epochs=10,
             samples_per_epoch=SAMPLES)
    # drain until tr actually crosses steady -> checkpoint (earlier popped
    # PHASE_TRANSITION events may be stale token-invalidated ones)
    nb, tr = c.jobs["nb"], c.jobs["tr"]
    step_before = None
    while c.events and tr.phase_transitions == 0:
        step_before = nb.step_s
        c.tick()
    assert step_before is not None
    assert c.jobs["tr"].current_span().name == "checkpoint"
    # checkpoint memory demand (x2.5) raises F_memory for the neighbour
    assert nb.step_s > step_before
    rep = c.run()
    assert rep.completed == 2


def _mixed_db():
    db = {}
    terms = {
        # saturating training arch: u_compute ~ 0.91 each
        "tr": {"compute_s": 0.01, "memory_s": 0.003, "step_s": 0.011,
               "peak": 0.30},
        # latency-dominated serve arch: busy << 1e-3 dispatch floor
        "sv": {"compute_s": 1.5e-4, "memory_s": 4.5e-5, "step_s": 1.15e-3,
               "peak": 0.06},
    }
    for arch, t in terms.items():
        for prof in _PROFILE_ORDER:
            db[(arch, SUITE.name, prof)] = {
                "fits": True,
                "step_s": t["step_s"],
                "compute_s": t["compute_s"],
                "memory_s": t["memory_s"],
                "collective_s": 0.0,
                "peak_bytes_per_device": t["peak"] * HBM_PER_CHIP,
            }
    return db


def test_serve_slo_met_on_isolated_mig_slice():
    c = Cluster(_mixed_db(), [("d0", CollocationMode.MIG)])
    sv = serve_workload("sv", "sv", SUITE, slo_step_s=1.3e-3, prefill_steps=2)
    c.submit(sv, 0.0, epochs=1, samples_per_epoch=SAMPLES)
    for i in range(2):
        c.submit(train_workload(f"tr{i}", "tr", SUITE, warmup_steps=0,
                                checkpoint_steps=0), 0.0,
                 epochs=2, samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.completed == 3
    assert rep.slo_attainment == pytest.approx(1.0)  # F3: isolation
    row = next(j for j in rep.jobs if j["name"] == "sv")
    assert row["kind"] == "serve" and row["slo_attainment"] == pytest.approx(1.0)


def test_serve_slo_missed_under_mps_dispatch_queue():
    """Same mix on a shared device: the saturating training neighbours'
    dispatch-queue pressure (F_lat ~ 1.9) pushes decode steps past the SLO
    — the cluster-level MIGPerf flip the train_serve_mix verdict rests on."""
    c = Cluster(_mixed_db(), [("d0", CollocationMode.MPS)])
    sv = serve_workload("sv", "sv", SUITE, slo_step_s=1.3e-3, prefill_steps=2)
    c.submit(sv, 0.0, epochs=1, samples_per_epoch=SAMPLES)
    for i in range(2):
        c.submit(train_workload(f"tr{i}", "tr", SUITE, warmup_steps=0,
                                checkpoint_steps=0), 0.0,
                 epochs=5, samples_per_epoch=SAMPLES)
    rep = c.run()
    assert rep.completed == 3
    assert rep.slo_attainment < 0.5
    assert rep.goodput_steps_per_s > 0


# -- determinism + the paper's dynamic findings ------------------------------------


def test_simulate_same_seed_byte_identical(tmp_path):
    from repro.launch import simulate

    out1, out2, out3 = tmp_path / "a", tmp_path / "b", tmp_path / "c"
    args = ["--steps", "24", "--devices", "2",
            "--scenarios", "mixed_dynamic,drift,train_serve_mix"]
    assert simulate.main(args + ["--seed", "7", "--out", str(out1)]) == 0
    assert simulate.main(args + ["--seed", "7", "--out", str(out2)]) == 0
    assert simulate.main(args + ["--seed", "8", "--out", str(out3)]) == 0
    s1 = (out1 / "_summary.json").read_bytes()
    s2 = (out2 / "_summary.json").read_bytes()
    s3 = (out3 / "_summary.json").read_bytes()
    assert s1 == s2  # same seed => byte-identical
    for f in out1.glob("*.json"):
        assert f.read_bytes() == (out2 / f.name).read_bytes()
    assert json.loads(s3)["cells"] != json.loads(s1)["cells"]  # seed matters
    assert json.loads(s1)["failures"] == 0


def test_simulate_reproduces_paper_dynamic_findings():
    """The acceptance criteria, pinned: (a) all-MIG accrues more queueing
    delay than all-MPS on the mixed dynamic trace; (b) MIG wins the
    partition-aligned static trace; (c) the best policy migrates and is
    charged reconfiguration cost."""
    from repro.launch.simulate import run_all, summarize_cell

    cells = {(c["scenario"], c["policy"]): summarize_cell(c)
             for c in run_all(seed=0, n_jobs=60, n_devices=4)}
    mig = cells[("mixed_dynamic", "all-mig")]
    mps = cells[("mixed_dynamic", "all-mps")]
    assert mig["mean_queueing_delay_s"] > mps["mean_queueing_delay_s"]
    amig = cells[("aligned_static", "all-mig")]
    amps = cells[("aligned_static", "all-mps")]
    assert amig["makespan_s"] < amps["makespan_s"]
    assert amig["mean_queueing_delay_s"] <= amps["mean_queueing_delay_s"]
    best = cells[("drift", "best")]
    assert best["migrations"] >= 1
    assert best["reconfig_cost_s"] > 0
    # (d) inference changes the collocation verdict (MIGPerf): MIG's
    # isolated slices protect decode latency that MPS's shared dispatch
    # queue gives up to the saturating training neighbours...
    smig = cells[("train_serve_mix", "all-mig")]
    smps = cells[("train_serve_mix", "all-mps")]
    assert smig["completed_serve"] > 0
    assert smig["slo_attainment"] >= 0.99
    assert smps["slo_attainment"] < 0.9
    assert smig["phase_transitions"] > 0
    # ... so the SLO-first fleet ordering differs from the training-only
    # trace, where every fleet trivially attains SLO 1.0 and MPS wins
    def ordering(scenario):
        mine = [(p, c) for (s, p), c in cells.items() if s == scenario]
        return [
            p for p, c in sorted(
                mine,
                key=lambda pc: (-pc[1]["slo_attainment"],
                                -pc[1]["goodput_steps_per_s"], pc[0]),
            )
        ]
    assert ordering("train_serve_mix") != ordering("mixed_dynamic")
    assert ordering("train_serve_mix")[0] == "all-mig"
    assert ordering("mixed_dynamic")[0] != "all-mig"
    # every cell drained its queue and completed every job
    for c in cells.values():
        assert c["still_queued"] == 0
        assert c["completed"] + c["rejected"] == c["n_jobs"]
