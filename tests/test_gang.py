"""Gang scheduling (core/gang/ + the cluster's all-or-nothing admission
path): parallelism descriptors, the comms cost model that makes co-located
slice sets strictly cheaper than scattered ones, the placement search, and
the event-loop integration — gang-wide re-queue on member failure, the
full/incremental re-timing equivalence, and the gang_pipeline scenario's
co-located > scattered goodput verdict."""
import dataclasses

import pytest

from repro.core.cluster import Cluster
from repro.core.elastic import split_by_failure
from repro.core.gang.comms import (
    DEFAULT_LINK,
    LinkModel,
    comm_overhead_s,
    gang_step_s,
    placement_spread,
    ring_links,
)
from repro.core.gang.parallelism import (
    PARALLELISMS,
    Parallelism,
    axis_rank_groups,
    gang_of_member,
    gang_world_size,
    is_gang,
    member_memory_fraction,
    member_name,
    rank_coords,
    resolve_parallelism,
)
from repro.core.gang.placement import plan_gang, split_counts
from repro.core.instance import JobSpec
from repro.core.sharing import CollocationMode
from repro.core.workload import train_workload
from repro.launch.simulate import (
    GANG_FLEET_SKUS,
    SIM_SAMPLES_PER_EPOCH,
    SIM_SUITE,
    make_trace,
    run_cell,
    summarize_cell,
    synthetic_sku_dbs,
)
from repro.launch.simulate import main as simulate_main

TP2 = Parallelism(tensor=2)
TP2PP2 = Parallelism(tensor=2, pipeline=2)

_DBS = synthetic_sku_dbs(GANG_FLEET_SKUS)


def gang_train(name, arch, par, **kw):
    """A phase-aware training gang over a registry arch (the helpers build
    singletons; a gang is the same workload plan, wider)."""
    return dataclasses.replace(
        train_workload(name, arch, SIM_SUITE, **kw),
        world_size=par.world_size,
        parallelism=par,
    )


def fleet(n, sku="a100-80gb", mode="mig"):
    return [(f"d{i}", mode, sku) for i in range(n)]


# -- parallelism descriptors -------------------------------------------------------


def test_descriptor_axes_label_and_world_size():
    assert TP2PP2.world_size == 4 and TP2PP2.model_degree == 4
    assert TP2PP2.label == "tp2.pp2.dp1"
    dp = Parallelism(data=4)
    assert dp.world_size == 4 and dp.model_degree == 1
    with pytest.raises(ValueError):
        Parallelism(tensor=0)


def test_resolve_parallelism_every_spelling():
    assert resolve_parallelism("tp2.pp2") == TP2PP2  # registry name
    assert resolve_parallelism(TP2) is TP2  # descriptor passthrough
    job = gang_train("g", "stablelm-12b", TP2)
    assert resolve_parallelism(job) == TP2  # job carrying one
    bare = dataclasses.replace(
        train_workload("b", "stablelm-12b", SIM_SUITE), world_size=3
    )
    assert resolve_parallelism(bare) == Parallelism(data=3)  # conservative DP
    with pytest.raises(KeyError, match="tp2.pp2"):  # lists registered names
        resolve_parallelism("tp3")


def test_member_memory_fraction_shrinks_with_model_degree_only():
    f1 = member_memory_fraction(Parallelism())
    f2 = member_memory_fraction(TP2)
    f4 = member_memory_fraction(TP2PP2)
    assert f1 == 1.0 and 1.0 > f2 > f4 > 0.15
    # data parallelism replicates the model: no memory relief
    assert member_memory_fraction(Parallelism(data=8)) == 1.0


def test_member_name_roundtrip_and_rank_layout():
    assert member_name("job", 3) == "job#r3"
    assert gang_of_member("job#r3") == "job"
    assert gang_of_member("plain-job") == "plain-job"
    # tensor fastest-varying: ranks 0,1 share a TP group under tp2.pp2
    assert rank_coords(TP2PP2, 1) == (1, 0, 0)
    assert rank_coords(TP2PP2, 2) == (0, 1, 0)
    groups = axis_rank_groups(TP2PP2)
    assert groups["tensor"] == [(0, 1), (2, 3)]
    assert groups["pipeline"] == [(0, 2), (1, 3)]
    assert "data" not in groups  # degree-1 axes carry no traffic
    assert gang_world_size(gang_train("g", "stablelm-12b", TP2)) == 2
    assert is_gang(gang_train("g", "stablelm-12b", TP2))
    assert not is_gang(JobSpec("s", "granite-3-2b", SIM_SUITE))


# -- comms cost model --------------------------------------------------------------


def test_colocated_overhead_strictly_below_scattered():
    colocated = comm_overhead_s(TP2, {0: "d0", 1: "d0"}, 1e-3)
    scattered = comm_overhead_s(TP2, {0: "d0", 1: "d1"}, 1e-3)
    assert 0.0 < colocated < scattered
    # the gap is the bandwidth ratio plus the hop latency — exactly
    expected = colocated / DEFAULT_LINK.cross_bandwidth_frac + DEFAULT_LINK.cross_latency_s
    assert scattered == pytest.approx(expected)


def test_latency_term_breaks_ties_for_pure_compute_gangs():
    # zero collective bytes: a scattered ring still pays per-hop latency
    assert comm_overhead_s(TP2, {0: "d0", 1: "d0"}, 0.0) == 0.0
    assert comm_overhead_s(TP2, {0: "d0", 1: "d1"}, 0.0) == pytest.approx(
        DEFAULT_LINK.cross_latency_s
    )


def test_world_size_one_gang_has_zero_comm_overhead():
    # the degenerate edge runtime/ring.py also honours (a 1-ring is a no-op)
    assert comm_overhead_s(Parallelism(), {0: "d0"}, 1e-3) == 0.0
    assert gang_step_s([0.01], Parallelism(), {0: "d0"}, 1e-3) == 0.01


def test_ring_links_edge_shapes():
    assert ring_links([0]) == ()
    assert ring_links([0, 1]) == ((0, 1),)  # two members: one link, no ring
    assert ring_links([0, 1, 2]) == ((0, 1), (1, 2), (2, 0))  # odd ring closes


def test_gang_step_is_slowest_member_plus_overhead():
    step = gang_step_s([0.01, 0.03], TP2, {0: "d0", 1: "d0"}, 1e-3)
    assert step == pytest.approx(0.03 + comm_overhead_s(TP2, {0: "d0", 1: "d0"}, 1e-3))
    assert placement_spread({0: "d0", 1: "d0", 2: "d1"}) == 2


def test_link_model_validation():
    with pytest.raises(ValueError):
        LinkModel(cross_bandwidth_frac=0.0)
    with pytest.raises(ValueError):
        LinkModel(cross_latency_s=-1.0)


# -- placement search --------------------------------------------------------------


def test_split_counts_pack_vs_scatter():
    caps = [2, 3, 1]
    assert split_counts(caps, 4, "colocate") == [(1, 3), (0, 1)]  # fewest devices
    # round-robin: one per device first, the remainder to the earliest
    # device with spare capacity — maximum spread, fleet-order ties
    assert split_counts(caps, 4, "scatter") == [(0, 2), (1, 1), (2, 1)]
    assert split_counts(caps, 7, "colocate") is None  # capacity short: no partial
    assert split_counts([2, 2], 2, "colocate") == [(0, 2)]  # fleet-order tie-break


def test_plan_gang_all_or_nothing_and_preference():
    def probe(dev_idx, ranks):
        return [(f"slot{dev_idx}.{r}", 0.01) for r in ranks]

    pack = plan_gang(TP2, ["d0", "d1"], [2, 2], probe, 1e-3)
    assert pack is not None and pack.spread == 1 and pack.devices == ("d0", "d0")
    spread = plan_gang(TP2, ["d0", "d1"], [2, 2], probe, 1e-3, prefer="scatter")
    assert spread is not None and spread.spread == 2
    assert pack.step_s < spread.step_s  # comms price the scatter
    assert plan_gang(TP2PP2, ["d0"], [2], probe, 1e-3) is None  # no partial gang
    with pytest.raises(ValueError):
        plan_gang(TP2, ["d0"], [2], probe, 1e-3, prefer="best")


# -- cluster integration: admission ------------------------------------------------


def test_gang_admission_is_all_or_nothing():
    # one 80GB device hosts only 2 qwen2 tp2.pp2 members — a world_size-4
    # gang is rejected outright, never partially placed
    c = Cluster(_DBS, fleet(1))
    c.submit(gang_train("g", "qwen2-72b", TP2PP2), 0.0, epochs=1,
             samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    rep = c.run()
    row = rep.jobs[0]
    assert rep.rejected == 1 and "gang unplaceable" in row["rejected_reason"]
    # two 80GB devices: the same gang spans both, two members each
    c2 = Cluster(_DBS, fleet(2))
    cj = c2.submit(gang_train("g", "qwen2-72b", TP2PP2), 0.0, epochs=1,
                   samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    while c2.events and not cj.member_devices:
        c2.tick()
    assert cj.member_devices == ("d0", "d0", "d1", "d1")  # 2 members/device
    rep2 = c2.run()
    row2 = rep2.jobs[0]
    assert rep2.completed == 1
    assert row2["world_size"] == 4 and row2["parallelism"] == "tp2.pp2.dp1"
    assert row2["gang_spread"] == 2 and row2["gang_requeues"] == 0


def test_gang_row_keys_absent_for_singletons():
    c = Cluster(_DBS, fleet(1))
    c.submit(JobSpec("s", "granite-3-2b", SIM_SUITE), 0.0, epochs=1,
             samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    row = c.run().jobs[0]
    # the artifact-schema compatibility contract: gang keys only on gangs
    assert "world_size" not in row and "gang_spread" not in row


def test_shared_mode_fleet_rejects_gangs():
    # gangs are MIG-only: member isolation is what makes the lockstep step
    # predictable — an MPS fleet has zero gang capacity by definition
    c = Cluster(_DBS, fleet(2, mode=CollocationMode.MPS))
    c.submit(gang_train("g", "stablelm-12b", TP2), 0.0, epochs=1,
             samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    rep = c.run()
    assert rep.rejected == 1 and rep.still_queued == 0


def test_colocated_gang_strictly_beats_scattered():
    """The tentpole inequality at cluster level: identical gang, identical
    fleet; only the placement preference differs."""
    results = {}
    for prefer in ("colocate", "scatter"):
        c = Cluster(_DBS, fleet(4), gang_placement=prefer)
        c.submit(gang_train("g", "qwen2-72b", TP2PP2), 0.0, epochs=3,
                 samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        rep = c.run()
        assert rep.completed == 1
        results[prefer] = (rep.jobs[0]["jct_s"], rep.goodput_steps_per_s,
                           rep.jobs[0]["gang_spread"])
    assert results["colocate"][2] < results["scatter"][2]  # fewer devices
    assert results["colocate"][0] < results["scatter"][0]  # faster
    assert results["colocate"][1] > results["scatter"][1]  # more goodput


# -- cluster integration: failure semantics ----------------------------------------


def test_member_failure_requeues_the_whole_gang():
    c = Cluster(_DBS, fleet(2))
    cj = c.submit(gang_train("g", "qwen2-72b", TP2PP2), 0.0, epochs=1,
                  samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    c.inject_failure("d0", (0,), 1.0)  # hits member r0's slice only
    c.inject_repair("d0", (0,), 2.0)
    rep = c.run()
    row = rep.jobs[0]
    # one member's slice failed; the re-queue is gang-wide — both of d0's
    # members are in the kill set and d1's members did not keep running
    fail = [e for e in rep.failure_events if e["device"] == "d0"][0]
    assert set(fail["killed"]) >= {"g#r0", "g#r1"}
    assert row["gang_requeues"] == 1 and cj.gang_requeues == 1
    assert rep.completed == 1 and row["finished_s"] > 1.0
    assert rep.lost_steps > 0.0  # checkpoint rollback charged


def test_split_by_failure_never_orphans_gang_siblings():
    """Satellite regression at the elastic layer: a failure that hits one
    member's span kills the same-device sibling too (no orphaned member
    keeps running), while unrelated singletons survive untouched."""
    from repro.core.collocation import Assignment
    from repro.core.profiles import Placement

    r0 = dataclasses.replace(
        JobSpec("g#r0", "stablelm-12b", SIM_SUITE), gang="g")
    r1 = dataclasses.replace(
        JobSpec("g#r1", "stablelm-12b", SIM_SUITE), gang="g")
    solo = JobSpec("solo", "granite-3-2b", SIM_SUITE)
    assignments = [
        Assignment(r0, Placement("1g.5gb", 0), 0.01),
        Assignment(r1, Placement("1g.5gb", 1), 0.01),
        Assignment(solo, Placement("1g.5gb", 2), 0.01),
    ]
    killed, survivors = split_by_failure(assignments, {0})
    assert sorted(j.name for j in killed) == ["g#r0", "g#r1"]
    assert all(j.priority > 0 for j in killed)  # re-queue priority bump
    assert [a.job.name for a in survivors] == ["solo"]
    # no gang in the blast radius: singleton semantics unchanged
    killed2, survivors2 = split_by_failure(assignments, {2})
    assert [j.name for j in killed2] == ["solo"]
    assert sorted(a.job.name for a in survivors2) == ["g#r0", "g#r1"]


# -- re-timing equivalence + scenario ----------------------------------------------


def test_gang_trace_full_and_incremental_engines_agree():
    reports = []
    for retime in ("full", "incremental"):
        c = Cluster(_DBS, fleet(4), retime=retime, gang_reserve_after_s=0.5)
        for t, spec, epochs in make_trace("gang_pipeline", 0, 30, 4):
            c.submit(spec, t, epochs=epochs,
                     samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        reports.append(c.run().to_dict())
    assert reports[0] == reports[1]


def test_gang_pipeline_scenario_colocated_beats_scattered_goodput():
    """The scenario-level acceptance inequality (also gated in CI): same
    seed-0 trace, same all-MIG gang fleet — co-located goodput strictly
    beats scattered, and the full-slice-only baseline rejects every
    only-fits-as-a-gang job instead of running it."""
    cells = {
        p: run_cell("gang_pipeline", "all-mig", seed=0, gang_placement=p)
        for p in ("colocate", "scatter")
    }
    sums = {p: summarize_cell(c) for p, c in cells.items()}
    for s in sums.values():
        assert s["still_queued"] == 0 and s["completed"] == s["n_jobs"]
    assert (sums["colocate"]["goodput_steps_per_s"]
            > sums["scatter"]["goodput_steps_per_s"])
    assert sums["colocate"]["mean_jct_s"] < sums["scatter"]["mean_jct_s"]

    def mean_spread(cell):
        gangs = [j for j in cell["report"]["jobs"] if j.get("world_size", 1) > 1]
        assert gangs
        return sum(j["gang_spread"] for j in gangs) / len(gangs)

    assert mean_spread(cells["colocate"]) < mean_spread(cells["scatter"])

    degraded = summarize_cell(
        run_cell("gang_pipeline", "all-mig", seed=0, gang_degrade=True)
    )
    n_gangs = sum(
        1 for _, spec, _ in make_trace("gang_pipeline", 0, 60, 4)
        if getattr(spec, "world_size", 1) > 1 and spec.arch == "qwen2-72b"
    )
    assert n_gangs > 0 and degraded["rejected"] == n_gangs


def test_gang_pipeline_drains_on_every_policy():
    from repro.launch.simulate import POLICIES

    for policy in POLICIES:
        s = summarize_cell(run_cell("gang_pipeline", policy, seed=0, n_jobs=30))
        assert s["still_queued"] == 0, (policy, s)
        assert s["completed"] + s["rejected"] == s["n_jobs"], (policy, s)


# -- cluster integration: phase transitions ----------------------------------------


def test_gang_phase_transition_reprices_every_member():
    """A gang member crossing its warmup boundary re-prices ALL members at
    the new demand and re-derives the comm-priced gang step — placements
    stay put (F3 per member slice), only the pricing moves."""
    from repro.core.workload import member_demand

    c = Cluster(_DBS, fleet(2))
    cj = c.submit(gang_train("g", "qwen2-72b", TP2PP2), 0.0, epochs=1,
                  samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
    c.run_until(0.0)  # placed
    warm_step = cj.step_s
    placements = {
        r: c.devices[d].assignments[member_name("g", r)].placement
        for r, d in enumerate(cj.member_devices)
    }
    while cj.phase_transitions == 0 and c.events:
        c.tick()
    assert cj.phase_transitions == 1  # warmup -> steady
    mdemand = member_demand(cj.spec, cj.active_demand())
    member_steps = []
    for rank, dname in enumerate(cj.member_devices):
        d = c.devices[dname]
        a = d.assignments[member_name("g", rank)]
        assert a.placement == placements[rank]  # no member moved
        assert a.predicted_step_s == pytest.approx(
            d.scheduler.predict_step(a.job, a.profile, mdemand)
        )
        member_steps.append(a.predicted_step_s)
    # the gang step is the slowest member plus non-negative comm overhead,
    # and the steady re-price actually changed the warmup-era step
    assert cj.step_s >= max(member_steps)
    assert cj.step_s != warm_step
    rep = c.run()
    assert rep.completed == 1
    assert rep.jobs[0]["phase_transitions"] >= 2  # ... -> checkpoint too


def test_gang_phase_transitions_identical_on_both_retime_engines():
    """PHASE_TRANSITION x gangs across the engine seam: phase-aware gangs
    (wide and narrow) plus singleton filler must re-time to identical
    reports under retime="full" and retime="incremental" — and the trace
    must actually cross phase boundaries for the comparison to bite."""
    reports = []
    for retime in ("full", "incremental"):
        c = Cluster(_DBS, fleet(4), retime=retime, gang_reserve_after_s=0.5)
        c.submit(gang_train("g4", "qwen2-72b", TP2PP2), 0.0, epochs=1,
                 samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        c.submit(gang_train("g2", "stablelm-12b", TP2), 0.01, epochs=2,
                 samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        c.submit(JobSpec("solo", "granite-3-2b", SIM_SUITE), 0.02, epochs=1,
                 samples_per_epoch=SIM_SAMPLES_PER_EPOCH)
        reports.append(c.run().to_dict())
    assert reports[0] == reports[1]
    gang_rows = [j for j in reports[0]["jobs"] if j.get("world_size", 1) > 1]
    assert gang_rows and all(j["phase_transitions"] >= 2 for j in gang_rows)


# -- CLI surfacing -----------------------------------------------------------------


def test_cli_list_surfaces_gang_scenario_and_parameters(capsys):
    assert simulate_main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "gang_pipeline" in out
    assert "colocate, scatter" in out
    for name in PARALLELISMS:
        assert name in out
    assert "world_size 4" in out  # derived world sizes are printed


def test_cli_unknown_gang_parallelism_errors_with_choices(capsys):
    with pytest.raises(SystemExit) as e:
        simulate_main(["--gang-parallelism", "tp3"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "tp2.pp2" in err and "invalid choice" in err


def test_cli_unknown_gang_world_size_errors_with_choices(capsys):
    with pytest.raises(SystemExit) as e:
        simulate_main(["--gang-world-size", "3"])
    assert e.value.code == 2
    assert "invalid choice: 3" in capsys.readouterr().err


def test_cli_mismatched_world_size_lists_registered_descriptors(capsys):
    # 4 is a legal world size, but not tp2's — the error names every
    # registered descriptor with its derived world size
    with pytest.raises(SystemExit) as e:
        simulate_main(["--gang-world-size", "4", "--gang-parallelism", "tp2"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "world_size is derived" in err and "tp2.pp2=4" in err
