"""Synthetic data determinism + host pipeline ordering/accounting."""
import time

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.base import ShapeSuite
from repro.configs.registry import get_config
from repro.data import synthetic
from repro.data.pipeline import HostPipeline


@given(st.integers(0, 2**31 - 1), st.integers(0, 5), st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_batches_are_pure_functions_of_seed_epoch_step(seed, epoch, step):
    a = synthetic.image_batch(synthetic.CIFAR10, 4, seed=seed, epoch=epoch, step=step)
    b = synthetic.image_batch(synthetic.CIFAR10, 4, seed=seed, epoch=epoch, step=step)
    np.testing.assert_array_equal(a["images"], b["images"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = synthetic.image_batch(synthetic.CIFAR10, 4, seed=seed, epoch=epoch, step=step + 1)
    assert not np.array_equal(a["images"], c["images"])


def test_token_batch_next_token_alignment():
    b = synthetic.token_batch(100, 2, 16, seed=3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].max() < 100


def test_batch_for_matches_input_specs():
    from repro.models.model_api import build_model

    for arch in ("granite-3-2b", "whisper-base", "llava-next-34b", "resnet_small"):
        cfg = get_config(arch).reduced() if arch != "resnet_small" else get_config(arch)
        suite = ShapeSuite("t", 32, 2, "train")
        batch = synthetic.batch_for(cfg, suite, seed=0)
        specs = build_model(cfg).input_specs(suite)
        assert set(batch) == set(specs), arch
        for k, s in specs.items():
            assert batch[k].shape == s.shape, (arch, k)


def _counter_source(step):
    return {"x": np.full((4,), step, dtype=np.int64)}


def test_pipeline_is_deterministically_ordered_with_many_workers():
    with HostPipeline(_counter_source, workers=4, max_queue_size=4) as p:
        got = [int(p.get()["x"][0]) for _ in range(40)]
    assert got == list(range(40))


def test_pipeline_start_step_resume():
    with HostPipeline(_counter_source, workers=2, max_queue_size=3, start_step=17) as p:
        got = [int(p.get()["x"][0]) for _ in range(5)]
    assert got == [17, 18, 19, 20, 21]


def test_pipeline_hides_slow_source():
    """With enough workers, consumer wait << producer latency (the paper's
    workers/max_queue_size tuning objective)."""

    def slow(step):
        time.sleep(0.02)
        return {"x": np.full((1,), step)}

    with HostPipeline(slow, workers=8, max_queue_size=16) as p:
        p.get()  # warmup
        t0 = time.perf_counter()
        for _ in range(20):
            p.get()
        elapsed = time.perf_counter() - t0
    # serial would be >= 0.4s; pipelined should be well under half that
    assert elapsed < 0.2, f"pipeline failed to hide latency: {elapsed:.3f}s"


def test_queue_bytes_accounting():
    b = synthetic.image_batch(synthetic.CIFAR10, 8, seed=0)
    per = b["images"].nbytes + b["labels"].nbytes
    assert HostPipeline.queue_bytes(b, 10) == 10 * per
