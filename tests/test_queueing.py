"""Admission-queue backfill edge cases (core/queueing.py + the cluster
dispatcher): the head-of-line job is never delayed by backfillers, the
EASY-style starvation bound holds once arrivals stop, the queue's
empty/duplicate behaviour is exact, and the gang reservation protocol —
exclusive, deterministically released — neither starves gangs nor blocks
backfillable singletons before the bound."""
import dataclasses

import pytest

from repro.configs.base import ShapeSuite
from repro.core.cluster import Cluster
from repro.core.collocation import _PROFILE_ORDER
from repro.core.gang.parallelism import Parallelism
from repro.core.instance import JobSpec
from repro.core.queueing import AdmissionQueue
from repro.core.sharing import CollocationMode
from repro.core.workload import train_workload
from repro.launch.simulate import SIM_SUITE, synthetic_sku_dbs
from repro.telemetry.constants import HBM_PER_CHIP

SUITE = ShapeSuite("t", 1024, 32, "train")
SAMPLES = 320  # batch 32 -> 10 steps per epoch


def make_db(arch, *, step_s=0.01, full_device_only=False):
    return {
        (arch, SUITE.name, prof): {
            "fits": (prof == "7g.40gb") if full_device_only else True,
            "step_s": step_s,
            "compute_s": step_s,
            "memory_s": 0.0,
            "collective_s": 0.0,
            "peak_bytes_per_device": 0.1 * HBM_PER_CHIP,
        }
        for prof in _PROFILE_ORDER
    }


def mixed_db():
    db = make_db("big", step_s=0.05, full_device_only=True)
    db.update(make_db("small", step_s=0.01))
    db.update(make_db("quick", step_s=0.001))
    return db


def run_trace(with_backfiller: bool):
    c = Cluster(mixed_db(), [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("s0", "small", SUITE), 0.0, epochs=1,
             samples_per_epoch=SAMPLES)
    c.submit(JobSpec("big", "big", SUITE, priority=5), 0.01, epochs=1,
             samples_per_epoch=SAMPLES)
    if with_backfiller:
        # 10 steps x 0.001s: finishes at 0.03, well inside s0's 0.1 window
        c.submit(JobSpec("q", "quick", SUITE), 0.02, epochs=1,
                 samples_per_epoch=SAMPLES)
    rep = c.run()
    return {j["name"]: j for j in rep.jobs}, rep


def test_backfill_inside_the_window_never_delays_the_head_of_line_job():
    """A backfiller that drains before the blocked head's start leaves the
    head's start time exactly unchanged: backfill is pure win (work
    conservation) whenever it fits the idle window."""
    without, _ = run_trace(with_backfiller=False)
    with_bf, rep = run_trace(with_backfiller=True)
    assert with_bf["big"]["started_s"] == without["big"]["started_s"] == 0.1
    assert with_bf["q"]["started_s"] == pytest.approx(0.02)  # did backfill
    assert with_bf["q"]["finished_s"] == pytest.approx(0.03)
    assert rep.hol_blocked_events >= 1


def test_backfill_without_reservation_can_push_a_full_device_head():
    """The documented EASY-without-reservations tradeoff (queueing.py): a
    *long* backfiller extends device occupancy past the incumbent's finish
    and the full-device head waits for it too. Pinning the semantics keeps
    the tradeoff a decision, not an accident."""
    c = Cluster(mixed_db(), [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("s0", "small", SUITE), 0.0, epochs=1,
             samples_per_epoch=SAMPLES)
    c.submit(JobSpec("big", "big", SUITE, priority=5), 0.01, epochs=1,
             samples_per_epoch=SAMPLES)
    c.submit(JobSpec("s1", "small", SUITE), 0.02, epochs=1,
             samples_per_epoch=SAMPLES)  # finishes 0.12 > s0's 0.1
    rep = c.run()
    rows = {j["name"]: j for j in rep.jobs}
    assert rows["s1"]["started_s"] == pytest.approx(0.02)
    assert rows["big"]["started_s"] == pytest.approx(0.12)
    assert rep.completed == 3


def test_starvation_bound_blocked_head_runs_when_arrivals_stop():
    """EASY backfill without reservations can starve the blocked
    full-device job only while backfillers keep arriving; the bound is
    that it starts the moment the last one frees the device — exactly."""
    c = Cluster(mixed_db(), [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("s_seed", "small", SUITE), 0.0, epochs=1,
             samples_per_epoch=SAMPLES)
    c.submit(JobSpec("big", "big", SUITE, priority=9), 0.01, epochs=1,
             samples_per_epoch=SAMPLES)
    # overlapping arrivals (every 0.05s, each 0.1s long) keep >= 1 slice
    # busy continuously, so the full-device head stays blocked throughout
    for i in range(10):
        c.submit(JobSpec(f"s{i}", "small", SUITE), 0.05 * (i + 1),
                 epochs=1, samples_per_epoch=SAMPLES)
    rep = c.run()
    rows = {j["name"]: j for j in rep.jobs}
    last_small_finish = max(rows[f"s{i}"]["finished_s"] for i in range(10))
    assert rows["big"]["started_s"] == pytest.approx(last_small_finish)
    assert rows["big"]["finished_s"] is not None
    assert rep.completed == 12 and rep.still_queued == 0


def test_admission_queue_empty_and_duplicate_behaviour():
    q = AdmissionQueue()
    assert len(q) == 0 and not q and q.ordered() == []
    with pytest.raises(KeyError):
        q.remove("ghost")  # empty-queue removal is a real error, not a no-op
    q.push("a", None, priority=0, enqueued_s=0.0)
    with pytest.raises(KeyError):
        q.push("a", None, priority=5, enqueued_s=1.0)  # duplicate key
    assert "a" in q and q.get("a") is not None
    q.remove("a")
    assert "a" not in q and q.get("a") is None


def test_reservation_api_exclusive_widening_and_release():
    q = AdmissionQueue()
    with pytest.raises(KeyError):
        q.reserve("ghost", {"d0"})  # only queued jobs may reserve
    q.push("g1", None, priority=0, enqueued_s=0.0)
    q.push("g2", None, priority=0, enqueued_s=0.1)
    q.reserve("g1", {"d0"})
    assert q.reserved_by == "g1"
    assert q.reserved_against("g2", "d0") and not q.reserved_against("g1", "d0")
    assert not q.reserved_against("g2", "d1")  # only the reserved devices
    with pytest.raises(ValueError):
        q.reserve("g2", {"d1"})  # exclusive: queue order decides the holder
    q.reserve("g1", {"d0", "d1"})  # the holder may widen its claim
    assert q.reserved_against("g2", "d1")
    assert q.release("g1") and not q.release("g1")  # idempotent
    assert q.reserved_by is None and not q.reserved_against("g2", "d0")
    q.reserve("g2", {"d0"})
    q.remove("g2")  # leaving the queue always frees the claim
    assert q.reserved_by is None and q.reservations_released == 2


# -- gang head-of-line behaviour (core/gang/ + the dispatcher) ---------------------

_GANG_DBS = synthetic_sku_dbs(("a100-80gb",))


def _gang(name):
    par = Parallelism(tensor=2)
    return dataclasses.replace(
        train_workload(name, "stablelm-12b", SIM_SUITE),
        world_size=2, parallelism=par,
    )


def _hol_cluster(reserve_after_s):
    """One 80GB MIG device, all seven 1g slices occupied: s0 frees its
    slice first, s1 second, the rest much later — then a world_size-2 gang
    and a backfillable singleton arrive and contend for the freed slices."""
    c = Cluster(_GANG_DBS, [("d0", CollocationMode.MIG, "a100-80gb")],
                gang_reserve_after_s=reserve_after_s)
    c.submit(JobSpec("s0", "granite-3-2b", SIM_SUITE), 0.0, epochs=1)
    c.submit(JobSpec("s1", "granite-3-2b", SIM_SUITE), 0.0, epochs=2)
    for i in range(2, 7):
        c.submit(JobSpec(f"s{i}", "granite-3-2b", SIM_SUITE), 0.0, epochs=3)
    c.submit(_gang("gang"), 0.01, epochs=1)
    c.submit(JobSpec("bf", "granite-3-2b", SIM_SUITE), 0.02, epochs=1)
    return c


def test_waiting_gang_does_not_block_backfill_before_the_bound():
    """Until the starvation bound expires the queued gang holds nothing:
    the singleton backfills into the first freed slice (which the gang —
    needing two — could not use anyway) the moment it opens."""
    c = _hol_cluster(reserve_after_s=10.0)  # bound far beyond the makespan
    rep = c.run()
    rows = {j["name"]: j for j in rep.jobs}
    assert rows["bf"]["started_s"] == pytest.approx(rows["s0"]["finished_s"])
    assert rows["bf"]["started_s"] < rows["gang"]["started_s"]
    assert rep.completed == 9 and rep.still_queued == 0
    assert c.queue.reservations_made == 0  # the bound never expired


def test_reservation_holds_freed_slices_for_the_gang_after_the_bound():
    """Once the bound expires the gang's reservation vetoes backfill on the
    reserved device: the freed slices accumulate for the gang (it starts
    exactly when the second slice frees) and the singleton that would have
    sniped the first slice now starts after the gang — the deterministic
    flip side of the backfill test above."""
    c = _hol_cluster(reserve_after_s=0.05)  # expires before any slice frees
    rep = c.run()
    rows = {j["name"]: j for j in rep.jobs}
    assert c.queue.reservations_made >= 1
    assert rows["gang"]["started_s"] == pytest.approx(rows["s1"]["finished_s"])
    assert rows["bf"]["started_s"] >= rows["gang"]["started_s"]
    assert rep.completed == 9 and rep.still_queued == 0
    assert c.queue.reserved_by is None  # released on placement, exactly once


def test_reservation_released_deterministically_on_rejection():
    """Fleet degradation while a gang holds the reservation: the next
    heartbeat finds the surviving capacity below world_size, rejects the
    gang, and the release is immediate — no reservation outlives its
    holder to deadlock the queue."""
    c = Cluster(_GANG_DBS, [("d0", CollocationMode.MIG, "a100-80gb")],
                gang_reserve_after_s=0.05)
    for i in range(7):
        c.submit(JobSpec(f"s{i}", "granite-3-2b", SIM_SUITE), 0.0, epochs=3)
    c.submit(_gang("gang"), 0.01, epochs=1)
    c.inject_failure("d0", range(1, 8), 0.1)  # one healthy unit: cap < 2
    rep = c.run()
    g = {j["name"]: j for j in rep.jobs}["gang"]
    assert g["rejected_reason"] is not None and "capacity" in g["rejected_reason"]
    assert c.queue.reserved_by is None
    assert c.queue.reservations_released == c.queue.reservations_made >= 1
    assert rep.still_queued == 0


def test_cluster_duplicate_submit_rejected_and_empty_run_is_clean():
    c = Cluster(make_db("small"), [("d0", CollocationMode.MIG)])
    c.submit(JobSpec("j", "small", SUITE), 0.0)
    with pytest.raises(KeyError):
        c.submit(JobSpec("j", "small", SUITE), 1.0)
    empty = Cluster(make_db("small"), [("d0", CollocationMode.MIG)])
    rep = empty.run()  # no jobs: the event loop drains trivially
    assert rep.completed == 0 and rep.still_queued == 0
    assert rep.goodput_steps_per_s == 0.0 and rep.slo_attainment == 1.0
