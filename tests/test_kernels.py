"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed under interpret=True (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa

KEY = jax.random.key(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention forward
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Skv, H, KVH, D, causal, dtype, bq, bk)
    (1, 64, 64, 4, 4, 32, True, jnp.float32, 16, 16),   # MHA
    (2, 128, 128, 8, 2, 64, True, jnp.float32, 32, 64),  # GQA g=4
    (2, 128, 128, 8, 1, 32, True, jnp.float32, 64, 32),  # MQA
    (1, 96, 96, 4, 4, 16, True, jnp.float32, 32, 32),    # non-pow2 seq
    (1, 64, 64, 4, 2, 32, False, jnp.float32, 16, 32),   # non-causal
    (2, 64, 64, 8, 4, 64, True, jnp.bfloat16, 32, 32),   # bf16 io
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_fwd_matches_oracle(case):
    B, Sq, Skv, H, KVH, D, causal, dtype, bq, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KVH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KVH, D), jnp.float32).astype(dtype)
    o_ref = ref.mha_reference(q, k, v, causal=causal)
    o_pal = ops.flash_attention(
        q, k, v, causal=causal, block_q=bq, block_k=bk, mode="interpret"
    )
    np.testing.assert_allclose(
        o_pal.astype(jnp.float32), o_ref.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "case", [(2, 64, 8, 2, 32, True), (1, 64, 4, 4, 16, True), (1, 64, 4, 2, 32, False)]
)
def test_flash_bwd_matches_oracle(case):
    B, S, H, KVH, D, causal = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)

    def loss_pal(q, k, v):
        o = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=32,
                                mode="interpret")
        return jnp.sum(jnp.sin(o))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha_reference(q, k, v, causal=causal)))

    gp = jax.grad(loss_pal, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for name, a, b in zip(("dq", "dk", "dv"), gp, gr):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4, err_msg=name)


def test_flash_lse_is_true_logsumexp():
    B, S, H, KVH, D = 1, 32, 2, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
    qf = ops._fold(q, KVH)
    _, lse = fa.flash_attention_fwd(
        qf, ops._kv_fold(k), ops._kv_fold(v), causal=True, scale=D**-0.5,
        block_q=8, block_k=8, interpret=True,
    )
    # oracle lse
    s = jnp.einsum("bqhd,bkhd->bhqk", q.reshape(B, S, KVH, D) * D**-0.5,
                   k) if KVH == H else None
    qs = (q.reshape(B, S, KVH, 1, D) * D**-0.5)
    scores = jnp.einsum("bqhgd,bkhd->bhqgk", qs, k)
    mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
    scores = jnp.where(mask[None, None, :, None, :], scores, -1e30)
    want = jax.scipy.special.logsumexp(scores, axis=-1)  # (B,H,S,G)
    np.testing.assert_allclose(lse, want.transpose(0, 1, 2, 3), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    # (B, Smax, H, KVH, D, kv_len, bk)
    (2, 128, 8, 2, 32, 128, 32),
    (2, 128, 8, 2, 32, 77, 32),    # partial cache
    (1, 256, 4, 4, 64, 1, 64),     # single valid entry
    (3, 96, 6, 1, 16, 50, 32),     # MQA, odd sizes
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_matches_oracle(case):
    B, Smax, H, KVH, D, kv_len, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, KVH, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, KVH, D), jnp.float32)
    o_ref = ref.decode_attention_reference(q, kc, vc, kv_len=kv_len)
    o_pal = ops.decode_attention(q, kc, vc, kv_len=kv_len, block_k=bk,
                                 mode="interpret")
    np.testing.assert_allclose(o_pal, o_ref, atol=2e-5, rtol=2e-5)


def test_decode_traced_kv_len():
    """kv_len must be traceable (it's a loop carry in the decode loop)."""
    B, Smax, H, KVH, D = 1, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, KVH, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, KVH, D), jnp.float32)

    @jax.jit
    def f(kv_len):
        return ops.decode_attention(q, kc, vc, kv_len=kv_len, block_k=16,
                                    mode="interpret")

    for n in (1, 13, 64):
        np.testing.assert_allclose(
            f(jnp.int32(n)),
            ref.decode_attention_reference(q, kc, vc, kv_len=n),
            atol=2e-5, rtol=2e-5,
        )


# ---------------------------------------------------------------------------
# WKV6 chunked scan
# ---------------------------------------------------------------------------

WKV_CASES = [
    # (B, T, H, K, chunk, zero_state)
    (1, 64, 2, 16, 16, True),
    (2, 128, 4, 32, 32, True),
    (1, 96, 2, 16, 32, False),  # nonzero initial state, odd chunk count
    (2, 64, 2, 8, 64, True),    # single chunk
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_matches_oracle(case):
    B, T, H, K, chunk, zero_state = case
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, K), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K), jnp.float32) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, K), jnp.float32) * 0.2
    s0 = (
        jnp.zeros((B, H, K, K), jnp.float32)
        if zero_state
        else jax.random.normal(ks[5], (B, H, K, K), jnp.float32) * 0.3
    )
    o_ref, s_ref = ref.wkv6_reference(r, k, v, logw, u, s0)
    o_pal, s_pal = ops.wkv6(r, k, v, logw, u, s0, chunk=chunk, mode="interpret")
    np.testing.assert_allclose(o_pal, o_ref, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(s_pal, s_ref, atol=5e-5, rtol=5e-5)


def test_wkv6_strong_decay_is_stable():
    """Strong decay (|logw| large) must not overflow the chunked form."""
    B, T, H, K = 1, 64, 1, 8
    ks = jax.random.split(KEY, 3)
    r = jax.random.normal(ks[0], (B, T, H, K), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, H, K), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, K), jnp.float32)
    logw = jnp.full((B, T, H, K), -3.0)  # e^{-3} per step, e^{-192}/chunk
    u = jnp.zeros((H, K))
    s0 = jnp.zeros((B, H, K, K))
    o_pal, s_pal = ops.wkv6(r, k, v, logw, u, s0, chunk=64, mode="interpret")
    assert jnp.isfinite(o_pal).all() and jnp.isfinite(s_pal).all()
    o_ref, _ = ref.wkv6_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(o_pal, o_ref, atol=5e-5, rtol=5e-5)


def test_model_chunked_wkv_matches_kernel():
    """The model's XLA chunked path and the Pallas kernel agree."""
    from repro.models.rwkv6 import wkv_chunked

    B, T, H, K = 1, 64, 2, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.3 - 2.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.2
    s0 = jnp.zeros((B, H, K, K))
    o_x, s_x = wkv_chunked(r, k, v, logw, u, s0, chunk=16)
    o_p, s_p = ops.wkv6(r, k, v, logw, u, s0, chunk=16, mode="interpret")
    np.testing.assert_allclose(o_p, o_x, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(s_p, s_x, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# calibration shapes (core/calib KernelBackend; ISSUE 10)
# ---------------------------------------------------------------------------

def test_calibration_shapes_match_ref_oracles():
    """Every kernel family's calibration shape runs interpret-mode on CPU
    and agrees with its pure-jnp oracle — the numerics bar the measured
    calibration backend stands on (benchmarks/kernel_bench.py)."""
    from benchmarks.kernel_bench import (
        CALIBRATION_KERNELS,
        CALIBRATION_SHAPES,
        measure_calibration_kernel,
    )

    # one representative arch per kernel family actually used in the map
    reps = {}
    for family, kernel in CALIBRATION_KERNELS.items():
        reps.setdefault(kernel, family)
    assert set(reps) <= set(CALIBRATION_SHAPES)
    archs = {"flash_attention": "llama3-8b", "wkv6": "rwkv6-1.6b"}
    for kernel in sorted(reps):
        arch = archs.get(kernel)
        if arch is None:
            continue
        meas = measure_calibration_kernel(arch, n=1)
        assert meas["kernel"] == kernel
        assert meas["wall_s"] > 0.0
        assert meas["max_err_vs_ref"] < 2e-4, (kernel, meas)
    # the serve-phase shape (no training arch maps to it) via the override
    meas = measure_calibration_kernel(
        "qwen2-72b", n=1, kernel="decode_attention"
    )
    assert meas["kernel"] == "decode_attention"
    assert meas["max_err_vs_ref"] < 2e-4, meas


def test_calibration_kernel_for_covers_registry():
    from benchmarks.kernel_bench import CALIBRATION_SHAPES, calibration_kernel_for
    from repro.configs.registry import CONFIGS

    for arch in CONFIGS:
        assert calibration_kernel_for(arch) in CALIBRATION_SHAPES
