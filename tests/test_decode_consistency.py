"""Serving-path correctness: incremental decode must reproduce the
teacher-forced forward logits for every architecture family.

Tolerance note: the decode path keeps softmax probabilities in bf16 for the
value matmul (avoiding f32 copies of the whole KV shard — 2x HBM traffic on
the serving hot path), so logits differ from the f32-accumulated forward by
up to ~5e-2 on <1% of elements. 6e-2 bounds that quantization noise while
still catching any real cache/rotary/position bug (those produce O(1)
errors)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED
from repro.models.model_api import build_model
from repro.sharding.plan import make_plan
from repro.runtime.serve_step import pad_cache

# families with a full-sequence `forward` producing (B, S, V) logits
DECODE_ARCHS = [
    "granite-3-2b",     # dense GQA
    "qwen2-72b",        # dense GQA + qkv bias
    "rwkv6-1.6b",       # attention-free recurrence
    "olmoe-1b-7b",      # MoE
    "zamba2-7b",        # mamba2 hybrid
]


def _fwd_logits(cfg, model, params, tokens, plan):
    """Full-sequence logits via the family's forward."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        from repro.models import transformer as tfm

        return tfm.forward(cfg, params, tokens, plan)
    if fam == "moe":
        from repro.models import moe

        return moe.forward(cfg, params, tokens, plan)[0]
    if fam == "rwkv":
        from repro.models import rwkv6

        return rwkv6.forward(cfg, params, tokens, plan)
    if fam == "hybrid":
        from repro.models import mamba2

        return mamba2.forward(cfg, params, tokens, plan)
    raise ValueError(fam)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_incremental_decode_matches_forward(arch):
    cfg = ASSIGNED[arch].reduced()
    model = build_model(cfg)
    plan = make_plan(cfg, None)
    params = model.init(jax.random.key(0))
    # S and S+extra divisible by the SSM chunk (8 in reduced configs)
    B, S, extra = 2, 16, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S + extra), 0, cfg.vocab, jnp.int32)

    # teacher-forced reference logits over the whole sequence
    ref_logits = _fwd_logits(cfg, model, params, tokens, plan)

    # prefill on the first S tokens, then decode the remaining `extra`
    last, cache = model.prefill(params, {"tokens": tokens[:, :S]}, plan)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(ref_logits[:, S - 1, :], np.float32),
        atol=6e-2, rtol=6e-2,
        err_msg=f"{arch}: prefill last-logits mismatch",
    )
    cache = pad_cache(cache, extra)
    for i in range(extra):
        logits, cache = model.decode(
            params, {"token": tokens[:, S + i]}, cache, S + i, plan
        )
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, S + i, :], np.float32),
            atol=6e-2, rtol=6e-2,
            err_msg=f"{arch}: decode step {i} mismatch",
        )


def test_whisper_decode_matches_prefill_path():
    """Enc-dec: the decoder's incremental path must agree with its own
    prefill logits when re-prefilling the extended sequence."""
    cfg = ASSIGNED["whisper-base"].reduced()
    model = build_model(cfg)
    plan = make_plan(cfg, None)
    params = model.init(jax.random.key(0))
    B, S = 2, 8
    frames = jax.random.normal(jax.random.key(2), (B, cfg.n_frames, cfg.d_model)).astype(jnp.bfloat16)
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab, jnp.int32)

    last_ref, _ = model.prefill(
        params, {"tokens": tokens, "frames": frames}, plan
    )
    last, cache = model.prefill(
        params, {"tokens": tokens[:, :S], "frames": frames}, plan
    )
    cache = pad_cache(cache, 1)
    logits, _ = model.decode(
        params, {"token": tokens[:, S], "frames": frames}, cache, S, plan
    )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(last_ref, np.float32),
        atol=6e-2, rtol=6e-2,
    )
