"""Sharding plans: FSDP+TP(+EP/SP) PartitionSpec policy per (config, mesh, shape).

The policy is 2D GSPMD sharding:
  * weights:   one dim over ``model`` (tensor-parallel), one over ``data``
               (ZeRO-3/FSDP); gathered per-layer inside the depth scan.
  * activations: batch over (``pod``, ``data``); heads / ffn-hidden / vocab
               over ``model`` when divisible.
  * KV caches: sequence dim over ``model`` (flash-decode style sharded
               softmax), batch over data axes; for ``long_500k`` (batch=1) the
               sequence dim is sharded over *all* axes (sequence parallelism).

Models never name mesh axes: they call ``plan.act(x, kind)`` and the plan
decides (or no-ops when plan is None — single-device smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSuite


@dataclasses.dataclass
class ShardingPlan:
    mesh: Optional[Mesh]
    act_specs: Dict[str, P]
    dp_axes: Tuple[str, ...]
    tp_axis: Optional[str]

    # -- activation constraints ---------------------------------------------
    def act(self, x: jax.Array, kind: str) -> jax.Array:
        if self.mesh is None:
            return x
        spec = self.act_specs.get(kind)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def spec(self, kind: str) -> P:
        return self.act_specs.get(kind, P())

    def sharding(self, kind: str) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.act_specs.get(kind, P()))


def _divisible(n: int, axis_size: int) -> bool:
    return axis_size > 0 and n % axis_size == 0


def make_plan(
    cfg: ModelConfig,
    mesh: Optional[Mesh],
    suite: Optional[ShapeSuite] = None,
    *,
    variant: str = "baseline",
) -> ShardingPlan:
    """Build the activation-sharding plan.

    variant:
      'baseline' — Megatron-style TP: the residual stream is replicated over
                   the model axis between blocks (2 activation all-reduces
                   per layer in fwd, 2 in bwd).
      'sp'       — Megatron sequence parallelism: the residual stream is
                   sharded over the model axis on the SEQUENCE dim between
                   blocks. Wire-neutral vs 'baseline' (AG+RS == AR in ring
                   cost) but cuts boundary activation memory and redundant
                   norm compute by ~tp.
      'zero'     — pure ZeRO-3 data parallelism: the batch is sharded over
                   EVERY mesh axis (model included) and no tensor dim is
                   contracted across devices; weights/optimizer are fully
                   sharded and gathered one layer at a time inside the depth
                   scan. Collective bytes scale with PARAMS instead of
                   ACTIVATIONS — the right regime whenever
                   tokens_per_step x d >> params (all train_4k cells).
    """
    if mesh is None:
        return ShardingPlan(None, {}, (), None)

    axes = mesh.axis_names
    if variant == "zero":
        return _make_zero_plan(cfg, mesh, suite)
    # 'serve' shares the baseline activation plan; it differs only in the
    # parameter residency (serve_param_pspecs)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    tp_size = mesh.shape[tp] if tp else 1

    batch = suite.global_batch if suite else None
    # batch too small to split over dp -> leave unsharded, push parallelism
    # into the sequence dim instead (long_500k cells).
    dp = dp_axes if (batch is None or _divisible(batch, dp_size)) else ()
    seq_axes: Tuple[str, ...] = ()
    if not dp and tp:
        seq_axes = dp_axes + (tp,)  # SP: all axes onto the sequence dim

    hd = cfg.resolved_head_dim
    heads_tp = tp if _divisible(cfg.n_heads, tp_size) else None
    kv_tp = tp if _divisible(cfg.n_kv_heads, tp_size) else None
    ffn_tp = tp if _divisible(cfg.d_ff, tp_size) else None
    vocab_tp = tp if _divisible(cfg.vocab, tp_size) else None

    # Megatron-SP: residual stream seq-sharded over the model axis between
    # blocks (only when the seq length divides; decode steps have seq=1)
    seq_len = suite.seq_len if suite else None
    sp_seq = (
        tp
        if (
            variant == "sp"
            and tp
            and suite is not None
            and suite.kind in ("train", "prefill")
            and _divisible(suite.seq_len, tp_size)
        )
        else None
    )

    specs: Dict[str, P] = {
        "tokens": P(dp, None),
        "hidden": P(dp, sp_seq, None),
        "heads": P(dp, None, heads_tp, None),
        "kv_heads": P(dp, None, kv_tp, None),
        "ffn": P(dp, None, ffn_tp),
        "logits": P(dp, None, vocab_tp),
        "last_logits": P(dp, vocab_tp),
        # KV cache (L, B, S, KVH, D): sequence over model (flash-decode);
        # falls back to SP over everything for batch-1 long-context cells.
        "cache": P(None, dp, seq_axes if seq_axes else tp, None, None),
        # recurrent state (L, B, H, K, V) — batch over dp, heads over tp.
        "state": P(None, dp if dp else None, heads_tp, None, None),
        # decode-step activations (B, 1, ...)
        "decode_hidden": P(dp, None, None),
        "decode_heads": P(dp, None, heads_tp, None),
        # MoE grouped-GEMM tensors (E, C, d/f): experts over model (EP),
        # capacity rows over data so both mesh axes stay busy.
        "expert_group": P(tp, dp if dp else None, None),
        "expert_hidden": P(tp, dp if dp else None, None),
        # per-example grouped dispatch (B, E, C, d): batch over data, experts
        # over model — GSPMD lowers the constraint into the MoE all-to-all.
        "grouped": P(dp, tp, None, None),
        # frames/patches stubs (B, T, D)
        "frames": P(dp, None, None),
    }
    return ShardingPlan(mesh, specs, dp_axes, tp)


def _make_zero_plan(cfg: ModelConfig, mesh: Mesh, suite: Optional[ShapeSuite]):
    """ZeRO-3 plan: batch over as many axes as divide it; nothing else
    sharded in activations (each device computes whole examples)."""
    axes = tuple(mesh.axis_names)
    # choose the largest prefix-product of axes that divides the batch,
    # preferring to use every axis (full 256/512-way DP)
    batch = suite.global_batch if suite else None
    dp: Tuple[str, ...] = ()
    if batch is not None:
        for take in range(len(axes), 0, -1):
            size = 1
            for a in axes[-take:]:
                size *= mesh.shape[a]
            if batch % size == 0:
                dp = axes[-take:]
                break
    else:
        dp = axes
    dp_entry = dp if dp else None
    specs: Dict[str, P] = {
        "tokens": P(dp_entry, None),
        "hidden": P(dp_entry, None, None),
        "heads": P(dp_entry, None, None, None),
        "kv_heads": P(dp_entry, None, None, None),
        "ffn": P(dp_entry, None, None),
        "logits": P(dp_entry, None, None),
        "last_logits": P(dp_entry, None),
        "cache": P(None, dp_entry, None, None, None),
        "state": P(None, dp_entry, None, None, None),
        "decode_hidden": P(dp_entry, None, None),
        "decode_heads": P(dp_entry, None, None, None),
        "expert_group": P(None, dp_entry, None),
        "expert_hidden": P(None, dp_entry, None),
        "grouped": P(dp_entry, None, None, None),
        "frames": P(dp_entry, None, None),
    }
    return ShardingPlan(mesh, specs, dp, None)


def serve_param_pspecs(params, mesh: Mesh):
    """Serving parameter specs: pure TP residency — weights sharded over the
    ``model`` axis ONLY, replicated over data axes. Decode steps then issue
    zero weight gathers (latency!) at the cost of params/tp per device; the
    data axes carry the request batch."""

    def rule(path, leaf):
        name = _leaf_name(path)
        spec = _kernel_spec(name, leaf.ndim)
        fixed = [ax if ax == "model" else None for ax in spec]
        fixed += [None] * (leaf.ndim - len(fixed))
        # divisibility guard
        out = []
        for dim, ax in zip(leaf.shape, fixed):
            size = mesh.shape[ax] if isinstance(ax, str) else 1
            out.append(ax if ax and dim % size == 0 else None)
        return P(*out) if any(a is not None for a in out) else P()

    return jax.tree_util.tree_map_with_path(rule, params)


def zero_param_pspecs(params, mesh: Mesh):
    """ZeRO-3 parameter specs: shard the largest dim of every leaf over the
    FULL merged mesh (every axis), falling back to progressively smaller
    axis groups until one divides. Norm vectors and small leaves replicate.
    Gathers happen per-layer inside the depth scan, so peak memory is one
    layer's worth of gathered weights."""
    axes = tuple(mesh.axis_names)
    groups = [axes[i:] for i in range(len(axes))]  # full, then suffixes

    def rule(path, leaf):
        if leaf.ndim == 0 or leaf.size < 1 << 14:
            return P()  # tiny: replicate
        # try dims largest-first (stacked layer kernels: skip the L dim 0
        # only if another dim fits)
        order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for grp in groups:
            size = 1
            for a in grp:
                size *= mesh.shape[a]
            for dim in order:
                if leaf.shape[dim] % size == 0:
                    spec = [None] * leaf.ndim
                    spec[dim] = grp if len(grp) > 1 else grp[0]
                    return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(rule, params)

# column-parallel (out dim -> model, in dim -> data)
_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_lm", "w_qkv")
# row-parallel (in dim -> model, out dim -> data)
_ROW = ("wo", "w_down", "w_out")
# embedding tables (vocab -> model, d -> data)
_EMB = ("table",)
# expert-stacked kernels: leading expert dim -> model (EP), then data
_EXPERT_COL = ("e_gate", "e_up", "e_in")
_EXPERT_ROW = ("e_down", "e_out")


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _kernel_spec(name: str, ndim: int) -> P:
    """Build a spec for an (optionally L-stacked) kernel of rank ``ndim``."""

    def pad(spec_tail: Tuple) -> P:
        lead = (None,) * (ndim - len(spec_tail))
        return P(*(lead + spec_tail))

    if name in _EMB:
        return P("model", "data") if ndim == 2 else pad(("model", "data"))
    if name in _EXPERT_COL:
        return pad(("model", "data", None))
    if name in _EXPERT_ROW:
        return pad(("model", None, "data"))
    if name in _COL and ndim >= 2:
        return pad(("data", "model"))
    if name in _ROW and ndim >= 2:
        return pad(("model", "data"))
    return P()  # replicate (norm scales, biases, small vectors)


def param_pspecs(params) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` by leaf-name rules."""

    def rule(path, leaf):
        name = _leaf_name(path)
        spec = _kernel_spec(name, leaf.ndim)
        # guard: only keep axes that divide the dim; replicate otherwise
        fixed = []
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
            elif isinstance(ax, str):
                fixed.append(ax)
            else:
                fixed.append(ax)
        return P(*fixed) if any(a is not None for a in fixed) else P()

    return jax.tree_util.tree_map_with_path(rule, params)


def validate_pspecs(params, specs, mesh: Mesh):
    """Replace any axis assignment that does not divide the dim (safety net)."""

    def fix(leaf, spec):
        new = []
        for i, ax in enumerate(spec):
            if ax is None:
                new.append(None)
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else 1
            new.append(ax if leaf.shape[i] % size == 0 else None)
        # pad spec to leaf rank
        new += [None] * (leaf.ndim - len(new))
        return P(*new)

    return jax.tree_util.tree_map(fix, params, specs)


def named_shardings(params_or_specs, specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda _, s: NamedSharding(mesh, s), params_or_specs, specs
    )
