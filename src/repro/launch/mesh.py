"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init
while tests and benches see the real single device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_shape(shape, axes) -> Mesh:
    """Arbitrary mesh for instance sub-partitions and tests."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def mesh_chips(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def mesh_label(mesh: Mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
