"""Trace-driven cluster simulation driver — the paper's dynamic half.

Where launch/collocate.py reproduces the paper's *static* §3.4 grid (one
batch of jobs, one device), this driver exercises the event-driven cluster
(core/cluster.py): a fleet of devices, each with its own collocation mode,
fed by a seeded synthetic arrival trace over the existing workload registry.
Every (scenario x fleet-policy) cell runs the *same* trace, so the printed
differences are pure policy effects:

  scenarios
    aligned_static   partition-aligned jobs, all at t=0 — the mix MIG is
                     built for (each job exactly fills a 1g.5gb slice and
                     its replicated working set makes shared modes admit
                     only ~half the set at once);
    mixed_dynamic    Poisson arrivals over tiny/medium/large jobs — the
                     "more dynamic mixed workloads" for which the paper
                     calls MIG's rigid partitioning sub-optimal; rigidity
                     shows up as queueing delay, not prose;
    drift            the composition drifts mid-trace (partition-aligned
                     burst, then a flood of tiny jobs) — exercises live
                     mode migration under the ``best`` policy, including
                     its checkpoint-rollback + reconfiguration charge;
    train_serve_mix  phase-aware training jobs (warmup / steady /
                     checkpoint) interleaved with Poisson inference
                     sessions (prefill / latency-SLO decode) over the
                     registry's serve shapes — the MIGPerf mixed fleet.
                     The per-fleet SLO-attainment and goodput columns show
                     inference flipping the collocation verdict: MIG's
                     isolated slices protect decode latency that MPS's
                     shared dispatch queue sacrifices to the saturating
                     training neighbours.
    fragmentation    a 1g-job stream followed by 2g-class jobs whose only
                     legal starts greedy first-fit has already blocked —
                     the placement-tree fragmentation the planner fleet
                     avoids (docs/placement.md).
    gang_pipeline    multi-slice gang jobs (core/gang/) on a mixed
                     80GB/40GB fleet: qwen2-72b-class trainers that fit
                     *no* single slice run as world_size-4 tensor+pipeline
                     gangs spanning two 80GB devices, 2g-class trainers
                     run as world_size-2 tensor gangs that co-locate on
                     one device, and singleton filler backfills around the
                     gangs' all-or-nothing reservations. Opt-in family
                     (like city_scale) — the default 30-cell grid is
                     unchanged; see docs/gang_scheduling.md.
    diurnal_serve    serve sessions arriving at 10x the train_serve_mix
                     rate, rate-modulated over three synthetic days, over
                     batch training — the forecast policy's testbed
                     (opt-in family; core/forecast/, docs/autoscaling.md).

  policies
    all-mig / all-mps / all-naive   homogeneous static fleets;
    best                            best-mode-per-device with live
                                    reconfiguration (adaptive policy);
    planner                         all-MIG hardware, placements chosen by
                                    the partition-tree optimizer
                                    (core/planner) with plan-driven
                                    re-partitions charged like migrations;
    forecast                        best's hardware + reactive machinery,
                                    plus a FORECAST_TICK loop that prices
                                    the predicted serve wave and pre-warms
                                    decode slices ahead of it.

The characterization DB is synthesized analytically from per-arch roofline
terms (busy seconds, replicated + sharded working-set fractions) over the
real MIG profile algebra (core/profiles.py, F6 compute discounts included),
so the simulation runs in milliseconds with no compilation; ``--db`` swaps
in records measured by launch/collocate.py instead.

Determinism contract: ``--seed`` fixes the trace and the cluster event loop
is reproducible, so the same seed yields a byte-identical
``artifacts/cluster/_summary.json`` (asserted by tests/test_cluster.py and
the CI smoke step).

Usage:
  python -m repro.launch.simulate [--steps 60] [--seed 0] [--devices 4]
                                  [--out artifacts/cluster]
                                  [--scenarios ...] [--policies ...]
"""
import argparse
import dataclasses
import json
import traceback
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs.base import ShapeSuite
from repro.configs.registry import CONFIGS
from repro.core.calib.records import seed_provenance
from repro.core.cluster import Cluster
from repro.core.collocation import is_sku_keyed_db
from repro.core.forecast import ForecastConfig
from repro.core.device import DEFAULT_SKU, SKUS, DeviceSKU, format_gib, get_sku
from repro.core.gang.parallelism import PARALLELISMS, resolve_parallelism
from repro.core.obs import EXPORTERS, TraceRecorder

# The seeded trace generators live in launch/traces.py (one copy of the
# Poisson / diurnal / burst stream machinery); the historical public names
# are re-exported here because this module *is* the scenario registry.
from repro.launch.traces import (  # noqa: F401  (re-exports)
    DIURNAL_SERVE_MEAN_INTERARRIVAL_S,
    GANG_XLARGE_PARALLELISM,
    SERVE_SLO_S,
    SERVE_SUITE,
    SIM_SAMPLES_PER_EPOCH,
    SIM_SUITE,
    TraceItem,
    aligned_static_trace,
    city_burst_trace,
    city_diurnal_trace,
    diurnal_serve_params,
    diurnal_serve_trace,
    drift_trace,
    fragmentation_trace,
    gang_pipeline_trace,
    hetero_sku_trace,
    make_trace,
    mixed_dynamic_trace,
    train_serve_mix_trace,
)
from repro.core.sharing import CollocationMode
from repro.telemetry.constants import HBM_PER_CHIP

# Analytic workload catalog over registry archs. Terms are full-device
# solo values: ``busy_s`` the dominant roofline term per step, ``repl``
# the per-chip working-set fraction that is replicated (params, per-chip
# activations — does not shrink with more chips), ``shard`` the fraction
# that shards away with chip count. Classes:
#   tiny     latency-dominated (GRACT << 1) — collocation's best case;
#   aligned  tiny compute but a slice-sized working set: exactly fills a
#            1g.5gb, so 7 of them tile a MIG device while shared modes can
#            only admit ~4 before aggregate HBM runs out;
#   medium   fits nothing below 3g.20gb;
#   large    full-device only (7g.40gb), saturating.
#   twog     too big for 1g.5gb, fits from 2g.10gb up — but 2g's only legal
#            starts are units {0, 2, 4}, so greedy first-fit 1g packing
#            strands it while the planner's flexibility tie-break keeps a
#            legal start open (the fragmentation scenario's pivot class).
#   xlarge   working set bigger than the whole 40GB part: its full-device
#            solo peak exceeds every a100-40gb/a30-24gb slice budget, so
#            only the 80GB generations' full slice admits it (serve
#            sessions halve the working set but still need > 16 GiB) — the
#            hetero_sku scenario's pivot class.
SIM_WORKLOADS: Dict[str, Dict] = {
    "resnet_small": {"cls": "tiny", "busy_s": 1.0e-4, "repl": 0.05, "shard": 0.005},
    "whisper-base": {"cls": "tiny", "busy_s": 1.5e-4, "repl": 0.06, "shard": 0.005},
    "granite-3-2b": {"cls": "aligned", "busy_s": 1.0e-4, "repl": 0.20, "shard": 0.005},
    "stablelm-12b": {"cls": "twog", "busy_s": 8.0e-4, "repl": 0.30, "shard": 0.10},
    "resnet_medium": {"cls": "medium", "busy_s": 4.0e-3, "repl": 0.22, "shard": 0.22},
    "llama3-8b": {"cls": "medium", "busy_s": 5.0e-3, "repl": 0.24, "shard": 0.20},
    "resnet_large": {"cls": "large", "busy_s": 2.0e-2, "repl": 0.35, "shard": 0.35},
    "qwen2-72b": {"cls": "xlarge", "busy_s": 3.0e-2, "repl": 2.60, "shard": 0.80},
}

#: The catalog's busy/footprint terms are defined on the 8-unit A100-40GB
#: baseline device; other SKUs scale by their own unit count and
#: compute_scale (synthetic_char_db).
_BASELINE_UNITS = DEFAULT_SKU.n_units

#: The mixed-generation fleet the hetero_sku scenario provisions (cycled
#: over --devices): the paper's part, its doubled-memory sibling, and the
#: 4-slice A30 — three placement trees in one cluster.
HETERO_FLEET_SKUS = ("a100-40gb", "a100-80gb", "a30-24gb")

#: The gang_pipeline fleet (cycled over --devices): the 80GB generation
#: first so a default 4-device fleet holds two a100-80gb — the only
#: devices whose 3g/4g slices admit a qwen2-72b tensor+pipeline gang
#: member, so the world_size-4 gangs *must* span both (docs/
#: gang_scheduling.md walks the memory math).
GANG_FLEET_SKUS = ("a100-80gb", "a100-40gb")

SCENARIO_HELP = {
    "aligned_static": "partition-aligned batch at t=0 — the mix MIG is built for",
    "mixed_dynamic": "Poisson arrivals over tiny/medium/large jobs (MIG rigidity)",
    "drift": "aligned burst then tiny-job flood — exercises live migration",
    "train_serve_mix": "phase-aware training + latency-SLO inference sessions",
    "fragmentation": "1g stream then 2g-class jobs — greedy first-fit strands "
                     "a slice the placement planner keeps open",
    "hetero_sku": "mixed-generation fleet (a100-40gb + a100-80gb + a30-24gb): "
                  "the queue drains each job onto whichever tree fits it; "
                  "big-memory serve jobs only fit the 80GB slices",
}
# The city_scale family is registered separately: its cells belong to the
# perf scoreboard (benchmarks/sim_perf.py runs them at 10^5+ arrivals over
# hundreds of devices) and are opt-in via --scenarios, not part of the
# default artifact grid — the 30 (scenario x policy) cells above stay the
# byte-pinned determinism surface.
CITY_SCENARIO_HELP = {
    "city_diurnal": "city-scale session stream: Poisson arrivals rate-"
                    "modulated by a diurnal cycle (serve-heavy mix) — the "
                    "scoreboard's steady-load cell (benchmarks/sim_perf.py)",
    "city_burst": "city-scale session stream: Markov-modulated Poisson "
                  "with short high-rate bursts — the queue-depth stressor "
                  "cell on the scoreboard",
}
# The gang family is opt-in for the same reason as city_scale: its cells
# carry gang-only schema keys, so keeping it out of the default grid keeps
# the 30 byte-pinned cells untouched while the equivalence suite still
# sweeps it (tests/test_retime_equivalence.py runs ALL_SCENARIOS).
GANG_SCENARIO_HELP = {
    "gang_pipeline": "multi-slice gangs (world_size 4 tensor+pipeline "
                     "qwen2-72b + world_size 2 tensor 2g-class) with "
                     "singleton filler on the 80GB/40GB gang fleet — "
                     "all-or-nothing admission, co-located beats scattered "
                     "(core/gang/, docs/gang_scheduling.md)",
}
# The forecast family is opt-in for the same reason as city_scale: the
# default 30-cell grid stays the byte-pinned determinism surface, and the
# equivalence suite sweeps this family via ALL_SCENARIOS.
FORECAST_SCENARIO_HELP = {
    "diurnal_serve": "diurnal serve sessions (10x the train_serve_mix "
                     "rate, three synthetic days) over batch training — "
                     "the forecast policy's autoscaling testbed "
                     "(core/forecast/, docs/autoscaling.md)",
}
POLICY_HELP = {
    "all-mig": "homogeneous MIG fleet, greedy first-fit placement",
    "all-mps": "homogeneous MPS fleet (spatial sharing)",
    "all-naive": "homogeneous naive time-slicing fleet",
    "best": "best-mode-per-device with live reconfiguration (adaptive)",
    "planner": "MIG fleet placed by the partition-tree optimizer "
               "(core/planner), with plan-driven re-partitions",
    "forecast": "adaptive fleet + forecast-driven autoscaling: estimates "
                "the serve arrival wave (core/forecast) and pre-warms "
                "decode slices ahead of the predicted ramp",
}
SCENARIOS = tuple(SCENARIO_HELP)
CITY_SCENARIOS = tuple(CITY_SCENARIO_HELP)
GANG_SCENARIOS = tuple(GANG_SCENARIO_HELP)
FORECAST_SCENARIOS = tuple(FORECAST_SCENARIO_HELP)
ALL_SCENARIOS = (
    SCENARIOS + CITY_SCENARIOS + GANG_SCENARIOS + FORECAST_SCENARIOS
)
POLICIES = tuple(POLICY_HELP)

#: gang placement preferences the cluster accepts (core/cluster.py) —
#: "scatter" exists so the gang report can price the counterfactual.
GANG_PLACEMENTS = ("colocate", "scatter")
#: world sizes the registered parallelism descriptors span — the legal
#: values for --gang-world-size (argparse lists these on a bad value).
GANG_WORLD_SIZES = tuple(sorted({
    resolve_parallelism(p).world_size for p in PARALLELISMS
}))


def synthetic_char_db(
    workloads: Optional[Dict[str, Dict]] = None,
    suite: ShapeSuite = SIM_SUITE,
    sku: Union[None, str, DeviceSKU] = None,
) -> Dict[Tuple[str, str, str], dict]:
    """Characterization records per (arch, suite, profile), analytically,
    over one device SKU's placement tree (default: the paper's A100-40GB —
    byte-identical records to the pre-device-model catalog).

    Mirrors what launch/collocate.py measures: per-profile step time from
    the roofline terms with the F6 compute discount, and per-chip peak
    memory from the replicated + sharded working-set split. The catalog
    terms are defined on the 8-unit baseline device, so a slice's busy
    time scales with the baseline-relative unit fraction (an A30's full
    4-unit device is half an A100 pod) divided by the SKU's generation
    speedup, and ``fits`` budgets the absolute working set against the
    SKU's own slice bytes. All archs must exist in the workload registry —
    the trace generator draws real keys.
    """
    dev = get_sku(sku)
    workloads = workloads if workloads is not None else SIM_WORKLOADS
    db: Dict[Tuple[str, str, str], dict] = {}
    for arch, w in workloads.items():
        if arch not in CONFIGS:
            raise KeyError(f"{arch!r} is not a registry arch")
        for prof in dev.profiles:
            chips_frac = prof.mem_units / _BASELINE_UNITS  # of baseline pod
            disc = dev.compute_discount(prof.name)
            compute_s = w["busy_s"] / chips_frac / disc / dev.compute_scale
            memory_s = 0.3 * compute_s
            collective_s = 0.1 * compute_s
            peak_bytes = (w["repl"] + w["shard"] / chips_frac) * HBM_PER_CHIP
            db[(arch, suite.name, prof.name)] = {
                "fits": peak_bytes <= dev.slice_bytes,
                "step_s": compute_s + dev.step_latency_s,
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "peak_bytes_per_device": peak_bytes,
                # where these numbers come from (core/calib/records.py):
                # the A100-40GB terms are anchored to the paper's measured
                # device; every other generation is scaled constants. Inert
                # to the schedulers, load-bearing for calibration and the
                # report's provenance column.
                "provenance": seed_provenance(dev.name),
            }
    return db


def synthetic_sku_dbs(
    sku_names: Sequence[str],
) -> Dict[str, Dict[Tuple[str, str, str], dict]]:
    """Per-SKU characterization DBs (each speaks its own profile names) —
    the ``char_db`` shape ``Cluster`` takes for a mixed-generation fleet."""
    return {
        name: synthetic_char_db(sku=name)
        for name in dict.fromkeys(sku_names)
    }


def load_char_db(artifact_dir: Path) -> Dict[Tuple[str, str, str], dict]:
    """Build the char DB from measured launch/collocate.py artifacts."""
    db: Dict[Tuple[str, str, str], dict] = {}
    for f in sorted(Path(artifact_dir).glob("*.json")):
        if f.name.startswith("_"):
            continue
        cell = json.loads(f.read_text())
        if cell.get("mode") not in ("mig", "solo"):
            continue
        for rec in cell.get("records", []):
            db[(rec["arch"], rec["shape"], rec["profile"])] = rec
    if not db:
        raise FileNotFoundError(f"no characterization records under {artifact_dir}")
    return db


def make_fleet(
    policy: str, n_devices: int, skus: Sequence[str] = ("a100-40gb",)
) -> Tuple[List[Tuple[str, CollocationMode, str]], str]:
    """(device list, cluster policy) for a fleet-mode policy. ``skus`` is
    cycled over the devices — one name for a homogeneous-generation fleet,
    several (hetero_sku) for a mixed one."""
    def fleet(mode: CollocationMode) -> List[Tuple[str, CollocationMode, str]]:
        return [
            (f"d{i}", mode, skus[i % len(skus)]) for i in range(n_devices)
        ]

    modes = {
        "all-mig": CollocationMode.MIG,
        "all-mps": CollocationMode.MPS,
        "all-naive": CollocationMode.NAIVE,
    }
    if policy in modes:
        return fleet(modes[policy]), "static"
    if policy == "best":
        # start from the paper's single-user recommendation (MPS) and let
        # per-device best_mode re-partition live as the mix drifts
        return fleet(CollocationMode.MPS), "adaptive"
    if policy == "planner":
        # same hardware as all-mig; only the placement decisions differ —
        # the printed deltas against all-mig are pure planner effects
        return fleet(CollocationMode.MIG), "planner"
    if policy == "forecast":
        # same starting hardware as best (the trough favours shared
        # training); the printed deltas against best are pure effects of
        # the proactive pre-warm loop (core/forecast/)
        return fleet(CollocationMode.MPS), "forecast"
    raise ValueError(
        f"unknown fleet policy {policy!r}; choose from: {', '.join(POLICIES)}"
    )


# -- cell execution ----------------------------------------------------------------


def forecast_config_for(scenario: str, n_jobs: int) -> ForecastConfig:
    """Scenario-matched forecast knobs for ``policy="forecast"`` cells.

    The diurnal_serve family pins the seasonal estimator's period to the
    trace's synthetic day (launch/traces.py derives day length from the
    job count), with the tick and horizon scaled to fractions of it —
    ~40 forecasts per day, pricing an eighth of a day ahead. Every other
    scenario runs the library defaults: with no seasonal structure to
    learn the estimator stays in cold start (zero lower band), the
    amortization gate never fires, and the policy degrades gracefully to
    its reactive-adaptive core."""
    if scenario in FORECAST_SCENARIOS:
        day_s = diurnal_serve_params(n_jobs)["day_s"]
        return ForecastConfig(
            period_s=day_s,
            n_bins=16,
            tick_s=day_s / 40.0,
            horizon_s=day_s / 8.0,
        )
    return ForecastConfig()


def run_cell(
    scenario: str,
    policy: str,
    *,
    seed: int = 0,
    n_jobs: int = 60,
    n_devices: int = 4,
    reconfig_cost_s: float = 0.5,
    char_db: Optional[Dict] = None,
    sku: str = "a100-40gb",
    retime: str = "incremental",
    gang_placement: str = "colocate",
    gang_parallelism: str = "tp2",
    gang_reserve_after_s: float = 0.5,
    gang_degrade: bool = False,
    trace: Optional[TraceRecorder] = None,
) -> Dict:
    """One (scenario x policy) simulation; returns the artifact cell dict.

    ``sku`` selects the fleet's device generation (--sku); the hetero_sku
    scenario overrides it with the fixed mixed-generation fleet and
    gang_pipeline with the 80GB-first gang fleet. When ``char_db`` is
    None, per-SKU synthetic DBs are built; a flat measured DB (--db) only
    speaks one SKU's profile names, so it is rejected for any other
    fleet. ``retime`` selects the cluster's re-pricing engine (--retime):
    the incremental default or the full reference path — the two must
    produce byte-identical cells (tests/test_retime_equivalence), so the
    choice is deliberately not recorded in the artifact schema.

    The ``gang_*`` knobs only matter when the trace contains gang jobs
    (the gang_pipeline family): placement preference and starvation bound
    are forwarded to the cluster, ``gang_parallelism`` picks the 2g-class
    gangs' descriptor, and ``gang_degrade`` collapses every gang spec to
    a world_size-1 singleton — the full-slice-only baseline the gang
    report prices (benchmarks/report.py gang), under which the qwen2-72b
    class fits nothing and is rejected instead of sharded.

    ``trace`` attaches a ``TraceRecorder`` (core/obs/, --trace): the cell
    dict is byte-identical either way — tracing is purely observational —
    and the caller exports the recorder afterwards."""
    fleet_skus: Tuple[str, ...] = (
        HETERO_FLEET_SKUS if scenario == "hetero_sku"
        else GANG_FLEET_SKUS if scenario == "gang_pipeline"
        else (sku,)
    )
    for name in fleet_skus:
        get_sku(name)  # fail fast on unknown SKU names
    if char_db is None:
        db: Dict = synthetic_sku_dbs(fleet_skus)
    elif is_sku_keyed_db(char_db):
        db = char_db  # already per-SKU
    elif set(fleet_skus) != {"a100-40gb"}:
        raise ValueError(
            "a flat characterization DB (--db) speaks a100-40gb profile "
            f"names only; the {scenario!r} fleet needs SKUs "
            f"{sorted(set(fleet_skus))} — drop --db or run the default SKU"
        )
    else:
        db = char_db
    devices, cluster_policy = make_fleet(policy, n_devices, fleet_skus)
    cluster = Cluster(
        db,
        devices,
        policy=cluster_policy,
        reconfig_cost_s=reconfig_cost_s,
        migration_cooldown_s=1.0,
        retime=retime,
        gang_placement=gang_placement,
        gang_reserve_after_s=gang_reserve_after_s,
        forecast=(
            forecast_config_for(scenario, n_jobs)
            if cluster_policy == "forecast"
            else None
        ),
        trace=trace,
    )
    jobs = make_trace(
        scenario, seed, n_jobs, n_devices, gang_parallelism=gang_parallelism
    )
    if gang_degrade:
        jobs = [
            (t, dataclasses.replace(spec, world_size=1, parallelism=None)
             if getattr(spec, "world_size", 1) > 1 else spec, epochs)
            for t, spec, epochs in jobs
        ]
    for arrival_s, spec, epochs in jobs:
        cluster.submit(
            spec, arrival_s, epochs=epochs, samples_per_epoch=SIM_SAMPLES_PER_EPOCH
        )
    report = cluster.run()
    cell = {
        "scenario": scenario,
        "policy": policy,
        "seed": seed,
        "n_jobs": len(jobs),
        "n_devices": n_devices,
        "reconfig_cost_s": reconfig_cost_s,
        "status": "OK",
        "report": report.to_dict(),
    }
    # schema extension only where the hardware axis is exercised — default
    # single-SKU cells stay byte-identical to the pre-device-model artifacts
    if len(set(fleet_skus)) > 1:
        cell["fleet_skus"] = list(fleet_skus)
    elif fleet_skus[0] != "a100-40gb":
        cell["sku"] = fleet_skus[0]
    if scenario in GANG_SCENARIOS:
        cell["gang_placement"] = gang_placement
        cell["gang_parallelism"] = gang_parallelism
        if gang_degrade:
            cell["gang_degrade"] = True
    return cell


def summarize_cell(cell: Dict) -> Dict:
    r = cell["report"]
    return {
        "scenario": cell["scenario"],
        "policy": cell["policy"],
        "n_jobs": cell["n_jobs"],
        "makespan_s": r["makespan_s"],
        "mean_jct_s": r["mean_jct_s"],
        "mean_queueing_delay_s": r["mean_queueing_delay_s"],
        "max_queueing_delay_s": r["max_queueing_delay_s"],
        "utilization_mean": r["utilization"]["mean"],
        "completed": r["completed"],
        "completed_train": r.get("completed_train", r["completed"]),
        "completed_serve": r.get("completed_serve", 0),
        "rejected": r["rejected"],
        "still_queued": r["still_queued"],
        "migrations": r["migrations"],
        "reconfig_cost_s": r["reconfig_cost_s"],
        "lost_steps": r["lost_steps"],
        "slo_attainment": r.get("slo_attainment", 1.0),
        "goodput_steps_per_s": r.get("goodput_steps_per_s", 0.0),
        "phase_transitions": r.get("phase_transitions", 0),
    }


def run_all(
    *,
    seed: int = 0,
    n_jobs: int = 60,
    n_devices: int = 4,
    reconfig_cost_s: float = 0.5,
    scenarios: Sequence[str] = SCENARIOS,
    policies: Sequence[str] = POLICIES,
    char_db: Optional[Dict] = None,
    sku: str = "a100-40gb",
    retime: str = "incremental",
) -> List[Dict]:
    if char_db is None:
        # one per-SKU DB set shared by every cell (covers the selected
        # fleet SKU plus the hetero fleet's generations)
        char_db = synthetic_sku_dbs((sku,) + HETERO_FLEET_SKUS)
    return [
        run_cell(
            sc,
            po,
            seed=seed,
            n_jobs=n_jobs,
            n_devices=n_devices,
            reconfig_cost_s=reconfig_cost_s,
            char_db=char_db,
            sku=sku,
            retime=retime,
        )
        for sc in scenarios
        for po in policies
    ]


def _rounded(obj, ndigits: int = 9):
    """Recursively round floats so artifacts are byte-stable."""
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _rounded(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_rounded(v, ndigits) for v in obj]
    return obj


def _dump(path: Path, obj) -> None:
    path.write_text(json.dumps(_rounded(obj), indent=2, sort_keys=True) + "\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__ and __doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=60,
                    help="number of jobs in each generated arrival trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--out", default="artifacts/cluster")
    ap.add_argument("--scenarios", default=",".join(SCENARIOS))
    ap.add_argument("--policies", default=",".join(POLICIES))
    ap.add_argument("--reconfig-cost", type=float, default=0.5,
                    help="device downtime charged per mode migration (s)")
    ap.add_argument("--sku", default="a100-40gb", choices=sorted(SKUS),
                    help="device generation of the fleet (core/device.py); "
                         "the hetero_sku scenario always provisions its "
                         "fixed mixed-generation fleet instead")
    ap.add_argument("--retime", default="incremental",
                    choices=("incremental", "full"),
                    help="cluster re-pricing engine: the incremental "
                         "deferred-batch path (default) or the full "
                         "reference path; both produce byte-identical "
                         "artifacts (tests/test_retime_equivalence.py)")
    ap.add_argument("--db", default=None,
                    help="load the char DB from collocate.py artifacts "
                         "instead of the synthetic catalog (a100-40gb "
                         "profile names — default SKU fleets only)")
    ap.add_argument("--gang-placement", default="colocate",
                    choices=GANG_PLACEMENTS,
                    help="gang placement preference (core/gang/placement): "
                         "pack members onto as few devices as possible "
                         "(default) or scatter them — the counterfactual "
                         "the gang report prices (benchmarks/report.py)")
    ap.add_argument("--gang-parallelism", default="tp2",
                    choices=sorted(PARALLELISMS),
                    help="parallelism descriptor for the gang_pipeline "
                         "scenario's 2g-class gangs (core/gang/"
                         "parallelism.py registry)")
    ap.add_argument("--gang-world-size", type=int, default=None,
                    choices=GANG_WORLD_SIZES,
                    help="expected world size of the 2g-class gangs; "
                         "purely a cross-check — it must equal the "
                         "--gang-parallelism descriptor's world size "
                         "(world_size is always derived, never free)")
    ap.add_argument("--trace", action="store_true",
                    help="record a deterministic scheduler trace per cell "
                         "(core/obs/) and export it next to the artifact "
                         "as _trace__<scenario>__<policy>.json (Perfetto) "
                         "and _counters__<scenario>__<policy>.json")
    ap.add_argument("--trace-exporter", default=None,
                    choices=sorted(EXPORTERS) + ["both"],
                    help="which trace export(s) --trace writes "
                         "(default: both)")
    ap.add_argument("--list", action="store_true",
                    help="print the registered scenarios, fleet policies, "
                         "and device SKUs, and exit")
    args = ap.parse_args(argv)

    if args.list:
        print("scenarios:")
        for name, desc in SCENARIO_HELP.items():
            print(f"  {name:<16} {desc}")
        print("city-scale scenarios (scoreboard family, opt-in via --scenarios):")
        for name, desc in CITY_SCENARIO_HELP.items():
            print(f"  {name:<16} {desc}")
        print("gang scenarios (multi-slice family, opt-in via --scenarios):")
        for name, desc in GANG_SCENARIO_HELP.items():
            print(f"  {name:<16} {desc}")
        print("forecast scenarios (autoscaling family, opt-in via --scenarios):")
        for name, desc in FORECAST_SCENARIO_HELP.items():
            print(f"  {name:<16} {desc}")
        print("gang parameters:")
        print(f"  placements       {', '.join(GANG_PLACEMENTS)} (--gang-placement)")
        print("  parallelisms     world_size is derived: tensor x pipeline x data")
        for pname in sorted(PARALLELISMS):
            par = resolve_parallelism(pname)
            print(f"    {pname:<14} {par.label} (world_size {par.world_size})")
        print("fleet policies:")
        for name, desc in POLICY_HELP.items():
            print(f"  {name:<16} {desc}")
        print("device SKUs:")
        for name, dev in SKUS.items():
            default = " (default)" if dev is DEFAULT_SKU else ""
            print(
                f"  {name:<16} {dev.n_units} units x "
                f"{format_gib(dev.slice_bytes)} GiB/slice, "
                f"{dev.n_compute_slices} compute slices, "
                f"{len(dev.profiles)} profiles{default}"
            )
        print("trace exporters (--trace, --trace-exporter):")
        print("  perfetto         Chrome-trace-event JSON (ui.perfetto.dev)")
        print("  counters         flat counter series + step samples")
        print("  both             write both files per cell (default)")
        return 0

    # fail fast with the registered choices listed — not a KeyError
    # traceback (or a silently FAILed artifact cell) deep in the run loop
    scenarios = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    unknown = [s for s in scenarios if s not in ALL_SCENARIOS]
    if unknown:
        ap.error(
            f"unknown scenario(s): {', '.join(unknown)} "
            f"(choose from: {', '.join(ALL_SCENARIOS)})"
        )
    unknown = [p for p in policies if p not in POLICIES]
    if unknown:
        ap.error(
            f"unknown fleet polic(y|ies): {', '.join(unknown)} "
            f"(choose from: {', '.join(POLICIES)})"
        )
    if not scenarios or not policies:
        ap.error("need at least one scenario and one fleet policy")
    if args.gang_world_size is not None:
        par = resolve_parallelism(args.gang_parallelism)
        if args.gang_world_size != par.world_size:
            ap.error(
                f"--gang-world-size {args.gang_world_size} does not match "
                f"--gang-parallelism {args.gang_parallelism} ({par.label}, "
                f"world_size {par.world_size}); world_size is derived from "
                "the descriptor — registered choices: "
                + ", ".join(
                    f"{p}={resolve_parallelism(p).world_size}"
                    for p in sorted(PARALLELISMS)
                )
            )
    if args.db and args.sku != "a100-40gb":
        ap.error(
            "--db loads a flat measured characterization DB, which speaks "
            "a100-40gb profile names only; it cannot drive a "
            f"--sku {args.sku} fleet"
        )
    if args.trace_exporter is not None and not args.trace:
        ap.error("--trace-exporter requires --trace")
    exporter = args.trace_exporter or "both"

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    if args.db:
        # parity with collocate.py for measured-DB reruns; kept out of
        # module scope so importing this module (tests, benchmarks) never
        # mutates XLA_FLAGS before an unrelated jax backend initializes
        from repro.launch.bootstrap import ensure_host_platform_devices

        ensure_host_platform_devices()
    char_db = (
        load_char_db(Path(args.db))
        if args.db
        else synthetic_sku_dbs((args.sku,) + HETERO_FLEET_SKUS)
    )

    summaries: List[Dict] = []
    failures = 0
    for scenario in scenarios:
        if args.db and scenario in ("hetero_sku",) + GANG_SCENARIOS:
            # a flat measured DB cannot price a mixed-generation fleet's
            # per-SKU trees — documented skip, not a failure (the synthetic
            # catalog path still covers the scenario)
            print(
                f"[SKIP] {scenario}: --db is a flat a100-40gb DB; the "
                "mixed-generation fleet needs per-SKU records",
                flush=True,
            )
            continue
        for policy in policies:
            try:
                recorder = TraceRecorder() if args.trace else None
                cell = run_cell(
                    scenario,
                    policy,
                    seed=args.seed,
                    n_jobs=args.steps,
                    n_devices=args.devices,
                    reconfig_cost_s=args.reconfig_cost,
                    char_db=char_db,
                    sku=args.sku,
                    retime=args.retime,
                    gang_placement=args.gang_placement,
                    gang_parallelism=args.gang_parallelism,
                    trace=recorder,
                )
                _dump(out_dir / f"{scenario}__{policy}.json", cell)
                if recorder is not None:
                    # "_"-prefixed so artifact loaders that glob cell files
                    # (benchmarks/common.load_cluster) skip trace exports
                    prefixes = {"perfetto": "_trace", "counters": "_counters"}
                    for ex_name in (
                        sorted(EXPORTERS) if exporter == "both" else [exporter]
                    ):
                        _dump(
                            out_dir
                            / f"{prefixes[ex_name]}__{scenario}__{policy}.json",
                            EXPORTERS[ex_name](recorder),
                        )
                s = summarize_cell(cell)
                summaries.append(s)
                print(
                    f"[OK]   {scenario:<16} {policy:<10} jobs={s['n_jobs']:>3} "
                    f"makespan={s['makespan_s']:.2f}s jct={s['mean_jct_s']:.2f}s "
                    f"qdelay={s['mean_queueing_delay_s']:.3f}s "
                    f"util={s['utilization_mean']:.2f} migr={s['migrations']}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"[FAIL] {scenario} {policy}: {e}", flush=True)
                traceback.print_exc(limit=3)
    _dump(
        out_dir / "_summary.json",
        {
            "seed": args.seed,
            "steps": args.steps,
            "devices": args.devices,
            "cells": summaries,
            "failures": failures,
        },
    )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
