"""Mesh-agnostic lowering helpers shared by the dry-run, the collocation
characterizer, and the benchmarks. No environment side effects — safe to
import from anywhere (unlike ``dryrun``, which pins XLA_FLAGS first thing).
"""
from __future__ import annotations

import jax

from repro.configs.base import ShapeSuite
from repro.configs.registry import get_config
from repro.models.model_api import build_model
from repro.optim import adamw
from repro.runtime import serve_step as serve
from repro.runtime import train_step as ts


def active_params(cfg, total: int) -> int:
    """Params touched per token (MoE: shared + top_k routed experts only)."""
    if cfg.moe is None:
        return total
    m = cfg.moe
    inactive_experts = m.n_experts - m.top_k
    per_expert = 3 * cfg.d_model * m.d_expert
    return total - cfg.n_layers * inactive_experts * per_expert


def lower_cell(arch: str, suite: ShapeSuite, mesh, *, grad_accum: int = 1,
               variant: str = "baseline", remat: bool | None = None):
    """Lower the real step function for (arch, suite) on ``mesh``.

    train shapes -> train_step (fwd+bwd+optimizer);
    prefill shapes -> prefill step; decode shapes -> one-token decode step.
    Returns (cfg, model, lowered). ``remat=None`` keeps the config default.
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if remat is not None:
        cfg = _dc.replace(cfg, remat=remat)
    model = build_model(cfg)
    if suite.kind == "train":
        jitted, st_sh, b_sh, plan = ts.jit_train_step(
            model, mesh, suite, adamw.AdamWConfig(), grad_accum=grad_accum,
            variant=variant,
        )
        state_shape = jax.eval_shape(
            lambda k: ts.init_train_state(model, k, adamw.AdamWConfig()),
            jax.random.key(0),
        )
        batch_shape = model.input_specs(suite)
        lowered = jitted.lower(state_shape, batch_shape)
    elif suite.kind == "prefill":
        jitted, p_sh, b_sh, plan = serve.jit_prefill_step(model, mesh, suite, variant=variant)
        params_shape = jax.eval_shape(model.init, jax.random.key(0))
        batch_shape = model.input_specs(suite)
        lowered = jitted.lower(params_shape, batch_shape)
    else:  # decode
        jitted, p_sh, tok_sh, c_sh, plan = serve.jit_decode_step(model, mesh, suite, variant=variant)
        params_shape = jax.eval_shape(model.init, jax.random.key(0))
        batch_shape = model.input_specs(suite)
        cache_shape = model.cache_spec(suite.global_batch, suite.seq_len)
        lowered = jitted.lower(params_shape, batch_shape, cache_shape)
    return cfg, model, lowered


# alias used by core/instance.py
lower_cell_on_mesh = lower_cell
