"""Calibration CLI: regenerate the char DB from measured observations.

    PYTHONPATH=src python -m repro.launch.calibrate                       \\
        [--backend stub|kernels] [--seed 0] [--skus a100-40gb,...]        \\
        [--out artifacts/calib] [--from-trace step_error.json]

The executable form of the calibration loop (docs/calibration.md): per
SKU, load the hand-seeded analytic catalog (``launch/simulate.py``),
measure the MISO probe set through the chosen backend (core/calib/
harness — the deterministic seeded stub by default; ``--backend
kernels`` times the repo's Pallas kernels, interpret-mode on CPU),
fit per-arch x per-slice residual corrections, refine every unmeasured
entry, and write the calibrated DB plus a scorecard:

  artifacts/calib/calib_db__<sku>.json   the ``calib_char_db/v1``
                                         document — every entry carries
                                         provenance (measured / predicted
                                         / refined / extrapolated);
  artifacts/calib/_summary.json          per-SKU seed-vs-calibrated error
                                         vs the stub's ground truth, the
                                         fitted residuals, and the online
                                         EWMA convergence demo.

Stub-backend artifacts are **byte-deterministic per seed** (the CI
``calibrate`` job runs the harness twice and byte-compares; floats are
rounded exactly like the cluster artifacts). ``--from-trace`` instead
fits residuals from a ``calib_step_error/v1`` document — the output of
``python -m benchmarks.report trace --format json`` — so a live
simulation's step samples calibrate the DB without re-deriving the error
aggregation.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Optional, Sequence

from repro.core.calib import (
    OnlineCalibrator,
    StubBackend,
    calibration_report,
    fit_from_error_doc,
    make_backend,
    refine_db,
    run_calibration,
)
from repro.core.calib.records import CharDB
from repro.core.device import SKUS, get_sku
from repro.core.metrics import epoch_time_s
from repro.launch.simulate import _dump, synthetic_char_db
from repro.launch.traces import SIM_SAMPLES_PER_EPOCH

#: Steps of the online-refinement convergence demo per measured key, and
#: the batch the epoch-time view of an observation assumes (the simulation
#: trace default).
ONLINE_DEMO_STEPS = 12
CALIB_BATCH = 32


def _epoch_s(step_s: float) -> float:
    """Epoch-time view of a measured step — benchmarks/time_per_epoch.py's
    helper when the benchmarks package is importable (running from the
    repo root, as CI does), the identical core.metrics algebra otherwise
    (the CLI must work from any cwd with only src/ on the path)."""
    try:
        from benchmarks.time_per_epoch import calibration_epoch_time_s

        return calibration_epoch_time_s(
            step_s, samples_per_epoch=SIM_SAMPLES_PER_EPOCH, batch=CALIB_BATCH
        )
    except ImportError:
        rec = type("R", (), {"step_s": step_s})()
        return epoch_time_s(rec, SIM_SAMPLES_PER_EPOCH, CALIB_BATCH)


def online_demo(backend: StubBackend, seed_db, *, sku) -> dict:
    """MISO's online-refinement claim as a deterministic convergence run.

    Feed ``ONLINE_DEMO_STEPS`` ground-truth step samples per measured key
    through an ``OnlineCalibrator`` exactly as ``Cluster.observe_step``
    does (predicted = the calibrator-corrected seed prediction, so the
    self-referencing feedback path is the one exercised), and report the
    prediction error at the first and last step: the EWMA must tighten."""
    dev = get_sku(sku)
    calib = OnlineCalibrator()
    first_errs, last_errs = [], []
    for key in sorted(seed_db):
        arch, _, profile = key
        true_s = backend.true_step_s(key)
        base_s = float(seed_db[key]["step_s"])
        if true_s <= 0.0 or base_s <= 0.0:
            continue
        for step in range(ONLINE_DEMO_STEPS):
            predicted_s = calib.correct(
                base_s, sku=dev.name, arch=arch, profile=profile
            )
            err = abs(predicted_s - true_s) / true_s
            if step == 0:
                first_errs.append(err)
            if step == ONLINE_DEMO_STEPS - 1:
                last_errs.append(err)
            calib.observe(
                sku=dev.name,
                arch=arch,
                profile=profile,
                measured_s=true_s,
                predicted_s=predicted_s,
                t_s=float(step),
            )
    return {
        "steps_per_key": ONLINE_DEMO_STEPS,
        "n_keys": len(first_errs),
        "first_step_mean_abs_rel_err": (
            sum(first_errs) / len(first_errs) if first_errs else 0.0
        ),
        "last_step_mean_abs_rel_err": (
            sum(last_errs) / len(last_errs) if last_errs else 0.0
        ),
        "n_observed": calib.n_observed,
        "residuals": calib.snapshot()["residuals"],
    }


def calibrate_sku(sku_name: str, *, backend_name: str, seed: int) -> tuple:
    """One SKU's full pass: (calibrated CharDB, summary dict)."""
    dev = get_sku(sku_name)
    seed_db = synthetic_char_db(sku=dev)
    backend = make_backend(backend_name, seed_db, sku=dev, seed=seed)
    result = run_calibration(seed_db, backend, sku=dev, seed=seed)
    summary = result.summary()
    summary["observations"] = [
        {
            "arch": o.arch,
            "shape": o.shape,
            "profile": o.profile,
            "step_s": o.step_s,
            "epoch_time_s": _epoch_s(o.step_s),
            "provenance": o.provenance,
            "n_samples": o.n_samples,
        }
        for o in result.observations
    ]
    if isinstance(backend, StubBackend):
        # only the stub carries its own ground truth; a kernel run's
        # scorecard needs a second measurement pass on real hardware
        summary["scorecard"] = calibration_report(result, backend.true_step_s)
        summary["online"] = online_demo(backend, seed_db, sku=dev)
    return result.calibrated, summary


def calibrate_from_trace(doc_path: Path, sku_name: str, *, seed: int) -> tuple:
    """Fit residuals from a ``calib_step_error/v1`` document (``report.py
    trace --format json``) and refine the SKU's seed catalog with them —
    no backend run; the simulation's own step samples are the evidence."""
    doc = json.loads(Path(doc_path).read_text())
    dev = get_sku(sku_name)
    fit = fit_from_error_doc(doc, sku=dev.name)
    seed_db = CharDB.from_plain_db(
        synthetic_char_db(sku=dev), sku=dev.name, seed=seed
    )
    calibrated = refine_db(seed_db, fit)
    return calibrated, {
        "sku": dev.name,
        "backend": "trace",
        "source": str(doc_path),
        "n_keys": len(calibrated),
        "n_rows": len(doc.get("rows", ())),
        "provenance": calibrated.provenance_counts(),
        "fit": fit.to_doc(),
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__ and __doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="stub", choices=("stub", "kernels"),
                    help="measurement backend (core/calib/harness): the "
                         "deterministic seeded stub (default; what CI "
                         "byte-compares) or the Pallas kernel path "
                         "(interpret-mode on CPU, compiled on TPU — wall "
                         "clock, not byte-deterministic)")
    ap.add_argument("--skus", default=",".join(sorted(SKUS)),
                    help="comma-separated SKUs to calibrate")
    ap.add_argument("--out", default="artifacts/calib")
    ap.add_argument("--from-trace", default=None, metavar="DOC.json",
                    help="fit from a calib_step_error/v1 document "
                         "(benchmarks/report.py trace --format json) "
                         "instead of running a backend; applies to the "
                         "first --skus entry")
    args = ap.parse_args(argv)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    skus = [s for s in args.skus.split(",") if s]
    summaries = {}
    if args.from_trace is not None:
        sku = skus[0]
        db, summary = calibrate_from_trace(
            Path(args.from_trace), sku, seed=args.seed
        )
        _dump(out / f"calib_db__{sku}.json", db.to_doc())
        summaries[sku] = summary
        print(f"calibrate[{sku}] <- {args.from_trace}: "
              f"{summary['n_rows']} error rows, {summary['provenance']}")
    else:
        for sku in skus:
            db, summary = calibrate_sku(
                sku, backend_name=args.backend, seed=args.seed
            )
            _dump(out / f"calib_db__{sku}.json", db.to_doc())
            summaries[sku] = summary
            card = summary.get("scorecard")
            if card is not None:
                print(
                    f"calibrate[{sku}] backend={args.backend} seed={args.seed}: "
                    f"err {card['seed_mean_abs_rel_err']:.4f} -> "
                    f"{card['calibrated_mean_abs_rel_err']:.4f} "
                    f"(-{100.0 * card['error_reduction']:.1f}%)"
                )
            else:
                print(f"calibrate[{sku}] backend={args.backend}: "
                      f"{summary['provenance']}")
    _dump(out / "_summary.json", {"seed": args.seed, "backend": args.backend,
                                  "skus": summaries})
    print(f"wrote {len(summaries)} calibrated DB(s) + _summary.json -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
