"""End-to-end training launcher.

The production entry point: build the model from ``--arch``, shard it over
the chosen mesh, stream deterministic synthetic data through the host
pipeline, checkpoint every ``--ckpt-every`` steps (async, atomic), resume
automatically from the latest valid checkpoint, and log step time / loss /
input-wait. On this CPU container use ``--reduced`` for a runnable config;
on a pod the same flags drive the full config.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSuite
from repro.configs.registry import get_config
from repro.checkpoint.store import CheckpointStore
from repro.data import synthetic
from repro.data.pipeline import HostPipeline
from repro.models.model_api import build_model
from repro.optim import adamw
from repro.runtime import train_step as ts
from repro.sharding.plan import make_plan


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--total-steps", type=int, default=0,
                    help="LR schedule horizon (0 -> --steps); pin it when a "
                         "run will be interrupted/resumed so the schedule "
                         "is invariant to the stopping point")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--max-queue-size", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", choices=("none", "host"), default="none",
                    help="'host': mesh over all local devices (data x model)")
    ap.add_argument("--metrics-out", default="")
    return ap


def make_host_mesh():
    n = len(jax.devices())
    if n == 1:
        return None
    rows = max(1, n // 2)
    return jax.make_mesh((rows, n // rows), ("data", "model"))


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    suite = ShapeSuite("train_cli", args.seq, args.batch, "train")
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(
        lr_peak=args.lr, warmup_steps=args.warmup,
        total_steps=args.total_steps or max(args.steps, 1),
    )

    mesh = make_host_mesh() if args.mesh == "host" else None
    if mesh is not None:
        jitted, st_sh, b_sh, plan = ts.jit_train_step(
            model, mesh, suite, opt_cfg, grad_accum=args.grad_accum
        )
    else:
        plan = make_plan(cfg, None)
        step_fn = ts.build_train_step(model, plan, opt_cfg, grad_accum=args.grad_accum)
        jitted = jax.jit(step_fn, donate_argnums=(0,))

    state = ts.init_train_state(model, jax.random.key(args.seed), opt_cfg)
    start_step = 0

    store = None
    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir)
        latest = store.latest_step()
        if latest is not None:
            state, extra = store.restore(state, latest)
            start_step = latest
            print(f"[train] resumed from step {latest}", flush=True)

    pipeline = HostPipeline(
        lambda step: synthetic.batch_for(cfg, suite, seed=args.seed, step=step),
        workers=args.workers,
        max_queue_size=args.max_queue_size,
        start_step=start_step,
    ).start()

    losses = []
    step_times = []
    t_train0 = time.perf_counter()
    try:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipeline.get().items()}
            t0 = time.perf_counter()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            step_times.append(time.perf_counter() - t0)
            losses.append(loss)
            if np.isnan(loss):
                raise FloatingPointError(f"NaN loss at step {step}")
            if (step + 1) % args.log_every == 0:
                print(
                    f"[train] step {step + 1}/{args.steps} loss={loss:.4f} "
                    f"step_time={np.mean(step_times[-args.log_every:]) * 1e3:.1f}ms",
                    flush=True,
                )
            if store and (step + 1) % args.ckpt_every == 0:
                store.save(step + 1, state, extra={"loss": loss}, async_save=True)
    finally:
        pipeline.stop()
    if store:
        store.save(args.steps, state, extra={"loss": losses[-1]})
        store.wait()

    wall = time.perf_counter() - t_train0
    result = {
        "arch": args.arch,
        "steps": args.steps - start_step,
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        # window means: single-step losses on stochastic batches are too
        # noisy to compare individually
        "head_mean_loss": float(np.mean(losses[:5])) if losses else None,
        "tail_mean_loss": float(np.mean(losses[-5:])) if losses else None,
        "mean_step_ms": float(np.mean(step_times[3:]) * 1e3) if len(step_times) > 3 else None,
        "wall_s": wall,
        "pipeline": pipeline.stats(),
    }
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(result, indent=2))
    return result


def main():
    args = build_argparser().parse_args()
    result = run(args)
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
