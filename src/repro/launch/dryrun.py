import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

For each cell this lowers the real step function (train_step for train
shapes; prefill/decode steps for serving shapes) with full GSPMD shardings,
compiles it, and records:
  * memory_analysis()  — per-device bytes (fits-in-HBM proof)
  * cost_analysis()    — per-device HLO FLOPs / bytes accessed
  * collective summary — parsed from optimized HLO, scan-multiplied,
                         ring-cost weighted (telemetry/hlo.py)
  * the roofline report (telemetry/roofline.py)

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json and
feed EXPERIMENTS.md §Dry-run/§Roofline and the hillclimb.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--tag baseline]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES_BY_NAME, ShapeSuite, shape_applicable
from repro.configs.registry import ASSIGNED, get_config
from repro.launch.lowering import active_params, lower_cell
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_label
from repro.telemetry import roofline as rl
from repro.telemetry.hlo import collective_summary, hlo_flops_bytes


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path, tag: str = "",
             grad_accum: int = 1, variant: str = "baseline",
             remat: bool | None = None, mesh_spec: str = "") -> dict:
    suite = SHAPES_BY_NAME[shape]
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, suite)
    label = f"{arch}__{shape}__{mesh_kind}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = {"cell": label, "status": "SKIP", "reason": why}
        (out_dir / f"{label}.json").write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    if mesh_spec:
        dims = tuple(int(x) for x in mesh_spec.split("x"))
        names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        from repro.launch.mesh import make_mesh_shape

        mesh = make_mesh_shape(dims, names)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg, model, lowered = lower_cell(arch, suite, mesh, grad_accum=grad_accum,
                                     variant=variant, remat=remat)
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_summary(hlo_text)
    # loop-aware flops/bytes (cost_analysis counts while bodies once — a
    # ~n_layers undercount for scan-over-depth programs)
    est = hlo_flops_bytes(hlo_text)

    chips = mesh_chips(mesh)
    n_total = model.param_count()
    n_active = active_params(cfg, n_total)
    peak_mem = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
        + mem.temp_size_in_bytes
    )
    report = rl.RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_label(mesh),
        chips=chips,
        flops_per_device=float(est["flops"]),
        hbm_bytes_per_device=float(est["bytes"]),
        wire_bytes_per_device=float(coll["per_device_wire_bytes"]),
        model_flops_global=rl.model_flops(cfg, suite, n_active),
        peak_mem_bytes_per_device=float(peak_mem),
        collective_detail={k: coll[k] for k in ("by_kind", "top_ops", "n_collective_sites")},
    )
    rec = {
        "cell": label,
        "status": "OK",
        "grad_accum": grad_accum,
        "variant": variant,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "n_params_total": n_total,
        "n_params_active": n_active,
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": peak_mem,
        },
        "dcgm_analogues": rl.dcgm_analogues(report),
        "roofline": report.to_dict(),
    }
    (out_dir / f"{label}.json").write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ASSIGNED), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME), default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--variant", default="baseline",
                    choices=("baseline", "sp", "zero", "serve"))
    ap.add_argument("--remat", default="default", choices=("default", "on", "off"))
    ap.add_argument("--mesh-spec", default="",
                    help="logical reshape of the pod, e.g. 64x4 (data x model);"
                         " same 256 chips, different axis split (perf variant)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES_BY_NAME:
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all) required"
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    failures = 0
    for arch, shape, mk in cells:
        try:
            remat = {"default": None, "on": True, "off": False}[args.remat]
            rec = run_cell(arch, shape, mk, out_dir, args.tag, args.grad_accum,
                           args.variant, remat, args.mesh_spec)
            if rec["status"] == "OK":
                r = rec["roofline"]
                print(
                    f"[OK]   {rec['cell']}: compute={r['compute_s']:.4f}s "
                    f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                    f"bound={r['bound']} mem/dev={r['peak_mem_bytes_per_device']/2**30:.2f}GiB "
                    f"(compile {rec['t_compile_s']}s)",
                    flush=True,
                )
            else:
                print(f"[SKIP] {rec['cell']}: {rec['reason']}", flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            failures += 1
            label = f"{arch}__{shape}__{mk}"
            (out_dir / f"{label}.json").write_text(
                json.dumps({"cell": label, "status": "FAIL", "error": str(e)[:2000],
                            "traceback": traceback.format_exc()[-4000:]}, indent=2)
            )
            print(f"[FAIL] {label}: {e}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
