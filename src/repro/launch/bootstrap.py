"""Process bootstrap shared by the launch entry points.

The collocation drivers emulate a 256-chip pod on CPU via XLA's host
platform. The flag must be set *before* jax initializes its backends, so
entry points call :func:`ensure_host_platform_devices` at the top of the
module — after the docstring (a bare statement above the docstring makes
``__doc__`` silently ``None``) and before any ``import jax``.
"""
from __future__ import annotations

import os

POD_DEVICE_COUNT = 256  # one 16x16 v5e pod; 2 rows (32 chips) per slice unit


def ensure_host_platform_devices(n: int = POD_DEVICE_COUNT) -> None:
    """Idempotently request ``n`` XLA host-platform devices via XLA_FLAGS."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()
