"""Collocation characterization driver — the paper's §3.4 experiment matrix.

For every (workload x device-group) cell of the paper's grid this lowers and
compiles the job's real train step on the instance's carved sub-mesh,
derives step-time roofline + DCGM analogues + memory admission, verifies the
isolation properties (core/interference.py), and writes one JSON artifact
per cell to ``artifacts/collocation/``. Every cell carries its collocation
mode: the MIG grid cells are ``mode="mig"``, the full-device baseline is
``mode="solo"``, and each workload additionally gets analytic shared-mode
cells (``mode="naive"`` / ``mode="mps"`` at k = 2, 4, 7 collocated copies)
derived from the solo characterization through the contention models in
core/sharing.py. The benchmarks (time_per_epoch, collocation_throughput,
utilization, memory_footprint, report) read these artifacts and print the
paper-table reproductions, including the naive-vs-MPS-vs-MIG comparison.

The 256 placeholder devices stand in for one 16x16 v5e pod; instances are
contiguous row-blocks of the grid (32 chips per slice unit).

Usage:
  python -m repro.launch.collocate [--workloads resnet_small,...]
                                   [--suite paper_train] [--out artifacts/collocation]
"""
from repro.launch.bootstrap import ensure_host_platform_devices

ensure_host_platform_devices()  # must precede the first jax import

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import numpy as np

import jax

from repro.configs.base import ShapeSuite
from repro.core import interference
from repro.core.collocation import paper_experiment_grid
from repro.core.instance import InstanceRecord, InstanceRuntime, JobSpec
from repro.core.metrics import (
    collocation_speedup,
    device_group_report,
    epoch_time_s,
)
from repro.core.partitioner import device_grid, partition
from repro.core.profiles import PROFILES
from repro.core.sharing import (
    CollocationMode,
    SoloProfile,
    shared_mode_report,
)

# collocated-copy counts for the analytic naive/MPS cells (the paper sweeps
# 2..7 concurrent models; 7 matches the max 1g.5gb MIG instance count)
SHARED_KS = (2, 4, 7)

# The paper's workloads: batch 32 everywhere (§3.4); epoch sizes from the
# datasets (CIFAR-10 45k train / ImageNet64 1.28M / ImageNet 1.28M).
PAPER_SUITES = {
    "resnet_small": (ShapeSuite("paper_small", 32 * 32, 32, "train"), 45_000),
    "resnet_medium": (ShapeSuite("paper_medium", 64 * 64, 32, "train"), 1_281_167),
    "resnet_large": (ShapeSuite("paper_large", 224 * 224, 32, "train"), 1_281_167),
}
# LM workloads reuse the assigned shape suites (collocation is arch-agnostic).
LM_SUITE = ShapeSuite("train_4k", 4096, 256, "train")


def run_cell(workload: str, group: str, placements, grid, suite, samples, out_dir):
    """One device-group cell: characterize each instance, verify isolation."""
    partitioned = group != "non-MIG"
    instances = partition(grid, placements, partitioned=partitioned)
    records = []
    hlo_texts = {}
    t0 = time.time()
    for i, inst in enumerate(instances):
        rt = InstanceRuntime(inst, partitioned=partitioned)
        job = JobSpec(name=f"{workload}#{i}", arch=workload, suite=suite)
        rec = rt.characterize(job)
        records.append(rec)
    iso = interference.verify_isolation(instances, records, hlo_texts or None)
    group_rep = device_group_report(group, workload, records)
    cell = {
        "workload": workload,
        "group": group,
        "mode": "mig" if partitioned else "solo",
        "status": "OK",
        "t_wall_s": round(time.time() - t0, 1),
        "suite": suite.name,
        "samples_per_epoch": samples,
        "records": [r.to_dict() for r in records],
        "epoch_time_s": [epoch_time_s(r, samples, suite.global_batch) for r in records],
        "device_group": group_rep.to_dict(),
        "isolation": dataclasses.asdict(iso),
    }
    label = f"{workload}__{group.replace(' ', '_').replace('.', '_')}"
    (out_dir / f"{label}.json").write_text(json.dumps(cell, indent=2))
    return cell


def run_shared_cell(workload, mode, k, solo_rec, suite, samples, out_dir):
    """One analytic shared-mode cell: k collocated copies of ``workload``
    under ``mode`` (naive/mps), derived from the full-device solo record
    through the contention model — no recompilation needed (the program is
    unchanged; only the predicted step time shifts)."""
    mode = CollocationMode(mode)
    solo = SoloProfile.from_record(f"{workload}#0", solo_rec)
    jobs = [
        dataclasses.replace(solo, name=f"{workload}#{i}") for i in range(k)
    ]
    rep = shared_mode_report(mode, jobs)
    quant = interference.quant_from_report(rep)
    base = InstanceRecord(**solo_rec)
    records = [
        dataclasses.replace(
            base,
            job=j.name,
            mode=mode.value,
            step_s=float(rep.effective_step_s[j.name]),
            fits=rep.fits,
        )
        for j in jobs
    ]
    cell = {
        "workload": workload,
        "group": f"{mode.value} x{k}",
        "mode": mode.value,
        "status": "OK",
        "suite": suite.name,
        "samples_per_epoch": samples,
        "records": [r.to_dict() for r in records],
        "epoch_time_s": [
            epoch_time_s(r, samples, suite.global_batch) for r in records
        ],
        "solo_step_s": solo.step_s,
        "shared": rep.to_dict(),
        "interference_quant": quant.to_dict(),
    }
    label = f"{workload}__{mode.value}_x{k}"
    (out_dir / f"{label}.json").write_text(json.dumps(cell, indent=2))
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--workloads",
        default="resnet_small,resnet_medium,resnet_large",
        help="comma-separated registry keys",
    )
    ap.add_argument("--out", default="artifacts/collocation")
    ap.add_argument("--lm-suite", action="store_true",
                    help="use train_4k for non-resnet workloads")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    workloads = args.workloads.split(",")

    grid = device_grid(rows=16)  # 16x16 pod; 2 rows per slice unit

    results = []
    failures = 0
    # isolated full-device reference for F2 speedup
    full_rec = {}
    for w in workloads:
        suite, samples = PAPER_SUITES.get(w, (LM_SUITE, 1_281_167))
        solo_rec = None
        for w2, group, placements in paper_experiment_grid([w], suite):
            try:
                cell = run_cell(w, group, placements, grid, suite, samples, out_dir)
                results.append(cell)
                recs = cell["records"]
                if group == "7g.40gb one":
                    full_rec[w] = recs[0]
                if group == "non-MIG":
                    solo_rec = recs[0]
                speed = ""
                if "parallel" in group and w in full_rec:
                    par = [InstanceRecord(**r) for r in recs]
                    iso_full = InstanceRecord(**full_rec[w])
                    speed = f" collocation_speedup={collocation_speedup(par, iso_full):.2f}x"
                print(
                    f"[OK]   {w:<16} {group:<18} inst={len(recs)} "
                    f"step={recs[0]['step_s']:.4f}s fits={all(r['fits'] for r in recs)}"
                    f" iso={cell['isolation']['disjoint']}" + speed,
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"[FAIL] {w} {group}: {e}", flush=True)
                traceback.print_exc(limit=3)
        # analytic shared-mode cells (naive / MPS) from the solo baseline
        if solo_rec is None:
            print(f"[SKIP] {w} shared modes: no non-MIG solo record", flush=True)
            continue
        for mode in (CollocationMode.NAIVE, CollocationMode.MPS):
            for k in SHARED_KS:
                try:
                    cell = run_shared_cell(
                        w, mode, k, solo_rec, suite, samples, out_dir
                    )
                    results.append(cell)
                    rep = cell["shared"]
                    print(
                        f"[OK]   {w:<16} {cell['group']:<18} "
                        f"inst={k} step={cell['records'][0]['step_s']:.4f}s "
                        f"fits={rep['fits']} "
                        f"max_interf={cell['interference_quant']['max_slowdown']:.2f}x",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"[FAIL] {w} {mode.value} x{k}: {e}", flush=True)
                    traceback.print_exc(limit=3)
    summary = {
        "cells": len(results),
        "failures": failures,
    }
    (out_dir / "_summary.json").write_text(json.dumps(summary, indent=2))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
