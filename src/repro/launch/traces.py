"""Seeded synthetic arrival-trace generators — the simulation's load side.

Every scenario in launch/simulate.py draws its trace from this module so
the stream machinery exists exactly once: a handful of arrival-time
processes (homogeneous Poisson, sinusoidally rate-modulated "diurnal"
Poisson, Markov-modulated bursts) composed with a handful of session
builders (phase-aware training jobs, latency-SLO inference sessions,
multi-slice gangs). The generators draw from a scenario-salted
``random.Random(f"{seed}:{scenario}")`` handed in by ``make_trace``, and
the *order* of RNG draws per arrival is part of the determinism contract:
the seed-0 artifacts are byte-pinned by tests/test_cluster.py and CI, so
refactors here must preserve each generator's exact draw sequence.

Time processes (all lazy iterators so per-arrival draws interleave with
gap draws in the original order):

  poisson_times    constant-rate exponential gaps;
  diurnal_times    each gap scaled by the instantaneous rate of a
                   sinusoidal day cycle (0.35x trough to 1.65x peak by
                   default) — equivalent to thinning without discarding
                   draws;
  mmpp_times       calm stretches punctuated by short high-rate bursts.

The ``diurnal_serve`` scenario (forecast-driven autoscaling,
docs/autoscaling.md) composes ``diurnal_times`` with the city session
builder at 10x the ``train_serve_mix`` session rate over several
synthetic days, so the seasonal estimator (core/forecast/) has completed
periods to learn from.
"""
import dataclasses
import math
import random
from typing import Dict, Iterator, List, Optional, Tuple, Union

from repro.configs.base import ShapeSuite
from repro.core.gang.parallelism import Parallelism, resolve_parallelism
from repro.core.instance import JobSpec
from repro.core.workload import Workload, serve_workload, train_workload

# One shape suite for the whole simulation: batch 32 (the paper's §3.4
# setting), 3200 samples/epoch -> 100 steps per epoch.
SIM_SUITE = ShapeSuite("sim", 1024, 32, "train")
SIM_SAMPLES_PER_EPOCH = 3200

# The registry's serve shape: same shape-suite name as SIM_SUITE (the char
# DB is keyed by suite *name*), decode kind like configs.base.DECODE_32K.
SERVE_SUITE = ShapeSuite("sim", 1024, 32, "decode")

# Per-arch p99 step-latency SLO for inference sessions: ~15% headroom over
# the decode step on a MIG 1g.5gb slice, so an isolated slice always
# attains it while a dispatch-queue factor F_lat >= ~1.4 under shared
# collocation with saturating training neighbours misses it. The xlarge
# serve arch is budgeted against its only admissible slice — the 80GB
# generation's full profile.
SERVE_SLO_S = {"whisper-base": 1.4e-3, "granite-3-2b": 1.35e-3,
               "qwen2-72b": 9.0e-3}

_MIX = (  # mixed_dynamic draw weights
    ("resnet_small", 0.35),
    ("whisper-base", 0.20),
    ("resnet_medium", 0.20),
    ("llama3-8b", 0.10),
    ("resnet_large", 0.15),
)

# train_serve_mix: phase-aware training jobs (warmup/steady/checkpoint) are
# drawn from the saturating archs — their steady compute demand is what
# loads the MPS dispatch queue — while inference sessions (prefill/decode,
# latency-sensitive) are drawn from the small archs whose decode working
# set tiles MIG's 1g.5gb slices.
_TRAIN_MIX = (
    ("llama3-8b", 0.40),
    ("resnet_medium", 0.30),
    ("resnet_large", 0.15),
    ("resnet_small", 0.15),
)
_SERVE_MIX = (("whisper-base", 0.55), ("granite-3-2b", 0.45))

# The city session mixes: archs every fleet mode admits on every
# registered SKU, so the city generators double as ordinary (small)
# scenario cells and as the 10^5-arrival scoreboard traces.
_CITY_SERVE_MIX = (("whisper-base", 0.60), ("granite-3-2b", 0.40))
_CITY_TRAIN_MIX = (
    ("resnet_small", 0.45),
    ("llama3-8b", 0.30),
    ("resnet_medium", 0.25),
)

TraceItem = Tuple[float, Union[JobSpec, Workload], int]  # (arrival_s, spec, epochs)


def weighted(rng: random.Random, mix) -> str:
    """One weighted draw from a ((name, weight), ...) mix — exactly one
    ``rng.random()`` call, whatever the outcome."""
    x = rng.random()
    acc = 0.0
    for arch, w in mix:
        acc += w
        if x < acc:
            return arch
    return mix[-1][0]


def _pick_arch(rng: random.Random) -> str:
    return weighted(rng, _MIX)


# -- arrival-time processes --------------------------------------------------------
#
# All three are lazy iterators: each ``next()`` draws exactly the gap for
# that arrival, so a consumer that interleaves per-arrival draws (arch
# picks, epoch counts) reproduces the draw order of the original inlined
# loops byte-for-byte.


def poisson_times(
    rng: random.Random, n: int, mean_interarrival_s: float, *, start_s: float = 0.0
) -> Iterator[float]:
    """Homogeneous Poisson arrivals: ``n`` exponential gaps at a constant
    rate, accumulated from ``start_s`` (the accumulation order is part of
    the byte-stability contract — gaps sum into the running ``t``, never
    into a separate offset)."""
    t = start_s
    for _ in range(n):
        t += rng.expovariate(1.0 / mean_interarrival_s)
        yield t


def diurnal_times(
    rng: random.Random,
    n: int,
    mean_interarrival_s: float,
    *,
    amplitude: float = 0.65,
    day_s: Optional[float] = None,
) -> Iterator[float]:
    """Non-homogeneous Poisson arrivals whose rate follows a sinusoidal
    day cycle (``1 - amplitude`` in the trough to ``1 + amplitude`` at the
    peak). ``day_s`` sets the period; the default spans the whole trace
    with one synthetic day (the city_diurnal contract — a 10^5-arrival
    scoreboard run and a 60-job test cell sweep the same load shape).
    Each exponential gap is scaled by the instantaneous rate (equivalent
    to thinning, without discarding draws)."""
    t = 0.0
    if day_s is None:
        day_s = max(n, 1) * mean_interarrival_s
    for _ in range(n):
        rate_x = 1.0 + amplitude * math.sin((t / day_s) * 2.0 * math.pi)
        t += rng.expovariate(rate_x / mean_interarrival_s)
        yield t


def mmpp_times(
    rng: random.Random,
    n: int,
    *,
    calm_interarrival_s: float,
    burst_interarrival_s: float,
    max_burst: int,
    burst_prob: float = 0.08,
    min_burst: int = 5,
) -> Iterator[float]:
    """Markov-modulated Poisson arrivals: calm stretches punctuated by
    short bursts of ``min_burst..max_burst`` arrivals at the burst rate."""
    t = 0.0
    burst_left = 0
    for _ in range(n):
        if burst_left == 0 and rng.random() < burst_prob:
            burst_left = rng.randint(min_burst, max_burst)
        if burst_left > 0:
            burst_left -= 1
            t += rng.expovariate(1.0 / burst_interarrival_s)
        else:
            t += rng.expovariate(1.0 / calm_interarrival_s)
        yield t


# -- session builders --------------------------------------------------------------


def serve_session(rng: random.Random, name: str, mix=_SERVE_MIX) -> Workload:
    """A latency-SLO inference session over a weighted serve mix: a
    prefill burst plus an elastic decode tail, priority 1 so
    latency-sensitive work is dispatched ahead of batch training."""
    arch = weighted(rng, mix)
    return serve_workload(
        name,
        arch,
        SERVE_SUITE,
        slo_step_s=SERVE_SLO_S[arch],
        prefill_steps=4,
        priority=1,
    )


def train_job(rng: random.Random, name: str, mix=_TRAIN_MIX) -> Workload:
    """A phase-aware (warmup/steady/checkpoint) training job over a
    weighted training mix."""
    arch = weighted(rng, mix)
    return train_workload(name, arch, SIM_SUITE, warmup_steps=5, checkpoint_steps=3)


def _city_session(rng: random.Random, t: float, i: int, serve_frac: float,
                  prefix: str = "ct") -> TraceItem:
    """One city arrival: a latency-SLO inference session (probability
    ``serve_frac`` — city streams are serve-heavy) or a phase-aware
    training job."""
    if rng.random() < serve_frac:
        return (t, serve_session(rng, f"{prefix}{i}", _CITY_SERVE_MIX), 1)
    return (t, train_job(rng, f"{prefix}{i}", _CITY_TRAIN_MIX), 1)


# -- scenario traces ---------------------------------------------------------------


def aligned_static_trace(rng: random.Random, n_jobs: int, n_devices: int) -> List[TraceItem]:
    """Partition-aligned batch: slice-sized jobs, all submitted at t=0."""
    n = min(n_jobs, 7 * n_devices)
    return [
        (0.0, JobSpec(f"al{i}", "granite-3-2b", SIM_SUITE), 3) for i in range(n)
    ]


def mixed_dynamic_trace(
    rng: random.Random, n_jobs: int, *, mean_interarrival_s: float = 0.2
) -> List[TraceItem]:
    """Poisson arrivals over the tiny/medium/large mix."""
    trace: List[TraceItem] = []
    for i, t in enumerate(poisson_times(rng, n_jobs, mean_interarrival_s)):
        arch = _pick_arch(rng)
        prio = 2 if rng.random() < 0.10 else 0
        epochs = rng.randint(1, 3)
        trace.append((t, JobSpec(f"dy{i}", arch, SIM_SUITE, priority=prio), epochs))
    return trace


def drift_trace(rng: random.Random, n_jobs: int, n_devices: int) -> List[TraceItem]:
    """Composition drift: a partition-aligned burst, then a tiny-job flood
    — the queue mix the adaptive policy answers with a live mode migration."""
    trace: List[TraceItem] = []
    n_aligned = min(7 * n_devices, max(1, n_jobs // 2))
    for i in range(n_aligned):
        trace.append(
            (0.01 * i, JobSpec(f"ph1-{i}", "granite-3-2b", SIM_SUITE), 2)
        )
    flood = poisson_times(rng, max(0, n_jobs - n_aligned), 0.005, start_s=4.0)
    for i, t in enumerate(flood):  # near-burst: > 7 per device in flight
        arch = "resnet_small" if rng.random() < 0.7 else "whisper-base"
        trace.append((t, JobSpec(f"ph2-{i}", arch, SIM_SUITE), rng.randint(1, 2)))
    return trace


def train_serve_mix_trace(
    rng: random.Random, n_jobs: int, *, mean_interarrival_s: float = 0.05
) -> List[TraceItem]:
    """Training jobs and inference sessions interleaved on one Poisson
    stream — the mixed fleet MIGPerf measures. ~40% of arrivals are
    phase-aware training jobs over the saturating archs; the rest are
    latency-SLO inference sessions (priority 1: latency-sensitive work is
    dispatched ahead of batch training) whose 100-step session is a
    prefill burst plus an elastic decode tail."""
    trace: List[TraceItem] = []
    for i, t in enumerate(poisson_times(rng, n_jobs, mean_interarrival_s)):
        if rng.random() < 0.4:
            wl = train_job(rng, f"tr{i}")
            trace.append((t, wl, rng.randint(1, 2)))
        else:
            trace.append((t, serve_session(rng, f"sv{i}"), 1))
    return trace


def fragmentation_trace(
    rng: random.Random, n_jobs: int, n_devices: int
) -> List[TraceItem]:
    """The planner's showcase: a stream of slice-sized 1g jobs followed by
    2g-class jobs (stablelm-12b: OOMs on 1g.5gb, fits 2g.10gb). Greedy
    first-fit packs the 1g jobs at the lowest start offsets, which blocks
    all three of 2g's legal starts (units 0, 2, 4) while free units remain
    — the 2g jobs strand until the 1g cohort drains. The planner's
    flexibility tie-break parks the same 1g jobs on offsets that keep a 2g
    start open, so the 2g jobs place on arrival."""
    trace: List[TraceItem] = []
    n_small = min(5 * n_devices, max(1, (n_jobs * 2) // 3))
    for i in range(n_small):
        trace.append(
            (0.005 * i, JobSpec(f"fr-s{i}", "granite-3-2b", SIM_SUITE), 3)
        )
    big = poisson_times(rng, max(0, n_jobs - n_small), 0.03, start_s=0.08)
    for i, t in enumerate(big):
        trace.append((t, JobSpec(f"fr-b{i}", "stablelm-12b", SIM_SUITE), 1))
    return trace


def hetero_sku_trace(
    rng: random.Random, n_jobs: int, *, mean_interarrival_s: float = 0.05
) -> List[TraceItem]:
    """The mixed-generation fleet's mix on one Poisson stream: ~25%
    big-memory inference sessions (xlarge: the 80GB generation's full
    slice is the only instance in the whole fleet that admits their
    working set), plus slice-aligned 1g jobs (fit every tree), 2g-class
    jobs (fit the 40/80GB 2g slices and the A30's 2g.12gb), and tiny
    filler. The queue, not the operator, routes each job to whichever
    generation's placement tree fits it."""
    trace: List[TraceItem] = []
    for i, t in enumerate(poisson_times(rng, n_jobs, mean_interarrival_s)):
        x = rng.random()
        if x < 0.25:
            wl = serve_workload(
                f"hx{i}",
                "qwen2-72b",
                SERVE_SUITE,
                slo_step_s=SERVE_SLO_S["qwen2-72b"],
                prefill_steps=4,
                priority=1,
            )
            trace.append((t, wl, 1))
        elif x < 0.55:
            trace.append(
                (t, JobSpec(f"ha{i}", "granite-3-2b", SIM_SUITE), rng.randint(1, 2))
            )
        elif x < 0.80:
            trace.append((t, JobSpec(f"ht{i}", "stablelm-12b", SIM_SUITE), 1))
        else:
            trace.append(
                (t, JobSpec(f"hs{i}", "resnet_small", SIM_SUITE), rng.randint(1, 2))
            )
    return trace


#: The gang_pipeline headline class: a qwen2-72b-class trainer whose
#: working set fits *no* single slice in the fleet (xlarge as a train
#: job), sharded tensor=2 x pipeline=2 into four members that each fit an
#: 80GB-generation 3g/4g slice — two members per a100-80gb, so the gang
#: spans both 80GB devices all-or-nothing.
GANG_XLARGE_PARALLELISM = Parallelism(tensor=2, pipeline=2)


def _gang_train(name: str, arch: str, par: Parallelism) -> Workload:
    """A phase-aware training gang: ``train_workload``'s warmup/steady/
    checkpoint plan with the gang descriptor stamped on (the registry
    helpers build singletons; gangs are the same plan, wider)."""
    return dataclasses.replace(
        train_workload(name, arch, SIM_SUITE, warmup_steps=5, checkpoint_steps=3),
        world_size=par.world_size,
        parallelism=par,
    )


def gang_pipeline_trace(
    rng: random.Random,
    n_jobs: int,
    *,
    mean_interarrival_s: float = 0.05,
    parallelism: str = "tp2",
) -> List[TraceItem]:
    """Multi-slice gangs with singleton filler on one Poisson stream:
    ~12% qwen2-72b world_size-4 tensor+pipeline gangs (fit *only* as a
    gang — full-slice-only placement rejects them outright), ~28%
    2g-class gangs under the ``parallelism`` descriptor (fit everywhere,
    so the co-located-vs-scattered comparison is theirs to decide), and
    ~60% slice-aligned / tiny singletons that backfill around the gangs'
    reservations — the head-of-line pressure the starvation bound caps."""
    par = resolve_parallelism(parallelism)
    trace: List[TraceItem] = []
    for i, t in enumerate(poisson_times(rng, n_jobs, mean_interarrival_s)):
        x = rng.random()
        if x < 0.12:
            trace.append(
                (t, _gang_train(f"gq{i}", "qwen2-72b", GANG_XLARGE_PARALLELISM), 1)
            )
        elif x < 0.40:
            trace.append(
                (t, _gang_train(f"gs{i}", "stablelm-12b", par), rng.randint(1, 2))
            )
        elif x < 0.75:
            trace.append(
                (t, JobSpec(f"ga{i}", "granite-3-2b", SIM_SUITE), rng.randint(1, 2))
            )
        else:
            trace.append((t, JobSpec(f"gt{i}", "resnet_small", SIM_SUITE), 1))
    return trace


def city_diurnal_trace(
    rng: random.Random,
    n_jobs: int,
    *,
    mean_interarrival_s: float = 0.02,
    serve_frac: float = 0.70,
) -> List[TraceItem]:
    """Diurnal city load: a non-homogeneous Poisson stream whose rate
    follows a sinusoidal day cycle (0.35x in the trough to 1.65x at the
    peak), one synthetic day per trace regardless of ``n_jobs`` — so a
    10^5-arrival scoreboard run and a 60-job test cell sweep the same
    load shape."""
    return [
        _city_session(rng, t, i, serve_frac)
        for i, t in enumerate(diurnal_times(rng, n_jobs, mean_interarrival_s))
    ]


def city_burst_trace(
    rng: random.Random,
    n_jobs: int,
    *,
    calm_interarrival_s: float = 0.05,
    burst_interarrival_s: float = 0.004,
    max_burst: int = 12,
    serve_frac: float = 0.70,
) -> List[TraceItem]:
    """Bursty city load: a Markov-modulated Poisson stream — calm
    stretches punctuated by short bursts at ~12x the calm rate (session
    storms). The burst windows are what drive ``peak_depth`` on the
    admission queue, the scoreboard's burst-pressure column."""
    times = mmpp_times(
        rng,
        n_jobs,
        calm_interarrival_s=calm_interarrival_s,
        burst_interarrival_s=burst_interarrival_s,
        max_burst=max_burst,
    )
    return [_city_session(rng, t, i, serve_frac) for i, t in enumerate(times)]


# -- diurnal_serve: the forecast-driven autoscaling trace --------------------------

#: Session rate of the diurnal_serve stream: 10x the train_serve_mix
#: default (0.05 s mean interarrival) — the "production wave" rate the
#: ROADMAP's predictive-autoscaling item asks for.
DIURNAL_SERVE_MEAN_INTERARRIVAL_S = 0.005
#: Synthetic days per trace. Several completed periods let the seasonal
#: estimator (core/forecast/estimator.py) learn the daily profile on day
#: one and pre-warm ahead of the day-two ramp.
DIURNAL_SERVE_DAYS = 3
#: Arrivals per --steps unit: the trace densifies the session stream
#: instead of lengthening it, so ``--steps 60`` spans the same three-day
#: window at 20x the arrival count (1200 sessions).
DIURNAL_SERVE_ARRIVALS_PER_JOB = 20
#: Fraction of arrivals that are latency-SLO serve sessions.
DIURNAL_SERVE_FRAC = 0.70


def diurnal_serve_params(n_jobs: int) -> Dict[str, float]:
    """The derived shape of a diurnal_serve trace for ``n_jobs`` steps:
    arrival count and synthetic day length. launch/simulate.py uses
    ``day_s`` to configure the forecast policy's seasonal period so the
    estimator's bins line up with the trace's day cycle."""
    n = max(1, n_jobs) * DIURNAL_SERVE_ARRIVALS_PER_JOB
    day_s = n * DIURNAL_SERVE_MEAN_INTERARRIVAL_S / DIURNAL_SERVE_DAYS
    return {"n_arrivals": n, "day_s": day_s}


def diurnal_serve_trace(
    rng: random.Random,
    n_jobs: int,
    *,
    serve_frac: float = DIURNAL_SERVE_FRAC,
) -> List[TraceItem]:
    """The forecast policy's showcase: diurnal serve sessions layered
    over batch training at 10x the train_serve_mix session rate, three
    synthetic days per trace (city_diurnal's rate machinery with an
    explicit multi-day period). Day one is the seasonal estimator's
    learning period; days two and three are where ``policy="forecast"``
    pre-warms decode slices ahead of the ramp the reactive policy only
    answers after SLO misses accumulate."""
    p = diurnal_serve_params(n_jobs)
    times = diurnal_times(
        rng,
        int(p["n_arrivals"]),
        DIURNAL_SERVE_MEAN_INTERARRIVAL_S,
        day_s=p["day_s"],
    )
    return [_city_session(rng, t, i, serve_frac, prefix="ds") for i, t in enumerate(times)]


def make_trace(
    scenario: str,
    seed: int,
    n_jobs: int,
    n_devices: int,
    *,
    gang_parallelism: str = "tp2",
) -> List[TraceItem]:
    # fresh, scenario-salted RNG: identical trace for every policy
    rng = random.Random(f"{seed}:{scenario}")
    if scenario == "aligned_static":
        return aligned_static_trace(rng, n_jobs, n_devices)
    if scenario == "mixed_dynamic":
        return mixed_dynamic_trace(rng, n_jobs)
    if scenario == "drift":
        return drift_trace(rng, n_jobs, n_devices)
    if scenario == "train_serve_mix":
        return train_serve_mix_trace(rng, n_jobs)
    if scenario == "fragmentation":
        return fragmentation_trace(rng, n_jobs, n_devices)
    if scenario == "hetero_sku":
        return hetero_sku_trace(rng, n_jobs)
    if scenario == "gang_pipeline":
        return gang_pipeline_trace(rng, n_jobs, parallelism=gang_parallelism)
    if scenario == "city_diurnal":
        return city_diurnal_trace(rng, n_jobs)
    if scenario == "city_burst":
        return city_burst_trace(rng, n_jobs)
    if scenario == "diurnal_serve":
        return diurnal_serve_trace(rng, n_jobs)
    from repro.launch.simulate import ALL_SCENARIOS  # registry lives with the CLI

    raise ValueError(
        f"unknown scenario {scenario!r}; choose from: {', '.join(ALL_SCENARIOS)}"
    )
