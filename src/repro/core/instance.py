"""Instance runtime: one MIG-instance analogue bound to a training job.

An ``InstanceRuntime`` wraps a carved sub-mesh (core/partitioner.py) with its
HBM budget and knows how to lower/compile the job's step function *on that
sub-mesh* and extract the characterization record (memory analysis, roofline
terms, DCGM-metric analogues). This is the unit the collocation scheduler
places jobs onto, and the unit the paper's per-instance metrics are reported
for.

The paper's compute:memory slice asymmetry (3g.20gb = 3/7 compute, 4/8
memory, plus the reserved 8th compute slice MIG keeps for itself) does not
exist on TPU sub-rectangles (chips carry both). We keep the algebra by
discounting the analytic compute roof: an instance of profile p owns
``compute_slices/8`` of the pod's total compute but ``mem_units/8`` of its
chips, so per-chip ``compute_discount = min(1, compute_slices/mem_units)``.
This reproduces F6 structurally: 7g.40gb runs at 7/8 of the non-partitioned
device's MXU roof (the paper measures 0.7-2.9% wall-clock because its
workloads are not purely compute-bound — ours shows the same collapse when
the bound is memory/collective), and 3g.20gb at 3/4.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

from repro.configs.base import ShapeSuite
from repro.telemetry import constants as C
from repro.telemetry import roofline as rl
from repro.telemetry.hlo import collective_summary, hlo_flops_bytes

if TYPE_CHECKING:  # jax/mesh machinery only needed by InstanceRuntime —
    # kept import-lazy so the scheduler/cluster stack stays jax-free
    from repro.core.gang.parallelism import Parallelism
    from repro.core.partitioner import InstanceMesh


def compute_discount(
    profile: str, *, partitioned: bool = True, sku=None
) -> float:
    """F6 analytically — delegates to the device model (core/device.py);
    ``sku=None`` keeps the old A100-40GB module-global behaviour."""
    from repro.core.device import get_sku

    return get_sku(sku).compute_discount(profile, partitioned=partitioned)


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One training/serving job the scheduler may place on an instance."""

    name: str  # unique job id ("hparam-3", "resnet_small#0")
    arch: str  # registry key (resnet_small, llama3-8b, ...)
    suite: ShapeSuite
    steps: int = 100
    grad_accum: int = 1
    priority: int = 0  # higher preempts lower on elastic repack
    # floor on the MIG profile the scheduler may pick — set by the straggler
    # repack path so a re-queued straggler lands on a larger slice
    min_profile: Optional[str] = None
    # gang scheduling (core/gang/): > 1 makes this a gang of cooperating
    # members, each needing its own MIG slice, admitted all-or-nothing
    world_size: int = 1
    # how the gang splits its work (tensor/pipeline/data); None = plain
    # data parallelism over world_size (core/gang/parallelism.py)
    parallelism: Optional["Parallelism"] = None
    # gang this spec is a *member* of — set only on the per-rank specs the
    # cluster binds to slices, so elastic.split_by_failure can map a hit
    # member back to its gang; user-submitted jobs leave it None
    gang: Optional[str] = None

    def __post_init__(self):
        if self.world_size < 1:
            raise ValueError(
                f"job {self.name!r}: world_size must be >= 1, "
                f"got {self.world_size}"
            )
        if self.parallelism is not None and (
            self.parallelism.world_size != self.world_size
        ):
            raise ValueError(
                f"job {self.name!r}: parallelism {self.parallelism.label} "
                f"implies world_size {self.parallelism.world_size}, "
                f"declared {self.world_size}"
            )


@dataclasses.dataclass
class InstanceRecord:
    """Characterization of one job on one instance — a paper table row."""

    job: str
    arch: str
    shape: str
    profile: str
    start: int
    chips: int
    hbm_budget_bytes: int
    peak_bytes_per_device: float
    fits: bool
    step_s: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    mfu: float
    dcgm: Dict[str, float]
    device_ids: Tuple[int, ...] = ()
    hlo_fingerprint: str = ""
    # collocation mode the record was characterized under: "mig" (partitioned
    # instance), "solo" (full non-partitioned device), or a shared mode
    # ("naive"/"mps") for analytically-derived effective records.
    mode: str = "mig"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class InstanceRuntime:
    """A carved instance plus the machinery to characterize jobs on it."""

    def __init__(
        self,
        inst: InstanceMesh,
        hbm_per_chip: Optional[int] = None,
        *,
        partitioned: bool = True,
        sku=None,
    ):
        from repro.core.device import get_sku

        self.inst = inst
        # the generation whose tree/budgets price this instance
        # (core/device.py); the default SKU's slice_bytes IS the old
        # HBM_PER_CHIP default, so existing callers are unchanged
        self.sku = get_sku(sku)
        if hbm_per_chip is None:
            hbm_per_chip = self.sku.slice_bytes
        self.hbm_budget = inst.n_chips * hbm_per_chip
        self.partitioned = partitioned

    @property
    def profile(self) -> str:
        return self.inst.profile

    @property
    def label(self) -> str:
        return self.inst.label

    def device_ids(self) -> Tuple[int, ...]:
        return tuple(int(d.id) for d in self.inst.mesh.devices.flat)

    # -- characterization ---------------------------------------------------

    def characterize(self, job: JobSpec, *, donate: bool = True) -> InstanceRecord:
        """Lower + compile ``job`` on this instance; derive the paper row.

        Uses the same step builders as the production launcher, so the
        record reflects exactly what would run.
        """
        import hashlib

        from repro.launch.lowering import active_params, lower_cell

        cfg, model, lowered = lower_cell(
            job.arch, job.suite, self.inst.mesh, grad_accum=job.grad_accum
        )
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        coll = collective_summary(hlo_text)
        est = hlo_flops_bytes(hlo_text)  # loop-aware (see telemetry.hlo)

        peak = (
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes
            + mem.temp_size_in_bytes
        )
        n_total = model.param_count()
        report = rl.RooflineReport(
            arch=job.arch,
            shape=job.suite.name,
            mesh=self.label,
            chips=self.inst.n_chips,
            flops_per_device=float(est["flops"]),
            hbm_bytes_per_device=float(est["bytes"]),
            wire_bytes_per_device=float(coll["per_device_wire_bytes"]),
            model_flops_global=rl.model_flops(
                cfg, job.suite, active_params(cfg, n_total)
            ),
            peak_mem_bytes_per_device=float(peak),
        )
        disc = compute_discount(
            self.profile, partitioned=self.partitioned, sku=self.sku
        )
        # asymmetric profiles: MXU roof discounted (see module docstring)
        compute_s = report.compute_s / disc
        step_s = max(compute_s, report.memory_s, report.collective_s)
        fp = hashlib.sha256(hlo_text.encode()).hexdigest()[:16]
        hbm_per_device = self.hbm_budget // max(self.inst.n_chips, 1)
        return InstanceRecord(
            job=job.name,
            arch=job.arch,
            shape=job.suite.name,
            profile=self.profile,
            start=self.inst.placement.start,
            chips=self.inst.n_chips,
            hbm_budget_bytes=self.hbm_budget,
            peak_bytes_per_device=float(peak),
            fits=bool(peak <= hbm_per_device),
            step_s=float(step_s),
            compute_s=float(compute_s),
            memory_s=float(report.memory_s),
            collective_s=float(report.collective_s),
            bound=max(
                {"compute": compute_s, "memory": report.memory_s,
                 "collective": report.collective_s},
                key=lambda k: {"compute": compute_s, "memory": report.memory_s,
                               "collective": report.collective_s}[k],
            ),
            mfu=float(report.model_flops_global / (step_s * self.inst.n_chips * C.PEAK_FLOPS_BF16))
            if step_s
            else 0.0,
            dcgm=rl.dcgm_analogues(report),
            device_ids=self.device_ids(),
            hlo_fingerprint=fp,
            mode="mig" if self.partitioned else "solo",
        )
