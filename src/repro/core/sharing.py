"""Collocation modes: naive time-slicing, MPS spatial sharing, MIG partitioning.

The paper's central axis is *how* concurrent training jobs share one device:

  NAIVE  multiple processes submitted to the same device; the driver
         time-slices contexts, so jobs run serially at quantum granularity
         and every switch pays a context-switch + cold-cache penalty;
  MPS    a single shared context; jobs run *concurrently* and partition the
         SMs / memory system spatially, so they contend for whichever
         resource their aggregate demand oversubscribes;
  MIG    hardware partitioning into instances (core/profiles.py); slices are
         interference-free but rigid, and enabling MIG reserves a compute
         slice (F6).

This module gives the two shared modes analytic contention models over the
same roofline terms the characterization pipeline already produces
(telemetry/roofline.py), so all three modes are scored in one currency:
per-job effective step time.

Model. A job's solo profile on the full (non-partitioned) device is its
roofline busy terms plus a per-step dispatch-latency floor::

    busy_s = max(compute_s, memory_s, collective_s)
    step_s = busy_s + latency_s

``latency_s`` is host dispatch / synchronization time during which the
device engines are idle — exactly the sub-saturation the paper measures as
GRACT < 1 and the reason collocation wins at all. Per-resource *activity
fractions* (the DCGM analogues SMACT / DRAMA) follow as ``u_r = r / step_s``.

MPS — spatial sharing with bandwidth contention. Concurrent jobs share each
resource proportionally: resource ``r``'s contention factor is
``F_r = max(1, sum_j u_rj)``; job i's effective terms are ``r_i * F_r`` and
its effective step is ``latency_i * F_lat + max_r(r_i * F_r)``, where the
dispatch-latency factor ``F_lat = max(1, sum_j u_compute_j)`` models kernel
launches queueing behind co-resident jobs' in-flight compute once aggregate
SM demand saturates. Sub-saturating mixes (all ``sum u_r <= 1``) run
interference-free — the paper's headline collocation win; saturated mixes
stretch proportionally, which conserves aggregate resource throughput (fair
sharing). The latency term is what makes training+inference mixes behave
differently from training-only mixes (MIGPerf's finding): a decode step is
almost all dispatch latency, so a saturating training neighbour inflates
its p99 even when no bandwidth resource is contended. All jobs share one
memory space: aggregate footprint must fit the device (the paper's OOM
constraint).

NAIVE — time-slicing with switch overhead. Each quantum runs one job
exclusively; nothing overlaps across jobs, so a scheduling round costs the
*sum* of solo steps, inflated by ``NAIVE_SWITCH_OVERHEAD_FRAC`` (context
switch, pipeline drain, cold cache). Every job's effective step is the full
round: naive collocation never beats sequential execution in this model and
shares the same aggregate-memory constraint — it loses on memory pressure
first (the paper's observed failure mode).

MIG — the existing interference-free partitioning: per-instance records from
``InstanceRuntime.characterize`` are used as-is, every interference factor
is exactly 1.0, and memory admission is per-slice (core/collocation.py).

A useful theorem (test_sharing.py asserts it on the paper grid): MPS
aggregate throughput >= naive aggregate throughput for *any* job mix —
``step_mps_i <= k * step_i`` since every ``F_r <= k`` and ``F_lat <= k``
(each activity fraction is at most 1), so by AM-HM
``sum 1/step_mps_i >= k / sum step_j > naive``'s ``k / ((1+o) sum step_j)``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

from repro.telemetry.constants import HBM_PER_CHIP

# Per-step host dispatch + sync latency floor (engines idle). This is the
# analytic stand-in for the paper's observed sub-saturation: small workloads
# are latency-dominated, so spatial sharing overlaps their idle time.
STEP_LATENCY_S = 1e-3

# Fractional penalty per time-slice quantum under naive sharing: context
# switch, pipeline drain, cold cache on re-entry.
NAIVE_SWITCH_OVERHEAD_FRAC = 0.07


class CollocationMode(str, enum.Enum):
    """How concurrent jobs share one device."""

    NAIVE = "naive"
    MPS = "mps"
    MIG = "mig"


_RESOURCES = ("compute_s", "memory_s", "collective_s")


@dataclasses.dataclass(frozen=True)
class SoloProfile:
    """One job's solo roofline profile on the full, non-partitioned device."""

    name: str
    compute_s: float
    memory_s: float
    collective_s: float
    latency_s: float = STEP_LATENCY_S
    peak_bytes_per_device: float = 0.0

    @property
    def busy_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_s(self) -> float:
        return self.busy_s + self.latency_s

    def activity(self, resource: str) -> float:
        """DCGM-analogue busy fraction of ``resource`` over the solo step."""
        return getattr(self, resource) / self.step_s if self.step_s else 0.0

    def scaled(self, demand) -> "SoloProfile":
        """This profile under a phase's demand vector (core/workload.py):
        every roofline term, the latency floor, and the working set are
        multiplied by the phase's per-resource demand. Identity demand
        returns ``self`` unchanged, so flat (steady-only) jobs keep their
        exact old contention inputs."""
        if getattr(demand, "is_identity", False):
            return self
        return SoloProfile(
            name=self.name,
            compute_s=self.compute_s * demand.compute,
            memory_s=self.memory_s * demand.memory,
            collective_s=self.collective_s * demand.collective,
            latency_s=self.latency_s * demand.latency,
            peak_bytes_per_device=self.peak_bytes_per_device * demand.mem_bytes,
        )

    @classmethod
    def from_record(
        cls,
        name: str,
        rec: Mapping,
        *,
        undiscount_compute: float = 1.0,
        latency_s: float = STEP_LATENCY_S,
    ) -> "SoloProfile":
        """Build a solo profile from a characterization-DB record.

        Records written by ``launch/collocate.py`` carry the three roofline
        terms; minimal records (tests, hand-built DBs) may only carry
        ``step_s`` — then the step is treated as pure dominant-resource busy
        time (compute). ``undiscount_compute`` removes the F6 reserved-slice
        discount when the record was characterized with MIG enabled but the
        shared modes run with MIG off (no reserved slice).
        """
        step = float(rec.get("step_s", 0.0))
        compute = float(rec.get("compute_s", step)) * undiscount_compute
        memory = float(rec.get("memory_s", 0.0))
        coll = float(rec.get("collective_s", 0.0))
        return cls(
            name=name,
            compute_s=compute,
            memory_s=memory,
            collective_s=coll,
            latency_s=latency_s,
            peak_bytes_per_device=float(rec.get("peak_bytes_per_device", 0.0)),
        )


@dataclasses.dataclass
class SharedModeReport:
    """Outcome of running a job set under one shared collocation mode."""

    mode: CollocationMode
    effective_step_s: Dict[str, float]  # job name -> effective step time
    interference: Dict[str, float]  # job name -> effective / solo (>= 1)
    contention: Dict[str, float]  # resource -> F_r (1.0 == no contention)
    aggregate_peak_bytes: float
    hbm_budget_bytes: float

    @property
    def fits(self) -> bool:
        return self.aggregate_peak_bytes <= self.hbm_budget_bytes

    @property
    def throughput_jobs_per_s(self) -> float:
        return sum(1.0 / t for t in self.effective_step_s.values() if t > 0)

    @property
    def max_interference(self) -> float:
        return max(self.interference.values(), default=1.0)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["mode"] = self.mode.value
        d["fits"] = self.fits
        d["throughput_jobs_per_s"] = self.throughput_jobs_per_s
        return d


def _aggregate_peak(jobs: Sequence[SoloProfile]) -> float:
    # Shared modes replicate every job's working set on every chip (the
    # non-partitioned device runs each job sharded over all chips), so
    # per-chip footprints add. MIG instead places jobs on disjoint chips.
    return sum(j.peak_bytes_per_device for j in jobs)


def mps_contention(
    jobs: Sequence[SoloProfile], *, hbm_budget_bytes: int = HBM_PER_CHIP
) -> SharedModeReport:
    """MPS: concurrent spatial sharing with proportional contention.

    The interference factor per resource is the aggregate activity demand
    ``sum_j u_rj`` from the roofline telemetry, floored at 1 (idle capacity
    absorbs sub-saturating demand for free). The dispatch-latency floor
    contends on aggregate *compute* activity: kernel launches queue behind
    in-flight kernels once the SMs saturate, which is how a saturating
    training neighbour hurts a latency-dominated decode step even though no
    bandwidth resource is oversubscribed (the MIGPerf mechanism).
    """
    contention = {}
    for r in _RESOURCES:
        demand = sum(j.activity(r) for j in jobs)
        contention[r] = max(1.0, demand)
    contention["latency_s"] = max(
        1.0, sum(j.activity("compute_s") for j in jobs)
    )
    eff: Dict[str, float] = {}
    interference: Dict[str, float] = {}
    for j in jobs:
        busy = max(getattr(j, r) * contention[r] for r in _RESOURCES)
        step = j.latency_s * contention["latency_s"] + busy
        eff[j.name] = step
        interference[j.name] = step / j.step_s if j.step_s else 1.0
    return SharedModeReport(
        mode=CollocationMode.MPS,
        effective_step_s=eff,
        interference=interference,
        contention=contention,
        aggregate_peak_bytes=_aggregate_peak(jobs),
        hbm_budget_bytes=hbm_budget_bytes,
    )


def naive_contention(
    jobs: Sequence[SoloProfile],
    *,
    hbm_budget_bytes: int = HBM_PER_CHIP,
    switch_overhead_frac: float = NAIVE_SWITCH_OVERHEAD_FRAC,
) -> SharedModeReport:
    """Naive process collocation: exclusive time-slicing, round-robin.

    Each job completes one step per round; the round is the sum of solo
    steps plus the per-quantum switch penalty, and nothing overlaps across
    jobs.
    """
    k = len(jobs)
    overhead = switch_overhead_frac if k > 1 else 0.0
    round_s = (1.0 + overhead) * sum(j.step_s for j in jobs)
    eff = {j.name: round_s for j in jobs}
    interference = {
        j.name: round_s / j.step_s if j.step_s else 1.0 for j in jobs
    }
    return SharedModeReport(
        mode=CollocationMode.NAIVE,
        effective_step_s=eff,
        interference=interference,
        contention=dict.fromkeys((*_RESOURCES, "latency_s"), 1.0),  # exclusive while scheduled
        aggregate_peak_bytes=_aggregate_peak(jobs),
        hbm_budget_bytes=hbm_budget_bytes,
    )


def mig_report(
    jobs: Sequence[SoloProfile],
    instance_step_s: Mapping[str, float],
    *,
    hbm_budget_bytes: int = HBM_PER_CHIP,
) -> SharedModeReport:
    """MIG partitioning expressed in the shared-mode currency.

    ``instance_step_s`` maps each job to its per-instance characterized step
    time; interference is 1.0 by construction (isolation, F3), and memory
    admission already happened per-slice in the scheduler, so the aggregate
    footprint check is vacuous here (each job's chips are its own).
    """
    eff = {j.name: float(instance_step_s[j.name]) for j in jobs}
    return SharedModeReport(
        mode=CollocationMode.MIG,
        effective_step_s=eff,
        interference={j.name: 1.0 for j in jobs},
        contention=dict.fromkeys((*_RESOURCES, "latency_s"), 1.0),
        aggregate_peak_bytes=0.0,
        hbm_budget_bytes=hbm_budget_bytes,
    )


def shared_mode_report(
    mode: CollocationMode,
    jobs: Sequence[SoloProfile],
    *,
    hbm_budget_bytes: int = HBM_PER_CHIP,
    switch_overhead_frac: float = NAIVE_SWITCH_OVERHEAD_FRAC,
) -> SharedModeReport:
    """Dispatch to the contention model for a *shared* mode (not MIG).

    ``hbm_budget_bytes`` and ``switch_overhead_frac`` are per-device-SKU
    knobs (core/device.py) — the scheduler threads its SKU's values in;
    the defaults are the A100-40GB baseline."""
    if mode == CollocationMode.MPS:
        return mps_contention(jobs, hbm_budget_bytes=hbm_budget_bytes)
    if mode == CollocationMode.NAIVE:
        return naive_contention(
            jobs,
            hbm_budget_bytes=hbm_budget_bytes,
            switch_overhead_frac=switch_overhead_frac,
        )
    raise ValueError(f"{mode} is not a shared mode — use the MIG scheduler path")


def device_busy_fraction(jobs: Sequence[SoloProfile]) -> float:
    """GRACT analogue for a shared (non-partitioned) device: the busiest
    engine's aggregate activity demand across the collocated jobs, clamped
    to 1. Sub-saturating mixes score < 1 — the idle fraction the paper
    measures as GRACT < 1 and the cluster simulator integrates into its
    per-device utilization metric (core/cluster.py)."""
    if not jobs:
        return 0.0
    return min(
        1.0, max(sum(j.activity(r) for j in jobs) for r in _RESOURCES)
    )


def sequential_time_s(jobs: Sequence[SoloProfile]) -> float:
    """Baseline the paper compares every mode against: run the jobs one
    after another, each alone on the full device."""
    return sum(j.step_s for j in jobs)


# -- precomputed-terms fast path (cluster re-timing storms) ---------------------
#
# The cluster simulator re-prices a shared device's whole co-resident set on
# every arrival, departure, and phase transition. The full path builds
# SharedModeReport objects (dicts, interference ratios, rejection prose)
# that the re-timing loop never reads; at city scale that object churn — and
# re-deriving each profile's activity fractions per call — dominates the
# event loop. ``SoloTerms`` freezes one scaled profile's contention inputs
# into a flat tuple once, and ``shared_effective_steps`` replays *exactly*
# the arithmetic of mps_contention / naive_contention over those tuples (the
# same sums in the same order, so results are bit-identical — the contract
# tests/test_retime_equivalence.py enforces against the full path).


class SoloTerms(NamedTuple):
    """One scaled solo profile reduced to the contention model's inputs."""

    compute_s: float
    memory_s: float
    collective_s: float
    latency_s: float
    step_s: float
    u_compute: float
    u_memory: float
    u_collective: float


def solo_terms(profile: SoloProfile) -> SoloTerms:
    """Freeze ``profile``'s contention inputs (same floats as the properties
    the full model reads — ``activity`` is evaluated once per resource)."""
    return SoloTerms(
        profile.compute_s,
        profile.memory_s,
        profile.collective_s,
        profile.latency_s,
        profile.step_s,
        profile.activity("compute_s"),
        profile.activity("memory_s"),
        profile.activity("collective_s"),
    )


def shared_effective_steps(
    mode: CollocationMode,
    terms: Sequence[SoloTerms],
    *,
    switch_overhead_frac: float = NAIVE_SWITCH_OVERHEAD_FRAC,
) -> Tuple[float, ...]:
    """Effective step times for a co-resident set, in input order.

    Bit-identical to ``mps_contention`` / ``naive_contention`` on the same
    set: every sum runs over the jobs in the same order and every max takes
    its operands in the same resource order, so no float can drift between
    this and the report-building path."""
    if mode == CollocationMode.NAIVE:
        overhead = switch_overhead_frac if len(terms) > 1 else 0.0
        round_s = (1.0 + overhead) * sum(t.step_s for t in terms)
        return tuple(round_s for _ in terms)
    if mode != CollocationMode.MPS:
        raise ValueError(f"{mode} is not a shared mode — use the MIG scheduler path")
    f_compute = max(1.0, sum(t.u_compute for t in terms))
    f_memory = max(1.0, sum(t.u_memory for t in terms))
    f_collective = max(1.0, sum(t.u_collective for t in terms))
    f_latency = max(1.0, sum(t.u_compute for t in terms))
    return tuple(
        t.latency_s * f_latency
        + max(t.compute_s * f_compute, t.memory_s * f_memory, t.collective_s * f_collective)
        for t in terms
    )


def busy_fraction_from_terms(terms: Sequence[SoloTerms]) -> float:
    """``device_busy_fraction`` over pre-frozen terms — same sums, same
    resource order, bit-identical result."""
    if not terms:
        return 0.0
    return min(
        1.0,
        max(
            sum(t.u_compute for t in terms),
            sum(t.u_memory for t in terms),
            sum(t.u_collective for t in terms),
        ),
    )
