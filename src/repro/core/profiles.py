"""MIG-faithful instance profiles and the placement tree, adapted to pods.

The A100 exposes 8 memory slices and 7(+1 reserved) compute slices; profiles
combine them and may only start at fixed slice offsets (the *placement
tree*). We keep the algebra bit-faithful — profile names, spans, start
offsets, max instance counts, and the documented 4g+3g exclusion — and map
one *slice unit* onto a contiguous block of pod rows, so every instance is a
contiguous sub-rectangle of the chip grid and ICI traffic stays
intra-instance (the TPU analogue of MIG's hardware isolation).

The reserved 8th unit reproduces the paper's F6 finding (enabling MIG costs
one compute slice): ``partitioned=True`` keeps unit 7 for the control plane
and jobs may only use units 0..6 — except the full-device ``7g`` profile,
which owns all 8 memory units like MIG's 7g.40gb owns the full 40 GB.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Sequence, Tuple

from repro.telemetry.constants import HBM_PER_CHIP

N_UNITS = 8  # memory slice units (placement granularity)
N_COMPUTE_SLICES = 7  # usable compute slices when partitioned


@dataclasses.dataclass(frozen=True)
class InstanceProfile:
    """One MIG profile mapped to pod slice units."""

    name: str  # canonical MIG name, kept paper-faithful
    compute_slices: int  # of 7 — scales the analytical compute roof
    mem_units: int  # of 8 — placement span in slice units
    starts: Tuple[int, ...]  # allowed start offsets (placement tree)

    @property
    def max_instances(self) -> int:
        return len(self.starts)


# The five profiles the paper sweeps (A100-40GB placement tree).
PROFILES: Dict[str, InstanceProfile] = {
    "1g.5gb": InstanceProfile("1g.5gb", 1, 1, (0, 1, 2, 3, 4, 5, 6)),
    "2g.10gb": InstanceProfile("2g.10gb", 2, 2, (0, 2, 4)),
    "3g.20gb": InstanceProfile("3g.20gb", 3, 4, (0, 4)),
    "4g.20gb": InstanceProfile("4g.20gb", 4, 4, (0,)),
    "7g.40gb": InstanceProfile("7g.40gb", 7, 8, (0,)),
}

# NVIDIA's documented invalid combination despite slices summing <= max
# (paper §2.1): one cannot create 4g.20gb + 3g.20gb together.
EXCLUSIONS: Tuple[FrozenSet[str], ...] = (frozenset({"4g.20gb", "3g.20gb"}),)


@dataclasses.dataclass(frozen=True)
class Placement:
    profile: str
    start: int  # slice-unit offset

    @property
    def span(self) -> Tuple[int, int]:
        p = PROFILES[self.profile]
        return (self.start, self.start + p.mem_units)


def validate_layout(
    placements: Sequence[Placement], *, partitioned: bool = True
) -> Tuple[bool, str]:
    """Check a set of instance placements against the placement tree."""
    names = [pl.profile for pl in placements]
    for pl in placements:
        if pl.profile not in PROFILES:
            return False, f"unknown profile {pl.profile}"
        p = PROFILES[pl.profile]
        if pl.start not in p.starts:
            return False, f"{pl.profile} may not start at unit {pl.start}"
    # overlap check
    spans = sorted(pl.span for pl in placements)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        if b0 < a1:
            return False, f"overlapping spans {(a0, a1)} and {(b0, b1)}"
    # compute-slice budget: 7 usable slices when partitioned (the 8th is the
    # MIG overhead slice — modelled as the per-profile compute discount
    # cs/mu in core/instance.py, reproducing F6 analytically)
    total_c = sum(PROFILES[n].compute_slices for n in names)
    if total_c > N_COMPUTE_SLICES:
        return False, f"compute slices {total_c} > {N_COMPUTE_SLICES}"
    # documented exclusions
    for bad in EXCLUSIONS:
        if bad <= set(names):
            return False, f"excluded combination {sorted(bad)}"
    return True, ""


def homogeneous_layout(profile: str) -> List[Placement]:
    """The paper's 'parallel' device group: max instances of one profile."""
    p = PROFILES[profile]
    placements = []
    occupied = 0
    for s in p.starts:
        if s >= occupied:
            placements.append(Placement(profile, s))
            occupied = s + p.mem_units
    return placements


def enumerate_layouts(max_results: int = 64) -> List[Tuple[Placement, ...]]:
    """All valid (order-insensitive) layouts — scheduler search space."""
    options = [
        Placement(name, s) for name, p in PROFILES.items() for s in p.starts
    ]
    results = []
    seen = set()

    def rec(chosen: List[Placement], rest: List[Placement]):
        if len(results) >= max_results:
            return
        key = frozenset((c.profile, c.start) for c in chosen)
        if chosen and key not in seen:
            ok, _ = validate_layout(chosen)
            if ok:
                seen.add(key)
                results.append(tuple(sorted(chosen, key=lambda c: c.start)))
        for i, cand in enumerate(rest):
            ok, _ = validate_layout(chosen + [cand])
            if ok:
                rec(chosen + [cand], rest[i + 1:])

    rec([], options)
    return results


def instance_hbm_bytes(profile: str, chips_per_unit: int) -> int:
    return PROFILES[profile].mem_units * chips_per_unit * HBM_PER_CHIP
