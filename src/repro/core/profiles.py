"""MIG-faithful instance profiles and the placement tree, adapted to pods.

The A100 exposes 8 memory slices and 7(+1 reserved) compute slices; profiles
combine them and may only start at fixed slice offsets (the *placement
tree*). We keep the algebra bit-faithful — profile names, spans, start
offsets, max instance counts, and the documented 4g+3g exclusion — and map
one *slice unit* onto a contiguous block of pod rows, so every instance is a
contiguous sub-rectangle of the chip grid and ICI traffic stays
intra-instance (the TPU analogue of MIG's hardware isolation).

The reserved 8th unit reproduces the paper's F6 finding (enabling MIG costs
one compute slice): ``partitioned=True`` keeps unit 7 for the control plane
and jobs may only use units 0..6 — except the full-device ``7g`` profile,
which owns all 8 memory units like MIG's 7g.40gb owns the full 40 GB.

Since the device-model API landed (core/device.py), the tree lives on a
:class:`~repro.core.device.DeviceSKU` and this module is the
**backwards-compatible view of the default SKU** (``a100-40gb`` — the
paper's device): ``PROFILES`` / ``N_UNITS`` / ``N_COMPUTE_SLICES`` /
``EXCLUSIONS`` are aliases of the default SKU's fields, and every function
takes an optional ``sku`` to operate on another registered generation.
New code should prefer ``device.get_sku(...)`` and the SKU methods
directly; these shims exist so the 12+ existing import sites (and any
external callers) keep working unchanged.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Sequence, Tuple, Union

# Re-exported for backwards compatibility: these classes moved to the
# device-model module so the SKU registry can own the placement tree.
from repro.core.device import (  # noqa: F401
    DEFAULT_SKU,
    DeviceSKU,
    InstanceProfile,
    Placement,
    get_sku,
)
from repro.telemetry.constants import HBM_PER_CHIP  # noqa: F401  (re-export)

SkuArg = Union[None, str, DeviceSKU]

N_UNITS = DEFAULT_SKU.n_units  # memory slice units (placement granularity)
N_COMPUTE_SLICES = DEFAULT_SKU.n_compute_slices  # usable when partitioned

# The five profiles the paper sweeps (A100-40GB placement tree) — the
# default SKU's own table, aliased.
PROFILES: Dict[str, InstanceProfile] = DEFAULT_SKU.profiles_by_name

# NVIDIA's documented invalid combination despite slices summing <= max
# (paper §2.1): one cannot create 4g.20gb + 3g.20gb together.
EXCLUSIONS: Tuple[FrozenSet[str], ...] = DEFAULT_SKU.exclusions


def validate_layout(
    placements: Sequence[Placement],
    *,
    partitioned: bool = True,
    sku: SkuArg = None,
) -> Tuple[bool, str]:
    """Check a set of instance placements against the placement tree."""
    return get_sku(sku).validate_layout(placements, partitioned=partitioned)


def homogeneous_layout(profile: str, sku: SkuArg = None) -> List[Placement]:
    """The paper's 'parallel' device group: max instances of one profile."""
    return get_sku(sku).homogeneous_layout(profile)


def enumerate_layouts(
    max_results: int = 64, sku: SkuArg = None
) -> List[Tuple[Placement, ...]]:
    """All valid (order-insensitive) layouts — scheduler search space.

    (The planner's ``enumerator.enumerate_configs`` is the memoized,
    exhaustive sibling; this bounded variant predates it and stays for the
    callers pinned to its ordering.)
    """
    dev = get_sku(sku)
    options = [
        Placement(p.name, s) for p in dev.profiles for s in p.starts
    ]
    results: List[Tuple[Placement, ...]] = []
    seen = set()

    def rec(chosen: List[Placement], rest: List[Placement]):
        if len(results) >= max_results:
            return
        key = frozenset((c.profile, c.start) for c in chosen)
        if chosen and key not in seen:
            ok, _ = dev.validate_layout(chosen)
            if ok:
                seen.add(key)
                results.append(tuple(sorted(chosen, key=lambda c: c.start)))
        for i, cand in enumerate(rest):
            ok, _ = dev.validate_layout(chosen + [cand])
            if ok:
                rec(chosen + [cand], rest[i + 1:])

    rec([], options)
    return results


def instance_hbm_bytes(
    profile: str, chips_per_unit: int, sku: SkuArg = None
) -> int:
    return get_sku(sku).instance_hbm_bytes(profile, chips_per_unit)
