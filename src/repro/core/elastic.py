"""Elastic re-partitioning: the MIG-reconfiguration analogue for pod slices.

Fault model: a *slice unit* (32-chip block) becomes unhealthy — chips lost,
links flapping, or persistent stragglers localized to the block. MIG's answer
is to destroy and re-create GPU instances around the bad slice; ours is the
same algebra on the placement tree:

  1. mark failed units; every instance whose span intersects them dies;
  2. jobs from dead instances re-enter the queue (priority bumped so they
     reclaim capacity first), joined by still-pending jobs;
  3. the scheduler re-packs onto the surviving units — the placement tree is
     filtered to placements that avoid failed units;
  4. re-placed jobs resume from their last checkpoint (checkpoint/),
     which is exactly the paper's "no interference" guarantee doing real
     work: survivors never restart, because their instances were untouched.

Elastic *scale-up* is the same path in reverse: units returning to health
re-enter the free set and the next scheduling round may widen placements.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.collocation import Assignment, CollocationScheduler, Schedule
from repro.core.instance import JobSpec
from repro.core.profiles import N_UNITS, Placement


@dataclasses.dataclass
class RepackEvent:
    failed_units: Tuple[int, ...]
    killed_jobs: Tuple[str, ...]
    survivors: Tuple[str, ...]
    new_schedule: Schedule
    resumed_from_checkpoint: Tuple[str, ...]


class ElasticController:
    """Tracks unit health and drives repacking through the scheduler."""

    def __init__(self, scheduler: CollocationScheduler):
        self.scheduler = scheduler
        self.failed: Set[int] = set()

    def mark_failed(self, units: Sequence[int]) -> None:
        self.failed.update(units)

    def mark_healthy(self, units: Sequence[int]) -> None:
        self.failed.difference_update(units)

    def _span_units(self, pl: Placement) -> Set[int]:
        if pl.profile == "7g.40gb":
            return set(range(N_UNITS))
        s0, s1 = pl.span
        return set(range(s0, s1))

    def repack(self, schedule: Schedule) -> RepackEvent:
        """Kill intersecting instances, re-pack their jobs onto survivors."""
        killed: List[JobSpec] = []
        survivors: List[Assignment] = []
        for a in schedule.assignments:
            if self._span_units(a.placement) & self.failed:
                killed.append(
                    dataclasses.replace(a.job, priority=a.job.priority + 10)
                )
            else:
                survivors.append(a)

        # re-pack ONLY the killed jobs into the remaining free units: the
        # scheduler sees survivors' units + failed units as occupied.
        occupied = set(self.failed)
        for a in survivors:
            occupied |= self._span_units(a.placement)
        partial = self.scheduler.schedule(killed, blocked_units=frozenset(occupied))

        new = Schedule(survivors + partial.assignments, partial.rejections)
        return RepackEvent(
            failed_units=tuple(sorted(self.failed)),
            killed_jobs=tuple(j.name for j in killed),
            survivors=tuple(a.job.name for a in survivors),
            new_schedule=new,
            resumed_from_checkpoint=tuple(
                a.job.name for a in partial.assignments
            ),
        )
