"""Elastic re-partitioning: the MIG-reconfiguration analogue for pod slices.

Fault model: a *slice unit* (32-chip block) becomes unhealthy — chips lost,
links flapping, or persistent stragglers localized to the block. MIG's answer
is to destroy and re-create GPU instances around the bad slice; ours is the
same algebra on the placement tree:

  1. mark failed units; every instance whose span intersects them dies;
  2. jobs from dead instances re-enter the queue (priority bumped so they
     reclaim capacity first), joined by still-pending jobs;
  3. the scheduler re-packs onto the surviving units — the placement tree is
     filtered to placements that avoid failed units;
  4. re-placed jobs resume from their last checkpoint (checkpoint/),
     which is exactly the paper's "no interference" guarantee doing real
     work: survivors never restart, because their instances were untouched.

Elastic *scale-up* is the same path in reverse: units returning to health
re-enter the free set and the next scheduling round may widen placements.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.collocation import Assignment, CollocationScheduler, Schedule
from repro.core.device import get_sku
from repro.core.instance import JobSpec
from repro.core.profiles import Placement
from repro.core.sharing import CollocationMode

# priority bump applied to killed jobs so they reclaim capacity first when
# they re-enter the queue (shared with the cluster's failure/migration path)
REQUEUE_PRIORITY_BUMP = 10


def span_units(pl: Placement, sku=None) -> Set[int]:
    """Slice units an instance placement occupies on ``sku`` (the full
    profile owns every unit by the SKU invariant; default A100-40GB)."""
    return set(get_sku(sku).units(pl))


def split_by_failure(
    assignments: Sequence[Assignment], failed: Set[int], sku=None
) -> Tuple[List[JobSpec], List[Assignment]]:
    """Partition assignments into (killed job specs, surviving assignments).

    Killed jobs come back with their priority bumped — the elastic-repack
    re-queue semantics both ``ElasticController.repack`` and the cluster's
    FAILURE event handler apply. Survivors are returned untouched (F3: their
    instances never intersected the failed units, so they keep running).

    Gang members (specs carrying ``gang``) fail collectively: a gang
    advances in lockstep, so losing one member stalls the rest — any
    member whose span intersects the failed units drags its same-device
    siblings into the killed set too, never leaving them behind as
    orphans to be silently re-timed. The cluster's FAILURE handler then
    widens the kill to the gang's *other* devices and re-queues the gang
    once (core/cluster.py).
    """
    hit_gangs: Set[str] = set()
    for a in assignments:
        gang = getattr(a.job, "gang", None)
        if gang and span_units(a.placement, sku) & failed:
            hit_gangs.add(gang)
    killed: List[JobSpec] = []
    survivors: List[Assignment] = []
    for a in assignments:
        gang = getattr(a.job, "gang", None)
        if span_units(a.placement, sku) & failed or (gang in hit_gangs):
            killed.append(
                dataclasses.replace(a.job, priority=a.job.priority + REQUEUE_PRIORITY_BUMP)
            )
        else:
            survivors.append(a)
    return killed, survivors


@dataclasses.dataclass
class RepackEvent:
    failed_units: Tuple[int, ...]
    killed_jobs: Tuple[str, ...]
    survivors: Tuple[str, ...]
    new_schedule: Schedule
    resumed_from_checkpoint: Tuple[str, ...]


class ElasticController:
    """Tracks unit health and drives repacking through the scheduler."""

    def __init__(self, scheduler: CollocationScheduler):
        self.scheduler = scheduler
        self.failed: Set[int] = set()

    def mark_failed(self, units: Sequence[int]) -> None:
        self.failed.update(units)

    def mark_healthy(self, units: Sequence[int]) -> None:
        self.failed.difference_update(units)

    def _span_units(self, pl: Placement) -> Set[int]:
        return span_units(pl, self.scheduler.sku)

    def repack(self, schedule: Schedule) -> RepackEvent:
        """Kill intersecting instances, re-pack their jobs onto survivors.

        Shared modes (naive/MPS) have no isolation to fall back on: every
        job spans the whole device, so any unit failure kills the entire
        job set and nothing can be re-placed on the degraded device — the
        contrapositive of the paper's F3 isolation finding. The cluster's
        admission queue (not this controller) re-homes those jobs.
        """
        if schedule.mode != CollocationMode.MIG:
            # (re-queueing with the priority bump is the caller's job — the
            # cluster's FAILURE handler does it; this event only reports)
            return RepackEvent(
                failed_units=tuple(sorted(self.failed)),
                killed_jobs=tuple(a.job.name for a in schedule.assignments),
                survivors=(),
                new_schedule=Schedule([], [], mode=schedule.mode),
                resumed_from_checkpoint=(),
            )

        killed, survivors = split_by_failure(
            schedule.assignments, self.failed, self.scheduler.sku
        )

        # re-pack ONLY the killed jobs into the remaining free units: the
        # scheduler sees survivors' units + failed units as occupied.
        occupied = set(self.failed)
        for a in survivors:
            occupied |= span_units(a.placement, self.scheduler.sku)
        partial = self.scheduler.schedule(
            killed, blocked_units=frozenset(occupied), mode=CollocationMode.MIG
        )

        new = Schedule(
            survivors + partial.assignments, partial.rejections, mode=schedule.mode
        )
        return RepackEvent(
            failed_units=tuple(sorted(self.failed)),
            killed_jobs=tuple(j.name for j in killed),
            survivors=tuple(a.job.name for a in survivors),
            new_schedule=new,
            resumed_from_checkpoint=tuple(
                a.job.name for a in partial.assignments
            ),
        )
