"""Partition-tree enumerator: every valid MIG reconfiguration profile.

The A100 exposes ~19 canonical partition configs; under our paper-faithful
algebra (five profiles, fixed start offsets, the 4g+3g exclusion, and the
7-slice compute budget of core/profiles.py) the same search yields 18
*maximal* configs out of 296 valid non-empty layouts — small enough that the
placement optimizer can afford exact search over all of them.

Enumeration is **per device SKU** (core/device.py): every function takes an
optional ``sku`` and defaults to the A100-40GB, and the memo tables key on
the (hashable, frozen) SKU descriptor — so an A30's 4-slice tree and an
H100's 1g.20gb-bearing tree each get their own canonical-config universe
without cross-contaminating the default one (tests/test_device.py pins the
per-SKU counts).

Canonical form: a layout is a set of placements; its canonical form is the
tuple sorted by (start, profile). Enumeration is memoized (each SKU's
placement tree is a process-wide constant) and deterministic: the same call
always returns the same tuple, in the same order, with no duplicates —
tests/test_planner.py pins all three properties plus the partitioner
invariants (disjoint spans == ``verify_disjoint``, compute budget within
the SKU's slice budget).

Incremental transitions: ``expansions(existing)`` returns every valid config
reachable from a live layout by only *creating* instances (running jobs keep
their placements — MIG instance creation does not disturb neighbours, the
F3 isolation the cluster's incremental admission relies on). A full
re-partition (destroying instances) is a plan the cluster must charge
checkpoint-rollback + downtime for; ``transition`` reports exactly which
instances such a plan keeps, destroys, and creates.
"""
from __future__ import annotations

import functools
from typing import Dict, FrozenSet, List, Sequence, Tuple

from repro.core.device import DeviceSKU, Placement, get_sku

Config = Tuple[Placement, ...]


def canonical_form(placements: Sequence[Placement]) -> Config:
    """Order-insensitive canonical form: sorted by (start, profile)."""
    return tuple(sorted(placements, key=lambda pl: (pl.start, pl.profile)))


def _all_options(sku: DeviceSKU) -> Tuple[Placement, ...]:
    return tuple(
        Placement(p.name, s) for p in sku.profiles for s in p.starts
    )


@functools.lru_cache(maxsize=None)
def _enumerate_cached(sku: DeviceSKU, partitioned: bool) -> Tuple[Config, ...]:
    options = _all_options(sku)
    seen: Dict[Tuple, Config] = {}

    def rec(chosen: List[Placement], rest: Tuple[Placement, ...]) -> None:
        for i, cand in enumerate(rest):
            trial = chosen + [cand]
            ok, _ = sku.validate_layout(trial, partitioned=partitioned)
            if not ok:
                continue
            cfg = canonical_form(trial)
            key = tuple((pl.start, pl.profile) for pl in cfg)
            if key not in seen:
                seen[key] = cfg
            rec(trial, rest[i + 1 :])

    rec([], options)
    return tuple(
        sorted(
            seen.values(),
            key=lambda cfg: (
                len(cfg),
                tuple((pl.start, pl.profile) for pl in cfg),
            ),
        )
    )


def enumerate_configs(partitioned: bool = True, sku=None) -> Tuple[Config, ...]:
    """All valid non-empty layouts of the SKU's placement tree,
    canonicalized, deterministically ordered (by size, then
    lexicographically), memoized per SKU."""
    return _enumerate_cached(get_sku(sku), partitioned)


@functools.lru_cache(maxsize=None)
def _maximal_cached(sku: DeviceSKU, partitioned: bool) -> Tuple[Config, ...]:
    options = _all_options(sku)
    out = []
    for cfg in _enumerate_cached(sku, partitioned):
        have = set(cfg)
        addable = any(
            sku.validate_layout(list(cfg) + [o], partitioned=partitioned)[0]
            for o in options
            if o not in have
        )
        if not addable:
            out.append(cfg)
    return tuple(out)


def maximal_configs(partitioned: bool = True, sku=None) -> Tuple[Config, ...]:
    """Configs to which no further instance can be added — the analogue of
    the vendor's canonical partition profiles (18 under the A100-40GB
    algebra; other SKUs have their own counts)."""
    return _maximal_cached(get_sku(sku), partitioned)


@functools.lru_cache(maxsize=None)
def _multisets_cached(
    sku: DeviceSKU, partitioned: bool
) -> Tuple[Tuple[str, ...], ...]:
    return tuple(
        sorted(
            {
                tuple(sorted(pl.profile for pl in cfg))
                for cfg in _enumerate_cached(sku, partitioned)
            }
        )
    )


def profile_multisets(
    partitioned: bool = True, sku=None
) -> Tuple[Tuple[str, ...], ...]:
    """Distinct profile combinations over all valid layouts (start-blind)."""
    return _multisets_cached(get_sku(sku), partitioned)


@functools.lru_cache(maxsize=None)
def _expansions_cached(
    sku: DeviceSKU,
    existing: Config,
    blocked_units: FrozenSet[int],
    partitioned: bool,
) -> Tuple[Config, ...]:
    have = set(existing)
    out = []
    for cfg in _enumerate_cached(sku, partitioned):
        if not have <= set(cfg):
            continue
        new = [pl for pl in cfg if pl not in have]
        if any(sku.units(pl) & blocked_units for pl in new):
            continue
        out.append(cfg)
    if not existing:
        # the empty layout itself is a legal (trivial) target
        out.insert(0, ())
    else:
        out.insert(0, existing)
    return tuple(dict.fromkeys(out))


def expansions(
    existing: Sequence[Placement] = (),
    *,
    blocked_units: FrozenSet[int] = frozenset(),
    partitioned: bool = True,
    sku=None,
) -> Tuple[Config, ...]:
    """Every valid config reachable from ``existing`` by only creating
    instances (supersets of the live layout), with no new instance touching
    a blocked (failed) slice unit. Includes ``existing`` itself (the
    zero-transition plan). ``existing`` must already be a valid layout."""
    dev = get_sku(sku)
    cfg = canonical_form(existing)
    if cfg:
        ok, why = dev.validate_layout(cfg, partitioned=partitioned)
        if not ok:
            raise ValueError(f"existing layout invalid: {why}")
    return _expansions_cached(dev, cfg, frozenset(blocked_units), partitioned)


@functools.lru_cache(maxsize=None)
def _free_cached(
    sku: DeviceSKU,
    existing: Config,
    blocked_units: FrozenSet[int],
    partitioned: bool,
) -> Tuple[Placement, ...]:
    have = set(existing)
    base = list(existing)
    out = []
    for cand in _all_options(sku):
        if cand in have or sku.units(cand) & blocked_units:
            continue
        if sku.validate_layout(base + [cand], partitioned=partitioned)[0]:
            out.append(cand)
    return tuple(out)


def free_placements(
    existing: Sequence[Placement] = (),
    *,
    blocked_units: FrozenSet[int] = frozenset(),
    partitioned: bool = True,
    sku=None,
) -> Tuple[Placement, ...]:
    """Placements individually addable to ``existing`` (one-step moves).
    Memoized on the canonical form — the optimizer's innermost loop."""
    return _free_cached(
        get_sku(sku), canonical_form(existing), frozenset(blocked_units),
        partitioned,
    )


def flexibility(
    layout: Sequence[Placement] = (),
    *,
    blocked_units: FrozenSet[int] = frozenset(),
    partitioned: bool = True,
    sku=None,
) -> int:
    """How much future capacity a layout preserves: the number of distinct
    placements still addable to it. The optimizer uses this as its final
    tie-break, which is what steers 1g jobs away from the start offsets
    whose occupation strands the larger profiles' few legal starts — the
    fragmentation greedy first-fit walks straight into."""
    return len(
        free_placements(
            layout, blocked_units=blocked_units, partitioned=partitioned,
            sku=sku,
        )
    )


def transition(
    current: Sequence[Placement], target: Sequence[Placement]
) -> Tuple[Config, Config, Config]:
    """(kept, destroyed, created) instance sets of a re-partition plan.

    ``destroyed`` is what the cluster must charge for: each destroyed
    instance's job rolls back to its last checkpoint and the device pays
    reconfiguration downtime (core/cluster.py). ``kept`` instances run
    through the reconfiguration untouched (F3 isolation)."""
    cur, tgt = set(current), set(target)
    return (
        canonical_form(cur & tgt),
        canonical_form(cur - tgt),
        canonical_form(tgt - cur),
    )
