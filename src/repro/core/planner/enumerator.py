"""Partition-tree enumerator: every valid MIG reconfiguration profile.

The A100 exposes ~19 canonical partition configs; under our paper-faithful
algebra (five profiles, fixed start offsets, the 4g+3g exclusion, and the
7-slice compute budget of core/profiles.py) the same search yields 18
*maximal* configs out of 296 valid non-empty layouts — small enough that the
placement optimizer can afford exact search over all of them.

Canonical form: a layout is a set of placements; its canonical form is the
tuple sorted by (start, profile). Enumeration is memoized (the placement
tree is a process-wide constant) and deterministic: the same call always
returns the same tuple, in the same order, with no duplicates —
tests/test_planner.py pins all three properties plus the partitioner
invariants (disjoint spans == ``verify_disjoint``, compute budget <= 7).

Incremental transitions: ``expansions(existing)`` returns every valid config
reachable from a live layout by only *creating* instances (running jobs keep
their placements — MIG instance creation does not disturb neighbours, the
F3 isolation the cluster's incremental admission relies on). A full
re-partition (destroying instances) is a plan the cluster must charge
checkpoint-rollback + downtime for; ``transition`` reports exactly which
instances such a plan keeps, destroys, and creates.
"""
from __future__ import annotations

import functools
from typing import Dict, FrozenSet, List, Sequence, Tuple

from repro.core.profiles import PROFILES, Placement, validate_layout

Config = Tuple[Placement, ...]


def canonical_form(placements: Sequence[Placement]) -> Config:
    """Order-insensitive canonical form: sorted by (start, profile)."""
    return tuple(sorted(placements, key=lambda pl: (pl.start, pl.profile)))


def _all_options() -> Tuple[Placement, ...]:
    return tuple(
        Placement(name, s) for name, p in PROFILES.items() for s in p.starts
    )


@functools.lru_cache(maxsize=None)
def enumerate_configs(partitioned: bool = True) -> Tuple[Config, ...]:
    """All valid non-empty layouts of the placement tree, canonicalized,
    deterministically ordered (by size, then lexicographically), memoized."""
    options = _all_options()
    seen: Dict[Tuple, Config] = {}

    def rec(chosen: List[Placement], rest: Tuple[Placement, ...]) -> None:
        for i, cand in enumerate(rest):
            trial = chosen + [cand]
            ok, _ = validate_layout(trial, partitioned=partitioned)
            if not ok:
                continue
            cfg = canonical_form(trial)
            key = tuple((pl.start, pl.profile) for pl in cfg)
            if key not in seen:
                seen[key] = cfg
            rec(trial, rest[i + 1 :])

    rec([], options)
    return tuple(
        sorted(
            seen.values(),
            key=lambda cfg: (
                len(cfg),
                tuple((pl.start, pl.profile) for pl in cfg),
            ),
        )
    )


def _units(pl: Placement) -> FrozenSet[int]:
    s0, s1 = pl.span
    return frozenset(range(s0, s1))


@functools.lru_cache(maxsize=None)
def maximal_configs(partitioned: bool = True) -> Tuple[Config, ...]:
    """Configs to which no further instance can be added — the analogue of
    the A100's canonical partition profiles (18 under our algebra)."""
    options = _all_options()
    out = []
    for cfg in enumerate_configs(partitioned):
        have = set(cfg)
        addable = any(
            validate_layout(list(cfg) + [o], partitioned=partitioned)[0]
            for o in options
            if o not in have
        )
        if not addable:
            out.append(cfg)
    return tuple(out)


@functools.lru_cache(maxsize=None)
def profile_multisets(partitioned: bool = True) -> Tuple[Tuple[str, ...], ...]:
    """Distinct profile combinations over all valid layouts (start-blind)."""
    return tuple(
        sorted({tuple(sorted(pl.profile for pl in cfg)) for cfg in enumerate_configs(partitioned)})
    )


@functools.lru_cache(maxsize=None)
def _expansions_cached(
    existing: Config, blocked_units: FrozenSet[int], partitioned: bool
) -> Tuple[Config, ...]:
    have = set(existing)
    out = []
    for cfg in enumerate_configs(partitioned):
        if not have <= set(cfg):
            continue
        new = [pl for pl in cfg if pl not in have]
        if any(_units(pl) & blocked_units for pl in new):
            continue
        out.append(cfg)
    if not existing:
        # the empty layout itself is a legal (trivial) target
        out.insert(0, ())
    else:
        out.insert(0, existing)
    return tuple(dict.fromkeys(out))


def expansions(
    existing: Sequence[Placement] = (),
    *,
    blocked_units: FrozenSet[int] = frozenset(),
    partitioned: bool = True,
) -> Tuple[Config, ...]:
    """Every valid config reachable from ``existing`` by only creating
    instances (supersets of the live layout), with no new instance touching
    a blocked (failed) slice unit. Includes ``existing`` itself (the
    zero-transition plan). ``existing`` must already be a valid layout."""
    cfg = canonical_form(existing)
    if cfg:
        ok, why = validate_layout(cfg, partitioned=partitioned)
        if not ok:
            raise ValueError(f"existing layout invalid: {why}")
    return _expansions_cached(cfg, frozenset(blocked_units), partitioned)


@functools.lru_cache(maxsize=None)
def _free_cached(
    existing: Config, blocked_units: FrozenSet[int], partitioned: bool
) -> Tuple[Placement, ...]:
    have = set(existing)
    base = list(existing)
    out = []
    for cand in _all_options():
        if cand in have or _units(cand) & blocked_units:
            continue
        if validate_layout(base + [cand], partitioned=partitioned)[0]:
            out.append(cand)
    return tuple(out)


def free_placements(
    existing: Sequence[Placement] = (),
    *,
    blocked_units: FrozenSet[int] = frozenset(),
    partitioned: bool = True,
) -> Tuple[Placement, ...]:
    """Placements individually addable to ``existing`` (one-step moves).
    Memoized on the canonical form — the optimizer's innermost loop."""
    return _free_cached(
        canonical_form(existing), frozenset(blocked_units), partitioned
    )


def flexibility(
    layout: Sequence[Placement] = (),
    *,
    blocked_units: FrozenSet[int] = frozenset(),
    partitioned: bool = True,
) -> int:
    """How much future capacity a layout preserves: the number of distinct
    placements still addable to it. The optimizer uses this as its final
    tie-break, which is what steers 1g jobs away from the start offsets
    whose occupation strands the larger profiles' few legal starts — the
    fragmentation greedy first-fit walks straight into."""
    return len(
        free_placements(
            layout, blocked_units=blocked_units, partitioned=partitioned
        )
    )


def transition(
    current: Sequence[Placement], target: Sequence[Placement]
) -> Tuple[Config, Config, Config]:
    """(kept, destroyed, created) instance sets of a re-partition plan.

    ``destroyed`` is what the cluster must charge for: each destroyed
    instance's job rolls back to its last checkpoint and the device pays
    reconfiguration downtime (core/cluster.py). ``kept`` instances run
    through the reconfiguration untouched (F3 isolation)."""
    cur, tgt = set(current), set(target)
    return (
        canonical_form(cur & tgt),
        canonical_form(cur - tgt),
        canonical_form(tgt - cur),
    )
