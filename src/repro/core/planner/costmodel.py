"""Predictive slice fitting: what does this job get out of that slice?

MISO (arXiv:2207.11428) shows a job's best MIG slice can be *predicted*
from measurements taken without reconfiguring — their probe is MPS spatial
sharing, whose contention algebra our ``core/sharing.py`` already expresses
over roofline activity fractions. This cost model is the planner's version
of that idea, in two tiers:

  1. characterized slices: the (arch, shape, profile) record exists in the
     characterization DB — the estimate is the record's step time rescaled
     by the job's active-phase demand vector (``workload.phase_step_s``),
     exactly what the greedy scheduler would predict. Bit-identical inputs,
     so planner-vs-greedy differences are pure *placement* effects.
  2. predicted slices: the record is missing — the estimate is derived from
     the job's full-device solo profile by the same roofline scaling the
     analytic characterization uses (busy terms grow as the inverse slice
     fraction, compute additionally pays the profile's F6 discount, the
     dispatch-latency floor is slice-size-invariant). This is the MISO
     move: one full-device measurement prices every slice in the tree.

Each estimate carries an SLO-constrained *goodput* (steps/s, zeroed for a
serve job whose predicted step misses its SLO — the same currency as
``ClusterReport.goodput_steps_per_s``), which is what the optimizer
maximizes. Estimates are memoized on (SKU, arch, shape, profile, demand,
peak multiplier, SLO): the planner's inner loop prices thousands of
(job x slice) pairs per dispatch and the vectors repeat heavily — and the
SKU in the key guarantees two generations' estimates can never
cross-contaminate (tests/test_device.py proves it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple, Union

from repro.core.device import DeviceSKU, format_gib, get_sku
from repro.core.workload import (
    STEADY_DEMAND,
    DemandTrace,
    peak_demand_multiplier,
    phase_step_s,
)
from repro.telemetry.constants import HBM_PER_CHIP

_FULL_PROFILE = "7g.40gb"  # default-SKU shim; SKU-aware code reads sku.full_profile


def record_fits(
    rec: Mapping, peak_mult: float, *, budget_bytes: int = HBM_PER_CHIP
) -> bool:
    """The one memory-admission predicate, shared with
    ``CollocationScheduler.admissible``: flat jobs (peak multiplier 1.0)
    keep the record's own ``fits`` verdict bit for bit (absent key ==
    reject — the record never proved the job fits); phase-aware workloads
    re-budget their phase-peak working set against the slice's HBM
    (``budget_bytes`` — the SKU's per-chip slice budget)."""
    if peak_mult == 1.0:
        return bool(rec.get("fits", False))
    return (
        float(rec.get("peak_bytes_per_device", 0.0)) * peak_mult
        <= budget_bytes
    )


@dataclasses.dataclass(frozen=True)
class SliceEstimate:
    """One (job, slice) price: can it run there, and how fast."""

    profile: str
    fits: bool
    reason: str  # empty when fits
    step_s: float  # predicted per-step time under the given demand
    goodput: float  # steps/s if fits and (for serve) SLO-met, else 0.0
    slo_ok: Optional[bool]  # None for jobs without a step-latency SLO
    predicted: bool  # True when derived MISO-style (no record for the slice)

    @property
    def throughput(self) -> float:
        """Unconstrained steps/s (SLO-blind) — rank_modes' currency."""
        return 1.0 / self.step_s if self.fits and self.step_s > 0 else 0.0


def predict_record(full_rec: Mapping, profile: str, sku=None) -> Dict[str, float]:
    """Derive a slice record from the full-device record, MISO-style.

    The busy terms scale with the inverse of the slice's chip fraction
    (mem_units/8), compute additionally pays the slice's F6 discount
    relative to the full profile's, and the dispatch-latency residual of
    the recorded step carries over unchanged (host-side time does not
    shrink with the slice). The per-device peak is kept as-recorded — the
    replicated working set (params, per-chip activations) dominates it and
    does not shrink with chip count; the sharded remainder makes this a
    slightly optimistic ``fits``, which is why measured records always win
    when present (docs/placement.md)."""
    dev = get_sku(sku)
    step = float(full_rec.get("step_s", 0.0))
    compute = float(full_rec.get("compute_s", step))
    memory = float(full_rec.get("memory_s", 0.0))
    collective = float(full_rec.get("collective_s", 0.0))
    busy = max(compute, memory, collective)
    residual = max(0.0, step - busy)
    frac = dev.profile(profile).mem_units / dev.n_units
    full_frac = dev.profile(dev.full_profile).mem_units / dev.n_units
    scale = full_frac / frac
    disc = dev.compute_discount(profile) / dev.compute_discount(dev.full_profile)
    out_compute = compute * scale / disc
    out_memory = memory * scale
    out_collective = collective * scale
    out_busy = max(out_compute, out_memory, out_collective)
    return {
        "fits": None,  # decided by the caller against the HBM budget
        "step_s": out_busy + residual,
        "compute_s": out_compute,
        "memory_s": out_memory,
        "collective_s": out_collective,
        "peak_bytes_per_device": float(
            full_rec.get("peak_bytes_per_device", 0.0)
        ),
    }


class PlanningCostModel:
    """Memoized (job x slice x phase) estimates over a characterization DB.

    The DB is treated as immutable for the model's lifetime (the same
    contract ``CollocationScheduler`` holds); swap the model, not the DB.
    Records must be keyed by the SKU's own profile names (an 80GB fleet's
    DB speaks 1g.10gb, not 1g.5gb); the cache keys carry ``sku.name`` so a
    model can never serve another generation's estimate.
    """

    def __init__(
        self,
        char_db: Mapping[Tuple[str, str, str], Mapping],
        *,
        sku: Union[None, str, DeviceSKU] = None,
    ):
        self.char_db = char_db
        self.sku = get_sku(sku)
        self._cache: Dict[Tuple, SliceEstimate] = {}

    def estimate(
        self,
        job,
        profile: str,
        demand: DemandTrace = STEADY_DEMAND,
    ) -> SliceEstimate:
        """Price ``job`` on a ``profile`` slice under a phase's demand.

        Admission mirrors ``CollocationScheduler.admissible`` bit for bit:
        flat jobs (peak multiplier 1.0) keep the record's own ``fits``
        verdict, phase-aware workloads re-budget their phase-peak working
        set against the slice's HBM."""
        peak_mult = peak_demand_multiplier(job)
        slo = getattr(job, "slo_step_s", None)
        key = (self.sku.name, job.arch, job.suite.name, profile, demand,
               peak_mult, slo)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        est = self._estimate(job.arch, job.suite.name, profile, demand,
                             peak_mult, slo)
        self._cache[key] = est
        return est

    def _estimate(
        self,
        arch: str,
        shape: str,
        profile: str,
        demand: DemandTrace,
        peak_mult: float,
        slo: Optional[float],
    ) -> SliceEstimate:
        budget = self.sku.slice_bytes
        rec = self.char_db.get((arch, shape, profile))
        predicted = False
        if rec is None:
            full = self.char_db.get((arch, shape, self.sku.full_profile))
            if full is None:
                return SliceEstimate(
                    profile=profile,
                    fits=False,
                    reason=f"no characterization for {(arch, shape, profile)}"
                    " and no full-device record to predict from",
                    step_s=0.0,
                    goodput=0.0,
                    slo_ok=None,
                    predicted=True,
                )
            rec = predict_record(full, profile, sku=self.sku)
            predicted = True
        if predicted:
            # no measured verdict to honour: budget the predicted phase
            # peak directly against the slice HBM
            fits = (
                float(rec.get("peak_bytes_per_device", 0.0)) * peak_mult
                <= budget
            )
        else:
            fits = record_fits(rec, peak_mult, budget_bytes=budget)
        if not fits:
            need = float(rec.get("peak_bytes_per_device", 0.0)) * peak_mult
            return SliceEstimate(
                profile=profile,
                fits=False,
                reason=(
                    f"OOM: needs {format_gib(need)} GiB/chip (phase peak) "
                    f"> {format_gib(budget)} GiB HBM on {profile}"
                ),
                step_s=0.0,
                goodput=0.0,
                slo_ok=None,
                predicted=predicted,
            )
        step = float(phase_step_s(rec, demand))
        slo_ok = None if slo is None else (step <= slo)
        goodput = 1.0 / step if step > 0 and slo_ok is not False else 0.0
        return SliceEstimate(
            profile=profile,
            fits=True,
            reason="",
            step_s=step,
            goodput=goodput,
            slo_ok=slo_ok,
            predicted=predicted,
        )
