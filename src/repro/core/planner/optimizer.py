"""Placement optimizer: exact search over (partition config x assignment).

The objective is lexicographic, extending the ranking the rest of the stack
already uses (``collocation.rank_modes`` scores (jobs placed, throughput)):

  1. placed weight   — sum of (1 + priority) over placed jobs: serving more
                       of the mix beats any speed win, and a high-priority
                       job is never left queued to squeeze in low-priority
                       ones (the admission-first finding F5);
  2. kept weight     — sum of (1 + priority) over jobs assigned exactly
                       their *current* instance (the ``preferred`` map).
                       Among plans serving the same weight, touch as few
                       running jobs as possible: every displaced job pays a
                       checkpoint rollback, so a re-partition plan must
                       justify each eviction with a placement it could not
                       otherwise have (zero when no preferences are given —
                       fresh placements are unaffected);
  3. flexibility     — how many placements the resulting layout still
                       admits (enumerator.flexibility): prefer the plan
                       that preserves future capacity. This is the
                       anti-fragmentation term — it steers 1g jobs off the
                       start offsets that strand the larger profiles' few
                       legal starts;
  4. compute thrift  — fewer compute slices consumed. Slice units can tie
                       on flexibility (a nearly full device admits nothing
                       either way) while the compute budget still differs:
                       a lone medium job taking 4g.20gb over 3g.20gb burns
                       an extra slice *and* arms the 4g+3g exclusion
                       against the next arrival. Spare compute, like spare
                       units, has option value in an online stream — a
                       lone job is never upgraded to a fatter slice it
                       merely prefers;
  5. goodput         — sum of SLO-constrained steps/s over placed jobs
                       (a serve job on a slice that misses its SLO counts
                       zero — the cluster's goodput currency). With the
                       capacity terms pinned, this is where MISO-style
                       slice fitting acts: among capacity-equivalent plans
                       it routes each job to the slice that serves it best
                       (e.g. the compute-bound job of a pair gets the
                       bigger slice of a fixed layout);
  6. canonical order — deterministic final tie-break (byte-stable plans).

Exact path (<= ``exact_max_jobs`` jobs): for every valid config reachable
from the live layout (enumerator.expansions) whose new slots could all be
occupied, a DP over (slot, job-subset) finds the best assignment; the best
(config, assignment) pair over the whole tree is provably optimal under
the objective — tests/test_planner.py checks it against brute force.

Beam path (larger instances): jobs in deterministic order, a beam of
partial layouts, each expanded by every feasible placement of the next job
(or leaving it unplaced), scored by the same objective. The reported
``gap`` bounds the distance to optimal: it compares the achieved (weight,
goodput) to the conflict-free upper bound where every job gets its best
slice — gap 0.0 means provably optimal even off the exact path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

from repro.core.device import DEFAULT_SKU, DeviceSKU, Placement
from repro.core.planner.costmodel import PlanningCostModel, SliceEstimate
from repro.core.planner.enumerator import (
    canonical_form,
    expansions,
    flexibility,
    free_placements,
    transition,
)
from repro.core.workload import STEADY_DEMAND, DemandTrace

# smallest-first, same order the greedy scheduler widens through (the
# default SKU's; per-SKU plans read ``sku.profile_order`` instead)
PROFILE_ORDER: Tuple[str, ...] = DEFAULT_SKU.profile_order

#: Above this many candidate jobs the optimizer switches to the beam path.
EXACT_MAX_JOBS = 6

#: Beam width of the fallback search (partial layouts kept per job step).
BEAM_WIDTH = 12


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """The planner's product: a full partition layout plus job assignments.

    ``layout`` includes the live (``existing``) placements; ``assignments``
    covers only the newly planned jobs. ``optimality`` is ``"exact"`` when
    the plan came from exhaustive partition-tree search, ``"beam"``
    otherwise; ``gap`` is an upper bound on the relative (weight, goodput)
    left on the table (0.0 == provably optimal)."""

    layout: Tuple[Placement, ...]
    existing: Tuple[Placement, ...]
    assignments: Mapping[str, Placement]
    step_s: Mapping[str, float]
    unplaced: Tuple[Tuple[str, str], ...]  # (job name, reason)
    placed_weight: float
    kept_weight: float
    goodput: float
    flexibility: int
    optimality: str  # "exact" | "beam"
    gap: float
    configs_evaluated: int
    # the device generation the plan was searched over — needed so `score`
    # prices compute thrift with the right tree
    sku: DeviceSKU = DEFAULT_SKU

    @property
    def score(self) -> Tuple[float, float, int, int, float]:
        """The full lexicographic objective the optimizer ranks by —
        including the compute-thrift term, so comparing two plans via
        ``score`` agrees with the search's own ordering."""
        return (
            self.placed_weight,
            self.kept_weight,
            self.flexibility,
            -_compute_slices(self.layout, self.sku),
            self.goodput,
        )

    def transition(self) -> Tuple[Tuple[Placement, ...], ...]:
        """(kept, destroyed, created) relative to the live layout."""
        return transition(self.existing, self.layout)

    def provenance(self) -> Dict[str, object]:
        """Committed-vs-considered summary for the trace layer (core/obs/):
        the layout this plan commits, which search tier chose it, the
        optimality gap bound, and how much of the partition tree was
        evaluated — everything a ``replan`` decision instant must explain."""
        return {
            "layout": [f"{pl.profile}@{pl.start}" for pl in self.layout],
            "optimality": self.optimality,
            "gap": self.gap,
            "configs_evaluated": self.configs_evaluated,
            "placed_weight": self.placed_weight,
            "kept_weight": self.kept_weight,
            "goodput": self.goodput,
            "unplaced": [name for name, _ in self.unplaced],
        }


def _job_weight(job) -> float:
    return 1.0 + float(getattr(job, "priority", 0))


def _compute_slices(cfg: Sequence[Placement], sku: DeviceSKU = DEFAULT_SKU) -> int:
    return sum(sku.profile(pl.profile).compute_slices for pl in cfg)


def _eligible_profiles(job, sku: DeviceSKU) -> Tuple[str, ...]:
    """Profiles the job may use, honouring its straggler-repack floor (a
    floor naming another generation's profile does not bind — same
    convention as ``CollocationScheduler.smallest_admissible``)."""
    order = sku.profile_order
    floor = getattr(job, "min_profile", None)
    start = order.index(floor) if floor and floor in order else 0
    return order[start:]


def _estimates(
    jobs: Sequence,
    cost: PlanningCostModel,
    active_phases: Mapping[str, DemandTrace],
    sku: DeviceSKU,
) -> List[Dict[str, SliceEstimate]]:
    """Per job: profile -> estimate, restricted to eligible+fitting slices."""
    out = []
    for job in jobs:
        demand = active_phases.get(job.name, STEADY_DEMAND)
        ests = {}
        for prof in _eligible_profiles(job, sku):
            est = cost.estimate(job, prof, demand)
            if est.fits:
                ests[prof] = est
        out.append(ests)
    return out


def _unplaced_reason(job, cost, active_phases, sku: DeviceSKU) -> str:
    demand = active_phases.get(job.name, STEADY_DEMAND)
    reasons = [
        f"{p}: {cost.estimate(job, p, demand).reason}"
        for p in _eligible_profiles(job, sku)
        if not cost.estimate(job, p, demand).fits
    ]
    if len(reasons) == len(_eligible_profiles(job, sku)):
        return "; ".join(reasons[:2])
    return "no free placement slot in the best plan"


def _config_key(cfg: Sequence[Placement]) -> Tuple[Tuple[int, str], ...]:
    return tuple((pl.start, pl.profile) for pl in cfg)


def _kept(job, slot: Placement, preferred: Mapping[str, Placement]) -> float:
    return _job_weight(job) if preferred.get(job.name) == slot else 0.0


def plan_placements(
    jobs: Sequence,
    cost: PlanningCostModel,
    *,
    existing: Sequence[Placement] = (),
    blocked_units: FrozenSet[int] = frozenset(),
    active_phases: Optional[Mapping[str, DemandTrace]] = None,
    preferred: Optional[Mapping[str, Placement]] = None,
    partitioned: bool = True,
    exact_max_jobs: int = EXACT_MAX_JOBS,
    beam_width: int = BEAM_WIDTH,
) -> PlacementPlan:
    """Plan placements for ``jobs`` on top of a live layout.

    Running jobs keep their instances (``existing`` placements are fixed);
    the plan only creates new ones. A from-scratch re-partition plan is
    ``existing=()`` with ``preferred`` mapping each running job to its
    current instance — the kept-weight term then makes eviction a last
    resort, and the *caller* (core/cluster.py) is responsible for charging
    the displaced jobs' rollback and the device downtime when it commits
    such a plan.

    The partition tree searched is the cost model's device generation
    (``cost.sku``) — heterogeneous fleets plan each device over its own
    tree."""
    active_phases = active_phases or {}
    preferred = preferred or {}
    jobs = list(jobs)
    blocked_units = frozenset(blocked_units)
    sku = cost.sku
    existing_cfg = canonical_form(existing)
    ests = _estimates(jobs, cost, active_phases, sku)

    if len(jobs) <= exact_max_jobs:
        best = _plan_exact(
            jobs, ests, existing_cfg, blocked_units, partitioned, preferred,
            sku,
        )
        optimality, gap = "exact", 0.0
        configs_evaluated = best.pop("configs_evaluated")
    else:
        best = _plan_beam(
            jobs, ests, existing_cfg, blocked_units, partitioned, preferred,
            beam_width, sku,
        )
        configs_evaluated = best.pop("configs_evaluated")
        optimality = "beam"
        # conflict-free upper bound: every job on its own best slice
        ub_w = sum(_job_weight(j) for j, e in zip(jobs, ests) if e)
        ub_g = sum(
            max(e.goodput for e in je.values()) for je in ests if je
        )
        gap = 0.0
        if ub_w > best["weight"] and ub_w > 0:
            gap = max(gap, (ub_w - best["weight"]) / ub_w)
        if ub_g > best["goodput"] and ub_g > 0:
            gap = max(gap, (ub_g - best["goodput"]) / ub_g)

    assignments: Dict[str, Placement] = best["assignments"]
    step_s = {name: best["steps"][name] for name in assignments}
    unplaced = tuple(
        (j.name, _unplaced_reason(j, cost, active_phases, sku))
        for j in jobs
        if j.name not in assignments
    )
    layout = canonical_form(list(existing_cfg) + list(assignments.values()))
    return PlacementPlan(
        layout=layout,
        existing=existing_cfg,
        assignments=assignments,
        step_s=step_s,
        unplaced=unplaced,
        placed_weight=best["weight"],
        kept_weight=best["kept"],
        goodput=best["goodput"],
        flexibility=flexibility(
            layout, blocked_units=blocked_units, partitioned=partitioned,
            sku=sku,
        ),
        optimality=optimality,
        gap=gap,
        configs_evaluated=configs_evaluated,
        sku=sku,
    )


def _plan_exact(
    jobs, ests, existing_cfg, blocked_units, partitioned, preferred, sku
) -> Dict:
    """Exhaustive (config x assignment) search, optimal under the model."""
    existing_set = set(existing_cfg)
    best_state: Dict = {
        "assignments": {},
        "steps": {},
        "weight": 0.0,
        "kept": 0.0,
        "goodput": 0.0,
    }
    best_score = (-1.0, -1.0, -1, 1 << 10, -1.0)
    best_key: Optional[Tuple] = None
    n = len(jobs)
    configs = expansions(
        existing_cfg, blocked_units=blocked_units, partitioned=partitioned,
        sku=sku,
    )
    for cfg in configs:
        slots = [pl for pl in cfg if pl not in existing_set]
        if len(slots) > n:
            continue
        # DP over slots: every slot must take a distinct job (layouts with
        # unused slots are enumerated separately as smaller configs).
        # Within a config, flexibility and compute cost are constants, so
        # the DP maximizes the remaining objective (weight, kept, goodput).
        dp: Dict[int, Tuple[float, float, float]] = {0: (0.0, 0.0, 0.0)}
        parents: List[Dict[int, Tuple[int, int]]] = []
        feasible = True
        for slot in slots:
            ndp: Dict[int, Tuple[float, float, float]] = {}
            parent: Dict[int, Tuple[int, int]] = {}
            for mask, (w, k, g) in dp.items():
                for ji in range(n):
                    if mask & (1 << ji):
                        continue
                    est = ests[ji].get(slot.profile)
                    if est is None:
                        continue
                    nm = mask | (1 << ji)
                    val = (
                        w + _job_weight(jobs[ji]),
                        k + _kept(jobs[ji], slot, preferred),
                        g + est.goodput,
                    )
                    if nm not in ndp or val > ndp[nm]:
                        ndp[nm] = val
                        parent[nm] = (mask, ji)
            if not ndp:
                feasible = False
                break
            dp = ndp
            parents.append(parent)
        if not feasible:
            continue
        mask, (w, k, g) = max(dp.items(), key=lambda kv: (kv[1], -kv[0]))
        flex = flexibility(
            cfg, blocked_units=blocked_units, partitioned=partitioned, sku=sku
        )
        score = (w, k, flex, -_compute_slices(cfg, sku), g)
        key = _config_key(cfg)
        if score > best_score or (
            score == best_score and (best_key is None or key < best_key)
        ):
            # reconstruct the winning assignment
            assignments: Dict[str, Placement] = {}
            steps: Dict[str, float] = {}
            m = mask
            for si in range(len(slots) - 1, -1, -1):
                pm, ji = parents[si][m]
                job = jobs[ji]
                assignments[job.name] = slots[si]
                steps[job.name] = ests[ji][slots[si].profile].step_s
                m = pm
            best_score, best_key = score, key
            best_state = {
                "assignments": assignments,
                "steps": steps,
                "weight": w,
                "kept": k,
                "goodput": g,
            }
    best_state["configs_evaluated"] = len(configs)
    return best_state


def _plan_beam(
    jobs, ests, existing_cfg, blocked_units, partitioned, preferred,
    beam_width, sku
) -> Dict:
    """Beam search over partial layouts; same objective, bounded width."""
    order = sorted(
        range(len(jobs)),
        key=lambda i: (
            -_job_weight(jobs[i]),
            -max((e.goodput for e in ests[i].values()), default=0.0),
            jobs[i].name,
        ),
    )
    # state: (layout, assignments, steps, weight, kept, goodput)
    State = Tuple[
        Tuple[Placement, ...], Dict[str, Placement], Dict[str, float],
        float, float, float,
    ]
    states: List[State] = [(existing_cfg, {}, {}, 0.0, 0.0, 0.0)]
    expanded = 0

    def assign_key(assign: Dict[str, Placement]) -> Tuple:
        return tuple(
            sorted((n, pl.start, pl.profile) for n, pl in assign.items())
        )

    for i in order:
        job, je = jobs[i], ests[i]
        nxt: Dict[Tuple, State] = {}

        def consider(st: State) -> None:
            key = (_config_key(st[0]), assign_key(st[1]))
            if key not in nxt:
                nxt[key] = st

        for layout, assign, steps, w, k, g in states:
            consider((layout, assign, steps, w, k, g))  # leave job unplaced
            for pl in free_placements(
                layout, blocked_units=blocked_units, partitioned=partitioned,
                sku=sku,
            ):
                est = je.get(pl.profile)
                if est is None:
                    continue
                expanded += 1
                consider(
                    (
                        canonical_form(list(layout) + [pl]),
                        {**assign, job.name: pl},
                        {**steps, job.name: est.step_s},
                        w + _job_weight(job),
                        k + _kept(job, pl, preferred),
                        g + est.goodput,
                    )
                )
        states = sorted(
            nxt.values(),
            key=lambda st: (
                -st[3],
                -st[4],
                -flexibility(
                    st[0], blocked_units=blocked_units, partitioned=partitioned,
                    sku=sku,
                ),
                _compute_slices(st[0], sku),
                -st[5],
                _config_key(st[0]),
                assign_key(st[1]),
            ),
        )[:beam_width]
    layout, assign, steps, w, k, g = states[0]
    return {
        "assignments": assign,
        "steps": steps,
        "weight": w,
        "kept": k,
        "goodput": g,
        "configs_evaluated": expanded,
    }
