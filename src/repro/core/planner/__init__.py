"""Placement-planner subsystem: partition-tree search + predictive slice fitting.

The greedy ``smallest_admissible``/first-fit packing in ``core/collocation.py``
reproduces the paper's central caveat — MIG's rigid partitioning "may create
sub-optimal GPU utilization for more dynamic mixed workloads" — but never
tries to beat it. This package is the planning layer that does:

  enumerator   every valid partition config of the placement tree
               (core/profiles.py), with memoized canonical forms and the
               legal incremental transitions from a live layout — the
               search space of "Optimal Workload Placement on MIG"
               (arXiv:2409.06646) over our paper-faithful algebra;
  costmodel    MISO-style (arXiv:2207.11428) predictive slice fitting: each
               job's throughput on each candidate slice estimated from its
               characterization record or, when the record is missing,
               predicted from the full-device roofline profile — no
               simulated reconfiguration required;
  optimizer    exact search over (partition config x job->slice assignment)
               maximizing (priority-weighted jobs placed, SLO-constrained
               goodput, residual flexibility), with a beam fallback above a
               size threshold and a reported optimality gap.

Import discipline: like the rest of the scheduling stack this package is
jax-free (tests/test_jax_free_core.py) — it builds on ``core/profiles.py``'s
placement algebra and mirrors ``partitioner.verify_disjoint``'s invariant
(disjoint spans == disjoint device rectangles) without touching meshes.
"""
from repro.core.planner.costmodel import PlanningCostModel, SliceEstimate
from repro.core.planner.enumerator import (
    canonical_form,
    enumerate_configs,
    expansions,
    flexibility,
    free_placements,
    maximal_configs,
    profile_multisets,
    transition,
)
from repro.core.planner.optimizer import PlacementPlan, plan_placements

__all__ = [
    "PlanningCostModel",
    "SliceEstimate",
    "canonical_form",
    "enumerate_configs",
    "expansions",
    "flexibility",
    "free_placements",
    "maximal_configs",
    "profile_multisets",
    "transition",
    "PlacementPlan",
    "plan_placements",
]
