"""Forecast → fleet-partition planning, as pure functions.

The cluster (core/cluster.py) owns all state — device modes, running
jobs, reservations. On every FORECAST_TICK it gathers the current
forecast plus per-device serve capacities and asks this module two
questions:

- :func:`plan_autoscale` — *how many* decode-capable devices should be
  warm to absorb the predicted concurrent serve sessions, and therefore
  how many pre-warm reservations to add or release;
- :func:`wave_amortizes` — *is it worth it*: does the conservative
  (lower-band) predicted serve demand amortize the reconfiguration
  downtime plus checkpoint-rollback redo the flip would cost? This is
  the same economics as the planner's ``_replan_pays_off`` gate, fed by
  the forecast instead of the realized queue.

Keeping these pure (no cluster imports, plain floats in / dataclass
out) keeps them unit-testable and jax-free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.forecast.estimator import (
    ESTIMATORS,
    RateForecast,
    make_estimator,
)

__all__ = [
    "ForecastConfig",
    "AutoscaleDecision",
    "plan_autoscale",
    "wave_amortizes",
    "next_tick",
    "forecast_provenance",
]


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    """Knobs for ``Cluster(policy="forecast")``.

    estimator        which arrival-rate estimator drives the autoscaler
                     ("seasonal" | "ewma" | "window").
    period_s         seasonal period (the synthetic "day" of the trace).
    n_bins           phase bins per period for the seasonal profile.
    window_s/tau_s   knobs for the structure-free estimators (and the
                     seasonal cold-start fallback).
    tick_s           FORECAST_TICK cadence; ticks ride a fixed grid so
                     both re-timing engines fire them at identical times.
    horizon_s        lookahead window the autoscaler prices.
    headroom         capacity margin over the predicted concurrency.
    amortize_factor  how many times over the predicted wave must cover a
                     flip's downtime + redo before we pay it (>=1 is
                     conservative).
    release_hysteresis  fraction of the warm set's capacity the *upper*
                     band must fall below before reservations are
                     released — avoids thrash at the band edge.
    session_alpha    EWMA weight for the serve session service-time
                     estimate learned from completions.
    demote_priority_below  running jobs with priority strictly below
                     this are preempted (checkpoint-rollback requeue,
                     not killed) when their device is pre-warmed.
    """

    estimator: str = "seasonal"
    period_s: float = 1.0
    n_bins: int = 16
    window_s: float = 0.25
    tau_s: float = 0.25
    tick_s: float = 0.05
    horizon_s: float = 0.5
    headroom: float = 1.2
    amortize_factor: float = 1.0
    release_hysteresis: float = 0.7
    session_alpha: float = 0.3
    demote_priority_below: int = 1

    def __post_init__(self) -> None:
        if self.estimator not in ESTIMATORS:
            raise ValueError(
                f"unknown estimator {self.estimator!r} "
                f"(choose from {sorted(ESTIMATORS)})"
            )
        for field in ("period_s", "tick_s", "horizon_s", "headroom"):
            if getattr(self, field) <= 0.0:
                raise ValueError(f"{field} must be positive")
        if not 0.0 <= self.release_hysteresis <= 1.0:
            raise ValueError("release_hysteresis must be in [0, 1]")

    def build_estimator(self):
        return make_estimator(
            self.estimator,
            window_s=self.window_s,
            tau_s=self.tau_s,
            period_s=self.period_s,
            n_bins=self.n_bins,
        )


@dataclasses.dataclass(frozen=True)
class AutoscaleDecision:
    """Target warm set emitted by :func:`plan_autoscale`.

    ``target_devices`` is a prefix length into the candidate order the
    cluster supplied: the first ``target_devices`` candidates should be
    warm (decode-capable + reserved), the rest should not. ``prewarm``
    and ``release`` are the deltas against the currently reserved count.
    """

    predicted_sessions: float
    target_devices: int
    prewarm: int
    release: int


def _prefix_for(demand: float, caps: Sequence[float]) -> int:
    """Smallest candidate prefix whose summed capacity covers demand."""
    if demand <= 0.0:
        return 0
    total = 0.0
    for i, cap in enumerate(caps):
        total += cap
        if total >= demand:
            return i + 1
    return len(caps)


def plan_autoscale(
    fc: RateForecast,
    *,
    session_s: float,
    device_caps: Sequence[float],
    reserved: int,
    cfg: ForecastConfig,
) -> AutoscaleDecision:
    """Size the warm set from the forecast.

    ``device_caps`` lists each candidate device's concurrent-serve
    capacity (sessions it can host decode-capable), in the cluster's
    preference order — already-reserved devices first so the target
    prefix naturally keeps them. Little's law sizes the demand:
    predicted concurrent sessions = rate x service time, padded by
    ``cfg.headroom``. Releases are sized against the *upper* band and
    damped by ``release_hysteresis`` so a noisy trough does not flap
    reservations that the next ramp would immediately re-acquire.
    """
    if session_s <= 0.0 or not device_caps:
        return AutoscaleDecision(0.0, 0, 0, max(0, reserved))
    predicted = fc.rate_per_s * session_s * cfg.headroom
    target = _prefix_for(predicted, device_caps)
    if target > reserved:
        return AutoscaleDecision(predicted, target, target - reserved, 0)
    # Shrinking: only release what even the optimistic (upper-band)
    # demand cannot use, and only once it clears the hysteresis margin.
    upper_demand = fc.upper_per_s * session_s * cfg.headroom
    upper_target = _prefix_for(upper_demand, device_caps)
    keep = max(target, upper_target)
    if keep < reserved:
        held_cap = sum(device_caps[:reserved])
        if held_cap > 0.0 and upper_demand > cfg.release_hysteresis * held_cap:
            keep = reserved  # still inside the hysteresis band: hold
    release = max(0, reserved - keep)
    return AutoscaleDecision(predicted, max(target, reserved - release), 0, release)


def wave_amortizes(
    fc: RateForecast,
    *,
    session_s: float,
    share_devices: int,
    cost_s: float,
    cfg: ForecastConfig,
) -> bool:
    """Does the conservative predicted wave pay for one device flip?

    The flip costs ``cost_s`` seconds (reconfiguration downtime plus the
    worst checkpoint-rollback redo among displaced jobs). The wave
    conservatively brings ``lower_per_s x session_s x horizon_s``
    serve-busy seconds, spread across ``share_devices`` warm devices.
    A seasonal estimator in cold start reports ``lower_per_s == 0`` and
    therefore never pays for a flip — day one is for learning.
    """
    if cost_s <= 0.0:
        return True
    share = max(1, share_devices)
    wave_busy_s = fc.lower_per_s * session_s * fc.horizon_s / share
    return wave_busy_s >= cfg.amortize_factor * cost_s


def next_tick(t: float, tick_s: float) -> float:
    """Next grid-aligned tick strictly after t (grid anchored at 0).

    Guarded against float quantization: when t sits exactly on a grid
    point but ``t / tick_s`` rounds *down* (e.g. 0.0375 / 0.0025 ->
    14.999...), the naive floor+1 lands back on t and the tick clock
    would stop advancing — re-arming itself at the same timestamp
    forever. Bump until strictly past t."""
    k = math.floor(t / tick_s) + 1.0
    nt = k * tick_s
    while nt <= t:
        k += 1.0
        nt = k * tick_s
    return nt


def forecast_provenance(fc: RateForecast, realized_per_s: float) -> dict:
    """Predicted band vs realized arrivals, for the trace layer (core/obs/).

    The cluster measures ``realized_per_s`` over the tick window that just
    closed and records one ``forecast_tick`` decision instant per tick —
    the per-tick absolute error series that ``benchmarks/report.py trace``
    summarizes, and the ground truth the estimator is judged against."""
    return {
        "rate_per_s": fc.rate_per_s,
        "lower_per_s": fc.lower_per_s,
        "upper_per_s": fc.upper_per_s,
        "horizon_s": fc.horizon_s,
        "realized_per_s": realized_per_s,
        "abs_err_per_s": abs(fc.rate_per_s - realized_per_s),
        "in_band": bool(fc.lower_per_s <= realized_per_s <= fc.upper_per_s),
    }
