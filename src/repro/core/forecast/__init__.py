"""Forecast-driven autoscaling (jax-free, like the rest of the
scheduling stack).

``estimator.py`` turns the cluster's arrival stream into a rate forecast
with a confidence band (windowed / EWMA / seasonal-diurnal);
``policy.py`` converts the forecast into a target decode-capable device
count and gates each pre-warm re-partition on the predicted wave
amortizing the reconfiguration downtime + checkpoint rollback. The
cluster integration — the FORECAST_TICK event, pre-warm reservations,
``Cluster(policy="forecast")`` — lives in core/cluster.py and
core/queueing.py. See docs/autoscaling.md.
"""
from repro.core.forecast.estimator import (  # noqa: F401
    ESTIMATORS,
    EWMARateEstimator,
    RateForecast,
    SeasonalRateEstimator,
    WindowedRateEstimator,
    make_estimator,
)
from repro.core.forecast.policy import (  # noqa: F401
    AutoscaleDecision,
    ForecastConfig,
    forecast_provenance,
    next_tick,
    plan_autoscale,
    wave_amortizes,
)
