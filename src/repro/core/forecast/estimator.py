"""Arrival-rate estimators over the cluster's event stream.

Every estimator consumes a strictly non-decreasing sequence of arrival
timestamps via ``observe(t)`` and answers ``forecast(t, horizon_s)``
with a :class:`RateForecast` — a predicted mean arrival rate over
``[t, t + horizon_s]`` plus a confidence band. All three are pure
stdlib/math code (no jax, no numpy) and fully deterministic functions
of the observation stream: the only randomness in a simulation enters
through the seeded trace, so two runs over the same trace produce
byte-identical forecasts.

Three estimators, increasing in structure:

- :class:`WindowedRateEstimator` — counts arrivals in a sliding window;
  the band is the Poisson standard error of the count. Zero lag, no
  memory beyond the window, blind to seasonality.
- :class:`EWMARateEstimator` — exponentially-weighted instantaneous
  rate with a continuous-time decay ``exp(-dt / tau_s)``, plus an
  exponentially-weighted variance for the band. Smooth, but always
  trails a ramp by ~``tau_s``.
- :class:`SeasonalRateEstimator` — learns a per-bin diurnal profile
  from *completed* periods and integrates it over the forecast window,
  so it predicts the morning ramp *before* it happens. During the first
  (incomplete) period it falls back to an internal EWMA and reports a
  zero lower band — "I have seen no full day yet" — which downstream
  gating treats as insufficient evidence to pay for a re-partition.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

# Two-sided 95% normal quantile used for every confidence band.
Z95 = 1.96

__all__ = [
    "RateForecast",
    "WindowedRateEstimator",
    "EWMARateEstimator",
    "SeasonalRateEstimator",
    "ESTIMATORS",
    "make_estimator",
]


@dataclasses.dataclass(frozen=True)
class RateForecast:
    """Predicted mean arrival rate over ``[at_s, at_s + horizon_s]``."""

    at_s: float
    horizon_s: float
    rate_per_s: float
    lower_per_s: float
    upper_per_s: float
    source: str
    # How many completed seasonal periods back the prediction (0 for the
    # structure-free estimators and during a seasonal cold start).
    periods: int = 0

    @property
    def expected_arrivals(self) -> float:
        return self.rate_per_s * self.horizon_s


def _band(rate: float, se: float) -> Tuple[float, float]:
    return (max(0.0, rate - Z95 * se), rate + Z95 * se)


class WindowedRateEstimator:
    """Sliding-window arrival counter with a Poisson error band."""

    name = "window"

    def __init__(self, window_s: float = 0.25) -> None:
        if window_s <= 0.0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.window_s = float(window_s)
        self._times: Deque[float] = deque()
        self.n_observed = 0

    def _evict(self, t: float) -> None:
        cutoff = t - self.window_s
        times = self._times
        while times and times[0] <= cutoff:
            times.popleft()

    def observe(self, t: float) -> None:
        self.n_observed += 1
        self._times.append(t)
        self._evict(t)

    def forecast(self, t: float, horizon_s: float) -> RateForecast:
        self._evict(t)
        n = len(self._times)
        rate = n / self.window_s
        # Poisson standard error of the window count; with an empty
        # window use the se of a single count so the upper band stays
        # non-degenerate ("we could have just missed one").
        se = math.sqrt(max(n, 1)) / self.window_s
        lower, upper = _band(rate, se)
        return RateForecast(
            at_s=t,
            horizon_s=horizon_s,
            rate_per_s=rate,
            lower_per_s=lower,
            upper_per_s=upper,
            source=self.name,
        )


class EWMARateEstimator:
    """Exponentially-weighted instantaneous rate with variance band.

    Each arrival contributes the instantaneous rate ``1 / dt`` (dt =
    gap since the previous arrival), blended with the continuous-time
    weight ``1 - exp(-dt / tau_s)`` so the smoothing is invariant to
    how irregular the arrivals are.
    """

    name = "ewma"

    def __init__(self, tau_s: float = 0.25) -> None:
        if tau_s <= 0.0:
            raise ValueError(f"tau_s must be positive, got {tau_s}")
        self.tau_s = float(tau_s)
        self._last_t: Optional[float] = None
        self._rate = 0.0
        self._var = 0.0
        self.n_observed = 0

    def observe(self, t: float) -> None:
        self.n_observed += 1
        if self._last_t is None:
            self._last_t = t
            return
        dt = max(t - self._last_t, 1e-12)
        self._last_t = t
        inst = 1.0 / dt
        w = 1.0 - math.exp(-dt / self.tau_s)
        diff = inst - self._rate
        self._rate += w * diff
        # Exponentially-weighted variance (West 1979 incremental form).
        self._var = (1.0 - w) * (self._var + w * diff * diff)

    def forecast(self, t: float, horizon_s: float) -> RateForecast:
        rate = self._rate
        if self._last_t is not None and rate > 0.0:
            # A silence much longer than the expected gap is evidence the
            # rate has collapsed; decay the estimate for the excess.
            silence = max(0.0, t - self._last_t)
            grace = 3.0 / rate
            if silence > grace:
                rate *= math.exp(-(silence - grace) / self.tau_s)
        se = math.sqrt(max(self._var, 0.0))
        lower, upper = _band(rate, se)
        return RateForecast(
            at_s=t,
            horizon_s=horizon_s,
            rate_per_s=rate,
            lower_per_s=lower,
            upper_per_s=upper,
            source=self.name,
        )


class SeasonalRateEstimator:
    """Learns a per-bin daily profile from completed periods.

    Time is folded modulo ``period_s`` into ``n_bins`` equal phase
    bins. While a period is in flight its bin counts accumulate; when
    the clock rolls past a period boundary the counts are finalized
    into a per-bin rate profile (up to ``max_periods`` kept, oldest
    dropped). A forecast integrates the across-period mean profile over
    the phase window ``[t, t + horizon_s]`` — which is what lets it see
    tomorrow's ramp in today's history. The band is the across-period
    standard error per bin (Poisson se when only one period has
    completed). Before any period completes it falls back to an
    internal :class:`EWMARateEstimator` with a zero lower band.
    """

    name = "seasonal"

    def __init__(
        self,
        period_s: float = 1.0,
        n_bins: int = 16,
        tau_s: float = 0.25,
        max_periods: int = 8,
    ) -> None:
        if period_s <= 0.0:
            raise ValueError(f"period_s must be positive, got {period_s}")
        if n_bins < 1:
            raise ValueError(f"n_bins must be >= 1, got {n_bins}")
        self.period_s = float(period_s)
        self.n_bins = int(n_bins)
        self.bin_s = self.period_s / self.n_bins
        self.max_periods = int(max_periods)
        self._cur_period: Optional[int] = None
        self._counts = [0] * self.n_bins
        # Completed-period profiles: list of per-bin rates (arrivals/s).
        self._profiles: List[List[float]] = []
        self._recent = EWMARateEstimator(tau_s=tau_s)
        self.n_observed = 0

    # -- period bookkeeping -------------------------------------------------

    def _period_of(self, t: float) -> int:
        return int(math.floor(t / self.period_s))

    def _bin_of(self, t: float) -> int:
        phase = t - self._period_of(t) * self.period_s
        return min(int(phase / self.bin_s), self.n_bins - 1)

    def _roll(self, t: float) -> None:
        pid = self._period_of(t)
        if self._cur_period is None:
            # First observation: anchor without fabricating empty
            # profiles for periods the estimator never watched.
            self._cur_period = pid
            return
        while self._cur_period < pid:
            profile = [c / self.bin_s for c in self._counts]
            self._profiles.append(profile)
            if len(self._profiles) > self.max_periods:
                self._profiles.pop(0)
            self._counts = [0] * self.n_bins
            self._cur_period += 1

    # -- observation --------------------------------------------------------

    def observe(self, t: float) -> None:
        self.n_observed += 1
        self._roll(t)
        self._counts[self._bin_of(t)] += 1
        self._recent.observe(t)

    # -- forecasting --------------------------------------------------------

    def _bin_stats(self, b: int) -> Tuple[float, float]:
        """Across-period (mean rate, standard error) for phase bin b."""
        k = len(self._profiles)
        vals = [p[b] for p in self._profiles]
        mean = sum(vals) / k
        if k >= 2:
            var = sum((v - mean) ** 2 for v in vals) / (k - 1)
            se = math.sqrt(var / k)
        else:
            # One completed period: Poisson se of the single bin count.
            se = math.sqrt(max(mean, 1.0 / self.bin_s) / self.bin_s)
        return mean, se

    def forecast(self, t: float, horizon_s: float) -> RateForecast:
        self._roll(t)
        if not self._profiles:
            # Cold start: no completed period yet. Report the reactive
            # EWMA view but with a floored lower band, so evidence-gated
            # consumers (the autoscaler) don't pay for structure we have
            # not actually observed.
            fb = self._recent.forecast(t, horizon_s)
            return RateForecast(
                at_s=t,
                horizon_s=horizon_s,
                rate_per_s=fb.rate_per_s,
                lower_per_s=0.0,
                upper_per_s=fb.upper_per_s,
                source=f"{self.name}:warmup",
            )
        # Integrate the mean profile (and band) over the phase window.
        horizon = max(horizon_s, 1e-12)
        pos = t
        remaining = horizon
        rate_w = 0.0
        se_w = 0.0
        while remaining > 1e-12:
            phase = pos - self._period_of(pos) * self.period_s
            b = min(int(phase / self.bin_s), self.n_bins - 1)
            seg = min(remaining, (b + 1) * self.bin_s - phase)
            if seg <= self.bin_s * 1e-9:
                # float edge at a bin boundary: the residual to the next
                # boundary can quantize to a denormal sliver that would
                # never drain ``remaining`` — step a full bin instead
                seg = min(remaining, self.bin_s)
            mean, se = self._bin_stats(b)
            rate_w += mean * seg
            se_w += se * seg
            pos += seg
            remaining -= seg
        rate = rate_w / horizon
        se = se_w / horizon
        lower, upper = _band(rate, se)
        return RateForecast(
            at_s=t,
            horizon_s=horizon_s,
            rate_per_s=rate,
            lower_per_s=lower,
            upper_per_s=upper,
            source=self.name,
            periods=len(self._profiles),
        )


ESTIMATORS: Dict[str, Callable[..., object]] = {
    WindowedRateEstimator.name: WindowedRateEstimator,
    EWMARateEstimator.name: EWMARateEstimator,
    SeasonalRateEstimator.name: SeasonalRateEstimator,
}


def make_estimator(
    name: str,
    *,
    window_s: float = 0.25,
    tau_s: float = 0.25,
    period_s: float = 1.0,
    n_bins: int = 16,
):
    """Build a named estimator with the knobs it understands."""
    if name == WindowedRateEstimator.name:
        return WindowedRateEstimator(window_s=window_s)
    if name == EWMARateEstimator.name:
        return EWMARateEstimator(tau_s=tau_s)
    if name == SeasonalRateEstimator.name:
        return SeasonalRateEstimator(period_s=period_s, n_bins=n_bins, tau_s=tau_s)
    raise ValueError(
        f"unknown estimator {name!r} (choose from {sorted(ESTIMATORS)})"
    )
