"""Priority + backfill admission queue for the cluster.

Replaces the one-shot scheduler's "reject forever" behaviour: a job that
cannot be placed *now* waits here and is retried on every capacity-changing
event (completion, repair, reconfiguration). Ordering is strict priority
first, then FIFO within a priority class (arrival time, then submission
sequence — the deterministic tie-break the simulator's reproducibility
contract relies on).

Backfill semantics live in the dispatcher (core/cluster.py): the queue is
scanned *in order* and any entry that fits somewhere starts immediately,
even if an earlier (higher-priority) entry is head-of-line blocked waiting
for a big slot. That is classic EASY-style backfill without reservations —
acceptable here because placed jobs never shrink a blocked job's future
options below what the empty device offers, and the paper's queueing-delay
comparison only needs work-conserving admission, not starvation-freedom
guarantees. ``hol_blocked_events`` counts how often backfill overtook a
blocked head — a cheap observability hook for the rigidity analysis.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class QueueEntry:
    key: str  # job name — unique within a cluster
    item: Any  # opaque to the queue (the cluster stores its ClusterJob)
    priority: int
    enqueued_s: float
    seq: int

    def sort_key(self):
        return (-self.priority, self.enqueued_s, self.seq)


class AdmissionQueue:
    """Priority queue with stable FIFO order inside each priority class.

    Dispatch order is maintained incrementally (bisect on push, indexed
    delete on remove) rather than re-sorted on every ``ordered()`` call —
    the dispatcher scans the queue on every capacity event, which made the
    O(n log n) re-sort a leading term at city-scale queue depths.
    ``peak_depth`` records the deepest the queue ever got (a burst-pressure
    metric benchmarks/sim_perf.py reports per scenario cell)."""

    def __init__(self) -> None:
        self._entries: Dict[str, QueueEntry] = {}
        self._sorted: List[QueueEntry] = []  # maintained in sort_key order
        self._seq = 0
        self.hol_blocked_events = 0
        self.peak_depth = 0

    def push(self, key: str, item: Any, *, priority: int, enqueued_s: float) -> QueueEntry:
        if key in self._entries:
            raise KeyError(f"{key!r} already queued")
        e = QueueEntry(key, item, int(priority), float(enqueued_s), self._seq)
        self._seq += 1
        self._entries[key] = e
        bisect.insort(self._sorted, e, key=QueueEntry.sort_key)
        if len(self._entries) > self.peak_depth:
            self.peak_depth = len(self._entries)
        return e

    def remove(self, key: str) -> QueueEntry:
        e = self._entries.pop(key)
        # sort_key ends in the unique push seq, so bisect lands exactly on e
        i = bisect.bisect_left(self._sorted, e.sort_key(), key=QueueEntry.sort_key)
        while self._sorted[i] is not e:  # pragma: no cover - defensive
            i += 1
        del self._sorted[i]
        return e

    def get(self, key: str) -> Optional[QueueEntry]:
        return self._entries.get(key)

    def ordered(self) -> List[QueueEntry]:
        """Entries in dispatch order: priority desc, then FIFO."""
        return list(self._sorted)

    def keys(self) -> List[str]:
        return [e.key for e in self.ordered()]

    def note_backfill_overtake(self) -> None:
        self.hol_blocked_events += 1

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries
