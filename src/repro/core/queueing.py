"""Priority + backfill admission queue for the cluster.

Replaces the one-shot scheduler's "reject forever" behaviour: a job that
cannot be placed *now* waits here and is retried on every capacity-changing
event (completion, repair, reconfiguration). Ordering is strict priority
first, then FIFO within a priority class (arrival time, then submission
sequence — the deterministic tie-break the simulator's reproducibility
contract relies on).

Backfill semantics live in the dispatcher (core/cluster.py): the queue is
scanned *in order* and any entry that fits somewhere starts immediately,
even if an earlier (higher-priority) entry is head-of-line blocked waiting
for a big slot. That is classic EASY-style backfill without reservations —
acceptable here because placed jobs never shrink a blocked job's future
options below what the empty device offers, and the paper's queueing-delay
comparison only needs work-conserving admission, not starvation-freedom
guarantees. ``hol_blocked_events`` counts how often backfill overtook a
blocked head — a cheap observability hook for the rigidity analysis.

Gang jobs (core/gang/) are the one exception to "no reservations": an
all-or-nothing k-slice gang CAN be starved by a work-conserving backfill
stream — singletons keep landing on the devices it needs, and capacity
never coincides. After a gang has waited out the cluster's starvation
bound, the dispatcher reserves a concrete device set for it here
(:meth:`reserve`); the dispatcher then refuses to backfill singletons
onto reserved devices, so the set drains and the gang places. At most
one gang holds reservations at a time (the oldest blocked one — that is
what makes the protocol deadlock-free), and a reservation is released
deterministically the moment its gang places or is rejected
(:meth:`release`).

Pre-warm reservations (:meth:`prewarm`) are the forecast policy's
(core/forecast/) second exception, with the opposite shape: not "drain
this device for one waiting job" but "keep this device answering *this
kind* of job". A device warmed for serve traffic ahead of a predicted
ramp would otherwise be backfilled away by queued training long before
the ramp arrives; ``prewarm_blocks`` is the dispatcher's veto that stops
that, while still admitting the kind the device was warmed for. Unlike
gang reservations these are per-device, any number may be live at once,
and they are held across events until the autoscaler releases them.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any, Callable, Dict, FrozenSet, List, Optional


@dataclasses.dataclass
class QueueEntry:
    key: str  # job name — unique within a cluster
    item: Any  # opaque to the queue (the cluster stores its ClusterJob)
    priority: int
    enqueued_s: float
    seq: int

    def sort_key(self):
        return (-self.priority, self.enqueued_s, self.seq)


class AdmissionQueue:
    """Priority queue with stable FIFO order inside each priority class.

    Dispatch order is maintained incrementally (bisect on push, indexed
    delete on remove) rather than re-sorted on every ``ordered()`` call —
    the dispatcher scans the queue on every capacity event, which made the
    O(n log n) re-sort a leading term at city-scale queue depths.
    ``peak_depth`` records the deepest the queue ever got (a burst-pressure
    metric benchmarks/sim_perf.py reports per scenario cell)."""

    def __init__(self) -> None:
        self._entries: Dict[str, QueueEntry] = {}
        self._sorted: List[QueueEntry] = []  # maintained in sort_key order
        self._seq = 0
        self.hol_blocked_events = 0
        self.peak_depth = 0
        # gang reservation state: at most one (gang key, device names)
        # pair at a time — see the module docstring's starvation protocol
        self._reserved_by: Optional[str] = None
        self._reserved_devices: FrozenSet[str] = frozenset()
        self.reservations_made = 0
        self.reservations_released = 0
        # pre-warm reservations (forecast policy): device name -> the job
        # kind the device is warmed for; other kinds are vetoed there
        self._prewarmed: Dict[str, str] = {}
        self.prewarms_made = 0
        self.prewarms_released = 0
        # decision-provenance sink (core/obs/): None means "not traced" and
        # every hook below is a single attribute check
        self._trace = None
        self._trace_clock: Optional[Callable[[], float]] = None

    def attach_trace(self, recorder, clock: Callable[[], float]) -> None:
        """Wire a ``TraceRecorder``. ``clock`` supplies the sim time for
        queue actions whose signatures carry none (reserve/prewarm) —
        the cluster passes its event-loop clock."""
        self._trace = recorder
        self._trace_clock = clock

    def push(self, key: str, item: Any, *, priority: int, enqueued_s: float) -> QueueEntry:
        if key in self._entries:
            raise KeyError(f"{key!r} already queued")
        e = QueueEntry(key, item, int(priority), float(enqueued_s), self._seq)
        self._seq += 1
        self._entries[key] = e
        bisect.insort(self._sorted, e, key=QueueEntry.sort_key)
        if len(self._entries) > self.peak_depth:
            self.peak_depth = len(self._entries)
        if self._trace is not None:
            self._trace.instant(
                "scheduler",
                "enqueue",
                e.enqueued_s,
                args={"job": key, "priority": e.priority, "depth": len(self._entries)},
            )
        return e

    def remove(self, key: str) -> QueueEntry:
        self.release(key)  # leaving the queue always frees the claim
        e = self._entries.pop(key)
        # sort_key ends in the unique push seq, so bisect lands exactly on e
        i = bisect.bisect_left(self._sorted, e.sort_key(), key=QueueEntry.sort_key)
        while self._sorted[i] is not e:  # pragma: no cover - defensive
            i += 1
        del self._sorted[i]
        return e

    def get(self, key: str) -> Optional[QueueEntry]:
        return self._entries.get(key)

    def ordered(self) -> List[QueueEntry]:
        """Entries in dispatch order: priority desc, then FIFO."""
        return list(self._sorted)

    def keys(self) -> List[str]:
        return [e.key for e in self.ordered()]

    def note_backfill_overtake(self) -> None:
        self.hol_blocked_events += 1

    # -- gang reservations ------------------------------------------------

    def reserve(self, key: str, devices) -> None:
        """Reserve ``devices`` for queued gang ``key``. Exclusive: a second
        gang may not reserve until the first's claim is released — queue
        order decides who reserves, which keeps the protocol deadlock-free.
        Re-reserving by the holder replaces its device set (the dispatcher
        widens a reservation when failures shrink a reserved device)."""
        if key not in self._entries:
            raise KeyError(f"{key!r} is not queued")
        if self._reserved_by is not None and self._reserved_by != key:
            raise ValueError(
                f"{self._reserved_by!r} already holds the reservation"
            )
        self._reserved_by = key
        self._reserved_devices = frozenset(devices)
        self.reservations_made += 1
        if self._trace is not None:
            self._trace.instant(
                "scheduler",
                "gang_reserve",
                self._trace_clock(),
                args={"gang": key, "devices": sorted(self._reserved_devices)},
            )

    def release(self, key: str) -> bool:
        """Drop ``key``'s reservation if it holds one; True if it did.
        Idempotent — rejection and placement paths may both call it."""
        if self._reserved_by != key:
            return False
        self._reserved_by = None
        self._reserved_devices = frozenset()
        self.reservations_released += 1
        if self._trace is not None:
            self._trace.instant(
                "scheduler", "gang_release", self._trace_clock(), args={"gang": key}
            )
        return True

    @property
    def reserved_by(self) -> Optional[str]:
        return self._reserved_by

    def reserved_against(self, key: str, device: str) -> bool:
        """Is ``device`` reserved for a job other than ``key``? The
        dispatcher's backfill veto: singletons (and other gangs) must not
        land on a reserved device."""
        return (
            self._reserved_by is not None
            and self._reserved_by != key
            and device in self._reserved_devices
        )

    # -- pre-warm reservations (forecast autoscaling) ---------------------

    def prewarm(self, device: str, kind: str = "serve") -> bool:
        """Reserve ``device`` for jobs of ``kind`` ahead of a predicted
        ramp. Idempotent per device (re-warming updates the kind without
        recounting). Returns True if a new reservation was created."""
        fresh = device not in self._prewarmed
        self._prewarmed[device] = kind
        if fresh:
            self.prewarms_made += 1
            if self._trace is not None:
                self._trace.instant(
                    "scheduler",
                    "prewarm",
                    self._trace_clock(),
                    args={"device": device, "kind": kind},
                )
        return fresh

    def prewarm_release(self, device: str) -> bool:
        """Drop ``device``'s pre-warm reservation; True if it had one."""
        if device not in self._prewarmed:
            return False
        del self._prewarmed[device]
        self.prewarms_released += 1
        if self._trace is not None:
            self._trace.instant(
                "scheduler", "prewarm_release", self._trace_clock(), args={"device": device}
            )
        return True

    def prewarm_blocks(self, device: str, kind: str) -> bool:
        """The dispatcher's backfill veto: is ``device`` warmed for a
        different kind than ``kind``? Jobs of the warmed kind still
        place freely — that is the point of warming."""
        warmed_for = self._prewarmed.get(device)
        return warmed_for is not None and warmed_for != kind

    def is_prewarmed(self, device: str) -> bool:
        return device in self._prewarmed

    def prewarmed_kind(self, device: str) -> Optional[str]:
        """The kind ``device`` is warmed for, or None — the trace layer's
        ``veto_prewarm`` provenance names what the device was held for."""
        return self._prewarmed.get(device)

    @property
    def prewarmed_devices(self) -> FrozenSet[str]:
        return frozenset(self._prewarmed)

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries
