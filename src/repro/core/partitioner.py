"""Pod partitioner: MIG placements -> disjoint JAX submeshes.

This is the TPU adaptation of MIG's hardware partitioning (DESIGN.md §2):
a *slice unit* (the A100 memory-slice granularity that the placement tree is
defined over) maps to a contiguous block of rows of the pod's chip grid, so
every instance is a contiguous sub-rectangle. Contiguity is what preserves
MIG's isolation property on a TPU torus — all ICI hops for an instance's
collectives stay inside its own rectangle, so instances cannot contend for
link bandwidth (the analogue of MIG's dedicated memory/SM slices).

Unlike MIG, a TPU sub-rectangle scales compute *and* HBM together (chips are
the unit of both). Profiles with unequal compute:memory ratios (3g.20gb,
4g.20gb) keep their paper-faithful placement algebra here, and the scheduler
accounts for the compute-slice ratio analytically (scheduler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import Mesh

from repro.core.device import get_sku
from repro.core.profiles import Placement, homogeneous_layout


@dataclasses.dataclass(frozen=True)
class InstanceMesh:
    """One GPU-instance analogue: a placement bound to a device sub-rectangle."""

    placement: Placement
    mesh: Mesh

    @property
    def profile(self) -> str:
        return self.placement.profile

    @property
    def n_chips(self) -> int:
        return int(np.prod(self.mesh.devices.shape))

    @property
    def label(self) -> str:
        return f"{self.profile}@{self.placement.start}"


def device_grid(
    devices: Optional[Sequence] = None, rows: Optional[int] = None, sku=None
) -> np.ndarray:
    """Arrange devices into a (rows, cols) grid. Default: squarest grid with
    rows divisible by the SKU's unit count when possible, else rows=n
    (column vector)."""
    n_units = get_sku(sku).n_units
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs)
    if rows is None:
        rows = n_units if n % n_units == 0 else n
    assert n % rows == 0, f"{n} devices not divisible into {rows} rows"
    return np.array(devs, dtype=object).reshape(rows, n // rows)


def rows_per_unit(grid: np.ndarray, sku=None) -> int:
    n_units = get_sku(sku).n_units
    rows = grid.shape[0]
    assert rows % n_units == 0, (
        f"grid rows {rows} must be divisible by {n_units} slice units"
    )
    return rows // n_units


def instance_mesh(
    grid: np.ndarray,
    placement: Placement,
    *,
    axis_names: Tuple[str, str] = ("data", "model"),
    sku=None,
) -> InstanceMesh:
    """The contiguous sub-rectangle of ``grid`` owned by ``placement``."""
    dev = get_sku(sku)
    rpu = rows_per_unit(grid, dev)
    s0, s1 = dev.span(placement)
    block = grid[s0 * rpu : s1 * rpu, :]
    mesh = Mesh(block, axis_names)
    return InstanceMesh(placement, mesh)


def partition(
    grid: np.ndarray,
    placements: Sequence[Placement],
    *,
    partitioned: bool = True,
    axis_names: Tuple[str, str] = ("data", "model"),
    sku=None,
) -> List[InstanceMesh]:
    """Validate a layout against the placement tree and carve the submeshes."""
    dev = get_sku(sku)
    ok, why = dev.validate_layout(placements, partitioned=partitioned)
    if not ok:
        raise ValueError(f"invalid MIG layout: {why}")
    return [
        instance_mesh(grid, pl, axis_names=axis_names, sku=dev)
        for pl in placements
    ]


def partition_homogeneous(
    grid: np.ndarray, profile: str, *, sku=None, **kw
) -> List[InstanceMesh]:
    """The paper's 'parallel' device group: max instances of one profile."""
    return partition(grid, homogeneous_layout(profile, sku=sku), sku=sku, **kw)


def verify_disjoint(instances: Sequence[InstanceMesh]) -> None:
    """Isolation precondition: no device may belong to two instances."""
    seen: Dict[int, str] = {}
    for inst in instances:
        for dev in inst.mesh.devices.flat:
            key = id(dev)
            if key in seen:
                raise AssertionError(
                    f"device {dev} shared by {seen[key]} and {inst.label}"
                )
            seen[key] = inst.label


def profile_mesh_shape(
    profile: str, pod_shape: Tuple[int, int] = (16, 16), sku=None
) -> Tuple[int, int]:
    """Mesh shape an instance of ``profile`` gets on a ``pod_shape`` pod.

    Used by the analytical characterization to dry-run-lower a workload at
    instance scale without building the full pod grid.
    """
    dev = get_sku(sku)
    rows, cols = pod_shape
    rpu = rows // dev.n_units
    return (dev.profile(profile).mem_units * rpu, cols)
