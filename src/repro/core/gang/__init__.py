"""Gang scheduling: multi-slice / multi-device jobs (Flex-MIG direction).

Jax-free subsystem (tests/test_jax_free_core.py). Three modules:

  parallelism.py  the tensor/pipeline/data descriptor a gang job carries,
                  member memory math, rank/axis layout;
  comms.py        the per-link communication cost model that prices
                  co-located vs scattered slice sets into step time;
  placement.py    all-or-nothing gang placement search over the fleet —
                  scheduler-agnostic (the cluster supplies capacities and
                  a probe callback), re-exported separately so the cheap
                  descriptor imports in instance.py/workload.py never pull
                  the search machinery.

See docs/gang_scheduling.md for the admission protocol and failure
semantics.
"""
from repro.core.gang.comms import (
    AXIS_TRAFFIC,
    DEFAULT_LINK,
    LinkModel,
    comm_overhead_s,
    gang_step_s,
    placement_spread,
    ring_links,
)
from repro.core.gang.parallelism import (
    PARALLELISMS,
    SHARDABLE_FRACTION,
    Parallelism,
    axis_rank_groups,
    gang_of_member,
    gang_world_size,
    is_gang,
    member_memory_fraction,
    member_name,
    rank_coords,
    resolve_parallelism,
)

__all__ = [
    "AXIS_TRAFFIC",
    "DEFAULT_LINK",
    "LinkModel",
    "PARALLELISMS",
    "SHARDABLE_FRACTION",
    "Parallelism",
    "axis_rank_groups",
    "comm_overhead_s",
    "gang_of_member",
    "gang_step_s",
    "gang_world_size",
    "is_gang",
    "member_memory_fraction",
    "member_name",
    "placement_spread",
    "rank_coords",
    "resolve_parallelism",
    "ring_links",
]
