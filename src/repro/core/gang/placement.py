"""All-or-nothing gang placement search over a fleet of MIG devices.

The cluster (core/cluster.py) cannot place a gang one slice at a time —
either every member gets a slice or none do, and *where* the members land
changes the gang's effective step time through the communication model
(comms.py). This module owns the search; the cluster supplies the fleet
through two callbacks so the search stays scheduler-agnostic and jax-free:

  capacities  per-device member capacity (how many members an otherwise
              unchanged device admits right now), in fleet order;
  probe       place a specific contiguous rank block on a specific device,
              returning the concrete (placement, member step) pairs the
              device's scheduler would bind — or None if they no longer
              all fit together.

Two candidate splits are generated and scored under a lexicographic
objective, mirroring the placement planner's style (core/planner/):

  pack    fewest devices: capacity-descending greedy fill — the
          co-located shape, contiguous same-device slice sets;
  spread  one member per device round-robin — the scattered shape the
          comms model prices against.

``prefer="colocate"`` scores (spread asc, priced gang step asc, device
names) so pack wins whenever feasible; ``prefer="scatter"`` flips the
spread term — that knob is what benchmarks/report.py's gang table uses to
show co-located strictly beating scattered goodput. Ranks are assigned to
devices in contiguous blocks, so tensor-parallel neighbours (the
fastest-varying, chattiest axis — parallelism.py's rank layout) share a
device whenever the split allows it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core.gang.comms import DEFAULT_LINK, LinkModel, comm_overhead_s
from repro.core.gang.parallelism import Parallelism

# probe(device_index, ranks) -> [(placement, member_step_s), ...] or None
ProbeFn = Callable[[int, Sequence[int]], Optional[List[Tuple[Any, float]]]]


@dataclasses.dataclass(frozen=True)
class MemberSlot:
    """One gang member bound to one device slice."""

    rank: int
    device: str
    placement: Any  # core/profiles.py Placement — opaque here
    step_s: float  # the member's solo step on its slice (pre-comms)


@dataclasses.dataclass(frozen=True)
class GangPlan:
    """A feasible all-or-nothing placement for every member of a gang."""

    slots: Tuple[MemberSlot, ...]  # rank order
    step_s: float  # effective gang step: max member + comm overhead
    comm_s: float  # the comm overhead term alone
    spread: int  # distinct devices spanned

    @property
    def devices(self) -> Tuple[str, ...]:
        """Device per rank — what the cluster records on the ClusterJob."""
        return tuple(s.device for s in self.slots)

    def provenance(self) -> dict:
        """Decision summary for the trace layer (core/obs/): where every
        rank landed and what the candidate cost — the terms the pack/spread
        search ranked by, so a ``gang_place`` instant explains why this
        layout beat the alternatives."""
        return {
            "devices": sorted(set(self.devices)),
            "slots": [
                f"r{s.rank}:{s.device}:{s.placement.profile}" for s in self.slots
            ],
            "spread": self.spread,
            "step_s": self.step_s,
            "comm_s": self.comm_s,
        }


def split_counts(
    capacities: Sequence[int], world_size: int, prefer: str
) -> Optional[List[Tuple[int, int]]]:
    """Assign ``world_size`` members to devices as ``(device_index, count)``
    blocks, or None when the fleet lacks capacity.

    ``prefer="colocate"``: capacity-descending greedy — provably the
    minimum device count for independent per-device capacities.
    ``prefer="scatter"``: round-robin one member at a time over every
    device with spare capacity, maximizing the number of devices spanned.
    Ties break on fleet order (device index), keeping the split a pure
    function of the capacity vector — the determinism contract.
    """
    if world_size > sum(capacities):
        return None
    if prefer == "scatter":
        counts = [0] * len(capacities)
        left = world_size
        while left > 0:
            progressed = False
            for i, cap in enumerate(capacities):
                if counts[i] < cap:
                    counts[i] += 1
                    left -= 1
                    progressed = True
                    if left == 0:
                        break
            if not progressed:  # pragma: no cover - guarded by the sum check
                return None
        return [(i, c) for i, c in enumerate(counts) if c > 0]
    order = sorted(range(len(capacities)), key=lambda i: (-capacities[i], i))
    split: List[Tuple[int, int]] = []
    left = world_size
    for i in order:
        if left == 0:
            break
        take = min(capacities[i], left)
        if take > 0:
            split.append((i, take))
            left -= take
    return split if left == 0 else None


def _realize(
    split: Sequence[Tuple[int, int]],
    device_names: Sequence[str],
    par: Parallelism,
    probe: ProbeFn,
    collective_s: float,
    link: LinkModel,
) -> Optional[GangPlan]:
    """Probe a split into a concrete GangPlan; None if any block fails."""
    slots: List[MemberSlot] = []
    rank = 0
    for dev_idx, count in split:
        ranks = list(range(rank, rank + count))
        placed = probe(dev_idx, ranks)
        if placed is None or len(placed) != count:
            return None
        for r, (pl, step) in zip(ranks, placed):
            slots.append(MemberSlot(r, device_names[dev_idx], pl, float(step)))
        rank += count
    rank_device = {s.rank: s.device for s in slots}
    comm = comm_overhead_s(par, rank_device, collective_s, link)
    step = max(s.step_s for s in slots) + comm
    return GangPlan(
        slots=tuple(slots),
        step_s=float(step),
        comm_s=float(comm),
        spread=len({s.device for s in slots}),
    )


def plan_gang(
    par: Parallelism,
    device_names: Sequence[str],
    capacities: Sequence[int],
    probe: ProbeFn,
    collective_s: float,
    *,
    prefer: str = "colocate",
    link: LinkModel = DEFAULT_LINK,
) -> Optional[GangPlan]:
    """Search for an all-or-nothing placement of ``par.world_size`` members.

    Both candidate splits are realized and scored lexicographically:
    colocate prefers (fewer devices, lower comm-priced gang step, device
    names); scatter prefers (more devices, ...). Returns the winner, or
    None when no candidate covers every member — admission stays
    all-or-nothing, the caller never sees a partial gang.
    """
    if prefer not in ("colocate", "scatter"):
        raise ValueError(f"prefer must be 'colocate' or 'scatter', got {prefer!r}")
    if len(device_names) != len(capacities):
        raise ValueError("device_names and capacities must align")
    world_size = par.world_size
    candidates: List[GangPlan] = []
    for mode in ("colocate", "scatter"):
        split = split_counts(capacities, world_size, mode)
        if split is None:
            continue
        plan = _realize(split, device_names, par, probe, collective_s, link)
        if plan is not None:
            candidates.append(plan)
    if not candidates:
        return None
    sign = 1 if prefer == "colocate" else -1
    return min(
        candidates, key=lambda p: (sign * p.spread, p.step_s, p.devices)
    )
