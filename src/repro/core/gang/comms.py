"""Communication cost model for gang placements.

Prices the *inter-member* collectives a gang adds on top of each member's
own (intra-slice) step, as a per-step overhead in seconds. Two link
classes, mirroring the hardware the placement decides between:

  intra   members on the SAME device: MIG slices share the package, so
          member-to-member traffic rides the on-device fabric (NVLink
          class) at the baseline bandwidth the characterization records'
          ``collective_s`` is already expressed in;
  cross   members on DIFFERENT devices: traffic crosses the node
          interconnect at a fraction of that bandwidth and pays a
          per-step hop latency.

That asymmetry is the whole point of gang-aware placement: a co-located
slice set is strictly cheaper than a scattered one whenever the gang
exchanges any bytes at all (and never more expensive — the latency term
alone breaks the tie for pure-compute gangs).

Traffic volume is derived from the solo record's ``collective_s`` — the
same derive-don't-invent convention the phase demand vectors use
(core/workload.py): an axis of degree d moves ``(d-1)/d`` of a ring
all-reduce's bytes per member, weighted by how chatty the axis is
(tensor >> data >> pipeline; see AXIS_TRAFFIC and runtime/pipeline.py /
sharding/plan.py for the mechanics each weight abstracts).

Jax-free; imports only the sibling parallelism module.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

from repro.core.gang.parallelism import Parallelism, axis_rank_groups

#: Per-axis traffic weight, as a multiple of the solo record's
#: ``collective_s``: TP all-reduces boundary activations every layer
#: (the full collective budget), ZeRO-DP gathers weights/reduces grads
#: once per layer but overlaps with compute, PP only ships stage-boundary
#: activations (runtime/pipeline.py's single ppermute per tick).
AXIS_TRAFFIC: Dict[str, float] = {
    "tensor": 1.0,
    "pipeline": 0.35,
    "data": 0.6,
}


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Relative link speeds, normalized to the on-device fabric = 1.0."""

    #: Cross-device interconnect bandwidth as a fraction of the on-device
    #: fabric (NVLink-to-IB class ratio).
    cross_bandwidth_frac: float = 0.25
    #: Per-step latency charged for each cross-device ring hop.
    cross_latency_s: float = 25e-6

    def __post_init__(self):
        if not (0.0 < self.cross_bandwidth_frac <= 1.0):
            raise ValueError(
                "cross_bandwidth_frac must be in (0, 1], got "
                f"{self.cross_bandwidth_frac}"
            )
        if self.cross_latency_s < 0.0:
            raise ValueError("cross_latency_s must be >= 0")


DEFAULT_LINK = LinkModel()


def ring_links(group: Sequence[int]) -> Tuple[Tuple[int, int], ...]:
    """Ring-neighbour rank pairs of one collective group: the links a
    ring all-reduce (or the GPipe stage chain) actually stresses. Two
    members share a single link; three or more close the ring."""
    g = list(group)
    if len(g) < 2:
        return ()
    if len(g) == 2:
        return ((g[0], g[1]),)
    return tuple(
        (g[i], g[(i + 1) % len(g)]) for i in range(len(g))
    )


def comm_overhead_s(
    par: Parallelism,
    rank_device: Mapping[int, str],
    collective_s: float,
    link: LinkModel = DEFAULT_LINK,
) -> float:
    """Per-step inter-member communication overhead of one placement.

    ``rank_device`` maps every rank to the device hosting its slice.
    Per axis of degree d: each group moves ``weight * collective_s *
    (d-1)/d`` per step, split evenly over its ring links; intra-device
    links carry their share at baseline bandwidth, cross-device links at
    ``cross_bandwidth_frac`` of it plus the hop latency. All members on
    one device => the cross terms vanish entirely.
    """
    collective_s = max(0.0, float(collective_s))
    total = 0.0
    for axis, groups in axis_rank_groups(par).items():
        d = par.axis_degrees()[axis]
        axis_bytes_s = AXIS_TRAFFIC[axis] * collective_s * (d - 1) / d
        for group in groups:
            links = ring_links(group)
            if not links:
                continue
            per_link = axis_bytes_s / len(links)
            for a, b in links:
                if rank_device[a] == rank_device[b]:
                    total += per_link
                else:
                    total += per_link / link.cross_bandwidth_frac
                    total += link.cross_latency_s
    return total


def gang_step_s(
    member_step_s: Sequence[float],
    par: Parallelism,
    rank_device: Mapping[int, str],
    collective_s: float,
    link: LinkModel = DEFAULT_LINK,
) -> float:
    """Effective gang step time: the slowest member (a gang advances in
    lockstep — every collective is a barrier) plus the placement's
    communication overhead."""
    if not member_step_s:
        return 0.0
    return max(member_step_s) + comm_overhead_s(
        par, rank_device, collective_s, link
    )


def placement_spread(rank_device: Mapping[int, str]) -> int:
    """Distinct devices a placement spans (1 == fully co-located)."""
    return len(set(rank_device.values()))
