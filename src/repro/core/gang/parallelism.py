"""Parallelism descriptors for gang-scheduled multi-slice jobs.

A gang is one logical job executed by ``world_size`` cooperating members,
each on its own MIG slice. The descriptor records how the job's work is
split across the members — the same three axes ``sharding/plan.py`` builds
GSPMD meshes from:

  tensor    Megatron-style TP: weights column/row-sharded over the axis,
            activations all-reduced every layer (plan.py's ``model`` axis).
            The chattiest axis — per-layer activation collectives.
  pipeline  GPipe stages (runtime/pipeline.py): layers partitioned, only
            boundary activations cross the axis once per microbatch tick.
            The quietest axis.
  data      ZeRO-3 data parallelism (plan.py's 'zero' variant): batch
            sharded, per-layer weight gathers + gradient reduce-scatters.

The descriptor is the scheduling-side mirror of those runtime modules: it
carries exactly what admission and the comms cost model need — how much
memory each member must budget (:func:`member_memory_fraction`) and which
rank pairs exchange traffic on which axis (:func:`axis_rank_groups`).

Import discipline: this module is the root of the jax-free gang subsystem
and imports nothing from ``repro`` — ``core/instance.py`` and
``core/workload.py`` both depend on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: Fraction of a member's working set that shards with the model-parallel
#: degree (weights, optimizer state, the sharded activations); the rest —
#: replicated activations, staging buffers, the runtime — is resident on
#: every member regardless of the split. The 0.85 figure matches the
#: ZeRO-3/TP regime of sharding/plan.py where parameters and optimizer
#: state dominate the footprint of the large configs.
SHARDABLE_FRACTION = 0.85


@dataclasses.dataclass(frozen=True)
class Parallelism:
    """How a gang splits one job over ``world_size`` members.

    Rank layout is row-major with ``tensor`` fastest-varying (the
    convention of sharding/plan.py's merged meshes):
    ``rank = (data_idx * pipeline + pipe_idx) * tensor + tensor_idx``.
    """

    tensor: int = 1
    pipeline: int = 1
    data: int = 1

    def __post_init__(self):
        for axis in ("tensor", "pipeline", "data"):
            d = getattr(self, axis)
            if not (isinstance(d, int) and d >= 1):
                raise ValueError(
                    f"Parallelism.{axis} must be an int >= 1, got {d!r}"
                )

    @property
    def world_size(self) -> int:
        return self.tensor * self.pipeline * self.data

    @property
    def model_degree(self) -> int:
        """Ways the *model state* is split (TP x PP) — data parallelism
        replicates parameters, so it never shrinks a member's footprint
        here (the ZeRO gather re-materializes them layer by layer)."""
        return self.tensor * self.pipeline

    def axis_degrees(self) -> Dict[str, int]:
        return {"tensor": self.tensor, "pipeline": self.pipeline,
                "data": self.data}

    @property
    def label(self) -> str:
        return f"tp{self.tensor}.pp{self.pipeline}.dp{self.data}"


#: Descriptors the simulator CLI accepts by name (launch/simulate.py
#: errors with this list on unknown values).
PARALLELISMS: Dict[str, Parallelism] = {
    "tp2": Parallelism(tensor=2),
    "tp4": Parallelism(tensor=4),
    "pp2": Parallelism(pipeline=2),
    "pp4": Parallelism(pipeline=4),
    "dp2": Parallelism(data=2),
    "tp2.pp2": Parallelism(tensor=2, pipeline=2),
}


def resolve_parallelism(job) -> Parallelism:
    """Descriptor lookup for every spelling a caller may hold: a
    registry name (KeyError listing the registered choices on a miss —
    the CLI's unknown-value contract), a :class:`Parallelism` itself, or
    a job carrying one. A job without a descriptor resolves to plain
    data-parallel over its ``world_size`` (weights replicated — the
    conservative default)."""
    if isinstance(job, str):
        try:
            return PARALLELISMS[job]
        except KeyError:
            raise KeyError(
                f"unknown parallelism {job!r}; registered: "
                + ", ".join(sorted(PARALLELISMS))
            ) from None
    if isinstance(job, Parallelism):
        return job
    p = getattr(job, "parallelism", None)
    if p is not None:
        return p
    return Parallelism(data=max(1, int(getattr(job, "world_size", 1))))


def gang_world_size(job) -> int:
    """Member count of ``job`` — 1 for every pre-gang JobSpec/Workload."""
    return int(getattr(job, "world_size", 1) or 1)


def is_gang(job) -> bool:
    return gang_world_size(job) > 1


def member_memory_fraction(par: Parallelism) -> float:
    """Fraction of the solo-job working set one member must hold.

    ``(1 - S) + S / model_degree`` with S the shardable fraction: the
    model-parallel split divides parameters/optimizer state, the rest is
    replicated on every member. Degree 1 (pure DP) is exactly 1.0 — each
    member holds the whole model, as plan.py's zero variant does between
    layer gathers at its per-layer peak."""
    m = max(1, par.model_degree)
    return (1.0 - SHARDABLE_FRACTION) + SHARDABLE_FRACTION / m


def member_name(gang_name: str, rank: int) -> str:
    """Per-member assignment key — unique within a device's assignment
    map, recoverable back to the gang via :func:`gang_of_member`."""
    return f"{gang_name}#r{rank}"


def gang_of_member(name: str) -> str:
    """Inverse of :func:`member_name` (identity for non-member names)."""
    base, sep, rank = name.rpartition("#r")
    if sep and rank.isdigit():
        return base
    return name


def rank_coords(par: Parallelism, rank: int) -> Tuple[int, int, int]:
    """(tensor_idx, pipe_idx, data_idx) of ``rank`` under the row-major
    layout documented on :class:`Parallelism`."""
    t = rank % par.tensor
    p = (rank // par.tensor) % par.pipeline
    d = rank // (par.tensor * par.pipeline)
    return t, p, d


def axis_rank_groups(par: Parallelism) -> Dict[str, List[Tuple[int, ...]]]:
    """Per axis: the rank groups that communicate over it (one group per
    fixed setting of the other two axes). Groups for degree-1 axes are
    omitted — no traffic flows on them."""
    out: Dict[str, List[Tuple[int, ...]]] = {}
    ws = par.world_size
    ranks = list(range(ws))
    for axis in ("tensor", "pipeline", "data"):
        if par.axis_degrees()[axis] == 1:
            continue
        groups: Dict[Tuple[int, int], List[int]] = {}
        for r in ranks:
            t, p, d = rank_coords(par, r)
            key = {
                "tensor": (p, d),
                "pipeline": (t, d),
                "data": (t, p),
            }[axis]
            groups.setdefault(key, []).append(r)
        out[axis] = [tuple(g) for _, g in sorted(groups.items())]
    return out
