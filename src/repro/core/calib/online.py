"""Online refinement: EWMA residual corrections from live step samples.

The static pipeline (harness -> fit -> refine) runs *before* a
simulation; this module is the MISO "refine online" half — the loop
closing *during* one. ``Cluster.observe_step`` already turns every
completed step into a measured-vs-predicted sample (the PR 9 trace
layer); an :class:`OnlineCalibrator` attached to the cluster folds each
sample into a running per-(sku, arch, profile) multiplicative residual,
and ``CollocationScheduler.predict_step`` multiplies its memoized base
prediction by the current residual — so predictions tighten as evidence
accumulates, without ever touching the char DB or the memo cache.

Determinism: the state is a pure fold over the observation sequence
(EWMA, no clocks, no randomness), so identical runs produce identical
residuals — the byte-determinism contract survives. Runs that do not
attach a calibrator are untouched: the scheduler hook multiplies by
nothing when ``calibrator`` is ``None``.

Convergence note: the samples feed back through the very predictions the
calibrator corrects (predicted_s already includes the current residual).
The update therefore divides the correction back out — it estimates the
ratio measured / *base* prediction — so the residual converges to the
true bias instead of compounding against itself. Jax-free stdlib.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

ResidualKey = Tuple[str, str, str]  # (sku, arch, profile)

#: Default EWMA smoothing: one sample moves the residual 20% of the way
#: to its observed ratio — fast enough to converge within a short run's
#: worth of steps, slow enough that one outlier step cannot whipsaw the
#: scheduler's packing decisions.
DEFAULT_ALPHA = 0.2

#: Residuals clamp to [1/BOUND, BOUND]; a wildly corrupt sample (a stall,
#: a clock glitch) can nudge predictions, never invert them.
DEFAULT_BOUND = 4.0


@dataclasses.dataclass
class _Residual:
    value: float = 1.0
    n: int = 0
    last_t_s: float = 0.0


class OnlineCalibrator:
    """Running per-(sku, arch, profile) multiplicative step corrections.

    ``observe`` folds one measured-vs-predicted sample in (EWMA in the
    ratio domain); ``correct`` applies the current residual to a base
    prediction; ``snapshot`` exports the state as a sorted plain dict
    (artifact- and report-ready).
    """

    def __init__(
        self, *, alpha: float = DEFAULT_ALPHA, bound: float = DEFAULT_BOUND
    ) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if bound < 1.0:
            raise ValueError(f"bound must be >= 1, got {bound}")
        self.alpha = float(alpha)
        self.bound = float(bound)
        self._residuals: Dict[ResidualKey, _Residual] = {}
        self.n_observed = 0

    # -- the fold -------------------------------------------------------

    def observe(
        self,
        *,
        sku: str,
        arch: str,
        profile: str,
        measured_s: float,
        predicted_s: float,
        t_s: float = 0.0,
        applied_residual: Optional[float] = None,
    ) -> float:
        """Fold one step sample in; returns the updated residual.

        ``predicted_s`` is the scheduler's *corrected* prediction (what
        ``predict_step`` returned, i.e. base x some residual) — the
        correction is divided back out so the EWMA tracks measured/base,
        not measured/corrected. ``applied_residual`` is the residual that
        prediction actually carried (the scheduler records it per job at
        pricing time; a job priced before the residual moved is divided
        by its *stale* value, not today's). When omitted, the current
        residual is assumed — exact only for callers that re-price on
        every step. Non-positive samples are ignored."""
        if measured_s <= 0.0 or predicted_s <= 0.0:
            return self.residual(sku=sku, arch=arch, profile=profile)
        key = (sku, arch, profile)
        st = self._residuals.setdefault(key, _Residual())
        r_applied = applied_residual if applied_residual else st.value
        base_s = predicted_s / r_applied if r_applied > 0.0 else predicted_s
        ratio = measured_s / base_s
        ratio = min(max(ratio, 1.0 / self.bound), self.bound)
        st.value = (1.0 - self.alpha) * st.value + self.alpha * ratio
        st.value = min(max(st.value, 1.0 / self.bound), self.bound)
        st.n += 1
        st.last_t_s = float(t_s)
        self.n_observed += 1
        return st.value

    # -- reads ----------------------------------------------------------

    def residual(self, *, sku: str, arch: str, profile: str) -> float:
        st = self._residuals.get((sku, arch, profile))
        return st.value if st is not None else 1.0

    def correct(
        self, step_s: float, *, sku: str, arch: str, profile: str
    ) -> float:
        """Apply the current residual to a base prediction — the hook
        ``CollocationScheduler.predict_step`` calls after its memo."""
        return step_s * self.residual(sku=sku, arch=arch, profile=profile)

    def snapshot(self) -> Dict:
        """Sorted, JSON-ready view of the state (launch/calibrate.py
        writes this into the calibration artifact)."""
        return {
            "alpha": self.alpha,
            "bound": self.bound,
            "n_observed": self.n_observed,
            "residuals": [
                {
                    "sku": k[0],
                    "arch": k[1],
                    "profile": k[2],
                    "residual": st.value,
                    "n": st.n,
                    "last_t_s": st.last_t_s,
                }
                for k, st in sorted(self._residuals.items())
            ],
        }

    def __len__(self) -> int:
        return len(self._residuals)
