"""Predicted-vs-measured error fitting: the MISO residual, re-derived.

``predict_step`` prices a slice from analytic records; a calibration
backend (core/calib/harness) or the trace layer's step samples
(core/obs) say what the slice *actually* did. This module closes the gap
with two moves:

1. **Aggregation** — ``step_error_rows`` folds raw measured-vs-predicted
   samples into the per-(arch, slice) error table. It is the one copy of
   that aggregation: ``benchmarks/report.py trace`` renders it (markdown
   and, with ``--format json``, as a ``calib_step_error/v1`` document)
   and the fitting below consumes the same rows, so the harness and the
   report can never disagree about what the error is.

2. **Fitting** — ``fit_residuals`` factors the observed ratios
   measured/predicted into a per-arch scale times a per-profile (slice)
   residual, geometric-mean in log space. That is exactly the shape of
   the MISO claim: a full-device profile predicts every slice up to a
   smooth per-slice correction. ``refine_db`` then applies the fitted
   correction to every *unmeasured* seed entry (provenance ``refined``),
   and ``evaluate_db`` scores any DB against a ground-truth oracle —
   the seed-vs-calibrated delta `benchmarks/report.py calibrate` prints
   and CI gates on.

Everything is jax-free, deterministic, and order-independent (sums are
taken over sorted keys).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.core.calib.records import CharDB, CharKey, CharRecord

ERROR_SCHEMA = "calib_step_error/v1"


# -- sample aggregation (shared with benchmarks/report.py trace) ------------


def step_error_rows(samples: Iterable[Mapping]) -> List[Dict]:
    """Fold step samples (``TraceRecorder.samples`` schema: dicts with
    ``arch``/``profile``/``measured_s``/``predicted_s``) into the
    per-(arch, slice) error table — n, mean measured, mean predicted,
    mean |relative error|."""
    by_key: Dict[Tuple[str, str], List[Mapping]] = {}
    for s in samples:
        by_key.setdefault((s["arch"], s["profile"]), []).append(s)
    rows = []
    for (arch, profile), group in sorted(by_key.items()):
        n = len(group)
        rows.append(
            {
                "arch": arch,
                "profile": profile,
                "n": n,
                "measured_s": sum(s["measured_s"] for s in group) / n,
                "predicted_s": sum(s["predicted_s"] for s in group) / n,
                "rel_err": sum(
                    abs(s["measured_s"] - s["predicted_s"]) / s["predicted_s"]
                    for s in group
                    if s["predicted_s"] > 0.0
                )
                / n,
            }
        )
    return rows


def step_error_doc(
    samples: Iterable[Mapping], *, meta: Optional[Mapping] = None
) -> Dict:
    """The machine-readable step-error document ``benchmarks/report.py
    trace --format json`` emits and ``fit_from_error_doc`` consumes."""
    doc = {"schema": ERROR_SCHEMA, "rows": step_error_rows(samples)}
    if meta:
        doc.update({k: meta[k] for k in sorted(meta)})
    return doc


# -- residual fitting -------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResidualFit:
    """Multiplicative corrections factored as per-arch x per-profile.

    ``correction(arch, profile)`` is what a seed prediction must be
    multiplied by to match the measurements; unseen archs/profiles fall
    back to 1.0 (no evidence, no correction)."""

    sku: str
    per_arch: Mapping[str, float]
    per_profile: Mapping[str, float]
    n_pairs: int

    def correction(self, arch: str, profile: str) -> float:
        return self.per_arch.get(arch, 1.0) * self.per_profile.get(profile, 1.0)

    def to_doc(self) -> Dict:
        return {
            "sku": self.sku,
            "n_pairs": self.n_pairs,
            "per_arch": dict(sorted(self.per_arch.items())),
            "per_profile": dict(sorted(self.per_profile.items())),
        }


def _geomean(values: List[float]) -> float:
    return math.exp(sum(math.log(v) for v in values) / len(values))


def fit_residuals(
    pairs: Iterable[Tuple[str, str, float, float]], *, sku: str
) -> ResidualFit:
    """Fit per-arch and per-profile corrections from ``(arch, profile,
    measured_s, predicted_s)`` pairs.

    Two-stage geometric-mean factorization: the per-arch scale absorbs
    each architecture's systematic bias (the wrong ``busy_s`` constant),
    then the per-profile residual absorbs what is left — the slice-level
    MISO residual shared across archs. Non-positive pairs are skipped."""
    clean = sorted(
        (a, p, m / pr)
        for a, p, m, pr in pairs
        if m > 0.0 and pr > 0.0
    )
    if not clean:
        return ResidualFit(sku=sku, per_arch={}, per_profile={}, n_pairs=0)
    by_arch: Dict[str, List[float]] = {}
    for arch, _, ratio in clean:
        by_arch.setdefault(arch, []).append(ratio)
    per_arch = {arch: _geomean(rs) for arch, rs in sorted(by_arch.items())}
    by_prof: Dict[str, List[float]] = {}
    for arch, prof, ratio in clean:
        by_prof.setdefault(prof, []).append(ratio / per_arch[arch])
    per_profile = {p: _geomean(rs) for p, rs in sorted(by_prof.items())}
    return ResidualFit(
        sku=sku, per_arch=per_arch, per_profile=per_profile, n_pairs=len(clean)
    )


def with_profile_interpolation(
    fit: ResidualFit, profile_fracs: Mapping[str, float]
) -> ResidualFit:
    """Fill per-profile corrections for *unmeasured* profiles by
    log-linear interpolation over the slice fraction.

    The MISO residual is smooth in how much of the device a slice is
    (``mem_units / n_units``): measuring the endpoints (full device +
    smallest slice) pins the curve, and every profile in between gets the
    interpolated residual instead of the no-evidence 1.0. Fractions
    outside the measured range clamp to the nearest endpoint."""
    known = sorted(
        (profile_fracs[p], r)
        for p, r in fit.per_profile.items()
        if p in profile_fracs and r > 0.0
    )
    if len(known) < 2:
        return fit
    fracs = [f for f, _ in known]
    logs = [math.log(r) for _, r in known]
    filled = dict(fit.per_profile)
    for prof, frac in sorted(profile_fracs.items()):
        if prof in filled:
            continue
        if frac <= fracs[0]:
            filled[prof] = math.exp(logs[0])
            continue
        if frac >= fracs[-1]:
            filled[prof] = math.exp(logs[-1])
            continue
        for i in range(1, len(fracs)):
            if frac <= fracs[i]:
                w = (frac - fracs[i - 1]) / (fracs[i] - fracs[i - 1])
                filled[prof] = math.exp(
                    logs[i - 1] * (1.0 - w) + logs[i] * w
                )
                break
    return dataclasses.replace(fit, per_profile=filled)


def fit_from_error_doc(doc: Mapping, *, sku: str) -> ResidualFit:
    """Fit residuals from a ``calib_step_error/v1`` document (the
    ``report.py trace --format json`` output) — the satellite contract:
    the harness consumes the report's table instead of re-deriving it."""
    if doc.get("schema") != ERROR_SCHEMA:
        raise ValueError(
            f"not a {ERROR_SCHEMA} document: schema={doc.get('schema')!r}"
        )
    return fit_residuals(
        (
            (row["arch"], row["profile"], row["measured_s"], row["predicted_s"])
            for row in doc.get("rows", ())
        ),
        sku=sku,
    )


# -- DB refinement + evaluation ---------------------------------------------


def refine_record(rec: CharRecord, corr: float) -> CharRecord:
    """Apply a multiplicative correction to a record's busy terms (the
    host-side latency residual of the step does not scale with the
    device, so it carries over unchanged — same convention as
    ``predict_record``)."""
    busy = max(rec.compute_s, rec.memory_s, rec.collective_s)
    residual = max(0.0, rec.step_s - busy)
    return dataclasses.replace(
        rec,
        step_s=busy * corr + residual,
        compute_s=rec.compute_s * corr,
        memory_s=rec.memory_s * corr,
        collective_s=rec.collective_s * corr,
        provenance="refined",
        source="fit",
    )


def refine_db(seed: CharDB, fit: ResidualFit) -> CharDB:
    """Seed DB with every non-measured entry corrected by the fit.

    Measured entries pass through untouched (a fit can never overwrite a
    measurement); everything else becomes ``refined``."""
    out = CharDB(seed.sku, seed=seed.seed)
    for key in sorted(seed.records):
        rec = seed.records[key]
        if rec.provenance == "measured" and rec.n_samples > 0:
            out.add(rec)
            continue
        corr = fit.correction(rec.arch, rec.profile)
        out.add(refine_record(rec, corr) if corr != 1.0 else rec)
    return out


def evaluate_db(
    db: CharDB,
    truth_step_s: Callable[[CharKey], float],
    *,
    keys: Optional[Iterable[CharKey]] = None,
) -> Dict:
    """Mean |relative step error| of ``db`` against a ground-truth oracle
    (a calibration backend's true step time per key). Returns the summary
    plus per-(arch, profile) rows — the ``report.py calibrate`` table."""
    use = sorted(keys) if keys is not None else sorted(db.records)
    rows = []
    errs = []
    for key in use:
        rec = db.records.get(key)
        if rec is None or rec.step_s <= 0.0:
            continue
        true = truth_step_s(key)
        if true <= 0.0:
            continue
        err = abs(rec.step_s - true) / true
        errs.append(err)
        rows.append(
            {
                "arch": key[0],
                "shape": key[1],
                "profile": key[2],
                "predicted_s": rec.step_s,
                "true_s": true,
                "rel_err": err,
                "provenance": rec.provenance,
            }
        )
    return {
        "n": len(errs),
        "mean_abs_rel_err": sum(errs) / len(errs) if errs else 0.0,
        "max_abs_rel_err": max(errs) if errs else 0.0,
        "rows": rows,
    }
