"""Versioned characterization records with provenance.

The whole decision stack — greedy packing, the planner cost model, shared-
mode contention, the cluster's completion clocks — prices jobs off
characterization records keyed ``(arch, shape, profile)``. Until this
module those records were bare dicts with no history: a hand-extrapolated
H100 constant looked exactly like a number measured on hardware, and
nothing downstream could tell the difference (the ROADMAP's "extrapolated
constants with no measurement path behind them").

A :class:`CharRecord` is the same record made accountable: the numeric
fields the schedulers read, plus *provenance* — where the number came
from — and the measurement metadata (backend, sample count) when there is
any. A :class:`CharDB` is one SKU's set of records as a versioned,
JSON-round-trippable document (``calib_char_db/v1``), convertible to and
from the plain ``{(arch, shape, profile): dict}`` mapping every existing
consumer takes, so calibration composes with the scheduler stack without
touching its call signatures.

Provenance states (ordered weakest to strongest trust):

  ``extrapolated``  hand-seeded analytic constants (the synthetic catalog;
                    every pre-calibration DB loads as this);
  ``predicted``     derived from a *measured* full-device record by the
                    MISO-style slice scaling (core/planner/costmodel
                    ``predict_record``) — one real measurement priced the
                    slice, but the slice itself was never run;
  ``refined``       an extrapolated record corrected by fitted residuals
                    (core/calib/fit) or online EWMA corrections — better
                    than the seed, still not a measurement;
  ``measured``      a calibration backend actually ran the (arch, shape,
                    slice) cell (core/calib/harness) — MIGPerf's
                    per-(model, slice) ground truth.

``merge`` prefers stronger provenance at equal keys, so re-running a
partial calibration can only upgrade a DB, never silently downgrade a
measured entry back to a guess. Everything here is jax-free stdlib.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, Mapping, Optional, Tuple

CharKey = Tuple[str, str, str]  # (arch, shape/suite, profile)

SCHEMA = "calib_char_db/v1"

#: Legal provenance states, weakest trust first — ``merge`` keeps the
#: entry whose provenance ranks higher at an equal key.
PROVENANCES: Tuple[str, ...] = (
    "extrapolated",
    "refined",
    "predicted",
    "measured",
)
_RANK = {p: i for i, p in enumerate(PROVENANCES)}

#: What the hand-seeded synthetic catalogs (launch/simulate.py) are worth
#: per SKU: the paper measured the A100-40GB — its catalog terms are
#: anchored to those numbers — while every other generation's entries are
#: scaled constants with no measurement path behind them.
SEED_PROVENANCE: Dict[str, str] = {
    "a100-40gb": "measured",
    "a100-80gb": "extrapolated",
    "h100-80gb": "extrapolated",
    "a30-24gb": "extrapolated",
}
DEFAULT_SEED_PROVENANCE = "extrapolated"


def seed_provenance(sku_name: str) -> str:
    """Provenance of a SKU's hand-seeded catalog entries."""
    return SEED_PROVENANCE.get(sku_name, DEFAULT_SEED_PROVENANCE)


@dataclasses.dataclass(frozen=True)
class CharRecord:
    """One (arch, shape, profile) characterization entry with provenance."""

    arch: str
    shape: str  # suite name
    profile: str
    step_s: float
    compute_s: float
    memory_s: float
    collective_s: float
    peak_bytes_per_device: float
    fits: bool
    provenance: str = "extrapolated"
    source: str = ""  # backend / "seed" / "fit" — where the number came from
    n_samples: int = 0  # measurement repetitions (0 for analytic entries)

    def __post_init__(self) -> None:
        if self.provenance not in _RANK:
            raise ValueError(
                f"unknown provenance {self.provenance!r}; "
                f"choose from {PROVENANCES}"
            )

    @property
    def key(self) -> CharKey:
        return (self.arch, self.shape, self.profile)

    def to_entry(self) -> Dict:
        """The scheduler-facing record dict (collocation / planner /
        cluster all read these keys; extra keys are inert to them)."""
        return {
            "fits": self.fits,
            "step_s": self.step_s,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "peak_bytes_per_device": self.peak_bytes_per_device,
            "provenance": self.provenance,
        }

    @classmethod
    def from_entry(
        cls,
        key: CharKey,
        rec: Mapping,
        *,
        provenance: Optional[str] = None,
        source: str = "",
        n_samples: int = 0,
    ) -> "CharRecord":
        """Wrap a plain record dict. ``provenance`` overrides; otherwise
        the record's own ``provenance`` key wins, falling back to
        ``extrapolated`` — the hand-seeded default the tentpole pins."""
        arch, shape, profile = key
        step = float(rec.get("step_s", 0.0))
        return cls(
            arch=arch,
            shape=shape,
            profile=profile,
            step_s=step,
            compute_s=float(rec.get("compute_s", step)),
            memory_s=float(rec.get("memory_s", 0.0)),
            collective_s=float(rec.get("collective_s", 0.0)),
            peak_bytes_per_device=float(rec.get("peak_bytes_per_device", 0.0)),
            fits=bool(rec.get("fits", False)),
            provenance=(
                provenance
                if provenance is not None
                else str(rec.get("provenance", DEFAULT_SEED_PROVENANCE))
            ),
            source=source,
            n_samples=int(n_samples),
        )


class CharDB:
    """One SKU's characterization records as a versioned document.

    Mutably accumulates records (``add`` / ``merge``); converts losslessly
    to/from JSON (``to_doc``/``from_doc``/``dumps``/``loads``) and down to
    the plain mapping the scheduler stack consumes (``to_plain_db``).
    """

    def __init__(
        self,
        sku: str,
        records: Optional[Iterable[CharRecord]] = None,
        *,
        seed: Optional[int] = None,
    ) -> None:
        self.sku = sku
        self.seed = seed
        self.records: Dict[CharKey, CharRecord] = {}
        for rec in records or ():
            self.records[rec.key] = rec

    # -- construction ---------------------------------------------------

    @classmethod
    def from_plain_db(
        cls,
        db: Mapping[CharKey, Mapping],
        *,
        sku: str,
        provenance: Optional[str] = None,
        source: str = "seed",
        seed: Optional[int] = None,
    ) -> "CharDB":
        """Load an existing hand-seeded ``{key: dict}`` DB. Entries keep
        their own ``provenance`` key when present; bare entries load as
        ``extrapolated`` unless ``provenance`` overrides."""
        out = cls(sku, seed=seed)
        for key in sorted(db):
            out.records[key] = CharRecord.from_entry(
                key, db[key], provenance=provenance, source=source
            )
        return out

    # -- mutation -------------------------------------------------------

    def add(self, rec: CharRecord) -> None:
        self.records[rec.key] = rec

    def merge(self, records: Iterable[CharRecord]) -> int:
        """Fold ``records`` in, keeping the stronger provenance at equal
        keys (ties go to the incoming record — fresher data). Returns how
        many entries changed."""
        changed = 0
        for rec in records:
            cur = self.records.get(rec.key)
            if cur is not None and _RANK[cur.provenance] > _RANK[rec.provenance]:
                continue
            if cur != rec:
                changed += 1
            self.records[rec.key] = rec
        return changed

    # -- views ----------------------------------------------------------

    def to_plain_db(self) -> Dict[CharKey, Dict]:
        """The ``{(arch, shape, profile): dict}`` mapping every scheduler
        consumer takes (CollocationScheduler / PlanningCostModel /
        Cluster)."""
        return {key: rec.to_entry() for key, rec in sorted(self.records.items())}

    def provenance_counts(self) -> Dict[str, int]:
        counts = {p: 0 for p in PROVENANCES}
        for rec in self.records.values():
            counts[rec.provenance] += 1
        return {p: n for p, n in counts.items() if n}

    def __len__(self) -> int:
        return len(self.records)

    def __eq__(self, other: object) -> bool:
        return (
            isinstance(other, CharDB)
            and self.sku == other.sku
            and self.seed == other.seed
            and self.records == other.records
        )

    # -- serialization --------------------------------------------------

    def to_doc(self) -> Dict:
        """Versioned JSON-ready document; records sorted by key so equal
        DBs serialize byte-identically."""
        return {
            "schema": SCHEMA,
            "sku": self.sku,
            "seed": self.seed,
            "records": [
                dataclasses.asdict(rec)
                for _, rec in sorted(self.records.items())
            ],
        }

    @classmethod
    def from_doc(cls, doc: Mapping) -> "CharDB":
        if doc.get("schema") != SCHEMA:
            raise ValueError(
                f"not a {SCHEMA} document: schema={doc.get('schema')!r}"
            )
        return cls(
            str(doc["sku"]),
            (CharRecord(**rec) for rec in doc.get("records", ())),
            seed=doc.get("seed"),
        )

    def dumps(self) -> str:
        return json.dumps(self.to_doc(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def loads(cls, text: str) -> "CharDB":
        return cls.from_doc(json.loads(text))
