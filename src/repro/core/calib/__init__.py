"""core/calib: close the loop from measured kernels to the char DB.

Versioned provenance-carrying characterization records (records),
measurement backends + the calibration loop (harness), residual fitting
and DB refinement (fit), and live EWMA refinement off the cluster's step
samples (online). Jax-free; the kernel backend imports jax lazily.
"""
from repro.core.calib.fit import (
    ERROR_SCHEMA,
    ResidualFit,
    evaluate_db,
    fit_from_error_doc,
    fit_residuals,
    refine_db,
    refine_record,
    step_error_doc,
    step_error_rows,
    with_profile_interpolation,
)
from repro.core.calib.harness import (
    BACKENDS,
    CalibrationResult,
    KernelBackend,
    Observation,
    StubBackend,
    calibration_report,
    make_backend,
    miso_probe_keys,
    run_calibration,
)
from repro.core.calib.online import OnlineCalibrator
from repro.core.calib.records import (
    PROVENANCES,
    SCHEMA,
    CharDB,
    CharKey,
    CharRecord,
    seed_provenance,
)

__all__ = [
    "BACKENDS",
    "ERROR_SCHEMA",
    "PROVENANCES",
    "SCHEMA",
    "CalibrationResult",
    "CharDB",
    "CharKey",
    "CharRecord",
    "KernelBackend",
    "Observation",
    "OnlineCalibrator",
    "ResidualFit",
    "StubBackend",
    "calibration_report",
    "evaluate_db",
    "fit_from_error_doc",
    "fit_residuals",
    "make_backend",
    "miso_probe_keys",
    "refine_db",
    "refine_record",
    "run_calibration",
    "seed_provenance",
    "step_error_doc",
    "step_error_rows",
    "with_profile_interpolation",
]
