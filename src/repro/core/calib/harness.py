"""Calibration harness: measure (arch, shape, slice) cells, regenerate records.

MIGPerf's method made executable: characterize each workload per (model,
slice) by *running* it there, then let the measurements replace the
hand-seeded constants. The harness drives a pluggable backend per
(arch, shape, profile) key and folds the observations back into a
:class:`~repro.core.calib.records.CharDB`:

  ``StubBackend``    a deterministic seeded ground-truth oracle: it
                     perturbs the seed catalog with a systematic per-arch
                     scale, a smooth per-slice skew (the MISO residual),
                     and small per-key noise — all derived from SHA-256 of
                     the seed, so two runs are byte-identical and CI can
                     exercise the *entire* pipeline (measure -> fit ->
                     refine -> evaluate) with no accelerator;
  ``KernelBackend``  the measured path: times the repo's Pallas kernels
                     through ``benchmarks/kernel_bench.py`` calibration
                     shapes — compiled on TPU, ``interpret=True`` on CPU
                     (wall-clock, so *not* byte-deterministic) — then
                     prices non-full slices from the measured full-device
                     observation MISO-style (``predict_record``), exactly
                     the one-measurement-prices-every-slice move.

``run_calibration`` is the loop: measure the plan's keys (by default the
MISO probe set — full device + smallest slice per (arch, shape)), fit
per-arch x per-slice residual corrections from the measured-vs-seed
ratios (core/calib/fit), refine every unmeasured seed entry, and return
the calibrated DB with full provenance. This module is jax-free; only
``KernelBackend.measure`` imports the kernel stack, lazily.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union

from repro.core.calib.fit import (
    ResidualFit,
    evaluate_db,
    fit_residuals,
    refine_db,
    with_profile_interpolation,
)
from repro.core.calib.records import CharDB, CharKey, CharRecord
from repro.core.device import DeviceSKU, get_sku


def _unit(*tag: object) -> float:
    """Deterministic uniform in [0, 1) from a stable hash of ``tag`` —
    byte-identical across processes and platforms (unlike ``hash()``,
    which is salted per interpreter)."""
    digest = hashlib.sha256("|".join(str(t) for t in tag).encode()).digest()
    return int.from_bytes(digest[:8], "big") / 2**64


@dataclasses.dataclass(frozen=True)
class Observation:
    """One backend measurement of an (arch, shape, profile) cell."""

    arch: str
    shape: str
    profile: str
    step_s: float
    compute_s: float
    memory_s: float
    collective_s: float
    peak_bytes_per_device: float
    fits: bool
    n_samples: int
    backend: str
    provenance: str = "measured"

    @property
    def key(self) -> CharKey:
        return (self.arch, self.shape, self.profile)

    def to_record(self) -> CharRecord:
        return CharRecord(
            arch=self.arch,
            shape=self.shape,
            profile=self.profile,
            step_s=self.step_s,
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            peak_bytes_per_device=self.peak_bytes_per_device,
            fits=self.fits,
            provenance=self.provenance,
            source=self.backend,
            n_samples=self.n_samples,
        )


class StubBackend:
    """Seeded deterministic ground truth over a seed catalog.

    The "hardware" this backend pretends to be differs from the seed
    catalog by exactly the error modes calibration must recover:

    - a per-arch systematic scale in [0.8, 1.25) — the wrong ``busy_s``
      constant for that architecture;
    - a smooth per-slice skew ``1 + gamma * (1 - frac)`` shared across
      archs (``gamma`` in [-0.15, 0.25) per seed) — sub-linear slice
      scaling the analytic inverse-fraction model misses (the paper's F1
      is exactly such a curve);
    - per-key multiplicative noise within ±1.5% — measurement jitter, the
      floor calibrated error converges to.

    Peak memory and ``fits`` verdicts pass through unchanged: the stub
    models timing error, not admission error.
    """

    name = "stub"

    def __init__(
        self,
        seed_db: Mapping[CharKey, Mapping],
        *,
        sku: Union[None, str, DeviceSKU] = None,
        seed: int = 0,
        n_samples: int = 3,
    ) -> None:
        self.seed_db = seed_db
        self.sku = get_sku(sku)
        self.seed = int(seed)
        self.n_samples = int(n_samples)
        self._gamma = -0.15 + 0.4 * _unit(self.seed, "slice-skew")

    def _scales(self, arch: str, shape: str, profile: str) -> float:
        frac = self.sku.profile(profile).mem_units / self.sku.n_units
        arch_scale = 0.8 + 0.45 * _unit(self.seed, "arch", arch)
        skew = 1.0 + self._gamma * (1.0 - frac)
        noise = 1.0 + 0.03 * (_unit(self.seed, "noise", arch, shape, profile) - 0.5)
        return arch_scale * skew * noise

    def true_record(self, key: CharKey) -> Dict:
        """What the pretend hardware would actually report for ``key``."""
        arch, shape, profile = key
        rec = self.seed_db[key]
        scale = self._scales(arch, shape, profile)
        compute = float(rec.get("compute_s", rec.get("step_s", 0.0))) * scale
        memory = float(rec.get("memory_s", 0.0)) * scale
        collective = float(rec.get("collective_s", 0.0)) * scale
        seed_busy = max(
            float(rec.get("compute_s", 0.0)),
            float(rec.get("memory_s", 0.0)),
            float(rec.get("collective_s", 0.0)),
        )
        residual = max(0.0, float(rec.get("step_s", 0.0)) - seed_busy)
        return {
            "fits": bool(rec.get("fits", False)),
            "step_s": max(compute, memory, collective) + residual,
            "compute_s": compute,
            "memory_s": memory,
            "collective_s": collective,
            "peak_bytes_per_device": float(rec.get("peak_bytes_per_device", 0.0)),
        }

    def true_step_s(self, key: CharKey) -> float:
        """Ground-truth oracle for ``evaluate_db``."""
        return float(self.true_record(key)["step_s"])

    def measure(self, arch: str, shape: str, profile: str) -> Observation:
        rec = self.true_record((arch, shape, profile))
        return Observation(
            arch=arch,
            shape=shape,
            profile=profile,
            step_s=rec["step_s"],
            compute_s=rec["compute_s"],
            memory_s=rec["memory_s"],
            collective_s=rec["collective_s"],
            peak_bytes_per_device=rec["peak_bytes_per_device"],
            fits=rec["fits"],
            n_samples=self.n_samples,
            backend=self.name,
        )


class KernelBackend:
    """Measured path: time the Pallas kernels at the calibration shapes.

    Full-device cells are wall-clock measurements of the arch's kernel
    family (``benchmarks/kernel_bench.py`` maps archs to kernels and owns
    the shapes — compiled Pallas on TPU, ``interpret=True`` elsewhere, so
    the pipeline runs end to end in CI without a GPU). Non-full slices
    are then priced from the arch's *measured* full-device observation by
    the planner's MISO scaling (``predict_record``) and stamped
    ``predicted`` — one real measurement prices the whole tree, which is
    the MISO result this repo leans on. Absolute CPU wall times are not
    GPU step times; what the measured path calibrates in CI is the
    *pipeline* (provenance, fitting, serialization), with the numbers
    becoming meaningful on real accelerator runs.
    """

    name = "kernels"

    def __init__(
        self,
        seed_db: Mapping[CharKey, Mapping],
        *,
        sku: Union[None, str, DeviceSKU] = None,
        n_samples: int = 2,
    ) -> None:
        self.seed_db = seed_db
        self.sku = get_sku(sku)
        self.n_samples = int(n_samples)
        self._full_cache: Dict[Tuple[str, str], Dict] = {}

    @staticmethod
    def available() -> bool:
        try:
            import jax  # noqa: F401
            import benchmarks.kernel_bench  # noqa: F401
        except ImportError:
            return False
        return True

    def _measure_full(self, arch: str, shape: str) -> Dict:
        key = (arch, shape)
        if key not in self._full_cache:
            from benchmarks.kernel_bench import measure_calibration_kernel

            meas = measure_calibration_kernel(arch, n=self.n_samples)
            rec = dict(self.seed_db[(arch, shape, self.sku.full_profile)])
            # the kernel's wall time *is* the measured compute term; the
            # seed's memory/collective proportions ride along so the record
            # stays phase-complete (workload demand vectors scale them)
            seed_c = float(rec.get("compute_s", rec.get("step_s", 1.0))) or 1.0
            ratio = meas["wall_s"] / seed_c
            rec["compute_s"] = meas["wall_s"]
            rec["memory_s"] = float(rec.get("memory_s", 0.0)) * ratio
            rec["collective_s"] = float(rec.get("collective_s", 0.0)) * ratio
            busy = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
            rec["step_s"] = busy + self.sku.step_latency_s
            rec["max_err_vs_ref"] = meas["max_err_vs_ref"]
            self._full_cache[key] = rec
        return self._full_cache[key]

    def measure(self, arch: str, shape: str, profile: str) -> Observation:
        from repro.core.planner.costmodel import predict_record

        full = self._measure_full(arch, shape)
        if profile == self.sku.full_profile:
            rec, provenance = full, "measured"
        else:
            rec = predict_record(full, profile, sku=self.sku)
            rec["fits"] = bool(
                self.seed_db.get((arch, shape, profile), {}).get("fits", False)
            )
            provenance = "predicted"
        return Observation(
            arch=arch,
            shape=shape,
            profile=profile,
            step_s=float(rec["step_s"]),
            compute_s=float(rec["compute_s"]),
            memory_s=float(rec["memory_s"]),
            collective_s=float(rec["collective_s"]),
            peak_bytes_per_device=float(rec["peak_bytes_per_device"]),
            fits=bool(rec["fits"]),
            n_samples=self.n_samples,
            backend=self.name,
            provenance=provenance,
        )


BACKENDS = ("stub", "kernels")


def make_backend(
    name: str,
    seed_db: Mapping[CharKey, Mapping],
    *,
    sku: Union[None, str, DeviceSKU] = None,
    seed: int = 0,
):
    if name == "stub":
        return StubBackend(seed_db, sku=sku, seed=seed)
    if name == "kernels":
        if not KernelBackend.available():
            raise RuntimeError(
                "the kernels backend needs jax and benchmarks/ importable; "
                "use --backend stub (the deterministic CI path)"
            )
        return KernelBackend(seed_db, sku=sku)
    raise ValueError(f"unknown backend {name!r}; choose from {BACKENDS}")


# -- the calibration loop ---------------------------------------------------


def miso_probe_keys(
    seed_db: Mapping[CharKey, Mapping],
    sku: Union[None, str, DeviceSKU] = None,
) -> Tuple[CharKey, ...]:
    """The default measurement plan: per (arch, shape), the full-device
    profile plus the smallest slice — the two endpoints that pin the
    slice-residual curve (MISO measures the full device; MIGPerf says the
    endpoints differ most). Keys the seed DB does not know are skipped."""
    dev = get_sku(sku)
    order = dev.profile_order  # smallest first
    probes = (order[0], dev.full_profile)
    keys = []
    for arch, shape in sorted({(a, s) for a, s, _ in seed_db}):
        for prof in dict.fromkeys(probes):
            if (arch, shape, prof) in seed_db:
                keys.append((arch, shape, prof))
    return tuple(keys)


@dataclasses.dataclass
class CalibrationResult:
    """Everything one calibration pass produced."""

    sku: str
    backend: str
    seed_db: CharDB
    calibrated: CharDB
    fit: ResidualFit
    observations: List[Observation]
    measured_keys: Tuple[CharKey, ...]

    def summary(self) -> Dict:
        return {
            "sku": self.sku,
            "backend": self.backend,
            "n_keys": len(self.calibrated),
            "n_measured": len(self.measured_keys),
            "provenance": self.calibrated.provenance_counts(),
            "fit": self.fit.to_doc(),
        }


def run_calibration(
    seed_db: Mapping[CharKey, Mapping],
    backend,
    *,
    sku: Union[None, str, DeviceSKU] = None,
    seed: Optional[int] = None,
    plan: Optional[Sequence[CharKey]] = None,
    seed_provenance: Optional[str] = None,
) -> CalibrationResult:
    """One full calibration pass: measure -> fit -> refine -> merge.

    ``seed_db`` is the hand-seeded plain mapping (loads as
    ``extrapolated`` unless entries carry their own provenance or
    ``seed_provenance`` overrides); ``plan`` defaults to the MISO probe
    set. The returned DB has ``measured`` entries at plan keys (or
    ``predicted`` where the backend itself derived the slice), ``refined``
    entries where the fit corrected an extrapolation, and untouched seed
    entries where there was no evidence to apply."""
    dev = get_sku(sku)
    seed_doc = CharDB.from_plain_db(
        seed_db, sku=dev.name, provenance=seed_provenance, seed=seed
    )
    keys = tuple(plan) if plan is not None else miso_probe_keys(seed_db, dev)
    observations = [backend.measure(*key) for key in keys]
    fit = fit_residuals(
        (
            (o.arch, o.profile, o.step_s, float(seed_db[o.key]["step_s"]))
            for o in observations
            if o.key in seed_db
        ),
        sku=dev.name,
    )
    fit = with_profile_interpolation(
        fit,
        {p.name: p.mem_units / dev.n_units for p in dev.profiles},
    )
    calibrated = refine_db(seed_doc, fit)
    calibrated.merge(o.to_record() for o in observations)
    return CalibrationResult(
        sku=dev.name,
        backend=backend.name,
        seed_db=seed_doc,
        calibrated=calibrated,
        fit=fit,
        observations=observations,
        measured_keys=keys,
    )


def calibration_report(
    result: CalibrationResult, truth_step_s
) -> Dict:
    """Seed-vs-calibrated error scorecard against a ground-truth oracle
    (``StubBackend.true_step_s`` in CI; a real backend's re-measurement
    pass on hardware). The acceptance inequality lives here: calibrated
    mean error strictly below seed mean error."""
    seed_eval = evaluate_db(result.seed_db, truth_step_s)
    calib_eval = evaluate_db(result.calibrated, truth_step_s)
    return {
        "sku": result.sku,
        "backend": result.backend,
        "n_keys": seed_eval["n"],
        "n_measured": len(result.measured_keys),
        "seed_mean_abs_rel_err": seed_eval["mean_abs_rel_err"],
        "calibrated_mean_abs_rel_err": calib_eval["mean_abs_rel_err"],
        "seed_max_abs_rel_err": seed_eval["max_abs_rel_err"],
        "calibrated_max_abs_rel_err": calib_eval["max_abs_rel_err"],
        "error_reduction": (
            1.0
            - calib_eval["mean_abs_rel_err"] / seed_eval["mean_abs_rel_err"]
            if seed_eval["mean_abs_rel_err"] > 0.0
            else 0.0
        ),
        "provenance": result.calibrated.provenance_counts(),
    }
