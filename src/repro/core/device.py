"""Device-model API: first-class GPU SKU descriptors for the whole stack.

The paper measures one device — an A100-40GB — and until this module that
device was baked into the codebase as module-level globals (``PROFILES`` /
``N_UNITS`` / ``EXCLUSIONS`` in core/profiles.py, ``HBM_PER_CHIP`` in
telemetry/constants.py). A ``DeviceSKU`` makes the hardware an explicit
value instead: the slice-unit count, usable compute slices, per-slice HBM
budget, the placement tree of :class:`InstanceProfile` s, the documented
exclusion pairs, and the shared-mode knobs (dispatch-latency floor, naive
switch overhead, reconfiguration cost) all travel together, so the
scheduler, planner, sharing models, and cluster can be instantiated per
GPU generation — and a single fleet can mix generations.

Why it matters for the paper's question: MIGPerf (Zhang et al., 2023)
measures MIG behaviour differing materially across A100/A30-class parts
(different slice counts, different memory-per-slice, different
latency floors), and Flex-MIG-style fleets reason about MIG across
heterogeneous multi-tenant clusters. Whether collocation wins — and in
which mode — is a function of the *device model*, not a universal
constant; this module is the axis those questions are asked along.

Registry (``SKUS``):

  a100-40gb   the paper's device and the **default** — byte-identical
              behaviour to the old module globals (same tree, same 4g+3g
              exclusion, same 7-of-8 compute budget, same budgets);
  a100-80gb   the same placement tree with doubled per-slice memory
              (NVIDIA's 1g.10gb ... 7g.80gb ladder);
  h100-80gb   the Hopper tree — adds the double-width-memory ``1g.20gb``
              profile and a lower dispatch-latency floor / reconfig cost;
  a30-24gb    the 4-slice part (1g.6gb / 2g.12gb / 4g.24gb): MIGPerf's
              evidence that slice algebra is per-SKU, not per-architecture.

Memory currency. The TPU adaptation (core/partitioner.py) gives every chip
the same HBM, so a slice's budget is expressed *per chip*:
``DeviceSKU.slice_bytes`` is the per-chip HBM budget a job sees on any
slice of the SKU, with the A100-40GB pinned to the v5e 16 GiB baseline
(``telemetry.constants.HBM_PER_CHIP``) and other SKUs scaled by their real
memory-per-slice ratio (A100-80GB/H100: 10 GB vs 5 GB per slice -> 2x;
A30: 6 GB vs 5 GB -> 1.2x). Characterization records store per-chip peaks,
so admission is always ``peak_bytes_per_device <= sku.slice_bytes``.

Import discipline: this module sits below the scheduling stack (profiles,
planner, collocation, cluster import it — never the reverse; its only
core dependency is sharing.py's model constants, which imports nothing
back) and is jax-free.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple, Union

# sharing.py is the authority for the shared-mode *model* and its baseline
# constants; the SKU carries the per-device values threaded into it. It
# imports nothing from this module, so aliasing is cycle-free — a model
# recalibration there cannot silently diverge from the SKU defaults here.
from repro.core.sharing import NAIVE_SWITCH_OVERHEAD_FRAC, STEP_LATENCY_S
from repro.telemetry.constants import HBM_PER_CHIP

#: Baseline live re-partitioning downtime (drain + MIG destroy/create +
#: daemon restart). core/cluster.py's DEFAULT_RECONFIG_COST_S aliases this;
#: per-SKU values scale relative to it (see Cluster._device_reconfig_cost).
DEFAULT_RECONFIG_COST_S = 2.0


def format_gib(nbytes: float) -> str:
    """The one GiB formatter admission/rejection messages use, so the
    printed budget can never drift from the budget actually enforced."""
    return f"{nbytes / 2**30:.1f}"


@dataclasses.dataclass(frozen=True)
class InstanceProfile:
    """One MIG profile mapped to pod slice units."""

    name: str  # canonical MIG name, kept vendor-faithful
    compute_slices: int  # scales the analytical compute roof
    mem_units: int  # placement span in slice units
    starts: Tuple[int, ...]  # allowed start offsets (placement tree)

    @property
    def max_instances(self) -> int:
        return len(self.starts)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A profile instance at a slice-unit offset. SKU-agnostic data — the
    (profile, start) pair; geometry comes from the SKU that owns it."""

    profile: str
    start: int  # slice-unit offset

    @property
    def span(self) -> Tuple[int, int]:
        # default-SKU shim (the old ``profiles.Placement.span`` behaviour);
        # SKU-aware code uses ``sku.span(placement)`` instead.
        return DEFAULT_SKU.span(self)


@dataclasses.dataclass(frozen=True)
class DeviceSKU:
    """Frozen descriptor of one GPU generation's partitioning model.

    Hashable (all fields are), so enumeration memos (core/planner) and
    cost-model caches can key per SKU.
    """

    name: str
    n_units: int  # memory slice units (placement granularity)
    n_compute_slices: int  # usable compute slices when partitioned
    # per-chip HBM budget (model currency) of any slice of this SKU —
    # see the module docstring for the cross-SKU scaling convention
    slice_bytes: int
    profiles: Tuple[InstanceProfile, ...]  # the placement tree
    # vendor-documented invalid profile combinations (A100: 4g+3g)
    exclusions: Tuple[FrozenSet[str], ...] = ()
    full_profile: str = ""  # the profile shared modes (naive/MPS) run on
    # shared-mode knobs: per-step host dispatch + sync latency floor, and
    # the per-quantum switch penalty of naive time-slicing
    step_latency_s: float = STEP_LATENCY_S
    naive_switch_overhead_frac: float = NAIVE_SWITCH_OVERHEAD_FRAC
    # live re-partitioning downtime (MIG destroy/create + daemon restart);
    # the cluster charges its configured cost scaled by this value's ratio
    # to the baseline, so the operator flag and the SKU knob compose
    reconfig_cost_s: float = DEFAULT_RECONFIG_COST_S
    # per-slice-unit compute speed relative to the A100 baseline — the
    # analytic characterization (launch/simulate.py) divides busy terms by
    # it. Capacity differences (A30's 4 units vs 8) are expressed by the
    # tree itself; this is the *generation* speedup (H100's fatter MXUs).
    compute_scale: float = 1.0

    def __post_init__(self):
        names = [p.name for p in self.profiles]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate profile names {names}")
        if self.full_profile not in names:
            raise ValueError(
                f"{self.name}: full_profile {self.full_profile!r} not in tree"
            )
        by_name = {p.name: p for p in self.profiles}
        if by_name[self.full_profile].mem_units != self.n_units:
            raise ValueError(
                f"{self.name}: full profile must own all {self.n_units} units"
            )
        for p in self.profiles:
            for s in p.starts:
                if s < 0 or s + p.mem_units > self.n_units:
                    raise ValueError(
                        f"{self.name}: {p.name} start {s} overflows "
                        f"{self.n_units} units"
                    )

    # -- tree lookups ------------------------------------------------------

    @functools.cached_property
    def profiles_by_name(self) -> Dict[str, InstanceProfile]:
        """Name -> profile, in tree order (the old ``PROFILES`` shape)."""
        return {p.name: p for p in self.profiles}

    @functools.cached_property
    def profile_order(self) -> Tuple[str, ...]:
        """Smallest profile first — the paper's throughput-maximizing
        packing order (matches the old hand-written ``_PROFILE_ORDER``)."""
        return tuple(
            sorted(
                self.profiles_by_name,
                key=lambda n: (
                    self.profiles_by_name[n].mem_units,
                    self.profiles_by_name[n].compute_slices,
                    n,
                ),
            )
        )

    def profile(self, name: str) -> InstanceProfile:
        p = self.profiles_by_name.get(name)
        if p is None:
            raise KeyError(
                f"profile {name!r} is not in the {self.name} placement tree "
                f"(has: {', '.join(self.profiles_by_name)})"
            )
        return p

    # -- geometry ----------------------------------------------------------

    def span(self, pl: Placement) -> Tuple[int, int]:
        p = self.profile(pl.profile)
        return (pl.start, pl.start + p.mem_units)

    def units(self, pl: Placement) -> FrozenSet[int]:
        s0, s1 = self.span(pl)
        return frozenset(range(s0, s1))

    def compute_discount(self, profile: str, *, partitioned: bool = True) -> float:
        """F6 analytically: an instance owns ``compute_slices/n_units`` of
        the device's compute but ``mem_units/n_units`` of its chips."""
        if not partitioned:
            return 1.0  # non-MIG: the full device, no reserved slice
        p = self.profile(profile)
        return min(1.0, p.compute_slices / p.mem_units)

    def instance_hbm_bytes(self, profile: str, chips_per_unit: int) -> int:
        return self.profile(profile).mem_units * chips_per_unit * self.slice_bytes

    # -- layout algebra ----------------------------------------------------

    def validate_layout(
        self, placements: Sequence[Placement], *, partitioned: bool = True
    ) -> Tuple[bool, str]:
        """Check instance placements against this SKU's placement tree —
        the same algebra the old module-level ``profiles.validate_layout``
        enforced for the A100-40GB."""
        names = [pl.profile for pl in placements]
        for pl in placements:
            if pl.profile not in self.profiles_by_name:
                return False, f"unknown profile {pl.profile}"
            p = self.profiles_by_name[pl.profile]
            if pl.start not in p.starts:
                return False, f"{pl.profile} may not start at unit {pl.start}"
        spans = sorted(self.span(pl) for pl in placements)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            if b0 < a1:
                return False, f"overlapping spans {(a0, a1)} and {(b0, b1)}"
        # compute-slice budget (the MIG overhead slice is a *compute*
        # budget, not a blocked memory unit — F6 lives in the per-profile
        # compute discount)
        total_c = sum(self.profiles_by_name[n].compute_slices for n in names)
        if total_c > self.n_compute_slices:
            return False, f"compute slices {total_c} > {self.n_compute_slices}"
        for bad in self.exclusions:
            if bad <= set(names):
                return False, f"excluded combination {sorted(bad)}"
        return True, ""

    def homogeneous_layout(self, profile: str) -> List[Placement]:
        """The paper's 'parallel' device group: max instances of one profile."""
        p = self.profile(profile)
        placements = []
        occupied = 0
        for s in p.starts:
            if s >= occupied:
                placements.append(Placement(profile, s))
                occupied = s + p.mem_units
        return placements


# -- registry -------------------------------------------------------------------

SKUS: Dict[str, DeviceSKU] = {}


def register_sku(sku: DeviceSKU) -> DeviceSKU:
    if sku.name in SKUS:
        raise ValueError(f"SKU {sku.name!r} already registered")
    SKUS[sku.name] = sku
    return sku


def get_sku(sku: Union[None, str, DeviceSKU]) -> DeviceSKU:
    """Resolve a SKU argument: None -> default, name -> registry lookup."""
    if sku is None:
        return DEFAULT_SKU
    if isinstance(sku, DeviceSKU):
        return sku
    found = SKUS.get(sku)
    if found is None:
        raise KeyError(
            f"unknown device SKU {sku!r}; registered: {', '.join(SKUS)}"
        )
    return found


#: The paper's device — the default everywhere, byte-identical to the old
#: module globals (tree, exclusion, budgets, knobs).
A100_40GB = register_sku(
    DeviceSKU(
        name="a100-40gb",
        n_units=8,
        n_compute_slices=7,
        slice_bytes=HBM_PER_CHIP,  # the v5e 16 GiB per-chip baseline
        profiles=(
            InstanceProfile("1g.5gb", 1, 1, (0, 1, 2, 3, 4, 5, 6)),
            InstanceProfile("2g.10gb", 2, 2, (0, 2, 4)),
            InstanceProfile("3g.20gb", 3, 4, (0, 4)),
            InstanceProfile("4g.20gb", 4, 4, (0,)),
            InstanceProfile("7g.40gb", 7, 8, (0,)),
        ),
        exclusions=(frozenset({"4g.20gb", "3g.20gb"}),),
        full_profile="7g.40gb",
    )
)

#: Same placement tree as the A100-40GB, doubled per-slice memory — the
#: NVIDIA 1g.10gb ... 7g.80gb ladder. Big-memory jobs that OOM on every
#: 40GB slice fit here, which is what makes a mixed-generation fleet drain
#: a queue the 40GB part alone cannot.
A100_80GB = register_sku(
    DeviceSKU(
        name="a100-80gb",
        n_units=8,
        n_compute_slices=7,
        slice_bytes=2 * HBM_PER_CHIP,
        profiles=(
            InstanceProfile("1g.10gb", 1, 1, (0, 1, 2, 3, 4, 5, 6)),
            InstanceProfile("2g.20gb", 2, 2, (0, 2, 4)),
            InstanceProfile("3g.40gb", 3, 4, (0, 4)),
            InstanceProfile("4g.40gb", 4, 4, (0,)),
            InstanceProfile("7g.80gb", 7, 8, (0,)),
        ),
        exclusions=(frozenset({"4g.40gb", "3g.40gb"}),),
        full_profile="7g.80gb",
    )
)

#: Hopper: the A100-80GB ladder plus the double-width-memory 1g.20gb
#: profile (1 compute slice spanning 2 memory units), and a faster host
#: interface (lower dispatch-latency floor, cheaper reconfiguration).
H100_80GB = register_sku(
    DeviceSKU(
        name="h100-80gb",
        n_units=8,
        n_compute_slices=7,
        slice_bytes=2 * HBM_PER_CHIP,
        profiles=(
            InstanceProfile("1g.10gb", 1, 1, (0, 1, 2, 3, 4, 5, 6)),
            InstanceProfile("1g.20gb", 1, 2, (0, 2, 4, 6)),
            InstanceProfile("2g.20gb", 2, 2, (0, 2, 4)),
            InstanceProfile("3g.40gb", 3, 4, (0, 4)),
            InstanceProfile("4g.40gb", 4, 4, (0,)),
            InstanceProfile("7g.80gb", 7, 8, (0,)),
        ),
        exclusions=(frozenset({"4g.40gb", "3g.40gb"}),),
        full_profile="7g.80gb",
        step_latency_s=0.8e-3,
        reconfig_cost_s=1.5,
        compute_scale=2.0,
    )
)

#: The 4-slice part: 4 memory units, 4 compute slices, 6 GB per slice, no
#: documented exclusions, and no reserved compute slice (the full 4g.24gb
#: profile owns all four — A30 MIG pays no F6 tax in our algebra). MIGPerf
#: is the evidence that this tree behaves materially differently from the
#: A100's, which is exactly what a per-SKU device model exists to express.
A30_24GB = register_sku(
    DeviceSKU(
        name="a30-24gb",
        n_units=4,
        n_compute_slices=4,
        slice_bytes=(6 * HBM_PER_CHIP) // 5,  # 6 GB vs the A100's 5 GB slice
        profiles=(
            InstanceProfile("1g.6gb", 1, 1, (0, 1, 2, 3)),
            InstanceProfile("2g.12gb", 2, 2, (0, 2)),
            InstanceProfile("4g.24gb", 4, 4, (0,)),
        ),
        full_profile="4g.24gb",
    )
)

DEFAULT_SKU = A100_40GB
