"""Collocation scheduler: pack jobs onto MIG-profile instances.

The paper demonstrates *why* (3x throughput for sub-saturating workloads,
admission limits, no interference); this module is the *how* a production
cluster acts on it:

  * admission control — a job may only be placed on a profile whose
    per-device HBM budget covers the job's compiled peak memory (reproduces
    F5: medium/large OOM on 1g.5gb as a scheduler rejection, not a crash);
  * packing — smallest admissible profile first (maximizes instances per
    pod, which is the paper's throughput lever), widened to bigger
    profiles only when the small slots are exhausted;
  * layout search — candidate layouts come from the paper-faithful
    placement tree (core/profiles.py), scored by predicted aggregate
    throughput from the characterization DB;
  * straggler mitigation — per-job step-time EMA; a job drifting > tol
    above its predicted step time is marked for repack to a larger profile
    (isolation F3 guarantees repacking cannot hurt neighbours).

The characterization DB is a dict {(arch, shape, profile): record-dict}
produced by ``launch/collocate.py`` (compiled dry-runs per instance shape) —
the same artifact the paper builds by measuring 135 hours of runs, built
here in minutes analytically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.instance import JobSpec
from repro.core.profiles import (
    N_UNITS,
    PROFILES,
    Placement,
    homogeneous_layout,
    validate_layout,
)
from repro.telemetry.constants import HBM_PER_CHIP

CharKey = Tuple[str, str, str]  # (arch, shape, profile)


@dataclasses.dataclass
class Assignment:
    job: JobSpec
    placement: Placement
    predicted_step_s: float

    @property
    def profile(self) -> str:
        return self.placement.profile


@dataclasses.dataclass
class Rejection:
    job: JobSpec
    reason: str


@dataclasses.dataclass
class Schedule:
    assignments: List[Assignment]
    rejections: List[Rejection]

    @property
    def placements(self) -> List[Placement]:
        return [a.placement for a in self.assignments]

    def throughput(self) -> float:
        return sum(
            1.0 / a.predicted_step_s
            for a in self.assignments
            if a.predicted_step_s > 0
        )


# profile order: smallest first — the paper's throughput-maximizing choice
_PROFILE_ORDER = ("1g.5gb", "2g.10gb", "3g.20gb", "4g.20gb", "7g.40gb")


class CollocationScheduler:
    """Greedy DP-free packer over the MIG placement tree."""

    def __init__(
        self,
        char_db: Dict[CharKey, dict],
        *,
        chips_per_unit: int = 32,
        partitioned: bool = True,
        straggler_tol: float = 1.5,
        ema_alpha: float = 0.25,
    ):
        self.char_db = char_db
        self.chips_per_unit = chips_per_unit
        self.partitioned = partitioned
        self.straggler_tol = straggler_tol
        self.ema_alpha = ema_alpha
        self._ema: Dict[str, float] = {}
        self._predicted: Dict[str, float] = {}

    # -- admission ------------------------------------------------------------

    def admissible(self, job: JobSpec, profile: str) -> Tuple[bool, str]:
        rec = self.char_db.get((job.arch, job.suite.name, profile))
        if rec is None:
            return False, f"no characterization for {(job.arch, job.suite.name, profile)}"
        if not rec.get("fits", False):
            need = rec["peak_bytes_per_device"] / 2**30
            have = HBM_PER_CHIP / 2**30
            return False, (
                f"OOM: needs {need:.1f} GiB/chip > {have:.1f} GiB HBM on {profile}"
            )
        return True, ""

    def smallest_admissible(self, job: JobSpec) -> Optional[str]:
        for prof in _PROFILE_ORDER:
            ok, _ = self.admissible(job, prof)
            if ok:
                return prof
        return None

    # -- packing ----------------------------------------------------------------

    def schedule(
        self, jobs: Sequence[JobSpec], *, blocked_units: frozenset = frozenset()
    ) -> Schedule:
        """Greedy: sort by priority desc, give each its smallest admissible
        profile at the lowest free placement offset; upgrade to a larger
        profile only if the small ones are exhausted. ``blocked_units`` are
        unavailable slice units (failed hardware or surviving neighbours
        during an elastic repack)."""
        # (the MIG overhead slice is a *compute* budget — enforced by
        # validate_layout's 7-slice check — not a blocked memory unit)
        free = [True] * N_UNITS
        for u in blocked_units:
            free[u] = False
        assignments: List[Assignment] = []
        rejections: List[Rejection] = []

        def try_place(profile: str) -> Optional[Placement]:
            p = PROFILES[profile]
            for s in p.starts:
                span = range(s, s + p.mem_units)
                if profile == "7g.40gb":
                    span = range(0, N_UNITS)  # full-device profile owns all
                if all(free[u] for u in span):
                    ok, _ = validate_layout(
                        [Placement(a.profile, a.placement.start) for a in assignments]
                        + [Placement(profile, s)],
                        partitioned=self.partitioned,
                    )
                    if ok:
                        for u in span:
                            free[u] = False
                        return Placement(profile, s)
            return None

        for job in sorted(jobs, key=lambda j: -j.priority):
            placed = False
            start_prof = self.smallest_admissible(job)
            if start_prof is None:
                reasons = [
                    f"{p}: {self.admissible(job, p)[1]}" for p in _PROFILE_ORDER
                ]
                rejections.append(Rejection(job, "; ".join(reasons[:2])))
                continue
            for prof in _PROFILE_ORDER[_PROFILE_ORDER.index(start_prof):]:
                ok, _ = self.admissible(job, prof)
                if not ok:
                    continue
                pl = try_place(prof)
                if pl is not None:
                    rec = self.char_db[(job.arch, job.suite.name, prof)]
                    a = Assignment(job, pl, float(rec["step_s"]))
                    assignments.append(a)
                    self._predicted[job.name] = a.predicted_step_s
                    placed = True
                    break
            if not placed:
                rejections.append(Rejection(job, "no free placement slot"))
        return Schedule(assignments, rejections)

    # -- straggler mitigation -----------------------------------------------------

    def observe_step(self, job_name: str, step_s: float) -> None:
        prev = self._ema.get(job_name)
        self._ema[job_name] = (
            step_s if prev is None else (1 - self.ema_alpha) * prev + self.ema_alpha * step_s
        )

    def stragglers(self) -> List[str]:
        out = []
        for name, ema in self._ema.items():
            pred = self._predicted.get(name)
            if pred and ema > self.straggler_tol * pred:
                out.append(name)
        return out

    def repack_plan(self, schedule: Schedule) -> Dict[str, str]:
        """job -> larger-profile suggestion for flagged stragglers."""
        plan = {}
        for a in schedule.assignments:
            if a.job.name not in self.stragglers():
                continue
            bigger = _PROFILE_ORDER[
                min(_PROFILE_ORDER.index(a.profile) + 1, len(_PROFILE_ORDER) - 1)
            ]
            ok, _ = self.admissible(a.job, bigger)
            if ok and bigger != a.profile:
                plan[a.job.name] = bigger
        return plan


def paper_experiment_grid(workloads: Sequence[str], suite) -> List[Tuple[str, str, List[Placement]]]:
    """The paper's §3.4 run matrix: for each profile x workload, an isolated
    ('one') run and a max-instances homogeneous ('parallel') run, plus the
    non-MIG full-device baseline."""
    grid: List[Tuple[str, str, List[Placement]]] = []
    for w in workloads:
        for prof in _PROFILE_ORDER:
            grid.append((w, f"{prof} one", [Placement(prof, PROFILES[prof].starts[0])]))
            par = homogeneous_layout(prof)
            if len(par) > 1:
                grid.append((w, f"{prof} parallel", par))
        grid.append((w, "non-MIG", [Placement("7g.40gb", 0)]))
    return grid
