"""Collocation scheduler: place jobs under a collocation mode.

The paper demonstrates *why* (3x throughput for sub-saturating workloads,
admission limits, no interference); this module is the *how* a production
cluster acts on it. The scheduler is mode-aware (core/sharing.py): MIG packs
jobs onto partitioned instances via the placement tree; NAIVE and MPS place
them together on the full non-partitioned device and predict each job's
effective step time from the mode's contention model. ``best_mode`` scores a
job mix under all three modes and picks the winner — reproducing the paper's
recommendation that MPS wins for a single user's homogeneous training jobs,
MIG when model sizes align with the partitioning options, and naive never.

The MIG path implements:

  * admission control — a job may only be placed on a profile whose
    per-device HBM budget covers the job's compiled peak memory (reproduces
    F5: medium/large OOM on 1g.5gb as a scheduler rejection, not a crash);
  * packing — smallest admissible profile first (maximizes instances per
    pod, which is the paper's throughput lever), widened to bigger
    profiles only when the small slots are exhausted; with
    ``use_planner=True`` the (profile, start) choice comes instead from
    exact/beam search over the whole partition tree (core/planner), which
    keeps the larger profiles' few legal starts unfragmented — greedy
    first-fit's known blind spot (docs/placement.md);
  * layout search — candidate layouts come from the paper-faithful
    placement tree (core/profiles.py), scored by predicted aggregate
    throughput from the characterization DB;
  * straggler mitigation — per-job step-time EMA; a job drifting > tol
    above its predicted step time is marked for repack to a larger profile
    (isolation F3 guarantees repacking cannot hurt neighbours).

The characterization DB is a dict {(arch, shape, profile): record-dict}
produced by ``launch/collocate.py`` (compiled dry-runs per instance shape) —
the same artifact the paper builds by measuring 135 hours of runs, built
here in minutes analytically.

Jobs may be flat ``JobSpec``s or phase-aware ``Workload``s
(core/workload.py) — the two share the fields the scheduler reads.
Admission always budgets the *phase-peak* working set; predicted step times
are for each job's currently active phase (``active_phases``), defaulting
to steady — which reproduces the flat-JobSpec numbers exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.core.device import DEFAULT_SKU, DeviceSKU, format_gib, get_sku
from repro.core.instance import JobSpec
from repro.core.planner import PlacementPlan, PlanningCostModel, plan_placements
from repro.core.planner.costmodel import record_fits
from repro.core.profiles import Placement, homogeneous_layout
from repro.core.sharing import (
    CollocationMode,
    SharedModeReport,
    SoloProfile,
    SoloTerms,
    shared_mode_report,
)
from repro.core.sharing import solo_terms as profile_terms
from repro.core.workload import (
    STEADY_DEMAND,
    DemandTrace,
    peak_demand_multiplier,
    phase_step_s,
)

CharKey = Tuple[str, str, str]  # (arch, shape, profile)


def is_sku_keyed_db(char_db) -> bool:
    """True when ``char_db`` is the mixed-fleet shape ``{sku_name: db}``
    rather than one flat ``{CharKey: record}`` DB — a char DB speaks one
    SKU's profile names, so heterogeneous fleets carry one DB per
    generation. The single shape test shared by ``Cluster`` and
    ``launch/simulate.py``."""
    return bool(char_db) and all(isinstance(k, str) for k in char_db)


@dataclasses.dataclass
class Assignment:
    job: JobSpec
    placement: Placement
    predicted_step_s: float

    @property
    def profile(self) -> str:
        return self.placement.profile


@dataclasses.dataclass
class Rejection:
    job: JobSpec
    reason: str


@dataclasses.dataclass
class Schedule:
    assignments: List[Assignment]
    rejections: List[Rejection]
    mode: CollocationMode = CollocationMode.MIG
    shared_report: Optional[SharedModeReport] = None  # NAIVE/MPS only
    plan: Optional[PlacementPlan] = None  # planned MIG path only

    @property
    def placements(self) -> List[Placement]:
        return [a.placement for a in self.assignments]

    def throughput(self) -> float:
        return sum(
            1.0 / a.predicted_step_s
            for a in self.assignments
            if a.predicted_step_s > 0
        )


@dataclasses.dataclass
class ModeDecision:
    """Outcome of ``best_mode``: the winner plus every mode's scorecard."""

    mode: CollocationMode
    schedules: Dict[CollocationMode, Schedule]

    @property
    def schedule(self) -> Schedule:
        return self.schedules[self.mode]

    def scores(self) -> Dict[CollocationMode, Tuple[int, float]]:
        return {
            m: (len(s.assignments), s.throughput())
            for m, s in self.schedules.items()
        }


# profile order: smallest first — the paper's throughput-maximizing choice.
# Default-SKU shims: the scheduler itself reads ``self.sku.profile_order`` /
# ``self.sku.full_profile`` so other device generations get their own.
_PROFILE_ORDER = DEFAULT_SKU.profile_order


# Full-device profile the shared modes (naive / MPS) run on.
_FULL_PROFILE = DEFAULT_SKU.full_profile

# Preference when modes tie on (jobs placed, aggregate throughput): the
# paper recommends MPS as the most flexible, MIG next, naive last.
MODE_PREFERENCE = (CollocationMode.MPS, CollocationMode.MIG, CollocationMode.NAIVE)
_MODE_PREFERENCE = MODE_PREFERENCE  # backwards-compat alias

# Import-time guard: a new CollocationMode member MUST take an explicit
# position in MODE_PREFERENCE — a silent fallback would change every
# tie-broken verdict in the repo without a single test naming the cause.
_UNRANKED = [m for m in CollocationMode if m not in MODE_PREFERENCE]
assert not _UNRANKED and len(MODE_PREFERENCE) == len(CollocationMode), (
    f"MODE_PREFERENCE must rank every CollocationMode exactly once; "
    f"unranked: {[m.value for m in _UNRANKED]}, "
    f"preference: {[m.value for m in MODE_PREFERENCE]}"
)
del _UNRANKED

# Explicit tie-break rank (0 = most preferred). KeyError here is impossible
# while the assert above holds.
_PREFERENCE_RANK: Dict[CollocationMode, int] = {
    m: i for i, m in enumerate(MODE_PREFERENCE)
}


def rank_modes(schedules: Dict[CollocationMode, Schedule]) -> CollocationMode:
    """Winner under the lexicographic ranking ``best_mode`` documents:
    (jobs placed, aggregate throughput), exact ties broken by the explicit
    ``_PREFERENCE_RANK`` position (MPS > MIG > naive — covered for every
    mode by the import-time assert above).

    Shared with the cluster's migration policy (core/cluster.py), which
    evaluates candidate schedules without committing the scheduler's
    straggler-prediction state the way ``best_mode`` does.
    """
    return max(
        schedules,
        key=lambda m: (
            len(schedules[m].assignments),
            schedules[m].throughput(),
            -_PREFERENCE_RANK[m],
        ),
    )


class CollocationScheduler:
    """Mode-aware placer: MIG placement-tree packing or shared-device
    scheduling under the naive / MPS contention models."""

    def __init__(
        self,
        char_db: Dict[CharKey, dict],
        *,
        chips_per_unit: int = 32,
        partitioned: bool = True,
        straggler_tol: float = 1.5,
        ema_alpha: float = 0.25,
        mode: CollocationMode = CollocationMode.MIG,
        use_planner: bool = False,
        sku: Union[None, str, DeviceSKU] = None,
    ):
        self.char_db = char_db
        # the device generation this scheduler places onto (core/device.py):
        # its placement tree, slice budgets, and shared-mode knobs. The
        # char DB must speak this SKU's profile names.
        self.sku = get_sku(sku)
        self.chips_per_unit = chips_per_unit
        self.partitioned = partitioned
        self.straggler_tol = straggler_tol
        self.ema_alpha = ema_alpha
        self.mode = CollocationMode(mode)
        # route MIG placement through the partition-tree optimizer
        # (core/planner) instead of greedy smallest-admissible first-fit
        self.use_planner = bool(use_planner)
        # optional online calibrator (core/calib/online.py): when attached
        # (the cluster wires it), predict_step multiplies its memoized base
        # prediction by the calibrator's running per-(sku, arch, profile)
        # residual — corrections stay OUT of the memo so they can evolve
        # between calls without poisoning the cache. None = exact pre-calib
        # behaviour (the byte-determinism contract for untouched runs).
        self.calibrator = None
        self._cost_model: Optional[PlanningCostModel] = None
        self._ema: Dict[str, float] = {}
        self._predicted: Dict[str, float] = {}
        # the residual each job's last prediction carried (1.0 = none):
        # Cluster.observe_step divides it back out so the calibrator's
        # EWMA tracks measured-vs-BASE even when the residual has moved
        # since the job was priced
        self._applied_residual: Dict[str, float] = {}
        # memoized lookups: the char DB is immutable for the scheduler's
        # lifetime, so (arch, shape, profile, phase) step predictions and
        # per-arch solo profiles are computed once — the planner's inner
        # loop and the cluster's shared-device re-timing on every
        # arrival/departure hit these paths thousands of times
        # key: (arch, shape, profile, demand, phase-peak multiplier)
        self._step_cache: Dict[Tuple, float] = {}
        self._solo_cache: Dict[Tuple[str, str, str], Optional[SoloProfile]] = {}
        # cluster fast-path memos (core/cluster.py incremental re-timing):
        # scaled contention terms per (SKU, arch, shape, demand) and the
        # shared-mode admission verdict per (SKU, arch, shape, peak mult)
        self._terms_cache: Dict[Tuple, Optional[SoloTerms]] = {}
        self._shared_admit_cache: Dict[Tuple, Optional[Tuple[float, bool]]] = {}

    @property
    def cost_model(self) -> PlanningCostModel:
        """Lazily built predictive cost model over the same char DB."""
        if self._cost_model is None:
            self._cost_model = PlanningCostModel(self.char_db, sku=self.sku)
        return self._cost_model

    # -- admission ------------------------------------------------------------

    def admissible(self, job, profile: str) -> Tuple[bool, str]:
        """Memory admission on the job's *phase-peak* working set.

        A placement must survive the job's hungriest phase (e.g. the
        checkpoint burst's serialization buffer), so the record's steady
        footprint is scaled by the workload's peak demand multiplier. Flat
        ``JobSpec``s have multiplier 1.0 and keep the record's own ``fits``
        verdict bit-for-bit; a phase-aware workload re-evaluates against
        the HBM budget — which can also *admit* where steady training OOMs
        (a serve session's decode working set is roughly half a train
        step's)."""
        rec = self.char_db.get((job.arch, job.suite.name, profile))
        if rec is None:
            return False, f"no characterization for {(job.arch, job.suite.name, profile)}"
        mult = peak_demand_multiplier(job)
        # the one shared admission predicate — the planner cost model must
        # reach the same verdict on the same record (core/planner/costmodel)
        fits = record_fits(rec, mult, budget_bytes=self.sku.slice_bytes)
        if not fits:
            return False, (
                f"OOM: needs "
                f"{format_gib(rec['peak_bytes_per_device'] * mult)} GiB/chip "
                f"(phase peak) > {format_gib(self.sku.slice_bytes)} GiB HBM "
                f"on {profile}"
            )
        return True, ""

    def smallest_admissible(self, job: JobSpec) -> Optional[str]:
        order = self.sku.profile_order
        start = 0
        if job.min_profile is not None and job.min_profile in order:
            # straggler-repack floor: never place below this profile again.
            # A floor naming another generation's profile (a repack victim
            # retried on a different SKU in a mixed fleet) does not bind —
            # slice names, like slice budgets, are per-SKU.
            start = order.index(job.min_profile)
        for prof in order[start:]:
            ok, _ = self.admissible(job, prof)
            if ok:
                return prof
        return None

    # -- packing ----------------------------------------------------------------

    def schedule(
        self,
        jobs: Sequence[JobSpec],
        *,
        blocked_units: frozenset = frozenset(),
        mode: Optional[CollocationMode] = None,
        existing: Sequence[Placement] = (),
        active_phases: Optional[Mapping[str, DemandTrace]] = None,
        preferred: Optional[Mapping[str, Placement]] = None,
    ) -> Schedule:
        """Place ``jobs`` under ``mode`` (defaults to the scheduler's own).

        MIG is a greedy pack: sort by priority desc, give each job its
        smallest admissible profile at the lowest free placement offset;
        upgrade to a larger profile only if the small ones are exhausted.
        ``blocked_units`` are unavailable slice units (failed hardware or
        surviving neighbours during an elastic repack). ``existing`` are
        placements already live on the device (the cluster's incremental
        admission path): their units are occupied AND they participate in
        layout validation, so profile exclusions and the compute-slice
        budget hold across the union, not just the new jobs. NAIVE/MPS
        share the full device instead — see ``_schedule_shared``.

        ``active_phases`` maps job name -> the demand vector of the phase
        the job is *currently in* (core/workload.py): predicted step times
        are for that phase, and the shared-mode contention models consume
        the active-phase vectors of the whole co-resident set. Memory
        admission always uses phase-peak regardless. Jobs absent from the
        map are timed at their steady (identity) demand — the flat-JobSpec
        behaviour.

        ``preferred`` (planner path only) maps job names to the instances
        they currently hold: a re-partition plan treats keeping them in
        place as the objective right after serving the most jobs, since
        every move costs a checkpoint rollback (core/planner/optimizer.py).
        """
        mode = CollocationMode(mode if mode is not None else self.mode)
        active_phases = active_phases or {}
        if mode != CollocationMode.MIG:
            return self._schedule_shared(jobs, mode, active_phases)
        if self.use_planner:
            return self._schedule_mig_planned(
                jobs,
                blocked_units=blocked_units,
                existing=existing,
                active_phases=active_phases,
                preferred=preferred,
            )
        # (the MIG overhead slice is a *compute* budget — enforced by
        # validate_layout's slice-count check — not a blocked memory unit;
        # the full-device profile owns all units by the SKU invariant)
        sku = self.sku
        order = sku.profile_order
        free = [True] * sku.n_units
        for u in blocked_units:
            free[u] = False
        existing = list(existing)
        for pl in existing:
            for u in sku.units(pl):
                free[u] = False
        assignments: List[Assignment] = []
        rejections: List[Rejection] = []

        def try_place(profile: str) -> Optional[Placement]:
            p = sku.profile(profile)
            for s in p.starts:
                span = range(s, s + p.mem_units)
                if all(free[u] for u in span):
                    ok, _ = sku.validate_layout(
                        existing
                        + [Placement(a.profile, a.placement.start) for a in assignments]
                        + [Placement(profile, s)],
                        partitioned=self.partitioned,
                    )
                    if ok:
                        for u in span:
                            free[u] = False
                        return Placement(profile, s)
            return None

        for job in sorted(jobs, key=lambda j: -j.priority):
            placed = False
            start_prof = self.smallest_admissible(job)
            if start_prof is None:
                reasons = [
                    f"{p}: {self.admissible(job, p)[1]}" for p in order
                ]
                rejections.append(Rejection(job, "; ".join(reasons[:2])))
                continue
            for prof in order[order.index(start_prof):]:
                ok, _ = self.admissible(job, prof)
                if not ok:
                    continue
                pl = try_place(prof)
                if pl is not None:
                    demand = active_phases.get(job.name, STEADY_DEMAND)
                    a = Assignment(job, pl, self.predict_step(job, prof, demand))
                    assignments.append(a)
                    placed = True
                    break
            if not placed:
                rejections.append(Rejection(job, "no free placement slot"))
        return Schedule(assignments, rejections, mode=CollocationMode.MIG)

    def _schedule_mig_planned(
        self,
        jobs: Sequence[JobSpec],
        *,
        blocked_units: frozenset = frozenset(),
        existing: Sequence[Placement] = (),
        active_phases: Mapping[str, DemandTrace] = {},
        preferred: Optional[Mapping[str, Placement]] = None,
    ) -> Schedule:
        """MIG placement via the partition-tree optimizer (core/planner).

        Same contract as the greedy path — every job is either assigned or
        rejected, ``existing`` placements are fixed and jointly validated,
        ``blocked_units`` are untouchable — but the (profile, start) choice
        comes from exact/beam search over the whole placement tree instead
        of smallest-admissible first-fit, and the returned ``Schedule``
        carries the ``PlacementPlan`` (optimality + gap included)."""
        plan = plan_placements(
            list(jobs),
            self.cost_model,
            existing=existing,
            blocked_units=frozenset(blocked_units),
            active_phases=active_phases,
            preferred=preferred,
            partitioned=self.partitioned,
        )
        by_name = {j.name: j for j in jobs}
        assignments: List[Assignment] = []
        for job in sorted(jobs, key=lambda j: -j.priority):
            pl = plan.assignments.get(job.name)
            if pl is None:
                continue
            demand = active_phases.get(job.name, STEADY_DEMAND)
            assignments.append(
                Assignment(job, pl, self.predict_step(job, pl.profile, demand))
            )
        rejections = [
            Rejection(by_name[name], reason) for name, reason in plan.unplaced
        ]
        return Schedule(
            assignments, rejections, mode=CollocationMode.MIG, plan=plan
        )

    def predict_step(self, job, profile: str, demand: DemandTrace = STEADY_DEMAND) -> float:
        """Predicted per-step time of ``job`` on a MIG ``profile`` under a
        phase's demand vector, recorded for straggler detection. The one
        source of truth for MIG step prediction — the scheduler's packing
        path and the cluster's phase-transition re-timing both call it.

        Memoized on (SKU, arch, shape, profile, demand, phase-peak
        multiplier): the char DB is immutable, so identical lookups (the
        planner inner loop, shared re-timing storms) stop recomputing the
        phase algebra — and the SKU in the key means a scheduler re-homed
        onto another generation can never serve a stale step time
        (tests/test_device.py). A profile with no record of its own falls
        back to the planner cost model's MISO-style prediction from the
        full-device record — whose fits/KeyError verdict depends on the
        job's phase-peak working set, hence the multiplier in the key."""
        key = (self.sku.name, job.arch, job.suite.name, profile, demand,
               peak_demand_multiplier(job))
        step = self._step_cache.get(key)
        if step is None:
            rec = self.char_db.get((job.arch, job.suite.name, profile))
            if rec is None:
                est = self.cost_model.estimate(job, profile, demand)
                if not est.fits or est.step_s <= 0:
                    # keep the old loud-failure contract: a step prediction
                    # for an uncharacterized, unpredictable slice is a bug
                    # in the caller, not a 0.0
                    raise KeyError((job.arch, job.suite.name, profile))
                step = float(est.step_s)
            else:
                step = float(phase_step_s(rec, demand))
            self._step_cache[key] = step
        if self.calibrator is not None:
            # applied after the memo on purpose: the cache holds the char
            # DB's immutable base prediction, the residual is live state
            r = self.calibrator.residual(
                sku=self.sku.name, arch=job.arch, profile=profile
            )
            step *= r
            self._applied_residual[job.name] = r
        self._predicted[job.name] = step
        return step

    def applied_residual(self, job_name: str) -> float:
        """The calibrator residual ``job_name``'s last prediction carried
        (1.0 when no calibrator, or the job was never priced here)."""
        return self._applied_residual.get(job_name, 1.0)

    # -- shared modes (naive / MPS) ------------------------------------------------

    def solo_profile(self, job: JobSpec) -> Optional[SoloProfile]:
        """The job's solo roofline profile on the full, non-partitioned
        device, from the characterization DB. Shared modes run with MIG
        disabled, so the F6 reserved-slice discount baked into the 7g record
        is removed.

        Memoized per (SKU, arch, shape) — only the profile's ``name`` is
        job-specific, so the cached arch profile is re-labelled per job
        instead of re-deriving the roofline terms on every arrival,
        departure, and re-timing."""
        base = self._solo_base(job.arch, job.suite.name)
        if base is None:
            return None
        return dataclasses.replace(base, name=job.name)

    def _solo_base(self, arch: str, suite_name: str) -> Optional[SoloProfile]:
        """The memoized arch-named solo profile behind ``solo_profile``."""
        full = self.sku.full_profile
        key = (self.sku.name, arch, suite_name)
        if key not in self._solo_cache:
            rec = self.char_db.get((arch, suite_name, full))
            self._solo_cache[key] = (
                None
                if rec is None
                else SoloProfile.from_record(
                    arch,
                    rec,
                    undiscount_compute=self.sku.compute_discount(full),
                    latency_s=self.sku.step_latency_s,
                )
            )
        return self._solo_cache[key]

    def solo_terms(self, job, demand) -> Optional[SoloTerms]:
        """Memoized contention terms of the job's solo profile scaled by a
        phase ``demand`` vector — the cluster's incremental re-timing input
        (core/cluster.py). Bit-identical to freezing
        ``solo_profile(job).scaled(demand)``: the scaling runs through the
        same ``SoloProfile.scaled`` arithmetic before the terms are taken.
        None when the full-device record is missing (same jobs the shared
        scheduling path rejects)."""
        key = (self.sku.name, job.arch, job.suite.name, demand)
        if key not in self._terms_cache:
            base = self._solo_base(job.arch, job.suite.name)
            self._terms_cache[key] = (
                None if base is None else profile_terms(base.scaled(demand))
            )
        return self._terms_cache[key]

    def shared_admission(self, job) -> Optional[Tuple[float, bool]]:
        """Memoized shared-mode admission inputs: ``(phase-peak bytes,
        solo-fits)`` — exactly the quantities ``_schedule_shared`` derives
        per job before summing footprints against the HBM budget. None when
        the job has no full-device characterization (the no-record
        rejection). Keyed on the phase-peak multiplier so a workload whose
        plan changes its memory peak can never reuse a stale verdict."""
        mult = peak_demand_multiplier(job)
        key = (self.sku.name, job.arch, job.suite.name, mult)
        if key not in self._shared_admit_cache:
            base = self._solo_base(job.arch, job.suite.name)
            if base is None:
                self._shared_admit_cache[key] = None
            else:
                peak_bytes = base.peak_bytes_per_device * mult
                full = self.sku.full_profile
                fits = (
                    self.char_db[(job.arch, job.suite.name, full)].get("fits", False)
                    if mult == 1.0
                    else peak_bytes <= self.sku.slice_bytes
                )
                self._shared_admit_cache[key] = (peak_bytes, bool(fits))
        return self._shared_admit_cache[key]

    def _schedule_shared(
        self,
        jobs: Sequence[JobSpec],
        mode: CollocationMode,
        active_phases: Mapping[str, DemandTrace] = {},
    ) -> Schedule:
        """Place jobs together on the full device under a shared mode.

        Admission is the paper's memory constraint: shared modes replicate
        every job's working set on every chip, so per-chip footprints add
        and the aggregate must fit HBM — budgeted at each job's *phase-peak*
        footprint, since a neighbour's checkpoint burst lands in the same
        memory space. Jobs are admitted in priority order until the budget
        is exhausted; the mode's contention model then predicts every
        admitted job's effective step time from the *currently active*
        phase vectors (a decode-heavy neighbour loads the memory system and
        dispatch queue very differently from a checkpoint burst).
        """
        assignments: List[Assignment] = []
        rejections: List[Rejection] = []
        admitted: List[Tuple[JobSpec, SoloProfile]] = []
        full = self.sku.full_profile
        budget = self.sku.slice_bytes
        used = 0.0
        for job in sorted(jobs, key=lambda j: -j.priority):
            prof = self.solo_profile(job)
            if prof is None:
                rejections.append(
                    Rejection(
                        job,
                        f"no characterization for "
                        f"{(job.arch, job.suite.name, full)}",
                    )
                )
                continue
            peak_mult = peak_demand_multiplier(job)
            peak_bytes = prof.peak_bytes_per_device * peak_mult
            solo_fits = (
                self.char_db[(job.arch, job.suite.name, full)].get("fits", False)
                if peak_mult == 1.0
                else peak_bytes <= budget
            )
            if not solo_fits:
                rejections.append(
                    Rejection(job, "OOM: does not fit the full device solo")
                )
                continue
            if used + peak_bytes > budget:
                rejections.append(
                    Rejection(
                        job,
                        f"OOM under {mode.value}: aggregate phase-peak "
                        f"footprint {format_gib(used + peak_bytes)} GiB "
                        f"> {format_gib(budget)} GiB shared HBM",
                    )
                )
                continue
            used += peak_bytes
            admitted.append(
                (job, prof.scaled(active_phases.get(job.name, STEADY_DEMAND)))
            )

        report = None
        if admitted:
            report = shared_mode_report(
                mode,
                [p for _, p in admitted],
                hbm_budget_bytes=budget,
                switch_overhead_frac=self.sku.naive_switch_overhead_frac,
            )
            for job, prof in admitted:
                step = report.effective_step_s[prof.name]
                a = Assignment(job, Placement(full, 0), float(step))
                assignments.append(a)
                self._predicted[job.name] = a.predicted_step_s
        return Schedule(assignments, rejections, mode=mode, shared_report=report)

    # -- mode search -----------------------------------------------------------------

    def best_mode(self, jobs: Sequence[JobSpec]) -> ModeDecision:
        """Score the job mix under all three modes; pick the winner.

        Modes are ranked lexicographically by (jobs placed, aggregate
        throughput in jobs/s) — a mode that serves more of the mix beats a
        faster mode that rejects jobs (the paper's admission findings F5),
        throughput breaks the tie, and on exact ties the paper's
        recommendation order applies: MPS > MIG > naive.
        """
        schedules = {m: self.schedule(jobs, mode=m) for m in CollocationMode}
        best = rank_modes(schedules)
        # the trial schedules above each overwrote _predicted; straggler
        # detection must compare against the mode actually deployed
        for a in schedules[best].assignments:
            self._predicted[a.job.name] = a.predicted_step_s
        return ModeDecision(mode=best, schedules=schedules)

    # -- straggler mitigation -----------------------------------------------------

    def observe_step(self, job_name: str, step_s: float) -> None:
        prev = self._ema.get(job_name)
        self._ema[job_name] = (
            step_s if prev is None else (1 - self.ema_alpha) * prev + self.ema_alpha * step_s
        )

    def reset_observation(self, job_name: str) -> None:
        """Forget a job's step-time EMA — called when the job is re-placed
        on a different profile, where the old observations no longer apply."""
        self._ema.pop(job_name, None)

    def stragglers(self) -> List[str]:
        out = []
        for name, ema in self._ema.items():
            pred = self._predicted.get(name)
            if pred and ema > self.straggler_tol * pred:
                out.append(name)
        return out

    def repack_plan(self, schedule: Schedule) -> Dict[str, str]:
        """job -> larger-profile suggestion for flagged stragglers."""
        plan = {}
        order = self.sku.profile_order
        straggling = set(self.stragglers())
        for a in schedule.assignments:
            if a.job.name not in straggling:
                continue
            bigger = order[min(order.index(a.profile) + 1, len(order) - 1)]
            ok, _ = self.admissible(a.job, bigger)
            if ok and bigger != a.profile:
                plan[a.job.name] = bigger
        return plan


def paper_experiment_grid(
    workloads: Sequence[str], suite, sku: Union[None, str, DeviceSKU] = None
) -> List[Tuple[str, str, List[Placement]]]:
    """The paper's §3.4 run matrix: for each profile x workload, an isolated
    ('one') run and a max-instances homogeneous ('parallel') run, plus the
    non-MIG full-device baseline."""
    dev = get_sku(sku)
    grid: List[Tuple[str, str, List[Placement]]] = []
    for w in workloads:
        for prof in dev.profile_order:
            grid.append(
                (w, f"{prof} one", [Placement(prof, dev.profile(prof).starts[0])])
            )
            par = homogeneous_layout(prof, sku=dev)
            if len(par) > 1:
                grid.append((w, f"{prof} parallel", par))
        grid.append((w, "non-MIG", [Placement(dev.full_profile, 0)]))
    return grid
