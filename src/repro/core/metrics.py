"""Device-group metric aggregation — the paper's §4.2 reporting, derived.

The paper reports GRACT/SMACT/SMOCC/DRAMA twice per experiment: once per
*instance* and once for the *full device*, where unoccupied slice units pull
the device-level number down (their engines are idle). We reproduce both
views from the per-instance characterization records:

    instance-level  = the record's own DCGM analogues;
    device-level    = sum_i(metric_i * mem_units_i) / 8   (idle units = 0).

This reproduces the paper's headline structure: 1g.5gb-parallel maximizes
device-level activity for small workloads, 7g.40gb-one minimizes it, and a
single small instance barely registers at device level.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.core.instance import InstanceRecord
from repro.core.profiles import N_UNITS, PROFILES


@dataclasses.dataclass
class DeviceGroupReport:
    """One paper 'device group' (e.g. ``2g.10gb parallel``) row."""

    group: str  # "1g.5gb one" | "1g.5gb parallel" | "non-MIG" ...
    workload: str
    instance_metrics: List[Dict[str, float]]  # per instance
    device_metrics: Dict[str, float]  # unit-weighted over the full pod
    occupied_units: int

    def to_dict(self):
        return dataclasses.asdict(self)


_METRICS = ("gract", "smact", "smocc_proxy", "drama")


def device_group_report(
    group: str, workload: str, records: Sequence[InstanceRecord]
) -> DeviceGroupReport:
    inst_metrics = [dict(r.dcgm) for r in records]
    occupied = sum(PROFILES[r.profile].mem_units for r in records)
    device = {}
    for m in _METRICS:
        device[m] = sum(
            r.dcgm[m] * PROFILES[r.profile].mem_units for r in records
        ) / N_UNITS
    return DeviceGroupReport(
        group=group,
        workload=workload,
        instance_metrics=inst_metrics,
        device_metrics=device,
        occupied_units=occupied,
    )


def epoch_time_s(record: InstanceRecord, samples_per_epoch: int, batch: int) -> float:
    """Paper metric #1: step-time roofline x steps per epoch."""
    steps = -(-samples_per_epoch // batch)
    return record.step_s * steps


def throughput_jobs_per_s(records: Sequence[InstanceRecord]) -> float:
    """Aggregate work rate of a parallel device group (jobs / second),
    where each job contributes 1/step_s. The paper's F2 compares this to
    running the same jobs sequentially on the full-device profile."""
    return sum(1.0 / r.step_s for r in records if r.step_s > 0)


def collocation_speedup(
    parallel: Sequence[InstanceRecord], isolated_full: InstanceRecord
) -> float:
    """F2: time(sequential on 7g) / time(parallel on k instances).

    k jobs sequentially on the full device take k * step_full; in parallel
    they take max_i(step_i). Ratio > 1 means collocation wins.
    """
    k = len(parallel)
    t_seq = k * isolated_full.step_s
    t_par = max(r.step_s for r in parallel)
    return t_seq / t_par if t_par else 0.0


def format_group_table(reports: Sequence[DeviceGroupReport]) -> str:
    hdr = (
        f"{'group':<22}{'workload':<16}{'n_inst':>7}"
        f"{'GRACT':>8}{'SMACT':>8}{'SMOCC':>8}{'DRAMA':>8}  (device-level)"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        d = r.device_metrics
        lines.append(
            f"{r.group:<22}{r.workload:<16}{len(r.instance_metrics):>7}"
            f"{d['gract']:>8.3f}{d['smact']:>8.3f}"
            f"{d['smocc_proxy']:>8.3f}{d['drama']:>8.3f}"
        )
    return "\n".join(lines)
