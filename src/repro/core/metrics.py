"""Device-group metric aggregation — the paper's §4.2 reporting, derived.

The paper reports GRACT/SMACT/SMOCC/DRAMA twice per experiment: once per
*instance* and once for the *full device*, where unoccupied slice units pull
the device-level number down (their engines are idle). We reproduce both
views from the per-instance characterization records:

    instance-level  = the record's own DCGM analogues;
    device-level    = sum_i(metric_i * mem_units_i) / 8   (idle units = 0).

This reproduces the paper's headline structure: 1g.5gb-parallel maximizes
device-level activity for small workloads, 7g.40gb-one minimizes it, and a
single small instance barely registers at device level.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.device import get_sku
from repro.core.instance import InstanceRecord


@dataclasses.dataclass
class DeviceGroupReport:
    """One paper 'device group' (e.g. ``2g.10gb parallel``) row."""

    group: str  # "1g.5gb one" | "1g.5gb parallel" | "non-MIG" ...
    workload: str
    instance_metrics: List[Dict[str, float]]  # per instance
    device_metrics: Dict[str, float]  # unit-weighted over the full pod
    occupied_units: int

    def to_dict(self):
        return dataclasses.asdict(self)


_METRICS = ("gract", "smact", "smocc_proxy", "drama")


def device_group_report(
    group: str, workload: str, records: Sequence[InstanceRecord], sku=None
) -> DeviceGroupReport:
    dev = get_sku(sku)
    inst_metrics = [dict(r.dcgm) for r in records]
    occupied = sum(dev.profile(r.profile).mem_units for r in records)
    device = {}
    for m in _METRICS:
        device[m] = sum(
            r.dcgm[m] * dev.profile(r.profile).mem_units for r in records
        ) / dev.n_units
    return DeviceGroupReport(
        group=group,
        workload=workload,
        instance_metrics=inst_metrics,
        device_metrics=device,
        occupied_units=occupied,
    )


def epoch_time_s(record: InstanceRecord, samples_per_epoch: int, batch: int) -> float:
    """Paper metric #1: step-time roofline x steps per epoch."""
    steps = -(-samples_per_epoch // batch)
    return record.step_s * steps


def throughput_jobs_per_s(records: Sequence[InstanceRecord]) -> float:
    """Aggregate work rate of a parallel device group (jobs / second),
    where each job contributes 1/step_s. The paper's F2 compares this to
    running the same jobs sequentially on the full-device profile."""
    return sum(1.0 / r.step_s for r in records if r.step_s > 0)


def collocation_speedup(
    parallel: Sequence[InstanceRecord], isolated_full: InstanceRecord
) -> float:
    """F2: time(sequential on 7g) / time(parallel on k instances).

    k jobs sequentially on the full device take k * step_full; in parallel
    they take max_i(step_i). Ratio > 1 means collocation wins.
    """
    k = len(parallel)
    t_seq = k * isolated_full.step_s
    t_par = max(r.step_s for r in parallel)
    return t_seq / t_par if t_par else 0.0


@dataclasses.dataclass
class ModeComparison:
    """One row of the paper's naive-vs-MPS-vs-MIG comparison for a workload:
    k jobs collocated under ``mode`` vs running them sequentially solo."""

    workload: str
    mode: str
    k_jobs: int
    effective_step_s: float  # slowest collocated job's step
    solo_step_s: float  # one job alone on the full device
    fits: bool
    # neighbour-induced slowdown: collocated step / what the job would do on
    # the same resources without neighbours. 1.0 for MIG by construction
    # (F3 — a slice's step is slice-sized whether or not neighbours exist);
    # effective/solo for the shared modes.
    max_interference: float = 1.0

    @property
    def speedup_vs_sequential(self) -> float:
        """k jobs sequentially take k*solo; collocated they finish together
        after max effective step. > 1 means collocation wins (F2)."""
        if not self.fits or self.effective_step_s <= 0:
            return 0.0
        return (self.k_jobs * self.solo_step_s) / self.effective_step_s


def mode_comparison(
    workload: str,
    mode: str,
    records: Sequence[InstanceRecord],
    solo_step_s: float,
    *,
    interference: Optional[float] = None,
) -> ModeComparison:
    """One comparison row. ``interference`` defaults to effective/solo (the
    shared-mode semantics); pass 1.0 explicitly for MIG rows (F3)."""
    effective = max((r.step_s for r in records), default=0.0)
    if interference is None:
        interference = effective / solo_step_s if solo_step_s else 0.0
    return ModeComparison(
        workload=workload,
        mode=mode,
        k_jobs=len(records),
        effective_step_s=effective,
        solo_step_s=solo_step_s,
        fits=all(r.fits for r in records),
        max_interference=interference,
    )


def format_mode_table(rows: Sequence[ModeComparison]) -> str:
    """The paper's headline table: collocation speedup per mode."""
    hdr = (
        f"{'workload':<16}{'mode':<8}{'k':>3}{'solo_s':>10}{'coll_s':>10}"
        f"{'speedup':>9}{'interf':>8}{'fits':>6}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.workload:<16}{r.mode:<8}{r.k_jobs:>3}{r.solo_step_s:>10.5f}"
            f"{r.effective_step_s:>10.5f}{r.speedup_vs_sequential:>8.2f}x"
            f"{r.max_interference:>7.2f}x{str(r.fits):>6}"
        )
    return "\n".join(lines)


def format_group_table(reports: Sequence[DeviceGroupReport]) -> str:
    hdr = (
        f"{'group':<22}{'workload':<16}{'n_inst':>7}"
        f"{'GRACT':>8}{'SMACT':>8}{'SMOCC':>8}{'DRAMA':>8}  (device-level)"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        d = r.device_metrics
        lines.append(
            f"{r.group:<22}{r.workload:<16}{len(r.instance_metrics):>7}"
            f"{d['gract']:>8.3f}{d['smact']:>8.3f}"
            f"{d['smocc_proxy']:>8.3f}{d['drama']:>8.3f}"
        )
    return "\n".join(lines)
