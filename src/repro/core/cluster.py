"""Event-driven multi-device cluster: dynamic arrivals, live reconfiguration.

The one-shot ``CollocationScheduler.schedule(jobs)`` answers the paper's
*static* question — how should a fixed batch share one device. Its sharpest
*temporal* finding ("MIG's rigid partitioning may create sub-optimal GPU
utilization for more dynamic mixed workloads") needs an always-on cluster:
jobs arrive over time, finish, free capacity, and the fleet's partitioning
decisions age as the mix drifts. This module is that state machine.

A ``Cluster`` owns N ``DeviceState``s — a heterogeneous fleet where each
device has its own ``CollocationMode`` (some MIG-partitioned, others
MPS/naive-shared), its own ``DeviceSKU`` (core/device.py — a fleet may mix
GPU generations, each with its own placement tree and slice budgets), and
its own ``CollocationScheduler`` instance holding the per-device placement
and straggler state. The cluster is driven by a discrete-event loop
(core/events.py):

  submit(job, arrival_s)  pushes an ARRIVAL; at fire time the job enters
                          the priority + backfill admission queue
                          (core/queueing.py) — *waiting replaces the
                          one-shot scheduler's "reject forever"*; only jobs
                          that cannot run on any empty device under any
                          allowed mode are rejected outright;
  COMPLETION              derived from the job's predicted step time x its
                          remaining steps (epoch_time_s x epochs algebra);
                          frees capacity, re-times shared neighbours whose
                          contention just dropped, and re-drains the queue;
  PHASE_TRANSITION        a placed job crosses a boundary of its workload's
                          phase plan (core/workload.py): its demand vector
                          changes, so a MIG job re-times itself while a
                          shared device re-times *every* co-resident job
                          (a neighbour entering its checkpoint burst or
                          decode phase changes everyone's contention), and
                          the adaptive policy gets a migration look;
  FAILURE / REPAIR        slice-unit health events; the MIG path reuses the
                          elastic-repack split (core/elastic.py) — jobs on
                          intersecting instances die, survivors keep
                          running untouched (F3); on a *shared* device any
                          failure kills every job (no isolation — F3's
                          contrapositive);
  RECONFIG_DONE           ends a mode migration and re-opens the device.

Mode migration is the dynamic half of the paper made executable: under the
``adaptive`` policy, whenever the (running + queued) composition drifts,
each device re-runs the ``best_mode`` ranking (collocation.rank_modes) and
— if another mode would serve strictly more of the mix, or the same number
at meaningfully higher throughput — re-partitions live. The cost is charged
with the existing checkpoint-store semantics (checkpoint/store.py): a
checkpoint is valid at epoch granularity, so every displaced job rolls its
progress back to the last completed epoch (work since the last manifest is
lost and re-done), re-enters the queue priority-bumped like an elastic
repack victim, and the device is down for ``reconfig_cost_s`` while it
re-partitions. That charge is exactly what lets the simulator reproduce
MIG rigidity as *measured queueing delay* rather than prose: an all-MIG
fleet on a mixed dynamic trace accrues waiting time that an all-MPS fleet
does not, while MIG still wins the partition-aligned static trace
(benchmarks/cluster_sim.py prints both).

Jobs are phase-aware ``Workload``s (training: warmup / steady / checkpoint;
serving: prefill / decode) or flat ``JobSpec``s through the single-phase
adapter. Serving jobs carry a per-step latency SLO scored over their decode
steps; the end-of-run report adds SLO attainment and mixed-fleet goodput
(useful train steps + SLO-met serve steps per second) to the queueing
metrics, which is what lets benchmarks/cluster_sim.py show inference
flipping the collocation verdict (MIGPerf's finding).

Straggler mitigation folds in as an event handler too: ``observe_step``
feeds the per-device EMA, and a flagged straggler is checkpointed,
re-queued with a ``min_profile`` floor one profile larger (the repack_plan
suggestion), and re-placed — the one-shot plan turned into a live action.

Gang jobs (core/gang/ — the Flex-MIG direction): a spec with
``world_size > 1`` runs as k cooperating members, each on its own MIG
slice, possibly across devices. Admission is all-or-nothing: the gang
placement search (core/gang/placement.py) either finds a slice for every
member or the gang waits whole — after ``gang_reserve_after_s`` of
waiting, a GANG_RESERVE event grants the oldest blocked gang the
admission queue's device reservation so backfilling singletons stop
refilling the capacity it needs (the starvation bound; reservations
release deterministically on placement or rejection). A placed gang is
ONE ClusterJob registered in every member device's ``running`` map, with
per-rank assignments keyed ``name#r<rank>``; its effective step is the
slowest member plus the communication overhead (core/gang/comms.py), so
co-located slice sets strictly beat scattered ones. One member's slice
failing kills the whole gang — surviving members are torn down on their
devices and the gang re-queues once, priority-bumped, resuming from its
last coordinated checkpoint (``elastic.split_by_failure`` maps the hit
member back to its gang). Gangs are MIG-only: shared-mode fleets reject
them at arrival, and the adaptive/planner migration paths leave
gang-hosting devices alone.

Determinism: given the same submitted trace, every run is bit-identical —
events tie-break in push order, queues order by (priority, arrival, seq),
and nothing reads wall clocks or unseeded RNG. launch/simulate.py layers a
seeded synthetic arrival-trace generator on top and tests/test_cluster.py
pins byte-identical artifacts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.core.collocation import (
    Assignment,
    CharKey,
    CollocationScheduler,
    Schedule,
    is_sku_keyed_db,
    rank_modes,
)
from repro.core.device import DEFAULT_SKU, DeviceSKU, get_sku
from repro.core.device import DEFAULT_RECONFIG_COST_S as _BASE_RECONFIG_COST_S
from repro.core.elastic import REQUEUE_PRIORITY_BUMP, split_by_failure
from repro.core.events import Event, EventKind, EventQueue
from repro.core.forecast import (
    ForecastConfig,
    RateForecast,
    forecast_provenance,
    next_tick,
    plan_autoscale,
    wave_amortizes,
)
from repro.core.gang.comms import DEFAULT_LINK, LinkModel, gang_step_s
from repro.core.gang.parallelism import (
    gang_world_size,
    member_name,
    resolve_parallelism,
)
from repro.core.gang.placement import GangPlan, plan_gang
from repro.core.instance import JobSpec
from repro.core.calib.online import OnlineCalibrator
from repro.core.obs import TraceRecorder
from repro.core.profiles import Placement
from repro.core.queueing import AdmissionQueue, QueueEntry
from repro.core.sharing import (
    CollocationMode,
    busy_fraction_from_terms,
    device_busy_fraction,
    shared_effective_steps,
)
from repro.core.workload import (
    PhaseSpan,
    Workload,
    as_workload,
    member_demand,
    peak_demand_multiplier,
    span_at,
)

# Live re-partitioning penalty: drain + MIG instance destroy/create + MPS
# daemon restart + checkpoint restore of the displaced jobs. Charged per
# migration on top of the per-job epoch rollback. Aliases the device
# model's baseline (core/device.py) so the two cannot drift; per-device
# SKUs scale it (Cluster._device_reconfig_cost).
DEFAULT_RECONFIG_COST_S = _BASE_RECONFIG_COST_S

# Checkpoint cadence the rollback models: train.py saves one manifest per
# epoch, and checkpoint/store.py makes a checkpoint visible only once its
# manifest lands — so a displaced job resumes from the last *epoch* boundary.
CHECKPOINT_EVERY_EPOCHS = 1


@dataclasses.dataclass
class ClusterJob:
    """A submitted job plus its simulation state.

    ``spec`` is either a flat ``JobSpec`` (adapted to a single steady
    phase) or a phase-aware ``Workload``; ``plan`` is the workload's phase
    sequence resolved onto this job's concrete step count at submit time.
    The job's *current* phase is always derived from ``steps_done``, so
    checkpoint rollbacks re-enter the right phase for free."""

    spec: Union[JobSpec, Workload]
    arrival_s: float
    epochs: int = 1
    samples_per_epoch: int = 3200
    plan: Tuple[PhaseSpan, ...] = ()
    kind: str = "train"
    slo_step_s: Optional[float] = None
    # -- runtime state ------------------------------------------------------
    steps_done: float = 0.0
    step_s: float = 0.0  # current effective step time on its device
    device: Optional[str] = None
    last_update_s: float = 0.0
    started_s: Optional[float] = None  # first placement (queueing delay end)
    finished_s: Optional[float] = None
    migrations: int = 0
    straggler_repacks: int = 0
    lost_steps: float = 0.0  # progress re-done after checkpoint rollbacks
    phase_transitions: int = 0
    slo_steps: float = 0.0  # latency-sensitive steps executed (serve)
    slo_met_steps: float = 0.0  # of those, steps whose step_s met the SLO
    token: int = 0  # completion-event generation (lazy invalidation)
    pending_event: Optional[Event] = None  # in-heap lifecycle event, if any
    rejected_reason: Optional[str] = None
    # -- gang runtime state (world_size > 1 only) ---------------------------
    member_devices: Tuple[str, ...] = ()  # device per member rank, placed
    gang_requeues: int = 0  # gang-wide failure re-queues
    gang_spread: int = 0  # distinct devices at the last placement
    gang_reserve_pending: bool = False  # a GANG_RESERVE event is in-heap

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def world_size(self) -> int:
        return gang_world_size(self.spec)

    def current_span(self) -> PhaseSpan:
        return span_at(self.plan, self.steps_done)

    def active_demand(self):
        return self.current_span().demand

    @property
    def slo_attainment(self) -> Optional[float]:
        if self.slo_step_s is None or self.slo_steps <= 0:
            return None
        return self.slo_met_steps / self.slo_steps

    @property
    def steps_per_epoch(self) -> int:
        return max(1, -(-self.samples_per_epoch // self.spec.suite.global_batch))

    @property
    def total_steps(self) -> int:
        return self.steps_per_epoch * self.epochs

    @property
    def remaining_steps(self) -> float:
        return max(0.0, self.total_steps - self.steps_done)

    @property
    def queueing_delay_s(self) -> Optional[float]:
        if self.started_s is None:
            return None
        return self.started_s - self.arrival_s

    @property
    def jct_s(self) -> Optional[float]:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    def rollback_to_checkpoint(self) -> float:
        """Roll progress back to the last saved checkpoint; return the lost
        steps (the reconfiguration charge beyond the device downtime)."""
        cadence = self.steps_per_epoch * CHECKPOINT_EVERY_EPOCHS
        kept = math.floor(self.steps_done / cadence) * cadence
        lost = self.steps_done - kept
        self.steps_done = float(kept)
        self.lost_steps += lost
        return lost

    def to_row(self) -> Dict:
        row = {
            "name": self.name,
            "arch": self.spec.arch,
            "kind": self.kind,
            "priority": self.spec.priority,
            "arrival_s": self.arrival_s,
            "started_s": self.started_s,
            "finished_s": self.finished_s,
            "queueing_delay_s": self.queueing_delay_s,
            "jct_s": self.jct_s,
            "total_steps": self.total_steps,
            "phases": [s.name for s in self.plan],
            "phase_transitions": self.phase_transitions,
            "slo_step_s": self.slo_step_s,
            "slo_attainment": self.slo_attainment,
            "migrations": self.migrations,
            "straggler_repacks": self.straggler_repacks,
            "lost_steps": self.lost_steps,
            "rejected_reason": self.rejected_reason,
        }
        # schema extension only where the gang axis is exercised: rows for
        # singleton jobs stay byte-identical to the pre-gang artifacts —
        # the same conditional-key rule DeviceState.to_row applies to SKUs
        if self.world_size > 1:
            row["world_size"] = self.world_size
            row["parallelism"] = resolve_parallelism(self.spec).label
            row["gang_requeues"] = self.gang_requeues
            row["gang_spread"] = self.gang_spread
        return row


@dataclasses.dataclass
class DeviceState:
    """One device of the fleet: its SKU, mode, scheduler, live placements."""

    name: str
    mode: CollocationMode
    scheduler: CollocationScheduler
    sku: DeviceSKU = DEFAULT_SKU
    running: Dict[str, ClusterJob] = dataclasses.field(default_factory=dict)
    assignments: Dict[str, Assignment] = dataclasses.field(default_factory=dict)
    failed_units: Set[int] = dataclasses.field(default_factory=set)
    reconfiguring_until: float = float("-inf")
    pending_mode: Optional[CollocationMode] = None
    migrations: int = 0
    reconfig_cost_s: float = 0.0
    last_migration_s: float = float("-inf")
    straggler_repacks: int = 0
    busy_integral_s: float = 0.0
    last_busy_update_s: float = 0.0
    mode_history: List[Tuple[float, str]] = dataclasses.field(default_factory=list)

    def available(self, t: float) -> bool:
        return t >= self.reconfiguring_until

    def occupied_units(self) -> Set[int]:
        occ = set(self.failed_units)
        for a in self.assignments.values():
            occ |= self.sku.units(a.placement)
        return occ

    def to_row(self) -> Dict:
        row = {
            "name": self.name,
            "mode": self.mode.value,
            "mode_history": list(self.mode_history),
            "migrations": self.migrations,
            "reconfig_cost_s": self.reconfig_cost_s,
            "straggler_repacks": self.straggler_repacks,
            "failed_units": sorted(self.failed_units),
        }
        # schema extension only where the hardware axis is exercised: rows
        # for the default SKU stay byte-identical to the pre-device-model
        # artifacts (the a100-40gb compatibility contract) — by name, the
        # same rule launch/simulate.py applies to its cells
        if self.sku.name != DEFAULT_SKU.name:
            row["sku"] = self.sku.name
        return row


@dataclasses.dataclass
class ClusterReport:
    """End-of-run metrics — the currency benchmarks/cluster_sim.py prints."""

    policy: str
    n_devices: int
    horizon_s: float
    makespan_s: float
    completed: int
    completed_train: int
    completed_serve: int
    rejected: int
    still_queued: int
    still_running: int
    mean_jct_s: float
    p95_jct_s: float
    mean_queueing_delay_s: float
    max_queueing_delay_s: float
    throughput_jobs_per_s: float
    # SERVE objective: fraction of executed latency-sensitive (decode)
    # steps whose effective step time met the session's SLO; 1.0 when the
    # trace has no serve steps.
    slo_attainment: float
    # mixed-fleet goodput: useful train steps (net of rollback re-work)
    # plus SLO-met serve steps, per second of horizon.
    goodput_steps_per_s: float
    phase_transitions: int
    utilization: Dict[str, float]  # device -> busy fraction, plus "mean"
    migrations: int
    reconfig_cost_s: float
    lost_steps: float
    straggler_repacks: int
    hol_blocked_events: int
    jobs: List[Dict]
    devices: List[Dict]
    migration_events: List[Dict]
    failure_events: List[Dict]
    # forecast-policy block (estimator + autoscaler counters); None — and
    # absent from to_dict() — for every other policy, so forecast-free
    # artifacts stay byte-identical to pre-forecast ones.
    forecast: Optional[Dict] = None

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        if d.get("forecast") is None:
            d.pop("forecast", None)
        return d


def _quantile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


class Cluster:
    """N devices + admission queue + event loop; see module docstring."""

    def __init__(
        self,
        char_db: Union[Dict[CharKey, dict], Dict[str, Dict[CharKey, dict]]],
        devices: Sequence[Union[
            Tuple[str, Union[CollocationMode, str]],
            Tuple[str, Union[CollocationMode, str], Union[str, DeviceSKU]],
        ]],
        *,
        policy: str = "static",  # "static" | "adaptive" | "planner" | "forecast"
        reconfig_cost_s: float = DEFAULT_RECONFIG_COST_S,
        migration_cooldown_s: float = 5.0,
        migration_hysteresis: float = 0.10,
        migration_window: int = 8,
        scheduler_kwargs: Optional[Dict] = None,
        retime: str = "incremental",
        gang_reserve_after_s: float = 8.0,
        gang_placement: str = "colocate",
        gang_link: Optional[LinkModel] = None,
        forecast: Optional[ForecastConfig] = None,
        trace: Optional[TraceRecorder] = None,
        calibrator: Optional[OnlineCalibrator] = None,
    ):
        """``devices`` entries are ``(name, mode)`` — the default SKU — or
        ``(name, mode, sku)`` for a heterogeneous-generation fleet
        (core/device.py). ``char_db`` is a flat characterization DB shared
        by every device, or — since a char DB speaks one SKU's profile
        names — a ``{sku_name: db}`` mapping for mixed fleets.

        ``retime`` selects the shared-device re-pricing engine:
        ``"incremental"`` (default) batches same-timestamp re-timings,
        serves contention steps from a composition memo, and skips
        admission-queue scans that cannot succeed; ``"full"`` re-runs the
        complete scheduling model on every event — the reference path the
        equivalence suite (tests/test_retime_equivalence.py) holds the
        fast one byte-identical to.

        ``gang_reserve_after_s`` is the gang starvation bound: how long a
        queued gang waits unplaced before a GANG_RESERVE event grants it
        the queue's device reservation. ``gang_placement`` selects the
        placement search's preference — ``"colocate"`` (default; fewest
        devices, the comm-cheap shape) or ``"scatter"`` (one member per
        device — the baseline benchmarks/report.py's gang table prices
        against). ``gang_link`` overrides the link cost model
        (core/gang/comms.py).

        ``forecast`` configures the forecast-driven autoscaler
        (core/forecast/) and requires ``policy="forecast"`` — that policy
        keeps the adaptive policy's reactive machinery and adds a
        FORECAST_TICK clock that pre-warms decode-capable devices ahead
        of the predicted serve ramp (docs/autoscaling.md).

        ``trace`` attaches a ``TraceRecorder`` (core/obs/): every
        scheduler decision, job lifecycle span, occupancy interval, and
        event-boundary counter sample is recorded against sim time
        (docs/observability.md). Tracing is purely observational — a
        traced run's report and artifacts are byte-identical to an
        untraced one.

        ``calibrator`` attaches an ``OnlineCalibrator`` (core/calib/):
        every ``observe_step`` sample additionally folds into running
        per-(SKU, arch, profile) EWMA residuals, and each device
        scheduler's ``predict_step`` multiplies its base prediction by
        the current residual — predictions tighten as measured evidence
        accumulates (MISO's online refinement). Unlike ``trace`` this IS
        behavioural: corrected step times change packing and completion
        clocks, which is why it is opt-in and ``None`` by default."""
        if policy not in ("static", "adaptive", "planner", "forecast"):
            raise ValueError(f"unknown policy {policy!r}")
        if forecast is not None and policy != "forecast":
            raise ValueError("a forecast config requires policy='forecast'")
        if retime not in ("incremental", "full"):
            raise ValueError(f"unknown retime mode {retime!r}")
        if gang_placement not in ("colocate", "scatter"):
            raise ValueError(f"unknown gang_placement {gang_placement!r}")
        self.policy = policy
        self.retime = retime
        self.gang_reserve_after_s = float(gang_reserve_after_s)
        self.gang_placement = gang_placement
        self.gang_link = gang_link if gang_link is not None else DEFAULT_LINK
        self.reconfig_cost_s = float(reconfig_cost_s)
        self.migration_cooldown_s = float(migration_cooldown_s)
        self.migration_hysteresis = float(migration_hysteresis)
        self.migration_window = int(migration_window)
        kwargs = dict(scheduler_kwargs or {})
        if policy == "planner":
            # the planner policy's whole point: MIG placement decisions come
            # from the partition-tree optimizer, not greedy first-fit
            kwargs.setdefault("use_planner", True)
        per_sku_db = is_sku_keyed_db(char_db)
        self.devices: Dict[str, DeviceState] = {}
        for spec in devices:
            name, mode = spec[0], CollocationMode(spec[1])
            sku = get_sku(spec[2] if len(spec) > 2 else None)
            if per_sku_db:
                db = char_db.get(sku.name)
                if db is None:
                    raise KeyError(
                        f"char_db has no entry for SKU {sku.name!r} "
                        f"(device {name!r}); has: {', '.join(char_db)}"
                    )
            else:
                db = char_db
            self.devices[name] = DeviceState(
                name=name,
                mode=mode,
                sku=sku,
                scheduler=CollocationScheduler(db, mode=mode, sku=sku, **kwargs),
            )
            if calibrator is not None:
                self.devices[name].scheduler.calibrator = calibrator
        if not self.devices:
            raise ValueError("a cluster needs at least one device")
        self.events = EventQueue()
        self.queue = AdmissionQueue()
        self.jobs: Dict[str, ClusterJob] = {}
        self.now = 0.0
        self.completed: List[str] = []
        self.rejected: List[Tuple[str, str]] = []
        self.migration_events: List[Dict] = []
        self.failure_events: List[Dict] = []
        # -- incremental re-timing state -----------------------------------
        # devices whose shared co-resident set changed at _dirty_t and have
        # not been re-priced yet (all marks in one batch share a timestamp;
        # the flush points guarantee a flush before any later event)
        self._dirty: Dict[str, float] = {}
        self._dirty_t = 0.0
        # effective-step memo per (mode, sku, ordered co-resident terms key)
        self._shared_steps_cache: Dict[Tuple, Tuple[float, ...]] = {}
        self._busy_cache: Dict[Tuple, float] = {}
        self._unplaceable_cache: Dict[Tuple, Optional[str]] = {}
        # gang arrival capacity memo (incremental engine), keyed like
        # _unplaceable_cache plus the gang shape — see _gang_unplaceable
        self._gang_capacity_cache: Dict[Tuple, int] = {}
        self._trial_reps: Optional[
            List[Tuple[CollocationScheduler, Tuple[CollocationMode, ...]]]
        ] = None
        # dispatch skip-scan: capacity only shrinks between these epoch
        # bumps, so entries that failed a full scan stay blocked until one
        self._capacity_epoch = 0
        self._blocked_epoch: Optional[int] = None
        self._blocked_keys: Set[str] = set()
        self._blocked_floor_key: Optional[Tuple] = None
        self._pending_entries: List[QueueEntry] = []
        self._next_reopen = float("inf")
        # -- forecast autoscaling state (policy="forecast" only) -----------
        self.forecast_config = (
            forecast
            if forecast is not None
            else (ForecastConfig() if policy == "forecast" else None)
        )
        self._fc_estimator = (
            self.forecast_config.build_estimator()
            if self.forecast_config is not None
            else None
        )
        self._fc_tick_pending = False
        self._fc_ticks = 0
        self._fc_last: Optional[RateForecast] = None
        self._fc_peak_rate = 0.0
        # latest serve spec seen: the representative the per-device
        # serve-capacity trials size the warm set against
        self._fc_serve_rep: Optional[Union[JobSpec, Workload]] = None
        self._fc_serve_seen = 0
        self._fc_session_s: Optional[float] = None  # EWMA of serve service time
        self._fc_capacity_cache: Dict[Tuple, int] = {}
        self._fc_prewarm_flips = 0
        self._fc_prewarm_preempts = 0
        self._fc_reactive = 0
        self._dev_index = {name: i for i, name in enumerate(self.devices)}
        # set to a list to record the live event stream (time, kind,
        # payload-sans-token) — the equivalence harness's comparison hook
        self.event_log: Optional[List[Tuple]] = None
        # instrumentation the perf suite reads (NOT part of the report —
        # the report schema is pinned by the artifact byte-compat contract)
        self.perf: Dict[str, int] = {
            "events_processed": 0,
            "retime_requests": 0,
            "retime_flushes": 0,
            "retime_jobs_repriced": 0,
            "retime_batched": 0,
            "shared_steps_hits": 0,
            "shared_steps_misses": 0,
            "dispatch_full_scans": 0,
            "dispatch_fast_scans": 0,
        }
        # -- observability (core/obs/) -------------------------------------
        # normalized to None when detached/disabled so every hook below is
        # a single attribute check on the hot path
        self.trace = trace if (trace is not None and trace.enabled) else None
        # online calibration (core/calib/): observe_step feeds it, the
        # device schedulers read it (wired above); None = no refinement
        self.calibrator = calibrator
        if self.trace is not None:
            self.trace.track("scheduler")
            self.trace.track("queue")
            self.trace.track("jobs")
            for name in self.devices:
                self.trace.track(f"dev:{name}")
            self.queue.attach_trace(self.trace, lambda: self.now)
            self._tr_queue_start: Dict[str, float] = {}
            self._tr_phase: Dict[str, Tuple[str, float]] = {}
            self._tr_occ: Dict[Tuple[str, str], Tuple[float, str]] = {}
            self._tr_fc_arrivals = 0
            self._tr_fc_last_tick_s = 0.0

    # -- trace input -----------------------------------------------------------

    def submit(
        self,
        spec: Union[JobSpec, Workload],
        arrival_s: float,
        *,
        epochs: int = 1,
        samples_per_epoch: int = 3200,
    ) -> ClusterJob:
        """Register a job to arrive at ``arrival_s`` (dynamic arrival).

        ``spec`` may be a flat ``JobSpec`` (single steady phase via the
        adapter) or a phase-aware ``Workload``; its phase sequence is
        resolved onto the job's concrete step count here, once."""
        if spec.name in self.jobs:
            raise KeyError(f"job {spec.name!r} already submitted")
        wl = as_workload(spec)
        cj = ClusterJob(
            spec=spec,
            arrival_s=float(arrival_s),
            epochs=int(epochs),
            samples_per_epoch=int(samples_per_epoch),
            kind=wl.kind.value,
            slo_step_s=wl.slo_step_s,
        )
        cj.plan = wl.resolve(cj.total_steps)
        self.jobs[spec.name] = cj
        self.events.push(arrival_s, EventKind.ARRIVAL, (spec.name,))
        return cj

    def inject_failure(self, device: str, units: Sequence[int], at_s: float) -> None:
        self.events.push(at_s, EventKind.FAILURE, (device, tuple(units)))

    def inject_repair(self, device: str, units: Sequence[int], at_s: float) -> None:
        self.events.push(at_s, EventKind.REPAIR, (device, tuple(units)))

    # -- event loop --------------------------------------------------------------

    def tick(self) -> Optional[Event]:
        """Process the next event; returns it (None if the heap is empty).

        Deferred shared re-pricings (the incremental engine's same-timestamp
        batch) are flushed before popping a strictly later event and again
        before control returns, so code stepping tick-by-tick always sees a
        consistent cluster between calls — only *within* a same-time run of
        events can step times be momentarily stale, which is exactly the
        window the full path's redundant intermediate re-timings occupy."""
        self._flush_if_due()
        if not self.events:
            return None
        ev = self.events.pop()
        self.now = max(self.now, ev.time_s)
        self.perf["events_processed"] += 1
        t = ev.time_s
        if self.event_log is not None:
            self._log_event(ev)
        if ev.kind == EventKind.ARRIVAL:
            self._on_arrival(ev.payload[0], t)
        elif ev.kind == EventKind.COMPLETION:
            self._on_completion(*ev.payload, t=t)
        elif ev.kind == EventKind.PHASE_TRANSITION:
            self._on_phase_transition(*ev.payload, t=t)
        elif ev.kind == EventKind.RECONFIG_DONE:
            self._on_reconfig_done(ev.payload[0], t)
        elif ev.kind == EventKind.FAILURE:
            self._on_failure(ev.payload[0], ev.payload[1], t)
        elif ev.kind == EventKind.REPAIR:
            self._on_repair(ev.payload[0], ev.payload[1], t)
        elif ev.kind == EventKind.GANG_RESERVE:
            self._on_gang_reserve(ev.payload[0], t)
        elif ev.kind == EventKind.FORECAST_TICK:
            self._on_forecast_tick(t)
        if self.trace is not None:
            self._trace_counters(t)
        self._flush_if_due()
        return ev

    def _flush_if_due(self) -> None:
        """Flush deferred re-pricings unless the next event shares their
        timestamp (then the batch is still open — flushing now would do
        work the rest of the same-time run immediately invalidates)."""
        if self._dirty:
            nt = self.events.peek_time()
            if nt is None or nt > self._dirty_t:
                self._flush_retimes()

    def run_until(self, t_end: float) -> None:
        while True:
            if self._dirty:
                nt = self.events.peek_time()
                if nt is None or nt > self._dirty_t:
                    self._flush_retimes()
                    continue  # the flush may schedule events <= t_end
            nt = self.events.peek_time()
            if nt is None or nt > t_end:
                break
            self.tick()
        self.now = max(self.now, t_end)

    def run(self) -> "ClusterReport":
        """Drain every event and return the end-of-run report."""
        while self.events or self._dirty:
            self.tick()
        return self.report()

    def _log_event(self, ev: Event) -> None:
        """Append the event to ``event_log`` if it is *live* — the stream
        both re-timing engines must agree on. Stale (token-mismatched)
        lifecycle events are omitted: the full path pops and drops them,
        the incremental path tombstones them before they surface; and the
        token itself is stripped from the payload because it counts
        re-timings, which is precisely what the engines do differently."""
        payload = ev.payload
        if ev.kind in (EventKind.COMPLETION, EventKind.PHASE_TRANSITION):
            dev_name, name, token = payload
            cj = self.jobs.get(name)
            dev = self.devices.get(dev_name)
            if (
                cj is None
                or dev is None
                or cj.token != token
                or name not in dev.running
            ):
                return
            payload = (dev_name, name)
        self.event_log.append((round(ev.time_s, 9), ev.kind.value, payload))

    # -- trace hooks (core/obs/) -------------------------------------------------
    #
    # Every method below is called only when ``self.trace`` is attached, and
    # none of them touch scheduler state — tracing a run cannot change it
    # (tests/test_obs.py pins a traced cell byte-identical to an untraced
    # one). Span bookkeeping lives cluster-side: occupancy and phase
    # intervals open here and close on completion/displacement, so the
    # recorder only ever sees closed spans.

    def _trace_counters(self, t: float) -> None:
        """Sample the counter series on an event boundary (post-handler)."""
        tr = self.trace
        tr.counter("queue_depth", t, len(self.queue))
        tr.counter("warm_set", t, len(self.queue.prewarmed_devices))
        running = 0
        for dev in self.devices.values():
            tr.counter(f"util:{dev.name}", t, round(self._busy_fraction(dev), 6))
            running += len(dev.running)
        tr.counter("running_jobs", t, running)
        slo_steps = 0.0
        slo_met = 0.0
        for j in self.jobs.values():
            slo_steps += j.slo_steps
            slo_met += j.slo_met_steps
        tr.counter(
            "slo_attainment",
            t,
            round(slo_met / slo_steps, 6) if slo_steps > 0 else 1.0,
        )

    def _tr_note_dispatch(self, cj: ClusterJob, t: float, *, first: bool) -> None:
        """Close the job's queued span and record the dispatch decision."""
        t0 = self._tr_queue_start.pop(cj.name, cj.arrival_s)
        self.trace.span("queue", f"{cj.name} queued", t0, t, cat="queue")
        self.trace.instant(
            "scheduler",
            "dispatch",
            t,
            args={
                "job": cj.name,
                "device": cj.device or "",
                "wait_s": round(t - t0, 9),
                "first": first,
            },
        )
        self._tr_phase[cj.name] = (cj.current_span().name, t)

    def _tr_close_phase(self, cj: ClusterJob, t: float) -> None:
        ph = self._tr_phase.pop(cj.name, None)
        if ph is not None:
            self.trace.span("jobs", f"{cj.name}:{ph[0]}", ph[1], t, cat="phase")

    def _tr_occupy(self, dev_name: str, key: str, label: str, t: float) -> None:
        self._tr_occ[(dev_name, key)] = (t, label)

    def _tr_release_occ(self, dev_name: str, key: str, t: float) -> None:
        rec = self._tr_occ.pop((dev_name, key), None)
        if rec is not None:
            self.trace.span(f"dev:{dev_name}", rec[1], rec[0], t, cat="occupancy")

    def _tr_completion_sample(self, cj: ClusterJob, profile: str, t: float) -> None:
        """Lifetime-average measured step vs the final predicted rate —
        the sample the calibration item gets even without live
        ``observe_step`` telemetry."""
        if cj.started_s is None or t <= cj.started_s or cj.total_steps <= 0:
            return
        self.trace.step_sample(
            t,
            cj.name,
            cj.spec.arch,
            profile,
            (t - cj.started_s) / cj.total_steps,
            cj.step_s,
            source="completion",
        )

    # -- handlers ---------------------------------------------------------------

    def _enqueue(self, name: str, cj: ClusterJob, t: float) -> None:
        """Queue a job for dispatch, remembering the entry as a fresh
        placement candidate for the skip-scan dispatcher."""
        e = self.queue.push(name, cj, priority=cj.spec.priority, enqueued_s=t)
        self._pending_entries.append(e)
        if self.trace is not None:
            self._tr_queue_start[name] = t

    def _on_arrival(self, name: str, t: float) -> None:
        cj = self.jobs[name]
        if cj.world_size > 1:
            reason = self._gang_unplaceable(cj)
        else:
            reason = self._definitely_unplaceable(cj.spec)
        if reason is not None:
            cj.rejected_reason = reason
            self.rejected.append((name, reason))
            if self.trace is not None:
                self.trace.instant(
                    "scheduler", "reject", t, args={"job": name, "reason": reason}
                )
            return
        if self._fc_estimator is not None:
            self._fc_observe_arrival(cj, t)
        self._enqueue(name, cj, t)
        self._dispatch(t)
        self._maybe_migrate(t)

    def _on_completion(self, dev_name: str, name: str, token: int, *, t: float) -> None:
        dev = self.devices[dev_name]
        cj = self.jobs[name]
        if cj.token != token or name not in dev.running:
            return  # stale event — the job was re-timed, migrated, or killed
        cj.pending_event = None  # this event; it just left the heap
        if cj.world_size > 1:
            self._finish_gang(cj, t)
            return
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        cj.steps_done = float(cj.total_steps)  # clamp fp residue
        cj.finished_s = t
        cj.device = None
        if self.trace is not None:
            self._tr_close_phase(cj, t)
            self._tr_release_occ(dev.name, name, t)
            self._tr_completion_sample(cj, dev.assignments[name].profile, t)
        del dev.running[name]
        del dev.assignments[name]
        self.completed.append(name)
        if (
            self._fc_estimator is not None
            and cj.kind == "serve"
            and cj.started_s is not None
        ):
            # learn the serve session's device-holding time — the
            # "service time" in the autoscaler's Little's-law sizing
            self._fc_note_session(t - cj.started_s)
        self._capacity_epoch += 1
        if dev.mode != CollocationMode.MIG and dev.running:
            # a departure lowers the contention factors for every neighbour
            self._retime_shared(dev, t)
        self._dispatch(t)
        self._maybe_migrate(t)

    def _finish_gang(self, cj: "ClusterJob", t: float) -> None:
        """Gang completion: every member device frees its slice at once —
        the lifecycle event lives on the primary (rank-0) device, but the
        gang occupies all of ``member_devices``."""
        for dname in dict.fromkeys(cj.member_devices):
            d = self.devices[dname]
            self._accrue_busy(d, t)
            self._update_progress(d, t)
        cj.steps_done = float(cj.total_steps)  # clamp fp residue
        cj.finished_s = t
        cj.device = None
        if self.trace is not None:
            self._tr_close_phase(cj, t)
        for rank, dname in enumerate(cj.member_devices):
            d = self.devices[dname]
            d.running.pop(cj.name, None)
            d.assignments.pop(member_name(cj.name, rank), None)
            if self.trace is not None:
                self._tr_release_occ(dname, member_name(cj.name, rank), t)
        cj.member_devices = ()
        self.completed.append(cj.name)
        self._capacity_epoch += 1
        # members are MIG-only: no shared neighbours to re-time
        self._dispatch(t)
        self._maybe_migrate(t)

    def _on_phase_transition(self, dev_name: str, name: str, token: int, *, t: float) -> None:
        """A placed job crossed into its next phase: its demand vector — and
        with it every shared neighbour's contention — just changed."""
        dev = self.devices[dev_name]
        cj = self.jobs[name]
        if cj.token != token or name not in dev.running:
            return  # stale event — the job was re-timed, migrated, or killed
        cj.pending_event = None  # this event; it just left the heap
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        # snap fp residue onto the integer boundary the event fired for, so
        # the derived current phase is exactly the next span
        boundary = round(cj.steps_done)
        if abs(cj.steps_done - boundary) < 1e-6:
            cj.steps_done = float(boundary)
        cj.phase_transitions += 1
        if self.trace is not None:
            self._tr_close_phase(cj, t)
            self._tr_phase[name] = (cj.current_span().name, t)
        if dev.mode == CollocationMode.MIG:
            if cj.world_size > 1:
                # every member re-prices at the new demand; the gang step
                # is the slowest member plus the (unchanged-placement)
                # communication overhead
                self._reprice_gang(cj, t)
                self._maybe_migrate(t)
                return
            # isolation (F3): only this job's own step time changes
            a = dev.assignments[name]
            cj.step_s = dev.scheduler.predict_step(
                cj.spec, a.profile, cj.active_demand()
            )
            a.predicted_step_s = cj.step_s
            self._schedule_next_event(dev, cj, t)
        else:
            # shared device: the new vector feeds everyone's contention
            self._retime_shared(dev, t)
        # a demand change is composition drift — let the adaptive policy
        # reconsider the device partitioning
        self._maybe_migrate(t)

    def _on_reconfig_done(self, dev_name: str, t: float) -> None:
        dev = self.devices[dev_name]
        self._accrue_busy(dev, t)
        if dev.pending_mode is not None:
            dev.mode = dev.pending_mode
            dev.scheduler.mode = dev.pending_mode
            dev.pending_mode = None
            dev.mode_history.append((t, dev.mode.value))
        self._capacity_epoch += 1  # the device re-opened
        self._dispatch(t)

    def _on_failure(self, dev_name: str, units: Sequence[int], t: float) -> None:
        dev = self.devices[dev_name]
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        dev.failed_units |= set(units)
        self._capacity_epoch += 1
        self._dirty.pop(dev.name, None)  # a pending re-price of the dead set
        if dev.mode == CollocationMode.MIG:
            killed_specs, survivors = split_by_failure(
                list(dev.assignments.values()), dev.failed_units, dev.sku
            )
            survivor_names = {a.job.name for a in survivors}
        else:
            # no isolation on a shared device: every job dies with it
            killed_specs = [
                dataclasses.replace(
                    cj.spec, priority=cj.spec.priority + REQUEUE_PRIORITY_BUMP
                )
                for cj in dev.running.values()
            ]
            survivor_names = set()
        killed_names = []
        hit_gangs: List[str] = []
        for spec in killed_specs:
            killed_names.append(spec.name)
            gang = getattr(spec, "gang", None)
            if gang is not None:
                # a member spec: the whole gang dies with it — widen the
                # kill to the gang's other devices and re-queue it once
                if gang not in hit_gangs:
                    hit_gangs.append(gang)
                continue
            self._displace(dev, spec.name, t, new_spec=spec)
        for gang in hit_gangs:
            self._requeue_gang(self.jobs[gang], t)
        self.failure_events.append(
            {
                "t_s": t,
                "device": dev_name,
                "units": sorted(set(units)),
                "killed": killed_names,
                "survivors": sorted(survivor_names),
            }
        )
        self._dispatch(t)
        self._maybe_migrate(t)

    def _on_repair(self, dev_name: str, units: Sequence[int], t: float) -> None:
        dev = self.devices[dev_name]
        self._accrue_busy(dev, t)
        dev.failed_units -= set(units)
        self._capacity_epoch += 1
        self._dispatch(t)
        self._maybe_migrate(t)

    # -- admission: priority + backfill -------------------------------------------

    def _definitely_unplaceable(self, spec: JobSpec) -> Optional[str]:
        """A job is rejected outright only if no device could run it even
        empty, under any mode the policy allows — everything else waits.

        An empty-device trial depends only on the device's (SKU, mode) —
        same char DB and placement tree — so dedupe to one trial per
        reachable (SKU, mode) pair instead of one per device: the first
        device of each SKU stands in for its generation. A mixed fleet is
        the point: a big-memory job unplaceable on every 40GB tree waits
        for (or lands on) the 80GB devices instead of being rejected.

        The verdict depends only on (arch, shape, repack floor, phase-peak
        multiplier) — the fleet's reachable (SKU, mode) pairs are fixed for
        a run under every policy (static/planner modes never change;
        adaptive trials all modes regardless) — so the incremental engine
        memoizes it per that key: a 10^5-arrival trace drawing from a
        handful of registry shapes pays for the trial schedules once."""
        if self.retime == "incremental":
            key = (
                spec.arch,
                spec.suite.name,
                getattr(spec, "min_profile", None),
                peak_demand_multiplier(spec),
            )
            if key not in self._unplaceable_cache:
                self._unplaceable_cache[key] = self._unplaceable_scan(spec)
            return self._unplaceable_cache[key]
        return self._unplaceable_scan(spec)

    def _unplaceable_scan(self, spec: JobSpec) -> Optional[str]:
        if self._trial_reps is None:
            reps: Dict[str, CollocationScheduler] = {}
            sku_modes: Dict[str, Tuple[CollocationMode, ...]] = {}
            for d in self.devices.values():
                if d.sku.name not in reps:
                    reps[d.sku.name] = d.scheduler
                    sku_modes[d.sku.name] = ()
                if self.policy in ("adaptive", "forecast"):
                    sku_modes[d.sku.name] = tuple(CollocationMode)
                elif d.mode not in sku_modes[d.sku.name]:
                    sku_modes[d.sku.name] += (d.mode,)
            self._trial_reps = [
                (reps[sn], sku_modes[sn]) for sn in reps
            ]
        last_reason = "no devices"
        for scheduler, modes in self._trial_reps:
            # trial schedules must not leave straggler predictions behind
            # for jobs that were never deployed
            snapshot = dict(scheduler._predicted)
            try:
                for m in modes:
                    trial = scheduler.schedule([spec], mode=m)
                    if trial.assignments:
                        return None
                    if trial.rejections:
                        last_reason = trial.rejections[0].reason
            finally:
                scheduler._predicted = snapshot
        return f"unplaceable on any empty device: {last_reason}"

    def _dispatch(self, t: float) -> None:
        """Drain the admission queue: strict priority order with backfill —
        a blocked high-priority job does not stop later entries that fit.

        The incremental engine remembers the outcome: between capacity
        epochs (completion / failure / repair / reconfiguration / displace)
        placements only *shrink* capacity, and phase transitions never
        change placeability (admission budgets the phase-peak working set,
        a per-job constant) — so entries that failed the last full scan
        must still fail, and only entries queued since then are tried."""
        if self._dirty and self.queue:
            # re-price before placing: the candidate admission below reads
            # the co-resident sets the deferred re-timings are about to touch
            self._flush_retimes()
        if self.retime != "incremental":
            self._dispatch_scan(t, self.queue.ordered())
            return
        if t >= self._next_reopen:
            # a reconfiguring device re-opened purely by time passing (its
            # RECONFIG_DONE shares this timestamp but may not have popped
            # yet) — conservative: rescan everything
            self._recompute_next_reopen(t)
            self._blocked_epoch = None
        if self._blocked_epoch == self._capacity_epoch:
            self.perf["dispatch_fast_scans"] += 1
            pending = [
                e
                for e in self._pending_entries
                if self.queue.get(e.key) is e and e.key not in self._blocked_keys
            ]
            self._pending_entries = []
            pending.sort(key=QueueEntry.sort_key)
            self._dispatch_scan(t, pending, known_blocked=True)
            return
        self.perf["dispatch_full_scans"] += 1
        self._pending_entries = []
        self._blocked_keys = set()
        self._blocked_floor_key = None
        self._dispatch_scan(t, self.queue.ordered())
        self._blocked_epoch = self._capacity_epoch

    def _dispatch_scan(
        self, t: float, entries: List[QueueEntry], *, known_blocked: bool = False
    ) -> None:
        """One in-order placement pass over ``entries``. With
        ``known_blocked`` the pass is a fast scan over fresh candidates
        only: previously blocked entries are not re-tried, but still count
        as "an earlier entry is blocked" for backfill-overtake accounting
        when they sort ahead of a candidate that places."""
        blocked_any = False
        floor = self._blocked_floor_key
        for entry in entries:
            cj = entry.item
            placed = False
            if cj.world_size > 1:
                placed = self._try_place_gang(cj, t)
            else:
                for dev in self._placement_order(cj):
                    if self._try_place(dev, cj, t):
                        placed = True
                        break
            if placed:
                self.queue.remove(entry.key)
                first = cj.started_s is None
                if cj.started_s is None:
                    cj.started_s = t
                if self.trace is not None:
                    self._tr_note_dispatch(cj, t, first=first)
                if blocked_any or (
                    known_blocked
                    and floor is not None
                    and floor < entry.sort_key()
                ):
                    self.queue.note_backfill_overtake()
                    if self.trace is not None:
                        self.trace.instant(
                            "scheduler",
                            "backfill_overtake",
                            t,
                            args={"job": cj.name, "device": cj.device or ""},
                        )
            else:
                blocked_any = True
                if self.retime == "incremental":
                    self._blocked_keys.add(entry.key)
                    k = entry.sort_key()
                    if floor is None or k < floor:
                        floor = k
        self._blocked_floor_key = floor

    def _placement_order(self, cj: ClusterJob):
        """Device iteration order for singleton placement. The forecast
        policy routes serve sessions decode-first — MIG (or MIG-pending)
        devices ahead of shared ones — so sessions land on the warmed
        slices instead of crowding the shared training devices. Every
        other policy keeps the fleet's insertion order (the byte-compat
        contract for existing artifacts)."""
        if self.policy != "forecast" or cj.kind != "serve":
            return self.devices.values()
        return sorted(
            self.devices.values(),
            key=lambda d: (
                0 if (d.pending_mode or d.mode) == CollocationMode.MIG else 1,
                self._dev_index[d.name],
            ),
        )

    def _recompute_next_reopen(self, t: float) -> None:
        nxt = float("inf")
        for d in self.devices.values():
            if d.reconfiguring_until > t:
                nxt = min(nxt, d.reconfiguring_until)
        self._next_reopen = nxt

    def _try_place(self, dev: DeviceState, cj: ClusterJob, t: float) -> bool:
        if not dev.available(t):
            return False
        if self.queue.reserved_against(cj.name, dev.name):
            if self.trace is not None:
                self.trace.instant(
                    "scheduler",
                    "veto_reserved",
                    t,
                    args={
                        "job": cj.name,
                        "device": dev.name,
                        "held_by": self.queue.reserved_by,
                    },
                )
            return False  # held for a starved gang — backfill must not refill
        if self.queue.prewarm_blocks(dev.name, cj.kind):
            if self.trace is not None:
                self.trace.instant(
                    "scheduler",
                    "veto_prewarm",
                    t,
                    args={
                        "job": cj.name,
                        "device": dev.name,
                        "warmed_for": self.queue.prewarmed_kind(dev.name),
                    },
                )
            return False  # pre-warmed for another kind ahead of a ramp
        if dev.mode == CollocationMode.MIG:
            sched = dev.scheduler.schedule(
                [cj.spec],
                blocked_units=frozenset(dev.failed_units),
                mode=CollocationMode.MIG,
                existing=[a.placement for a in dev.assignments.values()],
                active_phases={cj.name: cj.active_demand()},
            )
            if not sched.assignments:
                return False
            self._accrue_busy(dev, t)
            self._bind(dev, cj, sched.assignments[0], t)
            return True
        # shared device (naive / MPS): re-admit the whole set so the mode's
        # contention model re-times everyone; the candidate is admitted only
        # if every already-running job keeps its place (no preemption).
        if dev.failed_units:
            return False  # degraded shared device takes no new work
        if self.retime == "incremental":
            fast = self._try_place_shared_fast(dev, cj, t)
            if fast is not None:
                return fast
        specs = [j.spec for j in dev.running.values()] + [cj.spec]
        active = {j.name: j.active_demand() for j in dev.running.values()}
        active[cj.name] = cj.active_demand()
        sched = dev.scheduler.schedule(specs, mode=dev.mode, active_phases=active)
        placed_names = {a.job.name for a in sched.assignments}
        if cj.name not in placed_names:
            return False
        if not all(n in placed_names for n in dev.running):
            return False
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        dev.running[cj.name] = cj
        cj.device = dev.name
        cj.last_update_s = t
        if self.trace is not None:
            self._tr_occupy(dev.name, cj.name, f"{cj.name} {dev.mode.value}", t)
        for a in sched.assignments:
            j = dev.running[a.job.name]
            j.step_s = a.predicted_step_s
            dev.assignments[a.job.name] = a
            self._schedule_next_event(dev, j, t)
        self._dirty.pop(dev.name, None)  # the full re-admission re-priced all
        return True

    def _try_place_shared_fast(
        self, dev: DeviceState, cj: ClusterJob, t: float
    ) -> Optional[bool]:
        """Shared-device admission without rebuilding the scheduling model:
        replay ``_schedule_shared``'s admission scan (priority order, running
        footprints prefix-summed against the HBM budget) from the memoized
        per-job verdicts, then re-price the grown set through the
        contention-step memo. Returns None to defer to the full model in
        the cases it owns (a *running* job failing re-admission cannot
        happen — footprint sums of a subset are monotone — but the full
        path is the authority if it ever did)."""
        order = sorted(
            list(dev.running.values()) + [cj], key=lambda j: -j.spec.priority
        )
        budget = dev.sku.slice_bytes
        used = 0.0
        for j in order:
            adm = dev.scheduler.shared_admission(j.spec)
            if adm is None or not adm[1] or used + adm[0] > budget:
                if j is cj:
                    return False
                return None  # pragma: no cover - running jobs always re-admit
            used += adm[0]
        steps = self._shared_steps(dev, order)
        if steps is None:  # pragma: no cover - admitted jobs have records
            return None
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        dev.running[cj.name] = cj
        cj.device = dev.name
        cj.last_update_s = t
        if self.trace is not None:
            self._tr_occupy(dev.name, cj.name, f"{cj.name} {dev.mode.value}", t)
        self._apply_shared_steps(dev, order, steps, t)
        self._dirty.pop(dev.name, None)  # the placement re-priced everyone
        return True

    def _bind(self, dev: DeviceState, cj: ClusterJob, a: Assignment, t: float) -> None:
        """Bind a job to its MIG instance and schedule its next lifecycle
        event from ``t`` — the one binding invariant, shared by the
        dispatch path and the replan commit (which binds at the *end* of
        the reconfiguration window)."""
        dev.assignments[cj.name] = a
        dev.running[cj.name] = cj
        cj.device = dev.name
        cj.step_s = a.predicted_step_s
        cj.last_update_s = t
        if self.trace is not None:
            self._tr_occupy(
                dev.name, cj.name, f"{cj.name} {a.placement.profile}", t
            )
        self._schedule_next_event(dev, cj, t)

    # -- gang scheduling (core/gang/) -------------------------------------------

    def _member_specs(self, cj: ClusterJob) -> List[Workload]:
        """Per-rank member specs: the gang's workload re-labelled
        ``name#r<rank>`` with ``gang`` set, so admission prices the member
        memory fraction (workload.peak_demand_multiplier) and
        elastic.split_by_failure can map a hit member back to its gang."""
        wl = as_workload(cj.spec)
        return [
            dataclasses.replace(wl, name=member_name(cj.name, r), gang=cj.name)
            for r in range(cj.world_size)
        ]

    def _gang_collective_s(self, cj: ClusterJob, dev: DeviceState) -> float:
        """Per-step collective seconds the comms model scales per axis: the
        full-device solo record's collective term under the gang's active
        demand — inter-member traffic tracks the whole job's collective
        volume, not the member-scaled busy terms."""
        rec = dev.scheduler.char_db.get(
            (cj.spec.arch, cj.spec.suite.name, dev.sku.full_profile)
        )
        if rec is None:
            return 0.0
        return float(rec.get("collective_s", 0.0)) * cj.active_demand().collective

    def _gang_devices(self, cj: ClusterJob, t: float) -> List[DeviceState]:
        """MIG devices the gang may place on right now, fleet order."""
        return [
            dev
            for dev in self.devices.values()
            if dev.mode == CollocationMode.MIG
            and dev.available(t)
            and not self.queue.reserved_against(cj.name, dev.name)
            and not self.queue.prewarm_blocks(dev.name, cj.kind)
        ]

    def _try_place_gang(self, cj: ClusterJob, t: float) -> bool:
        """All-or-nothing gang placement: probe every eligible device's
        member capacity under its current occupancy, hand the capacity
        vector to the placement search (core/gang/placement.py), and bind
        the winning plan — or note the gang blocked (starting the
        starvation-bound clock) and place nothing."""
        members = self._member_specs(cj)
        mdemand = member_demand(cj.spec, cj.active_demand())
        devs = self._gang_devices(cj, t)
        if not devs:
            self._gang_note_blocked(cj, t)
            return False
        active = {m.name: mdemand for m in members}
        snapshots = [(d, dict(d.scheduler._predicted)) for d in devs]
        try:

            def trial(dev: DeviceState, chunk: List[Workload]):
                return dev.scheduler.schedule(
                    chunk,
                    blocked_units=frozenset(dev.failed_units),
                    mode=CollocationMode.MIG,
                    existing=[a.placement for a in dev.assignments.values()],
                    active_phases={m.name: active[m.name] for m in chunk},
                )

            caps = [len(trial(d, members).assignments) for d in devs]

            def probe(idx: int, ranks: Sequence[int]):
                chunk = [members[r] for r in ranks]
                sched = trial(devs[idx], chunk)
                if len(sched.assignments) != len(chunk):
                    return None
                by_name = {a.job.name: a for a in sched.assignments}
                return [
                    (by_name[m.name].placement, by_name[m.name].predicted_step_s)
                    for m in chunk
                ]

            plan = plan_gang(
                resolve_parallelism(cj.spec),
                [d.name for d in devs],
                caps,
                probe,
                self._gang_collective_s(cj, devs[0]),
                prefer=self.gang_placement,
                link=self.gang_link,
            )
        finally:
            # trial schedules must not leave straggler predictions behind
            for d, snap in snapshots:
                d.scheduler._predicted = snap
        if plan is None:
            self._gang_note_blocked(cj, t)
            return False
        self._bind_gang(cj, members, plan, t)
        return True

    def _bind_gang(
        self, cj: ClusterJob, members: List[Workload], plan: GangPlan, t: float
    ) -> None:
        """Bind every member to its planned slice. The gang is ONE
        ClusterJob registered in each member device's running map (the
        progress guard makes the multi-registration idempotent); its
        single lifecycle event lives on the primary (rank-0) device."""
        if self.trace is not None:
            self.trace.instant(
                "scheduler",
                "gang_place",
                t,
                args={
                    "gang": cj.name,
                    "prefer": self.gang_placement,
                    **plan.provenance(),
                },
            )
        for slot in plan.slots:
            dev = self.devices[slot.device]
            self._accrue_busy(dev, t)
            dev.assignments[member_name(cj.name, slot.rank)] = Assignment(
                members[slot.rank], slot.placement, slot.step_s
            )
            dev.running[cj.name] = cj
            if self.trace is not None:
                self._tr_occupy(
                    slot.device,
                    member_name(cj.name, slot.rank),
                    f"{cj.name}#r{slot.rank} {slot.placement.profile}",
                    t,
                )
        cj.member_devices = plan.devices
        cj.gang_spread = plan.spread
        cj.device = plan.slots[0].device
        cj.step_s = plan.step_s
        cj.last_update_s = t
        # the reservation veto (if this gang held one) lifts when the
        # dispatcher removes the entry — blocked singletons may fit again
        self._capacity_epoch += 1
        self._schedule_next_event(self.devices[cj.device], cj, t)

    def _reprice_gang(self, cj: ClusterJob, t: float) -> None:
        """Phase transition on a gang: re-price every member at the new
        demand vector and re-derive the comm-priced gang step. Placements
        do not move — only the demand changed (F3 per member slice)."""
        mdemand = member_demand(cj.spec, cj.active_demand())
        steps = []
        rank_device: Dict[int, str] = {}
        for rank, dname in enumerate(cj.member_devices):
            d = self.devices[dname]
            a = d.assignments[member_name(cj.name, rank)]
            step = d.scheduler.predict_step(a.job, a.profile, mdemand)
            a.predicted_step_s = step
            steps.append(step)
            rank_device[rank] = dname
        primary = self.devices[cj.member_devices[0]]
        cj.step_s = gang_step_s(
            steps,
            resolve_parallelism(cj.spec),
            rank_device,
            self._gang_collective_s(cj, primary),
            self.gang_link,
        )
        self._schedule_next_event(primary, cj, t)

    def _requeue_gang(self, cj: ClusterJob, t: float) -> None:
        """Gang-wide failure re-queue: one member's slice died, so every
        surviving member is torn down on its device and the gang re-enters
        the queue once, priority-bumped, rolled back to its last
        coordinated checkpoint — members advance in lockstep, so a partial
        gang can make no progress."""
        for dname in dict.fromkeys(cj.member_devices):
            d = self.devices[dname]
            self._accrue_busy(d, t)
            self._update_progress(d, t)
        for rank, dname in enumerate(cj.member_devices):
            d = self.devices[dname]
            d.running.pop(cj.name, None)
            d.assignments.pop(member_name(cj.name, rank), None)
            if self.trace is not None:
                self._tr_release_occ(dname, member_name(cj.name, rank), t)
        if self.trace is not None:
            self._tr_close_phase(cj, t)
        cj.member_devices = ()
        cj.rollback_to_checkpoint()
        cj.token += 1
        if cj.pending_event is not None:
            self.events.tombstone(cj.pending_event)
            cj.pending_event = None
        cj.device = None
        cj.spec = dataclasses.replace(
            cj.spec, priority=cj.spec.priority + REQUEUE_PRIORITY_BUMP
        )
        cj.gang_requeues += 1
        self._capacity_epoch += 1
        self._enqueue(cj.name, cj, t)

    # -- gang starvation bound (reserve-or-release) ----------------------------

    def _gang_note_blocked(self, cj: ClusterJob, t: float) -> None:
        """A gang just failed a placement pass: start the starvation-bound
        clock (once). Holders of the reservation simply keep waiting for
        their reserved devices to drain — the heartbeat re-check is driven
        by the GANG_RESERVE event itself."""
        if self.trace is not None:
            self.trace.instant(
                "scheduler",
                "gang_blocked",
                t,
                args={"gang": cj.name, "world_size": cj.world_size},
            )
        if not cj.gang_reserve_pending and self.queue.reserved_by != cj.name:
            self._push_gang_reserve(cj, t)

    def _push_gang_reserve(self, cj: ClusterJob, t: float) -> None:
        if cj.gang_reserve_pending:
            return
        cj.gang_reserve_pending = True
        self.events.push(
            t + self.gang_reserve_after_s, EventKind.GANG_RESERVE, (cj.name,)
        )

    def _on_gang_reserve(self, name: str, t: float) -> None:
        """The starvation bound elapsed for a queued gang: grant it the
        admission queue's (exclusive) device reservation so backfilling
        singletons stop refilling the capacity it needs, then re-drain.
        Re-fires as a heartbeat while the gang waits — re-checking (and
        widening) the reserved set against failures, and rejecting the
        gang outright if the fleet can no longer host it at all."""
        cj = self.jobs.get(name)
        if cj is None:
            return
        cj.gang_reserve_pending = False
        if name not in self.queue or cj.device is not None:
            return  # stale: the gang placed (or was rejected) while waiting
        if self.queue.reserved_by not in (None, name):
            # another gang holds the claim (it was blocked first); retry
            # after its reservation resolves
            self._push_gang_reserve(cj, t)
            return
        devices = self._gang_reservation_set(cj)
        if devices is None:
            self._reject_queued_gang(
                cj,
                "gang capacity lost: surviving MIG devices cannot host "
                f"{cj.world_size} members even when empty",
                t,
            )
            return
        self.queue.reserve(name, devices)
        self._capacity_epoch += 1
        self._push_gang_reserve(cj, t)  # heartbeat until placed/rejected
        self._dispatch(t)

    def _reject_queued_gang(self, cj: ClusterJob, reason: str, t: float) -> None:
        self.queue.remove(cj.name)  # releases any reservation it held
        cj.rejected_reason = reason
        self.rejected.append((cj.name, reason))
        if self.trace is not None:
            self.trace.instant(
                "scheduler", "gang_reject", t, args={"gang": cj.name, "reason": reason}
            )
        self._capacity_epoch += 1  # a released reservation re-opens devices
        self._dispatch(t)

    def _gang_member_capacity(
        self, dev: DeviceState, members: List[Workload], mdemand, *, blocked
    ) -> int:
        """How many gang members an *empty* tree of this device could host
        (its running jobs drain; ``blocked`` carries the failed units)."""
        if dev.mode != CollocationMode.MIG:
            return 0
        snapshot = dict(dev.scheduler._predicted)
        try:
            sched = dev.scheduler.schedule(
                members,
                blocked_units=frozenset(blocked),
                mode=CollocationMode.MIG,
                active_phases={m.name: mdemand for m in members},
            )
            return len(sched.assignments)
        finally:
            dev.scheduler._predicted = snapshot

    def _gang_reservation_set(self, cj: ClusterJob) -> Optional[List[str]]:
        """The concrete device set reserved for a starved gang: the fewest
        devices (capacity-descending, fleet order on ties) whose empty
        trees — minus currently failed units — cover ``world_size``
        members. None when the surviving fleet cannot cover the gang."""
        members = self._member_specs(cj)
        mdemand = member_demand(cj.spec, cj.active_demand())
        caps = [
            (
                self._gang_member_capacity(
                    dev, members, mdemand, blocked=dev.failed_units
                ),
                i,
                dev.name,
            )
            for i, dev in enumerate(self.devices.values())
        ]
        caps.sort(key=lambda c: (-c[0], c[1]))
        chosen: List[str] = []
        left = cj.world_size
        for cap, _, dname in caps:
            if left <= 0:
                break
            if cap <= 0:
                break  # sorted: nothing useful follows
            chosen.append(dname)
            left -= cap
        return chosen if left <= 0 else None

    def _gang_unplaceable(self, cj: ClusterJob) -> Optional[str]:
        """Arrival-time gang rejection: the fleet's *pristine* MIG trees
        (no failed units — the repair path may heal) must be able to host
        every member at once. Shared-only fleets reject gangs outright —
        members need slice isolation. Memoized per (SKU composition is
        fixed) gang shape under the incremental engine, mirroring
        _definitely_unplaceable."""
        spec = cj.spec
        key = (
            spec.arch,
            spec.suite.name,
            getattr(spec, "min_profile", None),
            peak_demand_multiplier(spec),
            cj.world_size,
        )
        if self.retime == "incremental" and key in self._gang_capacity_cache:
            total = self._gang_capacity_cache[key]
        else:
            members = self._member_specs(cj)
            mdemand = member_demand(cj.spec, cj.active_demand())
            total = sum(
                self._gang_member_capacity(dev, members, mdemand, blocked=())
                for dev in self.devices.values()
            )
            self._gang_capacity_cache[key] = total
        if total >= cj.world_size:
            return None
        return (
            f"gang unplaceable: fleet MIG capacity {total} member slices "
            f"< world_size {cj.world_size}"
        )

    def _retime_shared(self, dev: DeviceState, t: float) -> None:
        """Re-price a shared device after a departure or a neighbour's
        phase transition (progress must already be up to date at ``t``) —
        the contention inputs are the *active phase* vectors of whatever is
        co-resident now.

        The incremental engine *invalidates* every co-resident lifecycle
        event now — exactly like the full engine's eager re-push, so a
        same-timestamp boundary event of a neighbour is absorbed into the
        re-price rather than firing — but defers the actual re-pricing
        until the same-timestamp batch closes (a run of k events at one
        instant re-prices the survivors once, not k times); the full
        engine re-runs the whole scheduling model immediately."""
        self.perf["retime_requests"] += 1
        if self.retime == "incremental":
            if self._dirty:
                if t > self._dirty_t:  # pragma: no cover - direct-call safety
                    self._flush_retimes()
                elif dev.name in self._dirty:
                    self.perf["retime_batched"] += 1
            self._dirty[dev.name] = t
            self._dirty_t = t
            for j in dev.running.values():
                j.token += 1
                if j.pending_event is not None:
                    self.events.tombstone(j.pending_event)
                    j.pending_event = None
            return
        self._retime_shared_full(dev, t)

    def _retime_shared_full(self, dev: DeviceState, t: float) -> None:
        """The reference re-pricing: re-run the full contention model."""
        sched = dev.scheduler.schedule(
            [j.spec for j in dev.running.values()],
            mode=dev.mode,
            active_phases={
                j.name: j.active_demand() for j in dev.running.values()
            },
        )
        self.perf["retime_jobs_repriced"] += len(sched.assignments)
        for a in sched.assignments:
            j = dev.running[a.job.name]
            j.step_s = a.predicted_step_s
            dev.assignments[a.job.name] = a
            self._schedule_next_event(dev, j, t)

    def _flush_retimes(self) -> None:
        """Close the deferred-re-timing batch: re-price every marked device
        at its mark time. Runs before any strictly later event is popped,
        before any placement, and before a migration look — the three
        consumers of fresh step times."""
        if not self._dirty:
            return
        marks = list(self._dirty.items())
        self._dirty.clear()
        for name, mt in marks:
            dev = self.devices[name]
            if not dev.running or dev.mode == CollocationMode.MIG:
                continue  # drained (or repartitioned) before the batch closed
            self.perf["retime_flushes"] += 1
            order = sorted(dev.running.values(), key=lambda j: -j.spec.priority)
            steps = self._shared_steps(dev, order)
            if steps is None:  # pragma: no cover - running jobs have records
                self._retime_shared_full(dev, mt)
                continue
            self._apply_shared_steps(dev, order, steps, mt)

    def _shared_steps(
        self, dev: DeviceState, order: List[ClusterJob]
    ) -> Optional[Tuple[float, ...]]:
        """Effective steps for a shared co-resident set (admission order),
        memoized per (mode, SKU, ordered (arch, shape, demand) tuples) —
        the phase-transition-schedule memo: a composition the fleet has
        priced before (the common case on a city-scale trace drawing from
        a small registry) is a dict hit, not a contention-model run."""
        key = (
            dev.mode,
            dev.sku.name,
            tuple(
                (j.spec.arch, j.spec.suite.name, j.active_demand())
                for j in order
            ),
        )
        steps = self._shared_steps_cache.get(key)
        if steps is not None:
            self.perf["shared_steps_hits"] += 1
            return steps
        terms = []
        for j in order:
            tm = dev.scheduler.solo_terms(j.spec, j.active_demand())
            if tm is None:
                return None
            terms.append(tm)
        steps = shared_effective_steps(
            dev.mode,
            terms,
            switch_overhead_frac=dev.sku.naive_switch_overhead_frac,
        )
        self.perf["shared_steps_misses"] += 1
        if len(self._shared_steps_cache) > 200_000:
            self._shared_steps_cache.clear()  # bound memory on huge traces
        self._shared_steps_cache[key] = steps
        return steps

    def _apply_shared_steps(
        self,
        dev: DeviceState,
        order: List[ClusterJob],
        steps: Tuple[float, ...],
        t: float,
    ) -> None:
        """Commit re-priced steps in admission order — the same per-job
        writes (step_s, assignment, straggler prediction, next lifecycle
        event) the full path performs, in the same order."""
        full = dev.sku.full_profile
        predicted = dev.scheduler._predicted
        for j, step in zip(order, steps):
            j.step_s = step
            a = dev.assignments.get(j.name)
            if a is None:
                dev.assignments[j.name] = Assignment(
                    j.spec, Placement(full, 0), step
                )
            else:
                a.job = j.spec
                a.predicted_step_s = step
            predicted[j.name] = step
            self._schedule_next_event(dev, j, t)
        self.perf["retime_jobs_repriced"] += len(order)

    def _schedule_next_event(self, dev: DeviceState, cj: ClusterJob, t: float) -> None:
        """Schedule the job's next lifecycle event at its current step rate:
        COMPLETION if its active phase runs to the end of the job, else the
        PHASE_TRANSITION at the phase boundary. Either way the previous
        pending event is token-invalidated AND tombstoned, so the heap
        reclaims it without waiting for its time to come up."""
        cj.token += 1
        if cj.pending_event is not None:
            self.events.tombstone(cj.pending_event)
        span = cj.current_span()
        if span.end_step >= cj.total_steps:
            finish = t + cj.remaining_steps * cj.step_s
            cj.pending_event = self.events.push(
                finish, EventKind.COMPLETION, (dev.name, cj.name, cj.token)
            )
        else:
            boundary = t + max(0.0, span.end_step - cj.steps_done) * cj.step_s
            cj.pending_event = self.events.push(
                boundary, EventKind.PHASE_TRANSITION, (dev.name, cj.name, cj.token)
            )

    # -- progress & utilization accounting ------------------------------------------

    def _update_progress(self, dev: DeviceState, t: float) -> None:
        """Advance every running job by the elapsed interval at its current
        step rate. Events fire at every phase boundary, so a segment never
        straddles two phases — the whole delta belongs to the span that was
        active at the segment's start, which is what the serve-SLO ledger
        scores latency-sensitive (decode) steps against.

        A job bound during a re-partition has ``last_update_s`` in the
        *future* (it starts stepping only when the device re-opens); a
        neighbour's event firing inside that window must not rewind it —
        progress never runs backwards, and the downtime stays unscored."""
        for j in dev.running.values():
            if t <= j.last_update_s:
                continue  # not yet stepping (bound inside a reconfig window)
            if j.step_s > 0:
                span = j.current_span()  # span at segment start
                delta = min(
                    (t - j.last_update_s) / j.step_s,
                    float(j.total_steps) - j.steps_done,
                )
                if delta > 0 and span.latency_sensitive and j.slo_step_s:
                    j.slo_steps += delta
                    if j.step_s <= j.slo_step_s:
                        j.slo_met_steps += delta
                j.steps_done = min(float(j.total_steps), j.steps_done + delta)
            j.last_update_s = t

    def _busy_fraction(self, dev: DeviceState) -> float:
        if not dev.running:
            return 0.0
        if dev.mode == CollocationMode.MIG:
            # unit-weighted occupancy — the device-level GRACT aggregation
            # of core/metrics.py with active instances counted as busy
            occupied = sum(
                dev.sku.profile(a.profile).mem_units
                for a in dev.assignments.values()
            )
            return min(1.0, occupied / dev.sku.n_units)
        if self.retime == "incremental":
            # memoized per co-resident composition: busy fraction is a pure
            # function of the (arch, shape, demand) multiset, and _accrue_busy
            # recomputes it on every event touching the device
            key = (
                dev.sku.name,
                tuple(
                    (j.spec.arch, j.spec.suite.name, j.active_demand())
                    for j in dev.running.values()
                ),
            )
            frac = self._busy_cache.get(key)
            if frac is None:
                terms = []
                for j in dev.running.values():
                    tm = dev.scheduler.solo_terms(j.spec, j.active_demand())
                    if tm is not None:
                        terms.append(tm)
                frac = busy_fraction_from_terms(terms)
                if len(self._busy_cache) > 200_000:
                    self._busy_cache.clear()
                self._busy_cache[key] = frac
            return frac
        profiles = []
        for j in dev.running.values():
            p = dev.scheduler.solo_profile(j.spec)
            if p is not None:
                profiles.append(p.scaled(j.active_demand()))
        return device_busy_fraction(profiles)

    def _accrue_busy(self, dev: DeviceState, t: float) -> None:
        """Integrate the device's busy fraction up to ``t`` — call BEFORE
        mutating the running set so the old occupancy covers the interval."""
        dt = t - dev.last_busy_update_s
        if dt > 0:
            dev.busy_integral_s += self._busy_fraction(dev) * dt
            dev.last_busy_update_s = t

    # -- per-device costs --------------------------------------------------------

    def _device_reconfig_cost(self, dev: DeviceState) -> float:
        """Downtime charged when ``dev`` re-partitions: the cluster's
        configured cost scaled by the SKU's reconfig cost relative to the
        baseline — so the operator's --reconfig-cost flag and the device
        generation's knob (an H100 re-partitions faster) compose. Exactly
        the configured cost on baseline-cost SKUs (ratio 1.0)."""
        return self.reconfig_cost_s * (
            dev.sku.reconfig_cost_s / _BASE_RECONFIG_COST_S
        )

    # -- displacement (failure / migration / straggler repack) ----------------------

    def _displace(
        self,
        dev: DeviceState,
        name: str,
        t: float,
        *,
        new_spec: Optional[JobSpec] = None,
        count_migration: bool = False,
        count_repack: bool = False,
    ) -> None:
        """Kill a running job, roll it back to its last checkpoint, and
        re-queue it (priority-bumped) — the shared tail of the failure,
        migration, and straggler-repack handlers."""
        cj = dev.running.pop(name)
        dev.assignments.pop(name, None)
        if self.trace is not None:
            self._tr_release_occ(dev.name, name, t)
            self._tr_close_phase(cj, t)
        cj.rollback_to_checkpoint()
        cj.token += 1  # invalidate the in-flight completion event
        if cj.pending_event is not None:
            self.events.tombstone(cj.pending_event)
            cj.pending_event = None
        cj.device = None
        if new_spec is not None:
            cj.spec = new_spec
        if count_migration:
            cj.migrations += 1
        if count_repack:
            cj.straggler_repacks += 1
        self._capacity_epoch += 1
        if not dev.running:
            self._dirty.pop(dev.name, None)  # nothing left to re-price
        self._enqueue(name, cj, t)

    # -- mode migration ---------------------------------------------------------

    def _maybe_migrate(self, t: float) -> None:
        if self.policy == "static":
            return
        if self._dirty and self.queue:
            # migration trials rank candidate schedules against the live
            # composition — close the re-pricing batch first (the phase
            # -transition handler reaches here without passing _dispatch)
            self._flush_retimes()
        if self.policy == "planner":
            self._maybe_replan(t)
            return
        for dev in self.devices.values():
            if not dev.available(t):
                continue
            if self.queue.is_prewarmed(dev.name):
                # warmed for the predicted ramp (forecast policy): the
                # reactive pressure loop must not flip it back for the
                # queued training the veto is deliberately starving
                continue
            if not self.queue:
                # no queue pressure: the composition has not outgrown the
                # current partitioning, so reconfiguring (and killing the
                # running jobs back to their checkpoints) cannot pay off
                continue
            if any(j.world_size > 1 for j in dev.running.values()):
                # a gang member's slice must not be re-partitioned from
                # under the gang — its siblings on other devices would
                # stall; gang capacity changes only through completion,
                # failure, or the gang's own re-queue
                continue
            # gangs are placed by the all-or-nothing gang path, never by a
            # single device's mode trial — exclude them from the pressure
            # window (a gang-only queue is no reason to flip this device)
            queued_specs = [
                e.item.spec
                for e in self.queue.ordered()[: self.migration_window]
                if e.item.world_size == 1
            ]
            if not queued_specs:
                continue
            specs = [j.spec for j in dev.running.values()] + queued_specs
            if not specs:
                continue
            if dev.running and t - dev.last_migration_s < self.migration_cooldown_s:
                continue  # empty devices may flip freely (nothing to kill)
            # running jobs are scored at their active phase (queued ones at
            # steady) — a device full of decode phases ranks differently
            # from the same archs mid-checkpoint
            active = {j.name: j.active_demand() for j in dev.running.values()}
            snapshot = dict(dev.scheduler._predicted)
            schedules: Dict[CollocationMode, Schedule] = {}
            for m in CollocationMode:
                if m == CollocationMode.MIG:
                    schedules[m] = dev.scheduler.schedule(
                        specs,
                        blocked_units=frozenset(dev.failed_units),
                        mode=m,
                        active_phases=active,
                    )
                elif dev.failed_units:
                    # a degraded device cannot run a shared mode at all
                    # (_try_place refuses it), so the trial must be empty —
                    # otherwise a failed-unit MIG device would "migrate" to
                    # MPS and then strand every job
                    schedules[m] = Schedule([], [], mode=m)
                else:
                    schedules[m] = dev.scheduler.schedule(
                        specs, mode=m, active_phases=active
                    )
            # trial schedules must not poison the straggler predictions of
            # the jobs actually deployed
            dev.scheduler._predicted = snapshot
            best = rank_modes(schedules)
            if best == dev.mode:
                continue
            cur, cand = schedules[dev.mode], schedules[best]
            better = len(cand.assignments) > len(cur.assignments) or (
                len(cand.assignments) == len(cur.assignments)
                and cand.throughput()
                >= (1 + self.migration_hysteresis) * cur.throughput()
            )
            if better:
                self._migrate(dev, best, t)
                if self.policy == "forecast":
                    self._fc_reactive += 1

    def _migrate(
        self,
        dev: DeviceState,
        new_mode: CollocationMode,
        t: float,
        *,
        kind: Optional[str] = None,
    ) -> None:
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        cost = self._device_reconfig_cost(dev)
        requeued = []
        for name in list(dev.running):
            cj = dev.running[name]
            bumped = dataclasses.replace(
                cj.spec, priority=cj.spec.priority + REQUEUE_PRIORITY_BUMP
            )
            self._displace(dev, name, t, new_spec=bumped, count_migration=True)
            requeued.append(name)
        dev.pending_mode = new_mode
        dev.reconfiguring_until = t + cost
        self._next_reopen = min(self._next_reopen, dev.reconfiguring_until)
        self._capacity_epoch += 1  # the device closed; its jobs re-queued
        self._dirty.pop(dev.name, None)
        dev.migrations += 1
        dev.reconfig_cost_s += cost
        dev.last_migration_s = t
        event = {
            "t_s": t,
            "device": dev.name,
            "from": dev.mode.value,
            "to": new_mode.value,
            "requeued": requeued,
            "reconfig_cost_s": cost,
        }
        if kind is not None:
            # only forecast pre-warm flips tag a kind; the reactive path's
            # dict stays schema-identical to pre-forecast artifacts
            event["kind"] = kind
        self.migration_events.append(event)
        if self.trace is not None:
            self.trace.span(
                f"dev:{dev.name}",
                f"reconfig {event['from']}->{event['to']}",
                t,
                t + cost,
                cat="reconfig",
            )
            self.trace.instant(
                "scheduler",
                "migrate",
                t,
                args={
                    "device": dev.name,
                    "from": event["from"],
                    "to": event["to"],
                    "requeued": list(requeued),
                    "cost_s": cost,
                    "kind": kind or "reactive",
                },
            )
        self.events.push(t + cost, EventKind.RECONFIG_DONE, (dev.name,))

    # -- plan-driven re-partitions (planner policy) -----------------------------------

    def _maybe_replan(self, t: float) -> None:
        """Planner policy: when queued jobs cannot be placed incrementally,
        ask the partition-tree optimizer for a *from-scratch* plan over the
        running + queued mix (``preferred`` pins the running jobs' current
        instances, so eviction is a last resort) and commit it only when

          * it serves strictly more jobs than the device currently runs, and
          * the re-partition pays for itself before the device would free
            capacity naturally: downtime plus the slowest displaced job's
            redone work must undercut the earliest pending completion —
            re-partitioning a device that is about to drain anyway only
            buys back queueing delay the completion would erase for free.

        Committing is a re-partition: every running job whose planned
        instance differs from its live one is displaced through the
        standard checkpoint-rollback path, the device pays
        ``reconfig_cost_s`` downtime before the re-planned placements
        start stepping, and the event is recorded next to mode migrations
        (kind="replan"). Survivors whose instances the plan keeps run
        through the reconfiguration untouched — MIG instance create/destroy
        does not disturb neighbouring instances (F3)."""
        if not self.queue:
            return
        for dev in self.devices.values():
            if not self.queue:
                return  # drained by a replan committed on an earlier device
            if dev.mode != CollocationMode.MIG or not dev.available(t):
                continue
            if any(j.world_size > 1 for j in dev.running.values()):
                continue  # never re-partition a gang member's device
            if dev.running and t - dev.last_migration_s < self.migration_cooldown_s:
                continue
            # recomputed per device on purpose: a committed replan above
            # removed its placed jobs from the queue. Gangs are placed by
            # the all-or-nothing gang path, not a one-device replan.
            queued = [
                e.item
                for e in self.queue.ordered()[: self.migration_window]
                if e.item.world_size == 1
            ]
            specs = [j.spec for j in dev.running.values()] + [
                j.spec for j in queued
            ]
            # bring progress up to ``t`` first: the pays-off check below
            # compares rollback work and time-to-relief, and both are
            # computed from steps_done — stale values (no event since
            # placement) would understate the redo and overstate the
            # relief, approving replans whose real cost exceeds the bar
            self._accrue_busy(dev, t)
            self._update_progress(dev, t)
            active = {j.name: j.active_demand() for j in dev.running.values()}
            preferred = {
                name: a.placement for name, a in dev.assignments.items()
            }
            snapshot = dict(dev.scheduler._predicted)
            trial = dev.scheduler.schedule(
                specs,
                blocked_units=frozenset(dev.failed_units),
                mode=CollocationMode.MIG,
                active_phases=active,
                preferred=preferred,
            )
            dev.scheduler._predicted = snapshot
            if len(trial.assignments) <= len(dev.running):
                continue  # a re-partition must serve strictly more jobs
            placed_names = {a.job.name for a in trial.assignments}
            if any(name not in placed_names for name in dev.running):
                # re-partitions may shuffle running jobs to open holes, but
                # never evict one to the queue: pushing a job's completion
                # out lengthens the trace's critical path for a gain the
                # next natural completion would have delivered anyway
                continue
            if not self._replan_pays_off(dev, trial, t):
                continue
            self._commit_replan(dev, trial, t)

    def _replan_pays_off(self, dev: DeviceState, trial, t: float) -> bool:
        """Downtime + the slowest displaced job's redone work must finish
        before the device's earliest pending completion frees capacity."""
        if not dev.running:
            return True
        cost = self._device_reconfig_cost(dev)
        planned = {a.job.name: a.placement for a in trial.assignments}
        planned_step = {a.job.name: a.predicted_step_s for a in trial.assignments}
        relief_s = min(
            (
                cj.remaining_steps * cj.step_s
                for cj in dev.running.values()
                if cj.step_s > 0
            ),
            default=float("inf"),
        )
        redo_s = 0.0
        for name, cj in dev.running.items():
            if planned.get(name) == dev.assignments[name].placement:
                continue  # kept in place: no rollback
            cadence = cj.steps_per_epoch * CHECKPOINT_EVERY_EPOCHS
            lost = cj.steps_done - math.floor(cj.steps_done / cadence) * cadence
            # the lost steps are redone at the *planned* slice's rate,
            # which may be slower than the one the job is moved off
            step = max(cj.step_s, planned_step.get(name, cj.step_s))
            redo_s = max(redo_s, lost * step)
        return cost + redo_s < relief_s

    def _commit_replan(self, dev: DeviceState, trial, t: float) -> None:
        planned = {a.job.name: a.placement for a in trial.assignments}
        cost = self._device_reconfig_cost(dev)
        self._accrue_busy(dev, t)
        self._update_progress(dev, t)
        kept, displaced = [], []
        for name in list(dev.running):
            if planned.get(name) == dev.assignments[name].placement:
                planned.pop(name)  # survivor: instance untouched
                kept.append(name)
                continue
            cj = dev.running[name]
            bumped = dataclasses.replace(
                cj.spec, priority=cj.spec.priority + REQUEUE_PRIORITY_BUMP
            )
            self._displace(dev, name, t, new_spec=bumped, count_migration=True)
            displaced.append(name)
        # the device is down while it re-partitions; planned jobs are bound
        # to their instances now but only start stepping once it re-opens.
        # Score the downtime window's utilization at the *kept* occupancy
        # (survivors run through it; the new instances sit idle until the
        # device re-opens — same convention as the adaptive migrate path,
        # whose emptied device scores the window at zero).
        t_eff = t + cost
        dev.busy_integral_s += self._busy_fraction(dev) * (t_eff - t)
        dev.last_busy_update_s = t_eff
        placed = []
        for name, pl in planned.items():
            if name not in self.queue:
                continue  # displaced by the plan but left unplaced by it
            cj = self.jobs[name]
            self.queue.remove(name)
            step = dev.scheduler.predict_step(
                cj.spec, pl.profile, cj.active_demand()
            )
            self._bind(dev, cj, Assignment(cj.spec, pl, step), t_eff)
            first = cj.started_s is None
            if cj.started_s is None:
                cj.started_s = t_eff
            if self.trace is not None:
                self._tr_note_dispatch(cj, t_eff, first=first)
            placed.append(name)
        dev.reconfiguring_until = t_eff
        self._next_reopen = min(self._next_reopen, dev.reconfiguring_until)
        self._capacity_epoch += 1  # bindings + queue removals changed state
        dev.migrations += 1
        dev.reconfig_cost_s += cost
        dev.last_migration_s = t
        self.migration_events.append(
            {
                "t_s": t,
                "device": dev.name,
                "from": dev.mode.value,
                "to": dev.mode.value,
                "kind": "replan",
                "kept": sorted(kept),
                "requeued": displaced,
                "placed": sorted(placed),
                "optimality": trial.plan.optimality if trial.plan else None,
                "gap": trial.plan.gap if trial.plan else None,
                "reconfig_cost_s": cost,
            }
        )
        if self.trace is not None:
            self.trace.span(
                f"dev:{dev.name}",
                f"replan {dev.mode.value}",
                t,
                t_eff,
                cat="reconfig",
            )
            prov = (
                trial.plan.provenance()
                if trial.plan is not None
                else {
                    "layout": [],
                    "optimality": None,
                    "gap": None,
                    "configs_evaluated": 0,
                }
            )
            self.trace.instant(
                "scheduler",
                "replan",
                t,
                args={
                    "device": dev.name,
                    "kept": sorted(kept),
                    "requeued": list(displaced),
                    "placed": sorted(placed),
                    "cost_s": cost,
                    **prov,
                },
            )
        self.events.push(t_eff, EventKind.RECONFIG_DONE, (dev.name,))

    # -- forecast-driven autoscaling (forecast policy) --------------------------------
    #
    # The forecast policy is the adaptive policy's reactive machinery plus
    # a proactive loop: a FORECAST_TICK clock (fixed ``tick_s`` grid, armed
    # lazily on the first admitted arrival, re-armed while the cluster is
    # live) refreshes the arrival-rate forecast (core/forecast/estimator)
    # and re-sizes the warm set — decode-capable (MIG) devices held for the
    # predicted serve ramp by pre-warm reservations (core/queueing.py),
    # which veto training backfill without blocking serve sessions. Warming
    # a device may re-partition it (``_migrate`` with kind="prewarm") or,
    # if it is already MIG, demote its low-priority training into the
    # trough through the checkpoint-rollback displace path; either action
    # is gated by ``wave_amortizes`` — the same downtime + rollback
    # economics as the planner's pays-off bar, priced against the
    # forecast's conservative lower band instead of the realized queue.

    def _fc_observe_arrival(self, cj: ClusterJob, t: float) -> None:
        """Feed an admitted arrival into the estimator and arm the tick
        clock. Only serve arrivals move the rate — the autoscaler sizes
        decode capacity, so training arrivals are not its signal."""
        if cj.kind == "serve":
            self._fc_estimator.observe(t)
            self._fc_serve_seen += 1
            self._fc_serve_rep = cj.spec
            if self.trace is not None:
                self._tr_fc_arrivals += 1
        self._ensure_forecast_tick(t)

    def _fc_note_session(self, service_s: float) -> None:
        if service_s <= 0.0:
            return
        alpha = self.forecast_config.session_alpha
        if self._fc_session_s is None:
            self._fc_session_s = service_s
        else:
            self._fc_session_s += alpha * (service_s - self._fc_session_s)

    def _ensure_forecast_tick(self, t: float) -> None:
        if self._fc_tick_pending:
            return
        nt = next_tick(t, self.forecast_config.tick_s)
        self.events.push(nt, EventKind.FORECAST_TICK, ())
        self._fc_tick_pending = True

    def _on_forecast_tick(self, t: float) -> None:
        self._fc_tick_pending = False
        cfg = self.forecast_config
        self._fc_ticks += 1
        fc = self._fc_estimator.forecast(t, cfg.horizon_s)
        self._fc_last = fc
        if self.trace is not None:
            # realized rate over the tick window that just closed — the
            # ground truth this tick's prediction is scored against
            window = t - self._tr_fc_last_tick_s
            realized = self._tr_fc_arrivals / window if window > 0 else 0.0
            self.trace.instant(
                "scheduler",
                "forecast_tick",
                t,
                args=forecast_provenance(fc, round(realized, 9)),
            )
            self._tr_fc_arrivals = 0
            self._tr_fc_last_tick_s = t
        if fc.rate_per_s > self._fc_peak_rate:
            self._fc_peak_rate = fc.rate_per_s
        if self._fc_autoscale(t, fc):
            # reservations / modes changed: released devices may admit
            # queued training now, warmed slices may admit queued sessions
            self._dispatch(t)
        if not self.events and self.queue:
            # drain guard: nothing is in flight anywhere (running jobs
            # always hold a pending lifecycle event) yet work is queued —
            # holding warm slices now would starve it *forever*, since the
            # predicted wave, when it actually arrives, re-arms this clock
            # through its own arrivals and can re-warm then. Yield every
            # reservation and let the reactive machinery take over.
            released = False
            for dev in self.devices.values():
                if self.queue.prewarm_release(dev.name):
                    released = True
            if released:
                self._capacity_epoch += 1
                self._dispatch(t)
                self._maybe_migrate(t)  # queued work may need a mode flip
        # re-arm while the simulation is live (an empty heap here means
        # fully drained — or wedged in a way more ticks cannot fix)
        if self.events:
            self._ensure_forecast_tick(t)

    def _fc_candidate_order(self, t: float) -> List[DeviceState]:
        """Warm-set candidates in preference order: devices already
        reserved first (so the target prefix keeps them), then devices
        already decode-partitioned, then empty devices, then busy shared
        devices — ties broken by fleet order. Gang hosts are never
        candidates (their slices must not move under the gang), and an
        unreserved device that is mid-reconfiguration is not reachable."""
        ranked = []
        for dev in self.devices.values():
            if any(j.world_size > 1 for j in dev.running.values()):
                continue
            reserved = self.queue.is_prewarmed(dev.name)
            if not reserved and not dev.available(t):
                continue
            eff_mode = dev.pending_mode or dev.mode
            if reserved:
                rank = 0
            elif eff_mode == CollocationMode.MIG:
                rank = 1
            elif not dev.running:
                rank = 2
            else:
                rank = 3
            ranked.append((rank, self._dev_index[dev.name], dev))
        ranked.sort(key=lambda r: (r[0], r[1]))
        return [dev for _, _, dev in ranked]

    def _fc_serve_capacity(self, dev: DeviceState, rep) -> int:
        """How many concurrent sessions like ``rep`` the device could host
        decode-partitioned (MIG), from a trial schedule of clones on its
        empty tree — memoized per (SKU, health, shape) like the admission
        verdicts, since traces draw sessions from a handful of shapes."""
        key = (
            dev.sku.name,
            frozenset(dev.failed_units),
            rep.arch,
            rep.suite.name,
            peak_demand_multiplier(rep),
        )
        cached = self._fc_capacity_cache.get(key)
        if cached is not None:
            return cached
        probes = [
            dataclasses.replace(rep, name=f"__fc_probe_{i}")
            for i in range(max(1, dev.sku.n_units))
        ]
        snapshot = dict(dev.scheduler._predicted)
        trial = dev.scheduler.schedule(
            probes,
            blocked_units=frozenset(dev.failed_units),
            mode=CollocationMode.MIG,
        )
        dev.scheduler._predicted = snapshot
        cap = len(trial.assignments)
        self._fc_capacity_cache[key] = cap
        return cap

    def _fc_autoscale(self, t: float, fc: RateForecast) -> bool:
        """Re-size the warm set against the forecast; True if anything
        changed (reservations, modes, displaced jobs)."""
        cfg = self.forecast_config
        rep = self._fc_serve_rep
        session_s = self._fc_session_s
        if rep is None or session_s is None:
            return False  # nothing learned yet: no sessions seen/finished
        if self._dirty:
            # displacement decisions below read steps_done — price the
            # open re-timing batch first (same idiom as _maybe_migrate)
            self._flush_retimes()
        order = self._fc_candidate_order(t)
        if not order:
            return False
        caps = [float(self._fc_serve_capacity(d, rep)) for d in order]
        reserved = sum(1 for d in order if self.queue.is_prewarmed(d.name))
        decision = plan_autoscale(
            fc, session_s=session_s, device_caps=caps, reserved=reserved, cfg=cfg
        )
        changed = False
        if decision.release > 0:
            drop = decision.release
            for dev in reversed(order):  # shed from the least-preferred end
                if drop == 0:
                    break
                if self.queue.prewarm_release(dev.name):
                    self._capacity_epoch += 1  # training may place here again
                    drop -= 1
                    changed = True
        if decision.prewarm > 0:
            for dev in order[: decision.target_devices]:
                if self.queue.is_prewarmed(dev.name):
                    continue
                if self._fc_prewarm_device(
                    dev, fc, session_s, decision.target_devices, t
                ):
                    changed = True
        return changed

    def _fc_prewarm_device(
        self,
        dev: DeviceState,
        fc: RateForecast,
        session_s: float,
        share: int,
        t: float,
    ) -> bool:
        """Warm one device for the ramp: re-partition to MIG if needed
        (displacing everything through checkpoint rollback), or demote its
        low-priority training if it is already decode-capable — iff the
        forecast's conservative wave amortizes the downtime + redo."""
        cfg = self.forecast_config
        if dev.running and t - dev.last_migration_s < self.migration_cooldown_s:
            return False  # same thrash bound as the reactive path
        needs_flip = (dev.pending_mode or dev.mode) != CollocationMode.MIG
        if needs_flip:
            victims = list(dev.running)
            cost = self._device_reconfig_cost(dev)
        else:
            victims = [
                name
                for name, cj in dev.running.items()
                if cj.kind != "serve"
                and cj.spec.priority < cfg.demote_priority_below
            ]
            cost = 0.0  # MIG instance create/destroy is isolated (F3)
        if victims:
            # redo is computed from steps_done — bring progress up to t
            self._accrue_busy(dev, t)
            self._update_progress(dev, t)
        redo_s = 0.0
        for name in victims:
            cj = dev.running[name]
            cadence = cj.steps_per_epoch * CHECKPOINT_EVERY_EPOCHS
            lost = cj.steps_done - math.floor(cj.steps_done / cadence) * cadence
            redo_s = max(redo_s, lost * cj.step_s)
        if not wave_amortizes(
            fc,
            session_s=session_s,
            share_devices=share,
            cost_s=cost + redo_s,
            cfg=cfg,
        ):
            return False
        if needs_flip:
            self._migrate(dev, CollocationMode.MIG, t, kind="prewarm")
            self._fc_prewarm_flips += 1
        elif victims:
            for name in victims:
                cj = dev.running[name]
                bumped = dataclasses.replace(
                    cj.spec, priority=cj.spec.priority + REQUEUE_PRIORITY_BUMP
                )
                self._displace(dev, name, t, new_spec=bumped, count_migration=True)
                self._fc_prewarm_preempts += 1
            self.migration_events.append(
                {
                    "t_s": t,
                    "device": dev.name,
                    "from": dev.mode.value,
                    "to": dev.mode.value,
                    "kind": "prewarm_preempt",
                    "requeued": victims,
                    "reconfig_cost_s": 0.0,
                }
            )
        self.queue.prewarm(dev.name, "serve")
        self._capacity_epoch += 1  # the backfill veto changed placement options
        return True

    # -- straggler mitigation (EMA -> live repack) -----------------------------------

    def observe_step(self, job_name: str, step_s: float, at_s: Optional[float] = None) -> None:
        """Feed a measured step time into the owning device's straggler EMA
        and act on any job that drifted past tolerance: checkpoint it and
        re-queue it with a ``min_profile`` floor one profile up (the
        repack_plan suggestion made live)."""
        t = self.now if at_s is None else float(at_s)
        self.now = max(self.now, t)
        cj = self.jobs.get(job_name)
        if cj is None or cj.device is None:
            return
        if cj.world_size > 1:
            return  # gangs pace at the slowest member + comms; there is no
            # single bigger slice a straggler repack could move them to
        dev = self.devices[cj.device]
        if self.trace is not None or self.calibrator is not None:
            a = dev.assignments.get(job_name)
            profile = a.placement.profile if a is not None else dev.mode.value
            if self.trace is not None:
                self.trace.step_sample(
                    t,
                    job_name,
                    cj.spec.arch,
                    profile,
                    step_s,
                    cj.step_s,
                    source="observe",
                )
            if self.calibrator is not None:
                # MISO online refinement: fold the measured-vs-predicted
                # sample into the running residual for this (SKU, arch,
                # slice); the next predict_step on the key is corrected.
                # The residual the job's prediction carried is divided
                # back out (the scheduler recorded it at pricing time),
                # so the EWMA estimates measured-vs-base exactly.
                self.calibrator.observe(
                    sku=dev.sku.name,
                    arch=cj.spec.arch,
                    profile=profile,
                    measured_s=step_s,
                    predicted_s=cj.step_s,
                    t_s=t,
                    applied_residual=dev.scheduler.applied_residual(job_name),
                )
        dev.scheduler.observe_step(job_name, step_s)
        if dev.mode != CollocationMode.MIG:
            return  # shared modes have no bigger slice to repack onto
        sched = Schedule(list(dev.assignments.values()), [], mode=CollocationMode.MIG)
        plan = dev.scheduler.repack_plan(sched)
        acted = False
        for name, bigger in plan.items():
            if name not in dev.running:
                continue
            if not acted:
                self._accrue_busy(dev, t)
                self._update_progress(dev, t)
                acted = True
            jc = dev.running[name]
            bumped = dataclasses.replace(
                jc.spec,
                priority=jc.spec.priority + REQUEUE_PRIORITY_BUMP,
                min_profile=bigger,
            )
            if self.trace is not None:
                self.trace.instant(
                    "scheduler",
                    "straggler_repack",
                    t,
                    args={"job": name, "device": dev.name, "min_profile": bigger},
                )
            self._displace(dev, name, t, new_spec=bumped, count_repack=True)
            dev.scheduler.reset_observation(name)
            dev.straggler_repacks += 1
        if acted:
            self._dispatch(t)

    # -- reporting --------------------------------------------------------------

    def report(self) -> ClusterReport:
        if self._dirty:
            self._flush_retimes()  # report on re-priced, not stale, rates
        horizon = self.now
        if not self.events:
            # fully drained. The pre-tombstone event loop popped every
            # stale event too, advancing the clock to the latest time ever
            # scheduled — keep that horizon semantics (utilization and
            # goodput denominators) without paying for the dead pops.
            horizon = max(horizon, self.events.max_time_pushed)
            self.now = horizon
        for dev in self.devices.values():
            self._accrue_busy(dev, horizon)
        done = [self.jobs[n] for n in self.completed]
        jcts = sorted(j.jct_s for j in done)
        delays = sorted(
            j.queueing_delay_s
            for j in self.jobs.values()
            if j.queueing_delay_s is not None
        )
        arrivals = [j.arrival_s for j in self.jobs.values() if j.rejected_reason is None]
        finishes = [j.finished_s for j in done]
        makespan = (max(finishes) - min(arrivals)) if finishes and arrivals else 0.0
        util = {
            d.name: (d.busy_integral_s / horizon if horizon > 0 else 0.0)
            for d in self.devices.values()
        }
        util["mean"] = sum(util.values()) / len(self.devices)
        slo_steps = sum(j.slo_steps for j in self.jobs.values())
        slo_met = sum(j.slo_met_steps for j in self.jobs.values())
        useful_steps = sum(
            (j.slo_met_steps if j.kind == "serve" else j.steps_done)
            for j in self.jobs.values()
        )
        forecast = None
        if self.policy == "forecast":
            cfg = self.forecast_config
            forecast = {
                "estimator": cfg.estimator,
                "period_s": cfg.period_s,
                "tick_s": cfg.tick_s,
                "horizon_s": cfg.horizon_s,
                "ticks": self._fc_ticks,
                "serve_arrivals": self._fc_serve_seen,
                "session_s": (
                    self._fc_session_s if self._fc_session_s is not None else 0.0
                ),
                "peak_rate_per_s": self._fc_peak_rate,
                "prewarm_flips": self._fc_prewarm_flips,
                "prewarm_preempts": self._fc_prewarm_preempts,
                "reactive_migrations": self._fc_reactive,
                "prewarms_made": self.queue.prewarms_made,
                "prewarms_released": self.queue.prewarms_released,
            }
        return ClusterReport(
            policy=self.policy,
            n_devices=len(self.devices),
            horizon_s=horizon,
            makespan_s=makespan,
            completed=len(self.completed),
            completed_train=sum(
                1 for n in self.completed if self.jobs[n].kind == "train"
            ),
            completed_serve=sum(
                1 for n in self.completed if self.jobs[n].kind == "serve"
            ),
            rejected=len(self.rejected),
            still_queued=len(self.queue),
            still_running=sum(len(d.running) for d in self.devices.values()),
            mean_jct_s=sum(jcts) / len(jcts) if jcts else 0.0,
            p95_jct_s=_quantile(jcts, 0.95),
            mean_queueing_delay_s=sum(delays) / len(delays) if delays else 0.0,
            max_queueing_delay_s=delays[-1] if delays else 0.0,
            throughput_jobs_per_s=(
                len(self.completed) / makespan if makespan > 0 else 0.0
            ),
            slo_attainment=(slo_met / slo_steps if slo_steps > 0 else 1.0),
            goodput_steps_per_s=(useful_steps / horizon if horizon > 0 else 0.0),
            phase_transitions=sum(
                j.phase_transitions for j in self.jobs.values()
            ),
            utilization=util,
            migrations=sum(d.migrations for d in self.devices.values()),
            reconfig_cost_s=sum(d.reconfig_cost_s for d in self.devices.values()),
            lost_steps=sum(j.lost_steps for j in self.jobs.values()),
            straggler_repacks=sum(
                d.straggler_repacks for d in self.devices.values()
            ),
            hol_blocked_events=self.queue.hol_blocked_events,
            jobs=[j.to_row() for j in self.jobs.values()],
            devices=[d.to_row() for d in self.devices.values()],
            migration_events=list(self.migration_events),
            failure_events=list(self.failure_events),
            forecast=forecast,
        )
