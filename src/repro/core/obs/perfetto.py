"""Exporters: Chrome-trace-event JSON (Perfetto) and flat counter series.

`export_perfetto` renders a :class:`~repro.core.obs.recorder.TraceRecorder`
into the Chrome trace-event format that https://ui.perfetto.dev loads
directly: one "thread" (track) per device plus the scheduler-decision,
queue, and jobs tracks. Spans use async begin/end pairs so overlapping
occupancy intervals on one device render side by side instead of being
forced into a call-stack nesting; instants and counters use the ``i``
and ``C`` phases.

`export_counters` is the flat companion: raw ``(t, value)`` series plus
the measured-vs-predicted step samples, for scripting without a trace
viewer.

Both exporters are pure functions of the recorder, and all floats are
rounded before serialization, so same-seed runs export byte-identical
documents.
"""

from __future__ import annotations

from typing import Any, Dict, List

from repro.core.obs.recorder import TraceRecorder

COUNTERS_SCHEMA = "obs_counters/v1"


def _us(t_s: float) -> float:
    """Sim seconds -> trace microseconds, rounded for byte stability."""
    return round(t_s * 1e6, 3)


def _round_args(args: Any) -> Any:
    if isinstance(args, float):
        return round(args, 9)
    if isinstance(args, dict):
        return {k: _round_args(v) for k, v in args.items()}
    if isinstance(args, (list, tuple)):
        return [_round_args(v) for v in args]
    return args


def export_perfetto(rec: TraceRecorder) -> Dict[str, Any]:
    """Render the recorder as a Chrome-trace-event document."""
    tids: Dict[str, int] = {}

    def tid(track: str) -> int:
        t = tids.get(track)
        if t is None:
            t = tids[track] = len(tids) + 1
        return t

    for track in rec.tracks:
        tid(track)

    events: List[Dict[str, Any]] = []
    for i, (track, name, cat, t0, t1, args) in enumerate(rec.spans):
        begin: Dict[str, Any] = {
            "ph": "b",
            "cat": cat,
            "id": i + 1,
            "name": name,
            "pid": 1,
            "tid": tid(track),
            "ts": _us(t0),
        }
        if args:
            begin["args"] = _round_args(args)
        events.append(begin)
        events.append(
            {
                "ph": "e",
                "cat": cat,
                "id": i + 1,
                "name": name,
                "pid": 1,
                "tid": tid(track),
                "ts": _us(t1),
            }
        )
    for track, name, cat, t, args in rec.instants:
        ev: Dict[str, Any] = {
            "ph": "i",
            "s": "t",
            "cat": cat,
            "name": name,
            "pid": 1,
            "tid": tid(track),
            "ts": _us(t),
        }
        if args:
            ev["args"] = _round_args(args)
        events.append(ev)
    for cname in sorted(rec.counters):
        last: Any = object()
        for t, value in rec.counters[cname]:
            if value == last:
                continue  # collapse flat stretches; the flat export keeps them
            last = value
            events.append(
                {
                    "ph": "C",
                    "name": cname,
                    "pid": 1,
                    "ts": _us(t),
                    "args": {"value": _round_args(value)},
                }
            )

    meta: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "args": {"name": "cluster-sim"}}
    ]
    for track, t in tids.items():
        meta.append(
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": t, "args": {"name": track}}
        )
    return {"displayTimeUnit": "ms", "traceEvents": meta + events}


def export_counters(rec: TraceRecorder) -> Dict[str, Any]:
    """Render the recorder as a flat counter/sample document."""
    return {
        "schema": COUNTERS_SCHEMA,
        "counters": {
            name: [[round(t, 9), _round_args(v)] for t, v in series]
            for name, series in rec.counters.items()
        },
        "samples": [_round_args(s) for s in rec.samples],
        "totals": {
            "spans": len(rec.spans),
            "instants": len(rec.instants),
            "tracks": list(rec.tracks),
        },
    }


# Exporter registry, keyed by the `simulate.py --trace-exporter` choice.
EXPORTERS = {
    "perfetto": export_perfetto,
    "counters": export_counters,
}
