"""Deterministic observability layer for the cluster simulator.

`TraceRecorder` collects spans (job lifecycle, device occupancy, reconfig
windows), decision-provenance instants (every admission / veto / replan /
gang / forecast action with the *why*), counter series sampled on event
boundaries, and measured-vs-predicted step samples — all driven purely by
sim time, so a traced run is byte-deterministic per seed and a trace-off
run is byte-identical to an untraced one.

Exporters render the recorder into Chrome-trace-event JSON (loadable at
https://ui.perfetto.dev) or a flat counter-series document.
"""

from repro.core.obs.perfetto import EXPORTERS, export_counters, export_perfetto
from repro.core.obs.recorder import PROVENANCE, TraceRecorder

__all__ = [
    "EXPORTERS",
    "PROVENANCE",
    "TraceRecorder",
    "export_counters",
    "export_perfetto",
]
