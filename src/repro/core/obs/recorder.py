"""Sim-time trace recorder with decision provenance.

The recorder is a passive sink: the cluster (and the admission queue it
owns) pushes spans, instants, counters, and step samples into it at the
sim times the events happen. Nothing here reads wall clocks, allocates
ids from global state, or mutates scheduler state — recording the same
run twice yields byte-identical exports, and running with the recorder
detached yields byte-identical artifacts.

Three invariants the rest of the repo relies on:

- **No-op when disabled.** Every record method starts with
  ``if not self.enabled: return`` before touching its arguments, so a
  disabled recorder does zero allocation on the hot path.
- **Provenance completeness.** Decision instants whose name appears in
  :data:`PROVENANCE` must carry every required arg key; ``instant()``
  raises ``ValueError`` otherwise, so a hook that forgets the *why*
  fails loudly in tests rather than shipping an unexplained decision.
- **Sim time only.** All timestamps are the caller's ``t`` in seconds;
  the recorder never invents one.
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Tuple

# Required arg keys per decision-instant name. Hooks may add extra keys
# (e.g. a migrate records its trigger ``kind``); missing a required key
# raises at record time. tests/test_obs.py asserts both directions.
PROVENANCE: Dict[str, Tuple[str, ...]] = {
    # queueing.py — admission, backfill, reservations, pre-warm holds
    "enqueue": ("job", "priority", "depth"),
    "reject": ("job", "reason"),
    "dispatch": ("job", "device", "wait_s"),
    "backfill_overtake": ("job",),
    "veto_reserved": ("job", "device", "held_by"),
    "veto_prewarm": ("job", "device", "warmed_for"),
    "prewarm": ("device", "kind"),
    "prewarm_release": ("device",),
    # cluster.py — mode migrations and planner replans
    "migrate": ("device", "from", "to", "requeued", "cost_s"),
    "replan": (
        "device",
        "kept",
        "requeued",
        "placed",
        "layout",
        "optimality",
        "gap",
        "configs_evaluated",
    ),
    "straggler_repack": ("job", "device", "min_profile"),
    # gang/placement.py — all-or-nothing outcomes
    "gang_reserve": ("gang", "devices"),
    "gang_release": ("gang",),
    "gang_place": ("gang", "devices", "spread", "step_s", "comm_s"),
    "gang_blocked": ("gang", "world_size"),
    "gang_reject": ("gang", "reason"),
    # forecast/policy.py — predicted band vs realized arrivals
    "forecast_tick": (
        "rate_per_s",
        "lower_per_s",
        "upper_per_s",
        "realized_per_s",
        "abs_err_per_s",
        "in_band",
    ),
}


class TraceRecorder:
    """Accumulates one run's trace; export via ``repro.core.obs.perfetto``.

    Storage is plain lists/dicts of primitives so exports are cheap and
    deterministic:

    - ``spans``: ``(track, name, cat, t0_s, t1_s, args)`` tuples,
      appended when the interval *closes*.
    - ``instants``: ``(track, name, cat, t_s, args)`` tuples.
    - ``counters``: ``{name: [(t_s, value), ...]}`` — every sample is
      kept (the Perfetto exporter collapses consecutive duplicates).
    - ``samples``: measured-vs-predicted step-time dicts, the data
      source for the char-DB calibration item.
    """

    __slots__ = ("enabled", "tracks", "_track_set", "spans", "instants", "counters", "samples")

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = bool(enabled)
        self.tracks: List[str] = []
        self._track_set: set = set()
        self.spans: List[Tuple[str, str, str, float, float, Optional[Mapping[str, Any]]]] = []
        self.instants: List[Tuple[str, str, str, float, Optional[Mapping[str, Any]]]] = []
        self.counters: Dict[str, List[Tuple[float, Any]]] = {}
        self.samples: List[Dict[str, Any]] = []

    # -- registration ----------------------------------------------------

    def track(self, name: str) -> None:
        """Pre-register a track so exports list it in a stable order."""
        if not self.enabled:
            return
        if name not in self._track_set:
            self._track_set.add(name)
            self.tracks.append(name)

    # -- record methods --------------------------------------------------

    def span(
        self,
        track: str,
        name: str,
        t0_s: float,
        t1_s: float,
        *,
        cat: str = "span",
        args: Optional[Mapping[str, Any]] = None,
    ) -> None:
        """Record a closed interval ``[t0_s, t1_s]`` on ``track``."""
        if not self.enabled:
            return
        self.track(track)
        self.spans.append((track, name, cat, t0_s, t1_s, args))

    def instant(
        self,
        track: str,
        name: str,
        t_s: float,
        *,
        cat: str = "decision",
        args: Optional[Mapping[str, Any]] = None,
    ) -> None:
        """Record a point event; validates :data:`PROVENANCE` args."""
        if not self.enabled:
            return
        required = PROVENANCE.get(name)
        if required is not None:
            have = args or {}
            missing = [k for k in required if k not in have]
            if missing:
                raise ValueError(
                    f"decision instant {name!r} missing provenance keys {missing}"
                )
        self.track(track)
        self.instants.append((track, name, cat, t_s, args))

    def counter(self, name: str, t_s: float, value: Any) -> None:
        """Append one sample to the counter series ``name``."""
        if not self.enabled:
            return
        series = self.counters.get(name)
        if series is None:
            series = self.counters[name] = []
        series.append((t_s, value))

    def step_sample(
        self,
        t_s: float,
        job: str,
        arch: str,
        profile: str,
        measured_s: float,
        predicted_s: float,
        *,
        source: str,
    ) -> None:
        """Record a measured-vs-predicted step-time pair.

        ``source`` is ``"observe"`` for live `Cluster.observe_step`
        telemetry and ``"completion"`` for the lifetime-average sample
        the cluster emits when a job drains.
        """
        if not self.enabled:
            return
        self.samples.append(
            {
                "t_s": t_s,
                "job": job,
                "arch": arch,
                "profile": profile,
                "measured_s": measured_s,
                "predicted_s": predicted_s,
                "source": source,
            }
        )

    # -- convenience -----------------------------------------------------

    def instants_named(self, name: str) -> List[Tuple[str, str, str, float, Optional[Mapping[str, Any]]]]:
        """All recorded instants with the given decision name."""
        return [rec for rec in self.instants if rec[1] == name]

    def __len__(self) -> int:
        n_counters = sum(len(s) for s in self.counters.values())
        return len(self.spans) + len(self.instants) + n_counters + len(self.samples)
