"""Discrete-event machinery for the cluster simulator.

The cluster (core/cluster.py) is a state machine driven by a time-ordered
event heap. Everything that changes cluster state is an event:

  ARRIVAL        a job enters the admission queue (``Cluster.submit``);
  COMPLETION     a placed job finishes its remaining steps — scheduled from
                 the job's predicted step time, re-scheduled whenever the
                 device's contention changes, invalidated by a token bump;
  RECONFIG_DONE  a device finishes a mode migration (MIG re-partitioning /
                 MPS daemon restart) and rejoins the fleet;
  FAILURE        slice units on a device go unhealthy (elastic repack);
  REPAIR         failed units return to health (elastic scale-up);
  PHASE_TRANSITION  a placed job crosses a phase boundary of its workload
                 plan (core/workload.py) — its demand vector changes, so
                 shared devices re-time every neighbour and the adaptive
                 policy gets a chance to reconsider the partitioning.
                 Token-invalidated exactly like COMPLETION.
  GANG_RESERVE   a queued gang (core/gang/) has waited out the cluster's
                 starvation bound without placing; the handler grants it
                 the admission queue's device reservation so backfilling
                 singletons stop refilling the capacity it needs. Fired
                 only for gang jobs, so traces without gangs never see it.
  FORECAST_TICK  the forecast policy's clock (core/forecast/): on a fixed
                 grid of ``tick_s`` the cluster refreshes its arrival-rate
                 forecast and autoscales the warm decode-capable device
                 set. Scheduled lazily (ensured on arrival, re-armed while
                 the cluster is live), and only under policy="forecast",
                 so every other policy's event stream is untouched.

Determinism contract: events at equal times are processed in push order
(``seq`` breaks ties), so a run is a pure function of the submitted trace —
the property tests/test_cluster.py pins down to byte-identical artifacts.

Completion events are *lazy-invalidated*: rather than surgically removing a
stale event from the heap (O(n)), every job carries a generation token and
a completion event stores the token it was scheduled under; a popped event
whose token no longer matches the job's is dropped. This is the standard
discrete-event idiom for processor-sharing queues, where every arrival and
departure on a shared device re-times every neighbour.

Lazy invalidation leaks: on a re-timing-heavy trace (a shared device with k
neighbours re-prices all k on every arrival/departure/phase event) the heap
fills with dead events that are only reclaimed when their time comes up.
``tombstone`` marks an event dead at invalidation time so it is skipped in
O(log n) on the way out, and the queue compacts (rebuild + heapify) whenever
tombstones exceed half the heap — bounding the heap at ~2x the live event
count instead of the total number of re-timings. ``max_time_pushed`` records
the latest time ever scheduled, tombstoned or not: the old eager-pop drain
advanced the simulation clock over stale events too, and the cluster's
report keeps that horizon semantics without paying for the pops
(tests/test_events.py pins all of this).
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Any, List, Optional, Set, Tuple


class EventKind(str, enum.Enum):
    ARRIVAL = "arrival"
    COMPLETION = "completion"
    RECONFIG_DONE = "reconfig_done"
    FAILURE = "failure"
    REPAIR = "repair"
    PHASE_TRANSITION = "phase_transition"
    GANG_RESERVE = "gang_reserve"
    FORECAST_TICK = "forecast_tick"


@dataclasses.dataclass(frozen=True)
class Event:
    time_s: float
    seq: int  # tie-break: equal-time events fire in push order
    kind: EventKind
    payload: Tuple[Any, ...] = ()

    def sort_key(self) -> Tuple[float, int]:
        return (self.time_s, self.seq)


class EventQueue:
    """Min-heap of events ordered by (time, push sequence), with lazy
    deletion: ``tombstone``-marked events are skipped on pop/peek and
    physically reclaimed when they reach the top or when a compaction
    rebuilds the heap. ``len``/``bool`` count *live* events only."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = 0
        self._tombstoned: Set[int] = set()
        #: latest time ever scheduled (including later-tombstoned events) —
        #: the horizon the old eager-pop drain would have advanced the
        #: clock to; float("-inf") until the first push.
        self.max_time_pushed = float("-inf")
        #: number of heap rebuilds triggered by the tombstone threshold.
        self.compactions = 0
        #: total events ever tombstoned — with ``compactions`` this tells
        #: how much of a run's event traffic was re-timing churn.
        self.tombstones = 0
        #: largest physical heap length ever reached (live + dead), the
        #: memory high-water mark the compaction policy is bounding.
        self.peak_heap_len = 0

    def push(self, time_s: float, kind: EventKind, payload: Tuple[Any, ...] = ()) -> Event:
        ev = Event(float(time_s), self._seq, EventKind(kind), tuple(payload))
        heapq.heappush(self._heap, (ev.time_s, ev.seq, ev))
        self._seq += 1
        if len(self._heap) > self.peak_heap_len:
            self.peak_heap_len = len(self._heap)
        if ev.time_s > self.max_time_pushed:
            self.max_time_pushed = ev.time_s
        return ev

    def tombstone(self, ev: Event) -> bool:
        """Mark a still-queued event dead; it will never be returned by
        ``pop``. The caller must only tombstone events it pushed and has
        not yet popped (the cluster tracks one pending event per job).
        Returns False if the event was already tombstoned."""
        if ev.seq in self._tombstoned:
            return False
        self._tombstoned.add(ev.seq)
        self.tombstones += 1
        # reclaim space before dead weight dominates: compacting at the
        # half-full mark keeps the heap O(live) while amortizing the
        # rebuild over at least len(heap)/2 tombstone calls
        if len(self._tombstoned) * 2 > len(self._heap):
            self.compact()
        return True

    def compact(self) -> None:
        """Physically drop every tombstoned event and re-heapify."""
        if not self._tombstoned:
            return
        self._heap = [item for item in self._heap if item[1] not in self._tombstoned]
        heapq.heapify(self._heap)
        self._tombstoned.clear()
        self.compactions += 1

    def pop(self) -> Event:
        while self._heap:
            _, seq, ev = heapq.heappop(self._heap)
            if seq in self._tombstoned:
                self._tombstoned.discard(seq)
                continue
            return ev
        raise IndexError("pop from empty EventQueue")

    def peek_time(self) -> Optional[float]:
        while self._heap and self._heap[0][1] in self._tombstoned:
            self._tombstoned.discard(heapq.heappop(self._heap)[1])
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap) - len(self._tombstoned)

    def __bool__(self) -> bool:
        return len(self._heap) > len(self._tombstoned)
