"""Discrete-event machinery for the cluster simulator.

The cluster (core/cluster.py) is a state machine driven by a time-ordered
event heap. Everything that changes cluster state is an event:

  ARRIVAL        a job enters the admission queue (``Cluster.submit``);
  COMPLETION     a placed job finishes its remaining steps — scheduled from
                 the job's predicted step time, re-scheduled whenever the
                 device's contention changes, invalidated by a token bump;
  RECONFIG_DONE  a device finishes a mode migration (MIG re-partitioning /
                 MPS daemon restart) and rejoins the fleet;
  FAILURE        slice units on a device go unhealthy (elastic repack);
  REPAIR         failed units return to health (elastic scale-up);
  PHASE_TRANSITION  a placed job crosses a phase boundary of its workload
                 plan (core/workload.py) — its demand vector changes, so
                 shared devices re-time every neighbour and the adaptive
                 policy gets a chance to reconsider the partitioning.
                 Token-invalidated exactly like COMPLETION.

Determinism contract: events at equal times are processed in push order
(``seq`` breaks ties), so a run is a pure function of the submitted trace —
the property tests/test_cluster.py pins down to byte-identical artifacts.

Completion events are *lazy-invalidated*: rather than surgically removing a
stale event from the heap (O(n)), every job carries a generation token and
a completion event stores the token it was scheduled under; a popped event
whose token no longer matches the job's is dropped. This is the standard
discrete-event idiom for processor-sharing queues, where every arrival and
departure on a shared device re-times every neighbour.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Any, List, Optional, Tuple


class EventKind(str, enum.Enum):
    ARRIVAL = "arrival"
    COMPLETION = "completion"
    RECONFIG_DONE = "reconfig_done"
    FAILURE = "failure"
    REPAIR = "repair"
    PHASE_TRANSITION = "phase_transition"


@dataclasses.dataclass(frozen=True)
class Event:
    time_s: float
    seq: int  # tie-break: equal-time events fire in push order
    kind: EventKind
    payload: Tuple[Any, ...] = ()

    def sort_key(self) -> Tuple[float, int]:
        return (self.time_s, self.seq)


class EventQueue:
    """Min-heap of events ordered by (time, push sequence)."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = 0

    def push(self, time_s: float, kind: EventKind, payload: Tuple[Any, ...] = ()) -> Event:
        ev = Event(float(time_s), self._seq, EventKind(kind), tuple(payload))
        heapq.heappush(self._heap, (ev.time_s, ev.seq, ev))
        self._seq += 1
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
