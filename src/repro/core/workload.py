"""Workload API v2: phase-aware demand traces for training AND inference.

The paper's collocation verdicts assume steady-state *training* jobs: one
flat demand vector per job for its whole lifetime (the `JobSpec` model).
But its own sub-saturation argument — collocation wins exactly when a job
leaves engines idle — applies even more strongly to inference, and related
work shows the flat model is the wrong abstraction for mixed fleets:
MIGPerf (Zhang et al., 2023) measures training+inference mixes on MIG
behaving qualitatively differently from training-only mixes, and MISO
(Li et al., 2022) shows demand-aware dynamic reconfiguration beating any
static partition. Both need a workload whose resource demand *varies over
time*. This module is that abstraction:

  Workload      a named sequence of phases plus a kind-specific objective:
                  TRAIN  warmup -> steady -> checkpoint, objective =
                         throughput (useful steps per second);
                  SERVE  prefill -> decode, objective = step-latency SLO
                         attainment on the latency-sensitive decode steps;
  Phase         one lifecycle stage with its own duration model (a fixed
                step count, or elastic — absorbing the remaining steps)
                and its own per-resource demand vector;
  DemandTrace   the per-phase demand vector, expressed as multipliers over
                the *steady-state* roofline/DCGM vector the characterization
                pipeline already measures (telemetry/roofline.py). Steady is
                the identity by construction — phase demand is derived from
                the existing telemetry, never a parallel set of constants.

Phase demand semantics (why multipliers, not absolutes): a job's absolute
step-time terms depend on which instance profile it lands on — the char DB
carries one record per (arch, shape, profile). A phase scales every record
the same way (a checkpoint burst is memory-heavy on a 1g.5gb slice and on
the full device alike), so the multiplier form composes with the whole
existing characterization machinery for free: ``phase_step_s`` rescales any
record, and ``SoloProfile.scaled`` (core/sharing.py) feeds the active
phase's vector into the shared-mode contention models.

`JobSpec` stays supported as a thin single-phase adapter
(:func:`from_jobspec` — one elastic ``steady`` phase, identity demand), so
every existing entry point, artifact, and test runs unchanged: identity
demand leaves every characterization record's step time and footprint
untouched (``phase_step_s`` returns ``rec["step_s"]`` verbatim,
``SoloProfile.scaled`` returns ``self``). Note the one deliberate model
change that is *not* phase-gated: the MPS dispatch-queue latency factor
(core/sharing.py) also re-times flat-job mixes whose aggregate compute
demand saturates — that is the mechanism change, not an adapter leak.

Import discipline: this module is part of the jax-free scheduling stack
(see tests/test_jax_free_core.py) — it may import core/instance.py,
core/sharing.py, and core/gang/parallelism.py only.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Mapping, Optional, Sequence, Tuple, Union

from repro.configs.base import ShapeSuite
from repro.core.gang.parallelism import (
    Parallelism,
    gang_world_size,
    member_memory_fraction,
    resolve_parallelism,
)
from repro.core.instance import JobSpec


class WorkloadKind(str, enum.Enum):
    """What the job is for — selects the objective the cluster optimizes."""

    TRAIN = "train"  # objective: throughput (useful steps / second)
    SERVE = "serve"  # objective: p99 step latency / SLO attainment


@dataclasses.dataclass(frozen=True)
class DemandTrace:
    """Per-resource demand vector of one phase, as multipliers over the
    steady-state roofline vector (compute_s / memory_s / collective_s /
    dispatch-latency / peak memory) from the characterization record.

    The identity trace IS the steady phase: demand derived from the
    measured telemetry, nothing invented."""

    compute: float = 1.0
    memory: float = 1.0
    collective: float = 1.0
    latency: float = 1.0
    mem_bytes: float = 1.0  # scales the phase's peak working set

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if not v >= 0.0:
                raise ValueError(f"DemandTrace.{f.name} must be >= 0, got {v}")

    @property
    def is_identity(self) -> bool:
        return self == STEADY_DEMAND


#: Steady training / generic demand — the telemetry-derived baseline.
STEADY_DEMAND = DemandTrace()

#: First steps after (re)placement: cold caches, compiler autotuning, input
#: pipeline warm-up — compute and dispatch run fat until traces settle.
WARMUP_DEMAND = DemandTrace(compute=1.25, memory=1.10, latency=2.0)

#: Checkpoint burst: parameters + optimizer state stream out through HBM to
#: the host; the MXU mostly idles, and the serialization staging buffer
#: raises the peak working set slightly above steady state.
CHECKPOINT_DEMAND = DemandTrace(
    compute=0.15, memory=2.5, collective=0.5, mem_bytes=1.05
)

#: Prefill: one dense forward pass over the prompt — compute-shaped like a
#: third of a training step (no backward, no optimizer), working set roughly
#: halved (weights + KV cache, no gradients or optimizer state).
PREFILL_DEMAND = DemandTrace(
    compute=0.40, memory=0.35, collective=0.30, mem_bytes=0.50
)

#: Decode: one token per step — tiny compute, weight/KV-cache streaming
#: dominates the busy time, and the dispatch-latency floor dominates the
#: step. This is the paper's GRACT << 1 sub-saturation regime, which is why
#: inference is collocation's best case — and its latency SLO the most
#: exposed to neighbours.
DECODE_DEMAND = DemandTrace(
    compute=0.05, memory=0.60, collective=0.10, mem_bytes=0.45
)


@dataclasses.dataclass(frozen=True)
class Phase:
    """One lifecycle stage: a demand vector plus a duration model.

    ``steps`` is the duration in steps; ``None`` marks the phase *elastic*
    — it absorbs however many steps the fixed phases leave over (at most
    one phase of a workload may be elastic). ``latency_sensitive`` marks
    the steps the serve SLO is scored on (decode)."""

    name: str
    demand: DemandTrace = STEADY_DEMAND
    steps: Optional[int] = None
    latency_sensitive: bool = False

    def __post_init__(self):
        if not self.name:
            raise ValueError("phase needs a name")
        if self.steps is not None and self.steps < 0:
            raise ValueError(f"phase {self.name!r}: steps must be >= 0")


@dataclasses.dataclass(frozen=True)
class PhaseSpan:
    """A phase resolved onto a concrete step interval [start, end)."""

    name: str
    demand: DemandTrace
    start_step: int
    end_step: int
    latency_sensitive: bool = False

    @property
    def steps(self) -> int:
        return self.end_step - self.start_step


@dataclasses.dataclass(frozen=True)
class Workload:
    """A job as a named sequence of phases with a kind-specific objective.

    Field layout is a strict superset of what the scheduler and cluster
    read off a ``JobSpec`` (name / arch / suite / priority / min_profile),
    so a Workload flows through ``CollocationScheduler`` and ``Cluster``
    anywhere a JobSpec does."""

    name: str
    arch: str
    suite: ShapeSuite
    kind: WorkloadKind = WorkloadKind.TRAIN
    phases: Tuple[Phase, ...] = (Phase("steady"),)
    priority: int = 0
    # floor on the MIG profile the scheduler may pick (straggler repack)
    min_profile: Optional[str] = None
    # SERVE objective: per-step latency target on latency-sensitive steps
    slo_step_s: Optional[float] = None
    # gang scheduling (core/gang/): > 1 => this job runs as world_size
    # cooperating members, each on its own MIG slice, admitted
    # all-or-nothing; parallelism describes the tensor/pipeline/data
    # split (None = plain data parallelism over world_size)
    world_size: int = 1
    parallelism: Optional[Parallelism] = None
    # gang this workload is a *member* of — set only on the per-rank specs
    # the cluster binds to slices (mirrors JobSpec.gang); user-submitted
    # workloads leave it None
    gang: Optional[str] = None

    def __post_init__(self):
        if not self.phases:
            raise ValueError(f"workload {self.name!r} needs at least one phase")
        elastic = [p.name for p in self.phases if p.steps is None]
        if len(elastic) > 1:
            raise ValueError(
                f"workload {self.name!r}: at most one elastic phase, "
                f"got {elastic}"
            )
        if self.world_size < 1:
            raise ValueError(
                f"workload {self.name!r}: world_size must be >= 1, "
                f"got {self.world_size}"
            )
        if self.parallelism is not None and (
            self.parallelism.world_size != self.world_size
        ):
            raise ValueError(
                f"workload {self.name!r}: parallelism "
                f"{self.parallelism.label} implies world_size "
                f"{self.parallelism.world_size}, declared {self.world_size}"
            )

    @property
    def peak_demand_multiplier(self) -> float:
        """Phase-peak memory multiplier — what admission must budget for:
        the job will live through its hungriest phase on this placement."""
        return max(p.demand.mem_bytes for p in self.phases)

    @property
    def objective(self) -> str:
        return "slo" if self.kind == WorkloadKind.SERVE else "throughput"

    def resolve(self, total_steps: int) -> Tuple[PhaseSpan, ...]:
        """Pin the phase sequence onto ``total_steps`` concrete steps.

        Fixed phases take their declared steps (clamped when the budget
        runs out); the elastic phase absorbs the remainder. If no phase is
        elastic, the last phase that fits extends to cover the tail, so the
        spans always partition [0, total_steps) exactly."""
        total = max(1, int(total_steps))
        fixed = sum(p.steps for p in self.phases if p.steps is not None)
        elastic_steps = max(0, total - fixed)
        spans = []
        cursor = 0
        for p in self.phases:
            n = elastic_steps if p.steps is None else p.steps
            n = min(n, total - cursor)
            if n <= 0:
                continue
            spans.append(
                PhaseSpan(p.name, p.demand, cursor, cursor + n,
                          p.latency_sensitive)
            )
            cursor += n
        if not spans:  # total smaller than every declared phase: first wins
            p = self.phases[0]
            return (PhaseSpan(p.name, p.demand, 0, total, p.latency_sensitive),)
        if cursor < total:  # no elastic phase (or it got 0): extend the tail
            last = spans[-1]
            spans[-1] = dataclasses.replace(last, end_step=total)
        return tuple(spans)


def span_at(spans: Sequence[PhaseSpan], steps_done: float) -> PhaseSpan:
    """The span containing ``steps_done`` (the last span once past the end)."""
    for s in spans:
        if steps_done < s.end_step:
            return s
    return spans[-1]


# -- constructors --------------------------------------------------------------


def train_workload(
    name: str,
    arch: str,
    suite: ShapeSuite,
    *,
    warmup_steps: int = 5,
    checkpoint_steps: int = 2,
    priority: int = 0,
    min_profile: Optional[str] = None,
) -> Workload:
    """Training job: warmup burst, elastic steady bulk, checkpoint drain."""
    return Workload(
        name=name,
        arch=arch,
        suite=suite,
        kind=WorkloadKind.TRAIN,
        phases=(
            Phase("warmup", WARMUP_DEMAND, warmup_steps),
            Phase("steady", STEADY_DEMAND, None),
            Phase("checkpoint", CHECKPOINT_DEMAND, checkpoint_steps),
        ),
        priority=priority,
        min_profile=min_profile,
    )


def serve_workload(
    name: str,
    arch: str,
    suite: ShapeSuite,
    *,
    slo_step_s: float,
    prefill_steps: int = 2,
    priority: int = 0,
    min_profile: Optional[str] = None,
) -> Workload:
    """Inference session: prefill burst, then elastic latency-bound decode."""
    return Workload(
        name=name,
        arch=arch,
        suite=suite,
        kind=WorkloadKind.SERVE,
        phases=(
            Phase("prefill", PREFILL_DEMAND, prefill_steps),
            Phase("decode", DECODE_DEMAND, None, latency_sensitive=True),
        ),
        priority=priority,
        min_profile=min_profile,
        slo_step_s=float(slo_step_s),
    )


def from_jobspec(spec: JobSpec) -> Workload:
    """The backward-compat adapter: one elastic steady phase, identity
    demand — byte-for-byte the old flat-JobSpec behaviour."""
    return Workload(
        name=spec.name,
        arch=spec.arch,
        suite=spec.suite,
        kind=WorkloadKind.TRAIN,
        phases=(Phase("steady", STEADY_DEMAND, None),),
        priority=spec.priority,
        min_profile=spec.min_profile,
        world_size=spec.world_size,
        parallelism=spec.parallelism,
        gang=spec.gang,
    )


def as_workload(job: Union[JobSpec, Workload]) -> Workload:
    """Normalize either job type to the phase-aware form."""
    if isinstance(job, Workload):
        return job
    if isinstance(job, JobSpec):
        return from_jobspec(job)
    raise TypeError(f"expected JobSpec or Workload, got {type(job).__name__}")


def peak_demand_multiplier(job: Union[JobSpec, Workload]) -> float:
    """Phase-peak memory multiplier for admission; 1.0 for flat JobSpecs.

    For gang members (``world_size > 1``) the phase peak is further scaled
    by the member memory fraction (core/gang/parallelism.py): one member
    budgets only its shard of the model state, which is exactly what lets
    a job no single slice admits run as a gang of smaller slices."""
    base = job.peak_demand_multiplier if isinstance(job, Workload) else 1.0
    if gang_world_size(job) > 1:
        base *= member_memory_fraction(resolve_parallelism(job))
    return base


def member_demand(job: Union[JobSpec, Workload], demand: DemandTrace) -> DemandTrace:
    """One gang member's demand vector for an active phase: busy-time
    terms divide by ``world_size`` (the work is split), the collective
    term survives untouched (members still run the solo program's own
    collectives — inter-member traffic is priced separately by
    core/gang/comms.py), and ``mem_bytes`` scales by the member memory
    fraction. Identity for world_size 1."""
    ws = gang_world_size(job)
    if ws <= 1:
        return demand
    frac = member_memory_fraction(resolve_parallelism(job))
    return DemandTrace(
        compute=demand.compute / ws,
        memory=demand.memory / ws,
        collective=demand.collective,
        latency=demand.latency,
        mem_bytes=demand.mem_bytes * frac,
    )


# -- record algebra ------------------------------------------------------------


def phase_step_s(rec: Mapping, demand: DemandTrace) -> float:
    """Step time of one phase on one characterized instance record.

    The record's roofline terms are scaled by the phase's demand vector and
    re-maxed; whatever part of the recorded step was not busy time (the
    dispatch-latency floor) scales with the latency multiplier. Identity
    demand returns ``rec["step_s"]`` exactly — flat JobSpecs keep their old
    predicted step times to the bit."""
    step = float(rec.get("step_s", 0.0))
    if demand.is_identity:
        return step
    compute = float(rec.get("compute_s", step))
    memory = float(rec.get("memory_s", 0.0))
    collective = float(rec.get("collective_s", 0.0))
    busy = max(compute, memory, collective)
    residual = max(0.0, step - busy)  # the record's dispatch-latency floor
    scaled_busy = max(
        compute * demand.compute,
        memory * demand.memory,
        collective * demand.collective,
    )
    return residual * demand.latency + scaled_busy


