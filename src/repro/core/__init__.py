# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Public mode API (kept dependency-light: sharing pulls in no jax).
from repro.core.sharing import (  # noqa: F401
    CollocationMode,
    SharedModeReport,
    SoloProfile,
    mps_contention,
    naive_contention,
    shared_mode_report,
)
