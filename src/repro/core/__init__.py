# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Device-model API: first-class GPU SKU descriptors (placement tree,
# slice budgets, shared-mode knobs) + the registry of generations. The
# module-global A100 constants in core/profiles.py are aliases of
# DEFAULT_SKU — kept as deprecation shims.
from repro.core.device import (  # noqa: F401
    DEFAULT_SKU,
    SKUS,
    DeviceSKU,
    InstanceProfile,
    Placement,
    format_gib,
    get_sku,
)

# Public mode API (kept dependency-light: nothing here pulls in jax).
from repro.core.sharing import (  # noqa: F401
    CollocationMode,
    SharedModeReport,
    SoloProfile,
    device_busy_fraction,
    mps_contention,
    naive_contention,
    shared_mode_report,
)

# Event-driven cluster API (dynamic arrivals, per-device modes, live
# reconfiguration). The whole scheduling stack is jax-free at import time
# (core/instance.py defers jax to InstanceRuntime), so the simulator runs
# without touching an accelerator runtime.
from repro.core.cluster import (  # noqa: F401
    Cluster,
    ClusterJob,
    ClusterReport,
    DeviceState,
)
from repro.core.events import Event, EventKind, EventQueue  # noqa: F401
from repro.core.queueing import AdmissionQueue  # noqa: F401

# Workload API v2: phase-aware demand traces, TRAIN/SERVE objectives, and
# the flat-JobSpec single-phase adapter (also jax-free).
from repro.core.workload import (  # noqa: F401
    DemandTrace,
    Phase,
    PhaseSpan,
    Workload,
    WorkloadKind,
    as_workload,
    from_jobspec,
    serve_workload,
    train_workload,
)
