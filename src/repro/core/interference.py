"""Isolation verifier + shared-mode interference quantifier.

Two complementary halves of the paper's interference story live here:

  * for MIG (partitioned) layouts, ``verify_isolation`` *proves* the paper's
    F3 finding structurally — co-located instances cannot interfere;
  * for the shared modes (naive / MPS) isolation is impossible by
    construction, so ``quantify_interference`` instead *quantifies* the
    predicted interference from the mode's contention model
    (core/sharing.py): per-job slowdown factors, the contended resources,
    and whether the mix fits shared memory at all.

On the A100 the paper *measures* that co-located MIG instances do not
interfere (per-instance epoch time is unchanged). On a TPU pod, isolation of
contiguous sub-rectangles is a topological property; this module *proves* it
structurally for a concrete layout instead of assuming it:

  V1  device disjointness — no chip belongs to two instances;
  V2  collective containment — every collective in every instance's
      compiled HLO has replica_groups that are a subset of that instance's
      own device ids (no ICI hop leaves the rectangle, so instances cannot
      contend for link bandwidth);
  V3  program equivalence — the compiled HLO fingerprint, FLOPs, bytes and
      per-device memory of a job on instance X are identical to the same
      job on any other instance of the same profile (isolated-vs-collocated
      and instance-vs-instance runs are the *same program*, so per-instance
      step time cannot depend on neighbours).

Together V1-V3 are strictly stronger than the paper's empirical check: they
hold for every input, not just the measured epochs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Sequence, Tuple

from repro.core.instance import InstanceRecord
from repro.core.partitioner import InstanceMesh
from repro.core.sharing import (
    CollocationMode,
    SoloProfile,
    mig_report,
    shared_mode_report,
)

_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,{} ]*\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]")


@dataclasses.dataclass
class IsolationReport:
    disjoint: bool
    collectives_contained: bool
    programs_identical: bool
    detail: Dict[str, str]

    @property
    def isolated(self) -> bool:
        return self.disjoint and self.collectives_contained and self.programs_identical


def check_disjoint(instances: Sequence[InstanceMesh]) -> Tuple[bool, str]:
    seen: Dict[int, str] = {}
    for inst in instances:
        for dev in inst.mesh.devices.flat:
            if dev.id in seen:
                return False, f"device {dev.id} in {seen[dev.id]} and {inst.label}"
            seen[dev.id] = inst.label
    return True, ""


def collective_groups(hlo_text: str) -> List[List[int]]:
    """All replica groups appearing in a compiled HLO module."""
    groups: List[List[int]] = []
    for m in _GROUPS_RE.finditer(hlo_text):
        for grp in re.findall(r"\{([0-9, ]+)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(ids)
    for m in _GROUPS_IOTA_RE.finditer(hlo_text):
        # iota groups are logical ids 0..n-1 — translated by the runtime to
        # the program's own device assignment, which IS the instance's
        # device list; containment holds by construction. Record as local.
        n = int(m.group(1)) * int(m.group(2))
        groups.append(list(range(n)))
    return groups


def check_collective_containment(
    hlo_text: str, device_ids: Sequence[int], n_local_devices: int
) -> Tuple[bool, str]:
    """Explicit replica groups must index only the instance's own devices.

    Compiled-per-instance programs address devices by *logical* id
    0..n_local-1; any id >= n_local would mean the collective reaches
    outside the instance.
    """
    for grp in collective_groups(hlo_text):
        for logical in grp:
            if logical >= n_local_devices:
                return False, f"group {grp} exceeds instance size {n_local_devices}"
    return True, ""


def check_program_equivalence(records: Sequence[InstanceRecord]) -> Tuple[bool, str]:
    """Same job on same profile ⇒ identical compiled program + costs."""
    by_profile: Dict[Tuple[str, str, str], List[InstanceRecord]] = {}
    for r in records:
        by_profile.setdefault((r.job.split("#")[0], r.arch, r.profile), []).append(r)
    for key, rs in by_profile.items():
        fp0, r0 = rs[0].hlo_fingerprint, rs[0]
        for r in rs[1:]:
            if r.hlo_fingerprint != fp0:
                return False, f"{key}: fingerprint {r.hlo_fingerprint} != {fp0}"
            if (r.peak_bytes_per_device, r.step_s) != (
                r0.peak_bytes_per_device,
                r0.step_s,
            ):
                return False, f"{key}: cost mismatch across instances"
    return True, ""


@dataclasses.dataclass
class InterferenceQuant:
    """Predicted interference for one job mix under one collocation mode.

    ``slowdown`` maps each job to effective/solo step time (1.0 == no
    interference); ``contended`` lists resources whose aggregate demand
    exceeds capacity; ``fits`` is the shared-memory admission verdict.
    """

    mode: CollocationMode
    slowdown: Dict[str, float]
    contended: List[str]
    fits: bool

    @property
    def interference_free(self) -> bool:
        return all(abs(s - 1.0) < 1e-9 for s in self.slowdown.values())

    @property
    def max_slowdown(self) -> float:
        return max(self.slowdown.values(), default=1.0)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["mode"] = self.mode.value
        d["interference_free"] = self.interference_free
        d["max_slowdown"] = self.max_slowdown
        return d


def quant_from_report(rep) -> InterferenceQuant:
    """Derive the interference quantification from an already-computed
    ``SharedModeReport`` (avoids re-running the contention model when the
    caller, e.g. launch/collocate.py, holds one)."""
    contended = [r for r, f in rep.contention.items() if f > 1.0 + 1e-12]
    if rep.mode == CollocationMode.NAIVE and len(rep.effective_step_s) > 1:
        contended = ["device"]  # the whole device is the contended resource
    return InterferenceQuant(
        mode=rep.mode,
        slowdown=dict(rep.interference),
        contended=contended,
        fits=rep.fits,
    )


def quantify_interference(
    mode: CollocationMode,
    jobs: Sequence[SoloProfile],
    mig_instance_step_s: Dict[str, float] | None = None,
) -> InterferenceQuant:
    """Predict per-job interference for ``jobs`` collocated under ``mode``.

    MIG returns all-1.0 slowdowns (F3: proven isolation, see
    ``verify_isolation``); the shared modes return the contention model's
    per-job stretch — MPS only above aggregate saturation of a resource,
    naive always (time-slicing serializes every neighbour's step).
    """
    mode = CollocationMode(mode)
    if mode == CollocationMode.MIG:
        rep = mig_report(jobs, mig_instance_step_s or {j.name: j.step_s for j in jobs})
    else:
        rep = shared_mode_report(mode, jobs)
    return quant_from_report(rep)


def verify_isolation(
    instances: Sequence[InstanceMesh],
    records: Sequence[InstanceRecord],
    hlo_texts: Dict[str, str] | None = None,
) -> IsolationReport:
    d_ok, d_why = check_disjoint(instances)
    c_ok, c_why = True, ""
    if hlo_texts:
        for inst in instances:
            txt = hlo_texts.get(inst.label)
            if txt is None:
                continue
            ok, why = check_collective_containment(
                txt, [d.id for d in inst.mesh.devices.flat], inst.n_chips
            )
            if not ok:
                c_ok, c_why = False, f"{inst.label}: {why}"
                break
    p_ok, p_why = check_program_equivalence(records)
    return IsolationReport(
        disjoint=d_ok,
        collectives_contained=c_ok,
        programs_identical=p_ok,
        detail={"disjoint": d_why, "contained": c_why, "identical": p_why},
    )
