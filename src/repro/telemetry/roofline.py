"""Three-term roofline model + DCGM-analogue utilization metrics.

Terms are *seconds per step* on the target hardware, derived from the
compiled dry-run artifact (everything is per-device because post-SPMD HLO is
per-device):

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_LINK_BW

The dominant term is the bottleneck; roofline fraction for the step is
max_term / (compute_s + ideally-overlapped others) — we report
``bound = max(terms)`` and ``frac_of_roofline = compute_s / max(terms)``
(how close the step is to being pure-MXU-limited, the hillclimb objective).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.telemetry import constants as C


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_global: float
    peak_mem_bytes_per_device: float
    collective_detail: Optional[Dict] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / C.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / C.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / C.ICI_LINK_BW

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = slowest term (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global): remat/redundancy waste detector."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_s * self.chips * C.PEAK_FLOPS_BF16
        return self.model_flops_global / denom if denom else 0.0

    @property
    def frac_of_roofline(self) -> float:
        """compute_s / step_s: 1.0 == pure compute-bound (at the roof)."""
        return self.compute_s / self.step_s if self.step_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "peak_mem_bytes_per_device": self.peak_mem_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "frac_of_roofline": self.frac_of_roofline,
            "collective_detail": self.collective_detail,
        }


# ---------------------------------------------------------------------------
# DCGM-metric analogues (paper §3.2.2), derived from the same artifact
# ---------------------------------------------------------------------------


def dcgm_analogues(r: RooflineReport) -> Dict[str, float]:
    """Map roofline terms onto the paper's utilization metrics.

    GRACT  — fraction of step time *any* engine is busy: 1 by construction
             for a saturated step; we report busy = (compute ∪ memory ∪ coll)
             assuming perfect overlap => max-term / step = 1; instead we use
             (compute_s + memory_s + collective_s admixture) vs serialized
             time to expose idleness: gract = step_s / serial_s.
    SMACT  — MXU-issue fraction: compute_s / step_s.
    SMOCC  — latency-hiding proxy: arithmetic intensity / ridge intensity,
             capped at 1 (weaker semantics than warp occupancy; documented).
    DRAMA  — HBM bandwidth utilization: memory_s / step_s.
    """
    ai = r.flops_per_device / max(r.hbm_bytes_per_device, 1.0)
    ridge = C.PEAK_FLOPS_BF16 / C.HBM_BW
    step = r.step_s or 1.0
    return {
        # engines idle only while blocked on collectives
        "gract": min(1.0, max(r.compute_s, r.memory_s) / step),
        "smact": min(1.0, r.compute_s / step),
        "smocc_proxy": min(1.0, ai / ridge),
        "drama": min(1.0, r.memory_s / step),
    }


def model_flops(cfg, suite, n_params_active: int) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N·B decode (per step)."""
    if suite.kind == "train":
        return 6.0 * n_params_active * suite.seq_len * suite.global_batch
    if suite.kind == "prefill":
        return 2.0 * n_params_active * suite.seq_len * suite.global_batch
    return 2.0 * n_params_active * suite.global_batch  # one token / decode step


def format_table(reports) -> str:
    hdr = (
        f"{'arch':<18}{'shape':<13}{'mesh':<10}{'compute_s':>10}{'memory_s':>10}"
        f"{'coll_s':>10}{'bound':>11}{'MFU':>7}{'useful':>8}{'GB/dev':>8}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:<18}{r.shape:<13}{r.mesh:<10}"
            f"{r.compute_s:>10.4f}{r.memory_s:>10.4f}{r.collective_s:>10.4f}"
            f"{r.bound:>11}{r.mfu:>7.3f}{r.useful_flops_ratio:>8.3f}"
            f"{r.peak_mem_bytes_per_device/2**30:>8.2f}"
        )
    return "\n".join(lines)
