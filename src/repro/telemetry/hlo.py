"""Optimized-HLO analysis: collective byte accounting with while-loop
(scan) execution multipliers and ring-cost wire weighting.

``compiled.as_text()`` is post-SPMD, so every shape is a *per-device* shard
shape and every collective carries ``replica_groups``. Layers run under
``lax.scan`` → collectives inside the loop body execute ``trip_count`` times;
XLA records that as ``backend_config={"known_trip_count":{"n":...}}`` on the
``while`` op, which we propagate through the computation call graph.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.telemetry.constants import DTYPE_BYTES

_COLLECTIVE_RE = re.compile(
    r"=\s+(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%(?P<cond>[^,\s]+), body=%(?P<body>[^,\s]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([^,\s)]+)")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY )?%?([^\s(]+)\s*\(.*\)\s*->")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dtype")
        if dt not in DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_result: int
    group_size: int
    multiplier: int
    op_name: str

    @property
    def wire_bytes(self) -> float:
        """Per-device ring-cost bytes on the wire for one execution."""
        n, R = self.group_size, self.bytes_result
        if n <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * R * (n - 1) / n
        if self.kind == "all-gather":
            return R * (n - 1) / n  # R = gathered (full) result
        if self.kind == "reduce-scatter":
            return R * (n - 1)  # R = scattered shard; input = n*R
        if self.kind == "all-to-all":
            return R * (n - 1) / n
        return float(R)  # collective-permute

    @property
    def total_wire_bytes(self) -> float:
        return self.wire_bytes * self.multiplier

    @property
    def total_raw_bytes(self) -> float:
        return float(self.bytes_result) * self.multiplier


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


def _entry_name(hlo_text: str) -> Optional[str]:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            m = _COMP_HEADER_RE.match(line[len("ENTRY "):].strip())
            if m:
                return m.group(1)
    return None


def computation_multipliers(hlo_text: str) -> Dict[str, int]:
    """How many times each computation executes per program invocation."""
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text)
    # edges: caller -> [(callee, per-call multiplier)]
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                edges[name].append((wm.group("body"), trips))
                edges[name].append((wm.group("cond"), trips + 1))
                continue
            for callee in _CALLS_RE.findall(line):
                edges[name].append((callee, 1))

    mult: Dict[str, int] = {name: 0 for name in comps}
    if entry:
        mult[entry] = 1
    # fixed-point propagation (call graphs are DAGs; few iterations suffice)
    for _ in range(len(comps) + 2):
        changed = False
        for caller, outs in edges.items():
            base = mult.get(caller, 0)
            if base == 0:
                continue
            for callee, k in outs:
                want = base * k
                if callee in mult and mult[callee] < want:
                    mult[callee] = want
                    changed = True
        if not changed:
            break
    return mult


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    comps = _split_computations(hlo_text)
    mults = computation_multipliers(hlo_text)
    ops: List[CollectiveOp] = []
    for comp, lines in comps.items():
        m = mults.get(comp, 1) or 1
        for line in lines:
            cm = _COLLECTIVE_RE.search(line)
            if not cm:
                continue
            kind = cm.group("op")
            type_str = cm.group("type")
            b = shape_bytes(type_str)
            if cm.group("start"):
                # async start: result tuple aliases operand + result; halve
                b = b // 2
            gm = _GROUPS_EXPLICIT_RE.search(line)
            if gm:
                gsize = len(gm.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                gsize = int(gi.group(2)) if gi else 1
            om = _OPNAME_RE.search(line)
            ops.append(
                CollectiveOp(
                    kind=kind,
                    bytes_result=b,
                    group_size=gsize,
                    multiplier=m,
                    op_name=om.group(1) if om else "",
                )
            )
    return ops


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>[a-z][a-z0-9\-]*)\((?P<args>[^)]*)"
)
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIM_LABELS_RE = re.compile(r"dim_labels=([0-9a-z?]+)_([0-9a-z?]+)->")
_SKIP_BYTES_OPS = frozenset(
    "parameter constant tuple get-tuple-element bitcast while conditional "
    "call after-all add-dependency domain".split()
)


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group("dims").split(",") if d]


def hlo_flops_bytes(hlo_text: str) -> Dict[str, float]:
    """Loop-aware FLOP and HBM-byte estimate from optimized HLO text.

    ``compiled.cost_analysis()`` counts each while-loop body ONCE, so for
    scan-over-layers programs it undercounts by ~n_layers. This walks every
    computation, multiplies by the known_trip_count-derived execution
    multiplier (same machinery as the collective parser), and:
      * flops — 2*M*N*K for every ``dot`` (batch dims included via the
        result element count), 2*out*K_window for every ``convolution``;
      * bytes — a FUSED-TPU traffic model: operand+result bytes of the ops
        that necessarily touch HBM on TPU (dot/conv, gather/scatter,
        dynamic-(update-)slice on big buffers, reduces, collectives) plus
        the program's parameter/result footprint once. Elementwise chains
        and converts are assumed fused (XLA:CPU leaves them unfused and
        f32-normalized, which would overcount TPU traffic ~10x).
    """
    comps = _split_computations(hlo_text)
    mults = computation_multipliers(hlo_text)
    entry = _entry_name(hlo_text)
    # fused computations execute as part of their fusion op, not standalone;
    # their instructions must not be double-counted at top level. They never
    # appear in the call graph via calls= (fusion uses calls= too!) — so
    # track computations referenced by fusion ops and skip their bodies.
    fused: set = set()
    for name, lines in comps.items():
        for line in lines:
            om = _OP_RE.match(line)
            if om and om.group("op") == "fusion":
                cm = _CALLS_RE.search(line)
                if cm:
                    fused.add(cm.group(1))

    flops = 0.0
    bytes_ = 0.0
    for comp, lines in comps.items():
        if comp in fused:
            # count dots/convs inside fusions (CPU keeps most dots
            # unfused, but be safe); bytes are counted at the fusion site
            mult = mults.get(comp, 0) or 0
            if mult == 0:
                continue
            symtab = {}
            for line in lines:
                om = _OP_RE.match(line)
                if om:
                    symtab[om.group("name")] = om.group("type")
            for line in lines:
                om = _OP_RE.match(line)
                if om and om.group("op") in ("dot", "convolution"):
                    flops += mult * _op_flops(om, line, symtab)
            continue
        mult = mults.get(comp, 1) or 1
        symtab = {}
        for line in lines:
            om = _OP_RE.match(line)
            if om:
                symtab[om.group("name")] = om.group("type")
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            op = om.group("op")
            if op in ("dot", "convolution"):
                flops += mult * _op_flops(om, line, symtab)
            if op in _HBM_OPS:
                b = shape_bytes(om.group("type"))
                for arg in om.group("args").split(","):
                    arg = arg.strip().lstrip("%")
                    t = symtab.get(arg)
                    if t:
                        b += shape_bytes(t)
                bytes_ += mult * b
            elif op == "parameter" and comp == entry:
                # program inputs (params/opt state/batch) stream from HBM
                # once per step
                bytes_ += shape_bytes(om.group("type"))
    return {"flops": flops, "bytes": bytes_}


# ops whose operands/results necessarily stream HBM on a fused TPU backend
_HBM_OPS = frozenset(
    "dot convolution gather scatter dynamic-slice dynamic-update-slice "
    "reduce reduce-window sort all-gather all-reduce reduce-scatter "
    "all-to-all collective-permute".split()
)


def _op_flops(om, line: str, symtab: Dict[str, str]) -> float:
    out_elems = 1
    for d in _dims_of(om.group("type")):
        out_elems *= d
    args = [a.strip().lstrip("%") for a in om.group("args").split(",")]
    if om.group("op") == "dot":
        cm = _LHS_CDIMS_RE.search(line)
        lhs_t = symtab.get(args[0], "") if args else ""
        lhs_dims = _dims_of(lhs_t)
        k = 1
        if cm and lhs_dims:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
        return 2.0 * out_elems * k
    # convolution: K = product of rhs dims that are not the output-feature dim
    dm = _DIM_LABELS_RE.search(line)
    rhs_t = symtab.get(args[1], "") if len(args) > 1 else ""
    rhs_dims = _dims_of(rhs_t)
    if dm and rhs_dims:
        labels = dm.group(2)  # e.g. "01io"
        k = 1
        for i, ch in enumerate(labels):
            if ch != "o" and i < len(rhs_dims):
                k *= rhs_dims[i]
        return 2.0 * out_elems * k
    return 2.0 * out_elems


def collective_summary(hlo_text: str) -> Dict:
    ops = parse_collectives(hlo_text)
    by_kind: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "raw_bytes": 0.0, "wire_bytes": 0.0}
    )
    for op in ops:
        k = by_kind[op.kind]
        k["count"] += op.multiplier
        k["raw_bytes"] += op.total_raw_bytes
        k["wire_bytes"] += op.total_wire_bytes
    top = sorted(ops, key=lambda o: -o.total_wire_bytes)[:12]
    return {
        "per_device_raw_bytes": sum(o.total_raw_bytes for o in ops),
        "per_device_wire_bytes": sum(o.total_wire_bytes for o in ops),
        "n_collective_sites": len(ops),
        "by_kind": {k: v for k, v in by_kind.items()},
        "top_ops": [
            {
                "kind": o.kind,
                "bytes": o.bytes_result,
                "group": o.group_size,
                "x": o.multiplier,
                "wire": o.total_wire_bytes,
                "op_name": o.op_name[-110:],
            }
            for o in top
        ],
    }
