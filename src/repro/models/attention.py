"""Attention implementations.

``xla_flash`` is the default lowering path: a blocked online-softmax attention
expressed with ``lax.scan`` over KV blocks, so the S x S score matrix is never
materialized (required for the 32k prefill cells) while remaining pure XLA —
this is what the 512-device dry-run compiles. The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU hot-path with identical math and
is validated against ``repro.kernels.ref`` oracles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B,Sq,KVH,G,D), k: (B,bk,KVH,D) -> (B,Sq,KVH,G,bk), f32."""
    return jnp.einsum(
        "bqhgd,bkhd->bqhgk", q, k, preferred_element_type=jnp.float32
    )


def _gqa_values(p, v):
    """p: (B,Sq,KVH,G,bk) f32, v: (B,bk,KVH,D) -> (B,Sq,KVH,G,D) f32."""
    return jnp.einsum(
        "bqhgk,bkhd->bqhgd", p, v, preferred_element_type=jnp.float32
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_k: int = 1024,
    q_offset: int = 0,
    scale: Optional[float] = None,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Backend dispatch: Pallas kernel on TPU, XLA scan path elsewhere.

    The XLA path is what the 512-placeholder-device dry-run lowers (identical
    math, no Mosaic dependency); on a real TPU the Pallas kernel from
    ``repro.kernels`` takes over. kv_len/q_offset users (decode) stay XLA.
    """
    if (
        jax.default_backend() == "tpu"
        and kv_len is None
        and q_offset == 0
        and q.shape[1] % 512 == 0
        and k.shape[1] % 512 == 0
    ):
        from repro.kernels import ops

        return ops.flash_attention(
            q, k, v, causal=causal, scale=scale, mode="tpu"
        )
    return xla_flash_attention(
        q, k, v, causal=causal, block_k=block_k, q_offset=q_offset,
        scale=scale, kv_len=kv_len,
    )


def xla_flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_k: int = 1024,
    q_offset: int = 0,
    scale: Optional[float] = None,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Blocked GQA attention with online softmax, pure XLA.

    q: (B, Sq, H, D); k, v: (B, Skv, KVH, D). H = KVH * G.
    ``q_offset``: absolute position of q[0] (prefill=0; decode=cache length).
    ``kv_len``: optional dynamic valid-KV length (decode with ring cache).
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D**-0.5

    # q is upcast once (small); K/V blocks stay in storage dtype and the
    # score/value dots accumulate in f32 — avoids materializing f32 copies
    # of the whole K/V tensors (2x HBM traffic at 32k prefill)
    qf = (q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * scale).astype(k.dtype)
    block_k = min(block_k, Skv)
    n_blocks = -(-Skv // block_k)
    pad = n_blocks * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, block_k, KVH, D).swapaxes(0, 1)
    vb = v.reshape(B, n_blocks, block_k, KVH, D).swapaxes(0, 1)

    q_pos = q_offset + jnp.arange(Sq)  # (Sq,)

    def body(carry, inputs):
        acc, m, l = carry
        idx, kblk, vblk = inputs
        kv_pos = idx * block_k + jnp.arange(block_k)  # (bk,)
        s = _gqa_scores(qf, kblk)  # (B,Sq,KVH,G,bk)
        mask = jnp.ones((Sq, block_k), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        mask &= (kv_pos < Skv)[None, :] if pad else True
        if kv_len is not None:
            mask &= (kv_pos[None, :] < kv_len)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + _gqa_values(p, vblk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, KVH, G, D), jnp.float32)
    m0 = jnp.full((B, Sq, KVH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, G), jnp.float32)
    idxs = jnp.arange(n_blocks)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (idxs, kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    kv_len: jax.Array | int,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-step decode attention.

    q: (B, 1, H, D); caches: (B, Smax, KVH, D). ``kv_len``: number of valid
    cache entries (scalar). The cache sequence dim may be sharded (SP decode);
    the masked softmax reduces across it with f32 stats.
    """
    B, _, H, D = q.shape
    _, Smax, KVH, _ = k_cache.shape
    G = H // KVH
    scale = scale if scale is not None else D**-0.5
    # keep the cache in its storage dtype (bf16): upcasting it would
    # materialize an f32 copy of the whole KV shard (2x HBM reads + huge
    # temps at 32k-500k contexts); the dots accumulate in f32 instead.
    qf = (q.reshape(B, KVH, G, D).astype(jnp.float32) * scale).astype(k_cache.dtype)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qf, k_cache,
        preferred_element_type=jnp.float32,
    )  # (B,KVH,G,Smax) f32
    pos = jnp.arange(Smax)
    s = jnp.where(pos[None, None, None, :] < kv_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)
