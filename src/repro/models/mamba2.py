"""Mamba2 (SSD) blocks and the zamba2-7b hybrid (Mamba2 backbone + one
*shared* GQA attention block applied before every ``attn_every``-th layer).

SSD recurrence (per head h, state h_t in R^{P x N}, scalar decay a_t):
  h_t = a_t * h_{t-1} + (dt_t x_t) outer B_t
  y_t = h_t @ C_t + D * x_t
Training uses the chunked form (bounded pairwise decays, scan over chunks);
decode carries (B, H, P, N) state + a (B, d_conv-1, conv_channels) conv tail,
so serving cost is sequence-independent -> zamba2 runs ``long_500k``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import losses
from repro.models import module as nn
from repro.models import transformer as tfm
from repro.models.attention import decode_attention
from repro.models.model_api import Model, _input_specs, register_family
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # (B, T, H, P) inner activations (dt-scaled outside)
    dt: jax.Array,  # (B, T, H) softplus'd step sizes
    A: jax.Array,  # (H,) negative decay rates
    Bm: jax.Array,  # (B, T, N) input projections (single group)
    Cm: jax.Array,  # (B, T, N)
    state0: jax.Array,  # (B, H, P, N)
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,T,H,P) f32, final state)."""
    B_, T, H, P = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0
    n = T // chunk

    la_full = dt * A[None, None, :]  # (B,T,H) log-decay per step, <= 0
    xr = x.astype(jnp.float32).reshape(B_, n, chunk, H, P).transpose(1, 0, 3, 2, 4)
    dtr = dt.astype(jnp.float32).reshape(B_, n, chunk, H).transpose(1, 0, 3, 2)
    lar = la_full.astype(jnp.float32).reshape(B_, n, chunk, H).transpose(1, 0, 3, 2)
    Br = Bm.astype(jnp.float32).reshape(B_, n, chunk, N).transpose(1, 0, 2, 3)
    Cr = Cm.astype(jnp.float32).reshape(B_, n, chunk, N).transpose(1, 0, 2, 3)
    # xr/dtr/lar: (n,B,H,C[,P]); Br/Cr: (n,B,C,N)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))  # s <= t inclusive

    def body(S, inputs):
        xb, dtb, lab, Bb, Cb = inputs
        cla = jnp.cumsum(lab, axis=-1)  # (B,H,C) inclusive
        # pairwise decay exp(cla_t - cla_s) for s<=t (bounded <= 1)
        diff = cla[:, :, :, None] - cla[:, :, None, :]  # (B,H,C,C)
        decay = jnp.exp(jnp.where(tri[None, None], diff, -jnp.inf))
        cb = jnp.einsum("btn,bsn->bts", Cb, Bb)  # (B,C,C)
        scores = decay * cb[:, None, :, :]  # (B,H,C,C)
        xdt = xb * dtb[..., None]  # dt-weighted inputs
        y = jnp.einsum("bhts,bhsp->bhtp", scores, xdt)
        # cross-chunk: y += exp(cla_t) * (C_t . S)
        y = y + jnp.exp(cla)[..., None] * jnp.einsum("bhpn,btn->bhtp", S, Cb).transpose(
            0, 1, 2, 3
        )
        # state: S' = exp(cla[-1]) S + sum_s exp(cla[-1]-cla_s) (dt_s x_s) outer B_s
        last = cla[:, :, -1:]  # (B,H,1)
        w = jnp.exp(last - cla)  # (B,H,C)
        S_new = jnp.exp(last)[..., None] * S + jnp.einsum(
            "bhsp,bsn,bhs->bhpn", xdt, Bb, w
        )
        return S_new, y

    state, ys = jax.lax.scan(body, state0.astype(jnp.float32), (xr, dtr, lar, Br, Cr))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B_, T, H, P)
    return y, state


def ssd_step(x, dt, A, Bm, Cm, state):
    """Single step. x:(B,H,P), dt:(B,H), Bm/Cm:(B,N), state (B,H,P,N)."""
    la = dt * A[None, :]
    a = jnp.exp(la.astype(jnp.float32))  # (B,H)
    xdt = (x * dt[..., None]).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xdt, Bm.astype(jnp.float32))
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    return y, state


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------


def _inner(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.state_dim


def init_mamba_block(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    d = cfg.d_model
    d_inner, H, P, N = _inner(cfg)
    s = cfg.ssm
    conv_ch = d_inner + 2 * N  # x, B, C go through the short conv
    return {
        "norm": nn.rmsnorm_init(d),
        # fused in-proj: [z, x, B, C, dt]
        "w_in": nn.fan_in_init(kg(), (d, 2 * d_inner + 2 * N + H), jnp.bfloat16),
        "conv_w": nn.trunc_normal(kg(), (s.d_conv, conv_ch), 0.1, jnp.bfloat16),
        "conv_b": jnp.zeros((conv_ch,), jnp.bfloat16),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),  # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": nn.rmsnorm_init(d_inner),
        "w_out": nn.fan_in_init(
            kg(), (d_inner, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, H, P, N = _inner(cfg)
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, xin, Bc, Cc, dt


def _causal_conv_seq(w, b, x, tail: Optional[jax.Array] = None):
    """Depthwise causal conv along T. x: (B,T,C); w: (K,C). Returns (y, new_tail)."""
    K = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) if tail is None else tail
    )
    xp = jnp.concatenate([pad, x], axis=1)
    # sum_k w[k] * x[t - (K-1) + k]
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    y = y + b[None, None, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), xp[:, -(K - 1) :, :]


def mamba_seq(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B,T,d)
    plan: ShardingPlan,
    state0: jax.Array,
    conv_tail: Optional[jax.Array] = None,
):
    B, T, d = x.shape
    d_inner, H, P, N = _inner(cfg)
    xn = nn.rmsnorm_apply(p["norm"], x)
    proj = nn.dense_apply({"w": p["w_in"]}, xn)
    z, xin, Bc, Cc, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_tail = _causal_conv_seq(p["conv_w"], p["conv_b"], conv_in, conv_tail)
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = plan.act(xin.reshape(B, T, H, P), "heads")
    y, state = ssd_chunked(xh, dtv, A, Bc, Cc, state0, chunk=cfg.ssm.chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(jnp.bfloat16)
    y = nn.rmsnorm_apply(p["out_norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)
    ).astype(jnp.bfloat16)
    out = nn.dense_apply({"w": p["w_out"]}, y)
    return out, state, new_tail


def mamba_step(cfg: ModelConfig, p: Params, x, state, conv_tail):
    """x: (B,d). conv_tail: (B, K-1, C)."""
    B, d = x.shape
    d_inner, H, P, N = _inner(cfg)
    xn = nn.rmsnorm_apply(p["norm"], x)
    proj = nn.dense_apply({"w": p["w_in"]}, xn)
    z, xin, Bc, Cc, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)  # (B,C)
    window = jnp.concatenate([conv_tail, conv_in[:, None, :]], axis=1)  # (B,K,C)
    w = p["conv_w"]
    y = jnp.einsum("bkc,kc->bc", window, w.astype(window.dtype)) + p["conv_b"]
    y = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)
    xin, Bc, Cc = jnp.split(y, [d_inner, d_inner + N], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    yh, state = ssd_step(xin.reshape(B, H, P), dtv, A, Bc, Cc, state)
    yh = yh + p["D"][None, :, None] * xin.reshape(B, H, P).astype(jnp.float32)
    yh = yh.reshape(B, d_inner).astype(jnp.bfloat16)
    yh = nn.rmsnorm_apply(p["out_norm"], yh) * jax.nn.silu(
        z.astype(jnp.float32)
    ).astype(jnp.bfloat16)
    return nn.dense_apply({"w": p["w_out"]}, yh), state, window[:, 1:, :]


# ---------------------------------------------------------------------------
# zamba2 hybrid assembly
# ---------------------------------------------------------------------------


def _group_sizes(cfg: ModelConfig):
    """Layer groups: shared attention applied before each group."""
    k = cfg.attn_every
    n = cfg.n_layers
    if k <= 0:
        return [n]
    full, rem = divmod(n, k)
    return [k] * full + ([rem] if rem else [])


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    params: Params = {
        "embed": nn.embedding_init(kg(), cfg.padded_vocab, cfg.d_model),
        "layers": nn.stack_layer_init(
            functools.partial(init_mamba_block, cfg), kg(), cfg.n_layers
        ),
        "final_norm": nn.rmsnorm_init(cfg.d_model),
        "lm_head": {"w_lm": nn.fan_in_init(kg(), (cfg.d_model, cfg.padded_vocab), jnp.bfloat16)},
    }
    if cfg.attn_every:
        params["shared_attn"] = tfm.init_block(cfg, kg())
    return params


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, plan: ShardingPlan):
    B, T = tokens.shape
    d_inner, H, P, N = _inner(cfg)
    h = nn.embedding_apply(params["embed"], tokens)
    h = plan.act(h, "hidden")
    state0 = jnp.zeros((B, H, P, N), jnp.float32)

    def mamba_body(x, lp):
        y, _, _ = mamba_seq(cfg, lp, x, plan, state0)
        return plan.act(x + y, "hidden")

    start = 0
    for g, size in enumerate(_group_sizes(cfg)):
        if cfg.attn_every:
            h = tfm.block_fwd(cfg, plan, h, params["shared_attn"])
        group = nn.slice_layers(params["layers"], start, start + size)
        h = nn.scan_layers(mamba_body, h, group, remat=cfg.remat)
        start += size
    logits = tfm.logits_fn(cfg, params, h, plan)
    return plan.act(logits, "logits")


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    d_inner, H, P, N = _inner(cfg)
    s = cfg.ssm
    conv_ch = d_inner + 2 * N
    L = cfg.n_layers
    spec = {
        "ssm": jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((L, batch, s.d_conv - 1, conv_ch), jnp.bfloat16),
    }
    if cfg.attn_every:
        n_apps = len(_group_sizes(cfg))
        hd = cfg.resolved_head_dim
        spec["attn_k"] = jax.ShapeDtypeStruct(
            (n_apps, batch, max_len, cfg.n_kv_heads, hd), jnp.bfloat16
        )
        spec["attn_v"] = jax.ShapeDtypeStruct(
            (n_apps, batch, max_len, cfg.n_kv_heads, hd), jnp.bfloat16
        )
    return spec


def _attn_prefill_block(cfg, lp, x, plan, positions):
    """Shared-attn block forward that also returns rope'd K/V for the cache."""
    B, S, _ = x.shape
    xn = tfm._norm(cfg, lp["attn_norm"], x)
    q, k, v = tfm._qkv(cfg, lp["attn"], xn, plan)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    kr = nn.apply_rope(k, positions, cfg.rope_theta)
    out = tfm.xla_flash_attention(q, kr, v, causal=True, block_k=cfg.attn_block_k)
    x = x + nn.dense_apply({"w": lp["attn"]["wo"]}, out.reshape(B, S, -1))
    x = x + tfm._mlp(cfg, lp["mlp"], tfm._norm(cfg, lp["mlp_norm"], x), plan)
    return plan.act(x, "hidden"), kr.astype(jnp.bfloat16), v.astype(jnp.bfloat16)


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, plan: ShardingPlan):
    B, T = tokens.shape
    d_inner, H, P, N = _inner(cfg)
    h = nn.embedding_apply(params["embed"], tokens)
    h = plan.act(h, "hidden")
    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    positions = jnp.arange(T)

    def mamba_body(x, lp):
        y, state, tail = mamba_seq(cfg, lp, x, plan, state0)
        return plan.act(x + y, "hidden"), (state, tail)

    ssm_states, conv_tails, ks, vs = [], [], [], []
    start = 0
    for size in _group_sizes(cfg):
        if cfg.attn_every:
            h, kr, v = _attn_prefill_block(cfg, params["shared_attn"], h, plan, positions)
            ks.append(kr)
            vs.append(v)
        group = nn.slice_layers(params["layers"], start, start + size)

        def step(c, lp):
            c, extras = mamba_body(c, lp)
            return c, extras

        h, (st, tl) = jax.lax.scan(step, h, group)
        ssm_states.append(st)
        conv_tails.append(tl)
        start += size

    cache = {
        "ssm": plan.act(jnp.concatenate(ssm_states, axis=0), "state"),
        "conv": jnp.concatenate(conv_tails, axis=0),
    }
    if cfg.attn_every:
        cache["attn_k"] = plan.act(jnp.stack(ks), "cache")
        cache["attn_v"] = plan.act(jnp.stack(vs), "cache")
    logits = tfm.logits_fn(cfg, params, h[:, -1:, :], plan)[:, 0, :]
    return plan.act(logits, "last_logits"), cache


def decode_step(cfg, params, token, cache, pos, plan: ShardingPlan):
    B = token.shape[0]
    pos_arr = jnp.asarray(pos, jnp.int32)
    x = nn.embedding_apply(params["embed"], token[:, None])[:, 0, :]

    def mamba_scan(x, layer_in):
        lp, st, tail = layer_in
        y, st2, tail2 = mamba_step(cfg, lp, x, st, tail)
        return x + y, (st2, tail2)

    new_k, new_v = [], []
    start = 0
    sizes = _group_sizes(cfg)
    ssm_out = []
    conv_out = []
    for g, size in enumerate(sizes):
        if cfg.attn_every:
            lp = params["shared_attn"]
            xs = x[:, None, :]
            xn = tfm._norm(cfg, lp["attn_norm"], xs)
            q, k, v = tfm._qkv(cfg, lp["attn"], xn, plan)
            q = nn.apply_rope(q, pos_arr[None], cfg.rope_theta)
            k = nn.apply_rope(k, pos_arr[None], cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["attn_k"][g], k.astype(jnp.bfloat16), pos_arr, 1
            )
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["attn_v"][g], v.astype(jnp.bfloat16), pos_arr, 1
            )
            out = decode_attention(q, kc, vc, kv_len=pos_arr + 1)
            xs = xs + nn.dense_apply({"w": lp["attn"]["wo"]}, out.reshape(B, 1, -1))
            xs = xs + tfm._mlp(cfg, lp["mlp"], tfm._norm(cfg, lp["mlp_norm"], xs), plan)
            x = xs[:, 0, :]
            new_k.append(kc)
            new_v.append(vc)
        group = nn.slice_layers(params["layers"], start, start + size)
        st = jax.lax.dynamic_slice_in_dim(cache["ssm"], start, size, 0)
        tail = jax.lax.dynamic_slice_in_dim(cache["conv"], start, size, 0)
        x, (st2, tail2) = jax.lax.scan(mamba_scan, x, (group, st, tail))
        ssm_out.append(st2)
        conv_out.append(tail2)
        start += size

    new_cache = {
        "ssm": plan.act(jnp.concatenate(ssm_out, axis=0), "state"),
        "conv": jnp.concatenate(conv_out, axis=0),
    }
    if cfg.attn_every:
        new_cache["attn_k"] = plan.act(jnp.stack(new_k), "cache")
        new_cache["attn_v"] = plan.act(jnp.stack(new_v), "cache")
    logits = tfm.logits_fn(cfg, params, x[:, None, :], plan)[:, 0, :]
    return plan.act(logits, "last_logits"), new_cache


@register_family("hybrid")
def _build_hybrid(cfg: ModelConfig) -> Model:
    def loss(params, batch, plan: ShardingPlan):
        logits = forward(cfg, params, batch["tokens"], plan)
        return losses.softmax_cross_entropy(logits, batch["labels"])

    return Model(
        cfg=cfg,
        init=lambda key: init_params(cfg, key),
        loss=loss,
        prefill=lambda params, batch, plan: prefill(cfg, params, batch["tokens"], plan),
        decode=lambda params, batch, cache, pos, plan: decode_step(
            cfg, params, batch["token"], cache, pos, plan
        ),
        cache_spec=lambda b, s: cache_spec(cfg, b, s),
        input_specs=lambda suite: _input_specs(cfg, suite),
    )


register_family("ssm")(_build_hybrid)  # pure-mamba configs reuse the hybrid path
