"""Encoder-decoder transformer (whisper-base backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings of shape (B, n_frames, d_model) standing in for
the two-conv mel frontend; the backbone (encoder self-attn, decoder
self+cross attn, gelu MLPs, layernorm, learned decoder positions) is real.
Depth runs under ``lax.scan`` like every other family.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSuite
from repro.models import losses
from repro.models import module as nn
from repro.models import transformer as tfm
from repro.models.attention import decode_attention, flash_attention as xla_flash_attention
from repro.models.model_api import Model, _input_specs, register_family
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper-style fixed sinusoidal positions, (length, channels) f32."""
    log_timescale = jnp.log(10_000.0) / (channels // 2 - 1)
    inv_timescales = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv_timescales[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_mha(cfg: ModelConfig, key: jax.Array) -> Params:
    """Whisper MHA: bias on q/v/o, none on k."""
    kg = nn.KeyGen(key)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    return {
        "wq": nn.fan_in_init(kg(), (d, cfg.n_heads * hd), jnp.bfloat16),
        "bq": jnp.zeros((cfg.n_heads * hd,), jnp.bfloat16),
        "wk": nn.fan_in_init(kg(), (d, cfg.n_kv_heads * hd), jnp.bfloat16),
        "wv": nn.fan_in_init(kg(), (d, cfg.n_kv_heads * hd), jnp.bfloat16),
        "bv": jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16),
        "wo": nn.fan_in_init(kg(), (cfg.n_heads * hd, d), jnp.bfloat16),
        "bo": jnp.zeros((d,), jnp.bfloat16),
    }


def _init_mlp(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "w_up": nn.fan_in_init(kg(), (cfg.d_model, cfg.d_ff), jnp.bfloat16),
        "b_up": jnp.zeros((cfg.d_ff,), jnp.bfloat16),
        "w_down": nn.fan_in_init(kg(), (cfg.d_ff, cfg.d_model), jnp.bfloat16),
        "b_down": jnp.zeros((cfg.d_model,), jnp.bfloat16),
    }


def _init_enc_block(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "attn_norm": nn.layernorm_init(cfg.d_model),
        "attn": _init_mha(cfg, kg()),
        "mlp_norm": nn.layernorm_init(cfg.d_model),
        "mlp": _init_mlp(cfg, kg()),
    }


def _init_dec_block(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        "self_norm": nn.layernorm_init(cfg.d_model),
        "self_attn": _init_mha(cfg, kg()),
        "cross_norm": nn.layernorm_init(cfg.d_model),
        "cross_attn": _init_mha(cfg, kg()),
        "mlp_norm": nn.layernorm_init(cfg.d_model),
        "mlp": _init_mlp(cfg, kg()),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    return {
        # stub frontend projection: frame embeddings -> model space
        "frame_proj": {
            "w_in": nn.fan_in_init(kg(), (cfg.d_model, cfg.d_model), jnp.bfloat16)
        },
        "enc_layers": nn.stack_layer_init(
            functools.partial(_init_enc_block, cfg), kg(), cfg.enc_layers
        ),
        "enc_norm": nn.layernorm_init(cfg.d_model),
        "embed": nn.embedding_init(kg(), cfg.padded_vocab, cfg.d_model),
        "dec_pos": {
            "table": nn.trunc_normal(
                kg(), (cfg.max_dec_pos, cfg.d_model), 0.01, jnp.bfloat16
            )
        },
        "dec_layers": nn.stack_layer_init(
            functools.partial(_init_dec_block, cfg), kg(), cfg.n_layers
        ),
        "final_norm": nn.layernorm_init(cfg.d_model),
        # whisper ties the output head to the token embedding
    }


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------


def _mha_qkv(cfg: ModelConfig, p: Params, xq, xkv, plan: ShardingPlan):
    Bq, Sq, _ = xq.shape
    _, Skv, _ = xkv.shape
    hd = cfg.resolved_head_dim
    q = nn.dense_apply({"w": p["wq"], "b": p["bq"]}, xq)
    k = nn.dense_apply({"w": p["wk"]}, xkv)
    v = nn.dense_apply({"w": p["wv"], "b": p["bv"]}, xkv)
    q = plan.act(q.reshape(Bq, Sq, cfg.n_heads, hd), "heads")
    k = plan.act(k.reshape(Bq, Skv, cfg.n_kv_heads, hd), "kv_heads")
    v = plan.act(v.reshape(Bq, Skv, cfg.n_kv_heads, hd), "kv_heads")
    return q, k, v


def _mha_out(p: Params, out: jax.Array, B: int, S: int) -> jax.Array:
    return nn.dense_apply({"w": p["wo"], "b": p["bo"]}, out.reshape(B, S, -1))


def _mlp(p: Params, x: jax.Array) -> jax.Array:
    h = nn.dense_apply({"w": p["w_up"], "b": p["b_up"]}, x)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return nn.dense_apply({"w": p["w_down"], "b": p["b_down"]}, h)


def encode(cfg: ModelConfig, params: Params, frames: jax.Array, plan: ShardingPlan):
    """frames: (B, T, d) stub embeddings -> encoder states (B, T, d)."""
    B, T, _ = frames.shape
    h = nn.dense_apply({"w": params["frame_proj"]["w_in"]}, frames.astype(jnp.bfloat16))
    h = h + sinusoids(T, cfg.d_model).astype(h.dtype)[None]
    h = plan.act(h, "frames")

    def body(x, lp):
        xn = nn.layernorm_apply(lp["attn_norm"], x)
        q, k, v = _mha_qkv(cfg, lp["attn"], xn, xn, plan)
        out = xla_flash_attention(q, k, v, causal=False, block_k=cfg.attn_block_k)
        x = x + _mha_out(lp["attn"], out, B, T)
        x = x + _mlp(lp["mlp"], nn.layernorm_apply(lp["mlp_norm"], x))
        return plan.act(x, "frames")

    h = nn.scan_layers(body, h, params["enc_layers"], remat=cfg.remat)
    return nn.layernorm_apply(params["enc_norm"], h)


def _dec_block(cfg, plan, enc_out, B, S, x, lp, positions):
    xn = nn.layernorm_apply(lp["self_norm"], x)
    q, k, v = _mha_qkv(cfg, lp["self_attn"], xn, xn, plan)
    out = xla_flash_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
    x = x + _mha_out(lp["self_attn"], out, B, S)
    xn = nn.layernorm_apply(lp["cross_norm"], x)
    q, k, v = _mha_qkv(cfg, lp["cross_attn"], xn, enc_out, plan)
    out = xla_flash_attention(q, k, v, causal=False, block_k=cfg.attn_block_k)
    x = x + _mha_out(lp["cross_attn"], out, B, S)
    x = x + _mlp(lp["mlp"], nn.layernorm_apply(lp["mlp_norm"], x))
    return plan.act(x, "hidden")


def _dec_embed(cfg, params, tokens, plan, offset: int = 0):
    B, S = tokens.shape
    h = nn.embedding_apply(params["embed"], tokens)
    pos = jax.lax.dynamic_slice_in_dim(
        params["dec_pos"]["table"], offset, S, axis=0
    )
    return plan.act(h + pos[None].astype(h.dtype), "hidden")


def _logits(cfg, params, h, plan):
    h = nn.layernorm_apply(params["final_norm"], h)
    w = params["embed"]["table"].astype(jnp.bfloat16)
    return tfm.mask_pad_logits(cfg, jnp.einsum("...d,vd->...v", h, w))


def forward(cfg: ModelConfig, params: Params, frames, tokens, plan: ShardingPlan):
    enc_out = encode(cfg, params, frames, plan)
    B, S = tokens.shape
    h = _dec_embed(cfg, params, tokens, plan)
    body = functools.partial(_dec_block, cfg, plan, enc_out, B, S)
    h = nn.scan_layers(
        lambda x, lp: body(x, lp, None), h, params["dec_layers"], remat=cfg.remat
    )
    return plan.act(_logits(cfg, params, h, plan), "logits")


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    self_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    cross_shape = (cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(self_shape, jnp.bfloat16),
        "v": jax.ShapeDtypeStruct(self_shape, jnp.bfloat16),
        "xk": jax.ShapeDtypeStruct(cross_shape, jnp.bfloat16),
        "xv": jax.ShapeDtypeStruct(cross_shape, jnp.bfloat16),
    }


def prefill(cfg: ModelConfig, params: Params, frames, tokens, plan: ShardingPlan):
    enc_out = encode(cfg, params, frames, plan)
    B, S = tokens.shape
    T = enc_out.shape[1]
    h = _dec_embed(cfg, params, tokens, plan)

    def body(x, lp):
        xn = nn.layernorm_apply(lp["self_norm"], x)
        q, k, v = _mha_qkv(cfg, lp["self_attn"], xn, xn, plan)
        out = xla_flash_attention(q, k, v, causal=True, block_k=cfg.attn_block_k)
        x = x + _mha_out(lp["self_attn"], out, B, S)
        xn = nn.layernorm_apply(lp["cross_norm"], x)
        qx, xk, xv = _mha_qkv(cfg, lp["cross_attn"], xn, enc_out, plan)
        out = xla_flash_attention(qx, xk, xv, causal=False, block_k=cfg.attn_block_k)
        x = x + _mha_out(lp["cross_attn"], out, B, S)
        x = x + _mlp(lp["mlp"], nn.layernorm_apply(lp["mlp_norm"], x))
        x = plan.act(x, "hidden")
        kv = (
            k.astype(jnp.bfloat16),
            v.astype(jnp.bfloat16),
            xk.astype(jnp.bfloat16),
            xv.astype(jnp.bfloat16),
        )
        return x, kv

    h, (ks, vs, xks, xvs) = jax.lax.scan(body, h, params["dec_layers"])
    cache = {
        "k": plan.act(ks, "cache"),
        "v": plan.act(vs, "cache"),
        "xk": plan.act(xks, "cache"),
        "xv": plan.act(xvs, "cache"),
    }
    last = _logits(cfg, params, h[:, -1:, :], plan)[:, 0, :]
    return plan.act(last, "last_logits"), cache


def decode_step(cfg, params, token, cache, pos, plan: ShardingPlan):
    B = token.shape[0]
    pos_arr = jnp.asarray(pos, jnp.int32)
    h = nn.embedding_apply(params["embed"], token[:, None])
    pos_emb = jax.lax.dynamic_slice_in_dim(params["dec_pos"]["table"], pos_arr, 1, 0)
    h = plan.act(h + pos_emb[None].astype(h.dtype), "decode_hidden")

    def body(x, layer_in):
        lp, kc, vc, xk, xv = layer_in
        xn = nn.layernorm_apply(lp["self_norm"], x)
        q, k, v = _mha_qkv(cfg, lp["self_attn"], xn, xn, plan)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos_arr, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos_arr, 1)
        out = decode_attention(q, kc, vc, kv_len=pos_arr + 1)
        x = x + _mha_out(lp["self_attn"], out, B, 1)
        xn = nn.layernorm_apply(lp["cross_norm"], x)
        hd = cfg.resolved_head_dim
        qx = nn.dense_apply(
            {"w": lp["cross_attn"]["wq"], "b": lp["cross_attn"]["bq"]}, xn
        ).reshape(B, 1, cfg.n_heads, hd)
        out = decode_attention(qx, xk, xv, kv_len=xk.shape[1])
        x = x + _mha_out(lp["cross_attn"], out, B, 1)
        x = x + _mlp(lp["mlp"], nn.layernorm_apply(lp["mlp_norm"], x))
        return plan.act(x, "decode_hidden"), (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    logits = _logits(cfg, params, h, plan)[:, 0, :]
    new_cache = dict(cache, k=plan.act(k_new, "cache"), v=plan.act(v_new, "cache"))
    return plan.act(logits, "last_logits"), new_cache


@register_family("encdec")
def _build_encdec(cfg: ModelConfig) -> Model:
    def loss(params, batch, plan: ShardingPlan):
        logits = forward(cfg, params, batch["frames"], batch["tokens"], plan)
        return losses.softmax_cross_entropy(logits, batch["labels"])

    return Model(
        cfg=cfg,
        init=lambda key: init_params(cfg, key),
        loss=loss,
        prefill=lambda params, batch, plan: prefill(
            cfg, params, batch["frames"], batch["tokens"], plan
        ),
        decode=lambda params, batch, cache, pos, plan: decode_step(
            cfg, params, batch["token"], cache, pos, plan
        ),
        cache_spec=lambda b, s: cache_spec(cfg, b, s),
        input_specs=lambda suite: _input_specs(cfg, suite),
    )
