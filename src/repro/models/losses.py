"""Loss functions shared across families."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_cross_entropy(
    logits: jax.Array,
    labels: jax.Array,
    *,
    z_loss: float = 1e-4,
    label_smoothing: float = 0.0,
    mask: jax.Array | None = None,
):
    """Mean next-token CE over (B, S, V) logits and (B, S) int labels.

    f32 log-softmax for stability; optional z-loss regularizer (production
    stabilizer for large-vocab training) and label smoothing. Returns
    (loss, metrics-dict).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)  # (B,S)
    label_logit = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if label_smoothing > 0.0:
        smooth = lse - jnp.mean(lf, axis=-1)
        nll = (1.0 - label_smoothing) * nll + label_smoothing * smooth
    zl = jnp.square(lse)
    if mask is None:
        denom = nll.size
        loss = jnp.sum(nll) / denom
        zterm = jnp.sum(zl) / denom
    else:
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(m), 1.0)
        loss = jnp.sum(nll * m) / denom
        zterm = jnp.sum(zl * m) / denom
    total = loss + z_loss * zterm
    acc_pred = jnp.argmax(lf, axis=-1) == labels
    if mask is not None:
        acc = jnp.sum(acc_pred * mask) / denom
    else:
        acc = jnp.mean(acc_pred.astype(jnp.float32))
    return total, {"ce": loss, "z_loss": zterm, "accuracy": acc}
