"""Dense GQA decoder-only transformer (stablelm/qwen2/granite/llama3 + the
llava backbone). Depth is consumed with ``lax.scan`` over stacked layer params
so the lowered HLO is O(1) in layer count.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as nn
from repro.models.attention import decode_attention, flash_attention as xla_flash_attention
from repro.sharding.plan import ShardingPlan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------


def init_attn_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p = {
        "wq": nn.fan_in_init(kg(), (d, cfg.n_heads * hd), jnp.bfloat16),
        "wk": nn.fan_in_init(kg(), (d, cfg.n_kv_heads * hd), jnp.bfloat16),
        "wv": nn.fan_in_init(kg(), (d, cfg.n_kv_heads * hd), jnp.bfloat16),
        "wo": nn.fan_in_init(
            kg(), (cfg.n_heads * hd, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16)
    return p


def init_mlp_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w_gate": nn.fan_in_init(kg(), (d, f), jnp.bfloat16),
            "w_up": nn.fan_in_init(kg(), (d, f), jnp.bfloat16),
            "w_down": nn.fan_in_init(
                kg(), (f, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
            ),
        }
    return {
        "w_up": nn.fan_in_init(kg(), (d, f), jnp.bfloat16),
        "w_down": nn.fan_in_init(
            kg(), (f, d), jnp.bfloat16, scale=1.0 / (2 * cfg.n_layers) ** 0.5
        ),
    }


def init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    norm_init = nn.rmsnorm_init if cfg.norm == "rmsnorm" else nn.layernorm_init
    return {
        "attn_norm": norm_init(cfg.d_model),
        "attn": init_attn_layer(cfg, kg()),
        "mlp_norm": norm_init(cfg.d_model),
        "mlp": init_mlp_layer(cfg, kg()),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    kg = nn.KeyGen(key)
    params: Params = {
        "embed": nn.embedding_init(kg(), cfg.padded_vocab, cfg.d_model),
        "layers": nn.stack_layer_init(
            functools.partial(init_block, cfg), kg(), cfg.n_layers
        ),
        "final_norm": (nn.rmsnorm_init if cfg.norm == "rmsnorm" else nn.layernorm_init)(
            cfg.d_model
        ),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w_lm": nn.fan_in_init(kg(), (cfg.d_model, cfg.padded_vocab), jnp.bfloat16)
        }
    if cfg.n_patches:
        params["patch_proj"] = {
            "w_in": nn.fan_in_init(kg(), (cfg.d_model, cfg.d_model), jnp.bfloat16)
        }
    return params


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------


def _norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return nn.rmsnorm_apply(p, x)
    return nn.layernorm_apply(p, x)


def _mlp(cfg: ModelConfig, p: Params, x: jax.Array, plan: ShardingPlan) -> jax.Array:
    if cfg.act == "swiglu":
        gate = nn.dense_apply({"w": p["w_gate"]}, x)
        up = nn.dense_apply({"w": p["w_up"]}, x)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    else:
        h = jax.nn.gelu(nn.dense_apply({"w": p["w_up"]}, x).astype(jnp.float32)).astype(
            x.dtype
        )
    h = plan.act(h, "ffn")
    return nn.dense_apply({"w": p["w_down"]}, h)


def _qkv(
    cfg: ModelConfig, p: Params, x: jax.Array, plan: ShardingPlan
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = nn.dense_apply({"w": p["wq"], **({"b": p["bq"]} if "bq" in p else {})}, x)
    k = nn.dense_apply({"w": p["wk"], **({"b": p["bk"]} if "bk" in p else {})}, x)
    v = nn.dense_apply({"w": p["wv"], **({"b": p["bv"]} if "bv" in p else {})}, x)
    q = plan.act(q.reshape(B, S, cfg.n_heads, hd), "heads")
    k = plan.act(k.reshape(B, S, cfg.n_kv_heads, hd), "kv_heads")
    v = plan.act(v.reshape(B, S, cfg.n_kv_heads, hd), "kv_heads")
    return q, k, v


def _attn_train(
    cfg: ModelConfig, p: Params, x: jax.Array, plan: ShardingPlan, *, causal=True
) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x, plan)
    positions = jnp.arange(S)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    out = xla_flash_attention(q, k, v, causal=causal, block_k=cfg.attn_block_k)
    out = plan.act(out, "heads")
    return nn.dense_apply({"w": p["wo"]}, out.reshape(B, S, -1))


def block_fwd(
    cfg: ModelConfig, plan: ShardingPlan, x: jax.Array, lp: Params
) -> jax.Array:
    # constrain the block OUTPUTS (still partial-summed over tp), not the
    # post-residual stream: GSPMD then lowers partial->seq-sharded as a
    # reduce-scatter (Megatron-SP) instead of all-reduce + re-slice
    att = _attn_train(cfg, lp["attn"], _norm(cfg, lp["attn_norm"], x), plan)
    x = x + plan.act(att, "hidden")
    mlp = _mlp(cfg, lp["mlp"], _norm(cfg, lp["mlp_norm"], x), plan)
    return plan.act(x + plan.act(mlp, "hidden"), "hidden")


def logits_fn(cfg: ModelConfig, params: Params, h: jax.Array, plan: ShardingPlan):
    h = _norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(jnp.bfloat16).T
        logits = jnp.einsum("...d,dv->...v", h, w)
    else:
        logits = nn.dense_apply({"w": params["lm_head"]["w_lm"]}, h)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.logit_softcap
        )
    return mask_pad_logits(cfg, logits)


def mask_pad_logits(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Mask Megatron-style vocab-pad columns to -inf (elementwise, fuses)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab, logits, jnp.asarray(-1e30, logits.dtype))


def embed_tokens(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    plan: ShardingPlan,
    patches: Optional[jax.Array] = None,
) -> jax.Array:
    h = nn.embedding_apply(params["embed"], tokens)
    if patches is not None:
        # llava-style stub frontend: project precomputed patch embeddings and
        # overwrite the first n_patches token slots with them.
        pe = nn.dense_apply(
            {"w": params["patch_proj"]["w_in"]}, patches.astype(jnp.bfloat16)
        )
        n = pe.shape[1]
        h = jnp.concatenate([pe, h[:, n:, :]], axis=1)
    return plan.act(h, "hidden")


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    plan: ShardingPlan,
    patches: Optional[jax.Array] = None,
) -> jax.Array:
    """Token ids (B, S) -> logits (B, S, V)."""
    h = embed_tokens(cfg, params, tokens, plan, patches)
    body = functools.partial(block_fwd, cfg, plan)
    h = nn.scan_layers(body, h, params["layers"], remat=cfg.remat)
    logits = logits_fn(cfg, params, h, plan)
    return plan.act(logits, "logits")


# ---------------------------------------------------------------------------
# KV-cache serving path
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
        "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_len)
    )


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    plan: ShardingPlan,
    patches: Optional[jax.Array] = None,
):
    """Full-sequence forward that also returns the populated KV cache.

    Returns (last-position logits (B, V), cache).
    """
    B, S = tokens.shape
    h = embed_tokens(cfg, params, tokens, plan, patches)
    positions = jnp.arange(S)
    hd = cfg.resolved_head_dim

    def body(carry, lp):
        x = carry
        xn = _norm(cfg, lp["attn_norm"], x)
        q, k, v = _qkv(cfg, lp["attn"], xn, plan)
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        kr = nn.apply_rope(k, positions, cfg.rope_theta)
        out = xla_flash_attention(q, kr, v, causal=True, block_k=cfg.attn_block_k)
        x = x + nn.dense_apply({"w": lp["attn"]["wo"]}, out.reshape(B, S, -1))
        x = x + _mlp(cfg, lp["mlp"], _norm(cfg, lp["mlp_norm"], x), plan)
        x = plan.act(x, "hidden")
        # store rope'd keys so decode never re-rotates the cache
        return x, (kr.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    def step(c, lp):
        c, kv = body(c, lp)
        return c, kv

    h, (ks, vs) = jax.lax.scan(step, h, params["layers"])
    cache = {"k": plan.act(ks, "cache"), "v": plan.act(vs, "cache")}
    last = logits_fn(cfg, params, h[:, -1:, :], plan)[:, 0, :]
    return plan.act(last, "last_logits"), cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B,) int32
    cache: Dict[str, jax.Array],
    pos,  # scalar int32: current length (tokens already in cache)
    plan: ShardingPlan,
):
    """One decode step against a (possibly sequence-sharded) KV cache."""
    B = token.shape[0]
    hd = cfg.resolved_head_dim
    h = nn.embedding_apply(params["embed"], token[:, None])
    h = plan.act(h, "decode_hidden")
    pos_arr = jnp.asarray(pos, jnp.int32)

    def body(carry, layer_in):
        x = carry
        lp, kc, vc = layer_in
        xn = _norm(cfg, lp["attn_norm"], x)
        q, k, v = _qkv(cfg, lp["attn"], xn, plan)
        q = nn.apply_rope(q, pos_arr[None], cfg.rope_theta)
        k = nn.apply_rope(k, pos_arr[None], cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos_arr, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos_arr, 1)
        out = decode_attention(q, kc, vc, kv_len=pos_arr + 1)
        out = plan.act(out, "decode_heads")
        x = x + nn.dense_apply({"w": lp["attn"]["wo"]}, out.reshape(B, 1, -1))
        x = x + _mlp(cfg, lp["mlp"], _norm(cfg, lp["mlp_norm"], x), plan)
        x = plan.act(x, "decode_hidden")
        return x, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": plan.act(k_new, "cache"), "v": plan.act(v_new, "cache")}
    logits = logits_fn(cfg, params, h, plan)[:, 0, :]
    return plan.act(logits, "last_logits"), new_cache
